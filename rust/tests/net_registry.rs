//! Hot-swap-under-load parity: clients stream dense requests over TCP
//! while the registry swaps the model several times. Pins the four
//! steps of the swap protocol (load new → atomic switch → drain
//! in-flight → retire on refcount): every reply is bitwise-equal to
//! the offline transform of *some* served version, post-swap replies
//! are exactly the final version, nothing is dropped or duplicated,
//! and after shutdown the artifact weight region is back to baseline.

use rfdot::artifact::MapArtifact;
use rfdot::coordinator::CoordinatorConfig;
use rfdot::features::FeatureMap;
use rfdot::kernels::Exponential;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::net::{NetClient, NetConfig, NetServer, Registry};
use rfdot::obs::MetricsSnapshot;
use rfdot::rng::Rng;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::thread;
use std::time::Duration;

/// Serializes the tests in this binary: they assert on the global
/// `artifact.bytes` gauge and the obs counters, which concurrent
/// artifact-touching tests would perturb.
static SERIAL: Mutex<()> = Mutex::new(());

const D: usize = 8;
const FEATS: usize = 32;
const CLIENTS: usize = 4;
const SWAPS: u64 = 3;

fn artifact(seed: u64) -> Arc<MapArtifact> {
    let mut rng = Rng::seed_from(seed);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        D,
        FEATS,
        RmConfig::default().with_max_order(6),
        &mut rng,
    );
    Arc::new(MapArtifact::from_map(&map).expect("encode artifact"))
}

fn coord_config() -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        ..CoordinatorConfig::default()
    }
}

#[test]
fn hot_swap_under_load_keeps_every_reply_exact() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let baseline = rfdot::artifact::resident_bytes();
    let before = MetricsSnapshot::collect();
    let requests_before =
        before.counters.get("net.model.hot.requests").copied().unwrap_or(0);

    // One artifact per version, plus the offline reference transform of
    // each version for every client's fixed input. Only the plain
    // expectation vectors outlive this block, so the weight regions can
    // all drain back to baseline at the end.
    let arts: Vec<Arc<MapArtifact>> = (0..=SWAPS).map(|v| artifact(100 + v)).collect();
    let inputs: Vec<Vec<f32>> = (0..CLIENTS)
        .map(|c| (0..D).map(|i| (c * D + i) as f32 * 0.01 - 0.3).collect())
        .collect();
    let expected: Vec<Vec<Vec<f32>>> = arts
        .iter()
        .map(|a| {
            let map = a.instantiate().expect("instantiate reference map");
            inputs.iter().map(|x| map.transform(x)).collect()
        })
        .collect();

    let registry = Arc::new(Registry::new(coord_config()));
    assert_eq!(registry.insert("hot", arts[0].clone()).unwrap(), 1);
    let mut server = NetServer::start(
        registry.clone(),
        NetConfig {
            heartbeat: Duration::from_secs(1),
            max_missed: 10,
            ..NetConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    let barrier = Arc::new(Barrier::new(CLIENTS + 1));
    let stop = Arc::new(AtomicBool::new(false));
    let final_v = SWAPS as usize; // index into `expected`
    let clients: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let barrier = barrier.clone();
            let stop = stop.clone();
            let x = inputs[c].clone();
            let expect: Vec<Vec<f32>> = expected.iter().map(|e| e[c].clone()).collect();
            thread::spawn(move || {
                let mut client =
                    NetClient::connect(addr, Duration::from_secs(30)).unwrap();
                let mut versions_seen = BTreeSet::new();
                let mut sent = 0u64;
                let mut classify = |y: &[f32], post_swap: bool| {
                    let v = expect
                        .iter()
                        .position(|e| {
                            e.len() == y.len()
                                && e.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits())
                        })
                        .unwrap_or_else(|| {
                            panic!("reply matches no served version bitwise")
                        });
                    if post_swap {
                        assert_eq!(
                            v, final_v,
                            "a reply requested after the last swap must come from \
                             the final version"
                        );
                    }
                    versions_seen.insert(v);
                };
                // First round trip before the swaps start, so version 1
                // is observably serving under load.
                let y = client.transform("hot", &x).unwrap();
                sent += 1;
                classify(&y, false);
                barrier.wait();
                while !stop.load(Ordering::Acquire) {
                    let y = client.transform("hot", &x).unwrap();
                    sent += 1;
                    classify(&y, false);
                }
                // The swapper set `stop` strictly after the last swap's
                // atomic switch: these requests must hit the final
                // version, exactly.
                for _ in 0..5 {
                    let y = client.transform("hot", &x).unwrap();
                    sent += 1;
                    classify(&y, true);
                }
                (versions_seen, sent)
            })
        })
        .collect();

    barrier.wait();
    for v in 0..SWAPS {
        thread::sleep(Duration::from_millis(20));
        let got = registry.insert("hot", arts[(v + 1) as usize].clone()).unwrap();
        assert_eq!(got, v + 2, "swap must advance the version");
    }
    stop.store(true, Ordering::Release);

    let mut all_versions = BTreeSet::new();
    let mut total_sent = 0u64;
    for c in clients {
        let (versions, sent) = c.join().expect("client thread");
        // `NetClient::transform` checks the reply id against the
        // request id, so `sent` replies means exactly-once delivery.
        assert!(sent >= 6, "each client must complete its request quota");
        total_sent += sent;
        all_versions.extend(versions);
    }
    assert!(
        all_versions.len() >= 2,
        "the load must observe at least two versions (saw {all_versions:?})"
    );
    assert!(
        all_versions.contains(&final_v),
        "the final version must serve the post-swap requests"
    );

    let stats = registry.model_stats();
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].name, "hot");
    assert_eq!(stats[0].version, SWAPS + 1);
    assert_eq!(stats[0].swaps, SWAPS);
    assert!(
        stats[0].requests >= total_sent,
        "admission counter {} must cover the {} client requests",
        stats[0].requests,
        total_sent
    );
    assert!(stats[0].latency_us.n > 0, "latency histogram must have samples");

    // Per-model metrics flow into the global snapshot under their
    // dynamic names.
    let snap = MetricsSnapshot::collect();
    let requests = snap.counters.get("net.model.hot.requests").copied().unwrap_or(0);
    assert!(
        requests - requests_before >= total_sent,
        "net.model.hot.requests must appear in MetricsSnapshot and cover the load"
    );
    assert!(
        snap.histograms.contains_key("net.model.hot.latency_us"),
        "per-model latency histogram must appear in MetricsSnapshot"
    );

    // Teardown order from the server module docs: front-end first, then
    // the registry. Dropping our own artifact handles lets every weight
    // region drain; `shutdown` joins the retirers, so the gauge check
    // is race-free.
    server.shutdown();
    drop(server);
    drop(arts);
    registry.shutdown();
    assert_eq!(
        rfdot::artifact::resident_bytes(),
        baseline,
        "after retiring all versions the artifact bytes must return to baseline"
    );
}

#[test]
fn removed_model_turns_unknown_without_disturbing_others() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let baseline = rfdot::artifact::resident_bytes();
    let registry = Arc::new(Registry::new(coord_config()));
    registry.insert("keep", artifact(7)).unwrap();
    registry.insert("gone", artifact(8)).unwrap();
    let mut server = NetServer::start(registry.clone(), NetConfig::default()).unwrap();
    let mut client =
        NetClient::connect(server.local_addr(), Duration::from_secs(10)).unwrap();

    let x = vec![0.5; D];
    assert_eq!(client.list_models().unwrap().len(), 2);
    client.transform("gone", &x).unwrap();
    assert!(registry.remove("gone"));

    // The removed name now rejects with the unknown-model error, while
    // the surviving model keeps serving on the same connection.
    let err = client.transform("gone", &x).unwrap_err().to_string();
    assert!(err.contains("unknown model"), "{err}");
    let y = client.transform("keep", &x).unwrap();
    assert_eq!(y.len(), FEATS);

    drop(client);
    server.shutdown();
    drop(server);
    registry.shutdown();
    assert_eq!(rfdot::artifact::resident_bytes(), baseline);
}
