//! Front-end behavior regressions: heartbeat liveness reaping,
//! permit-accounted write-queue backpressure, and loopback bitwise
//! parity between TCP replies and in-process `Coordinator::submit_batch`
//! over the same artifact.

use rfdot::artifact::MapArtifact;
use rfdot::coordinator::{Coordinator, CoordinatorConfig, MapArtifactFactory};
use rfdot::kernels::Exponential;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::net::protocol::{
    decode_header, decode_payload, encode_frame, ErrorCode, Frame, Request, HEADER_LEN,
};
use rfdot::net::{NetClient, NetConfig, NetServer, Registry};
use rfdot::rng::Rng;
use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Serializes the tests in this binary: they assert deltas on global
/// obs counters.
static SERIAL: Mutex<()> = Mutex::new(());

fn artifact(seed: u64, d: usize, feats: usize) -> Arc<MapArtifact> {
    let mut rng = Rng::seed_from(seed);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        feats,
        RmConfig::default().with_max_order(6),
        &mut rng,
    );
    Arc::new(MapArtifact::from_map(&map).expect("encode artifact"))
}

fn coord_config(workers: usize, max_wait: Duration) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        max_batch: 64,
        max_wait,
        ..CoordinatorConfig::default()
    }
}

fn read_frame_raw(s: &mut TcpStream) -> Frame {
    let mut header = [0u8; HEADER_LEN];
    s.read_exact(&mut header).expect("read frame header");
    let (ty, len) = decode_header(&header).expect("decode header");
    let mut payload = vec![0u8; len as usize];
    s.read_exact(&mut payload).expect("read frame payload");
    decode_payload(ty, &payload).expect("decode payload")
}

#[test]
fn silent_connections_are_reaped_while_heartbeating_peers_survive() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let reaped_before = rfdot::obs::counter("net.reaped").get();
    let registry = Arc::new(Registry::new(coord_config(1, Duration::from_micros(200))));
    registry.insert("live", artifact(11, 6, 16)).unwrap();
    let mut server = NetServer::start(
        registry.clone(),
        NetConfig {
            heartbeat: Duration::from_millis(40),
            max_missed: 2,
            ..NetConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    // The silent connection never sends a byte; the heartbeating peer
    // stays chatty through the whole reap window.
    let mut silent = TcpStream::connect(addr).unwrap();
    silent.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let peer = thread::spawn(move || {
        let mut client = NetClient::connect(addr, Duration::from_secs(10)).unwrap();
        for _ in 0..12 {
            client.heartbeat().unwrap();
            thread::sleep(Duration::from_millis(25));
        }
        client.transform("live", &vec![0.25; 6]).unwrap()
    });

    // Reap fires after (max_missed + 1) empty intervals ≈ 120 ms: one
    // final protocol error frame naming the liveness policy, then EOF.
    match read_frame_raw(&mut silent) {
        Frame::Error(e) => {
            assert_eq!(e.code, ErrorCode::Protocol);
            assert!(e.message.contains("liveness"), "{}", e.message);
        }
        f => panic!("expected reap error frame, got {:?}", f.frame_type()),
    }
    let mut probe = [0u8; 1];
    assert_eq!(
        silent.read(&mut probe).expect("post-reap read"),
        0,
        "reaped connection must be closed"
    );
    assert!(
        rfdot::obs::counter("net.reaped").get() > reaped_before,
        "reaping must count into net.reaped"
    );

    // The heartbeats kept the peer alive well past the reap window, and
    // its request still round-trips.
    let y = peer.join().expect("peer thread");
    assert_eq!(y.len(), 16);
    server.shutdown();
}

#[test]
fn write_queue_overflow_rejects_retryably_and_answers_exactly_once() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let rejects_before = rfdot::obs::counter("net.reject").get();
    const D: usize = 4;
    const FEATS: usize = 4096;
    const REQUESTS: u64 = 30;
    // A long coalescing window holds the first reply back until well
    // after every request has hit admission, so the two reply permits
    // stay claimed while the rest of the burst arrives.
    let registry = Arc::new(Registry::new(coord_config(1, Duration::from_millis(50))));
    registry.insert("big", artifact(12, D, FEATS)).unwrap();
    let mut server = NetServer::start(
        registry.clone(),
        NetConfig {
            write_queue: 2,
            heartbeat: Duration::from_secs(5),
            max_missed: 10,
            ..NetConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    // The whole burst goes out in one write before a single reply is
    // read: with 2 permits, the overflow must reject retryably.
    let mut burst = Vec::new();
    for req_id in 1..=REQUESTS {
        burst.extend_from_slice(&encode_frame(&Frame::Dense(Request {
            req_id,
            model: "big".into(),
            values: vec![0.125; D],
        })));
    }
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(&burst).unwrap();

    // A second connection is a different permit budget: its request
    // must sail through while the first connection is saturated.
    let mut other = NetClient::connect(addr, Duration::from_secs(10)).unwrap();
    assert_eq!(other.transform("big", &vec![0.5; D]).unwrap().len(), FEATS);

    // Exactly one frame per request: replies for the admitted ones,
    // retryable coordinator rejects naming the write queue for the
    // overflow. No drops, no duplicates.
    let mut answered = BTreeSet::new();
    let mut replies = 0u64;
    let mut rejects = 0u64;
    for _ in 0..REQUESTS {
        match read_frame_raw(&mut stream) {
            Frame::Reply { req_id, values } => {
                assert!(answered.insert(req_id), "duplicate reply for {req_id}");
                assert_eq!(values.len(), FEATS);
                replies += 1;
            }
            Frame::Error(e) => {
                assert!(answered.insert(e.req_id), "duplicate answer for {}", e.req_id);
                assert_eq!(e.code, ErrorCode::Coordinator);
                assert!(e.retryable, "backpressure rejects must be retryable");
                assert!(e.message.contains("write queue"), "{}", e.message);
                rejects += 1;
            }
            f => panic!("expected reply or reject, got {:?}", f.frame_type()),
        }
    }
    assert_eq!(answered.len() as u64, REQUESTS);
    assert_eq!(answered, (1..=REQUESTS).collect::<BTreeSet<_>>());
    assert!(replies >= 1, "the admitted requests must still be answered");
    assert!(rejects >= 1, "overflow beyond the write queue must reject");
    assert!(
        rfdot::obs::counter("net.reject").get() - rejects_before >= rejects,
        "rejects must count into net.reject"
    );
    server.shutdown();
}

#[test]
fn loopback_replies_are_bitwise_equal_to_in_process_batches() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const D: usize = 10;
    const FEATS: usize = 64;
    const ROWS: usize = 8;
    let art = artifact(13, D, FEATS);

    let registry = Arc::new(Registry::new(coord_config(2, Duration::from_micros(200))));
    registry.insert("par", art.clone()).unwrap();
    let mut server = NetServer::start(registry.clone(), NetConfig::default()).unwrap();
    let mut client =
        NetClient::connect(server.local_addr(), Duration::from_secs(10)).unwrap();

    let mut rng = Rng::seed_from(99);
    let dense_rows: Vec<Vec<f32>> =
        (0..ROWS).map(|_| (0..D).map(|_| rng.f32() - 0.5).collect()).collect();
    let sparse_rows: Vec<(Vec<u32>, Vec<f32>)> = (0..ROWS)
        .map(|_| {
            let indices: Vec<u32> = (0..D as u32).step_by(2).collect();
            let values: Vec<f32> = indices.iter().map(|_| rng.f32() - 0.5).collect();
            (indices, values)
        })
        .collect();

    // The in-process reference: a coordinator over the same artifact
    // through the same factory, answering the same rows as one batch.
    let factory = MapArtifactFactory::new(art.clone()).unwrap();
    let coord =
        Coordinator::start(Arc::new(factory), coord_config(2, Duration::from_micros(200)));
    let offline_dense: Vec<Vec<f32>> = coord
        .submit_batch(dense_rows.clone())
        .unwrap()
        .wait()
        .into_iter()
        .map(|r| r.expect("in-process dense reply"))
        .collect();
    let offline_sparse: Vec<Vec<f32>> = coord
        .submit_batch_sparse(sparse_rows.clone())
        .unwrap()
        .wait()
        .into_iter()
        .map(|r| r.expect("in-process sparse reply"))
        .collect();

    for (row, offline) in dense_rows.iter().zip(&offline_dense) {
        let wire = client.transform("par", row).unwrap();
        assert_eq!(wire.len(), offline.len());
        assert!(
            wire.iter().zip(offline).all(|(a, b)| a.to_bits() == b.to_bits()),
            "dense TCP reply must be bitwise-equal to the in-process batch"
        );
    }
    for ((indices, values), offline) in sparse_rows.iter().zip(&offline_sparse) {
        let wire = client.transform_sparse("par", indices, values).unwrap();
        assert_eq!(wire.len(), offline.len());
        assert!(
            wire.iter().zip(offline).all(|(a, b)| a.to_bits() == b.to_bits()),
            "sparse TCP reply must be bitwise-equal to the in-process batch"
        );
    }
    drop(client);
    server.shutdown();
}
