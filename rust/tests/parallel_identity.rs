//! Exact-equality properties of the data-parallel execution subsystem.
//!
//! The contract of `rfdot::parallel` is that row-chunked partitioning
//! never reorders any floating-point reduction: every hot path must
//! produce **bit-identical** output for every thread count — 1 thread,
//! a handful, or far more threads than rows. These properties hold
//! `matmul`, `matvec`, `matmul_transposed`, `transform_batch` (all four
//! map families), `gram` and `feature_gram` to that with `==`, across
//! randomized shapes from the in-tree property harness.

use rfdot::features::{feature_gram_threads, FeatureMap};
use rfdot::kernels::{Exponential, Polynomial};
use rfdot::linalg::Matrix;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::nystrom::Nystrom;
use rfdot::prop::{forall, PropConfig};
use rfdot::rff::RandomFourier;
use rfdot::rng::Rng;
use rfdot::tensorsketch::TensorSketch;

/// Thread counts to compare against the serial (1-thread) path;
/// includes counts far larger than any generated row count.
const THREADS: [usize; 4] = [2, 3, 8, 64];

fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let data: Vec<f32> = (0..rows * cols).map(|_| rng.f32() - 0.5).collect();
    Matrix::from_vec(rows, cols, data).unwrap()
}

#[derive(Debug)]
struct ShapeCase {
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
}

fn gen_shape(rng: &mut Rng, size: usize) -> ShapeCase {
    // Sides in 0..=size: exercises empty, single-row and multi-chunk.
    let side = |rng: &mut Rng| rng.below(size as u64 + 1) as usize;
    ShapeCase { m: side(rng), k: side(rng), n: side(rng), seed: rng.next_u64() }
}

#[test]
fn prop_matmul_bit_identical_across_threads() {
    forall(
        PropConfig { cases: 60, seed: 0x9A11, max_size: 40 },
        gen_shape,
        |case| {
            let mut rng = Rng::seed_from(case.seed);
            let a = random_matrix(&mut rng, case.m, case.k);
            let b = random_matrix(&mut rng, case.k, case.n);
            let serial = a.matmul_threads(&b, 1).map_err(|e| e.to_string())?;
            for t in THREADS {
                let par = a.matmul_threads(&b, t).map_err(|e| e.to_string())?;
                if par != serial {
                    return Err(format!(
                        "matmul {}x{}x{} differs at {t} threads",
                        case.m, case.k, case.n
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_matvec_and_matmul_transposed_bit_identical() {
    forall(
        PropConfig { cases: 50, seed: 0x9A12, max_size: 40 },
        gen_shape,
        |case| {
            let mut rng = Rng::seed_from(case.seed);
            let a = random_matrix(&mut rng, case.m, case.k);
            let b = random_matrix(&mut rng, case.n, case.k);
            let v: Vec<f32> = (0..case.k).map(|_| rng.f32() - 0.5).collect();
            let mv = a.matvec_threads(&v, 1).map_err(|e| e.to_string())?;
            let mt = a.matmul_transposed_threads(&b, 1).map_err(|e| e.to_string())?;
            for t in THREADS {
                if a.matvec_threads(&v, t).map_err(|e| e.to_string())? != mv {
                    return Err(format!("matvec differs at {t} threads"));
                }
                if a.matmul_transposed_threads(&b, t).map_err(|e| e.to_string())? != mt {
                    return Err(format!("matmul_transposed differs at {t} threads"));
                }
            }
            Ok(())
        },
    );
}

#[derive(Debug)]
struct BatchCase {
    d: usize,
    n_feat: usize,
    rows: usize,
    h01: bool,
    seed: u64,
}

fn gen_batch(rng: &mut Rng, size: usize) -> BatchCase {
    BatchCase {
        d: 1 + rng.below(1 + size as u64 / 2) as usize,
        n_feat: 1 + rng.below(1 + 2 * size as u64) as usize,
        rows: rng.below(size as u64 + 2) as usize,
        h01: rng.bernoulli(0.5),
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_transform_batch_bit_identical_all_families() {
    forall(
        PropConfig { cases: 40, seed: 0x9A13, max_size: 24 },
        gen_batch,
        |case| {
            let mut rng = Rng::seed_from(case.seed);
            let x = random_matrix(&mut rng, case.rows, case.d);
            let maps: Vec<(&str, Box<dyn FeatureMap>)> = vec![
                (
                    "maclaurin",
                    Box::new(RandomMaclaurin::sample(
                        &Polynomial::new(3, 1.0),
                        case.d,
                        case.n_feat,
                        RmConfig::default().with_h01(case.h01),
                        &mut rng,
                    )),
                ),
                (
                    "rff",
                    Box::new(RandomFourier::sample(0.9, case.d, case.n_feat, &mut rng)),
                ),
                (
                    "tensorsketch",
                    Box::new(TensorSketch::sample(3, 1.0, case.d, case.n_feat, &mut rng)),
                ),
            ];
            for (name, map) in &maps {
                let serial = map.transform_batch_threads(&x, 1);
                for t in THREADS {
                    if map.transform_batch_threads(&x, t) != serial {
                        return Err(format!(
                            "{name} transform_batch ({} rows) differs at {t} threads",
                            case.rows
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_feature_gram_and_gram_bit_identical() {
    forall(
        PropConfig { cases: 30, seed: 0x9A14, max_size: 20 },
        gen_batch,
        |case| {
            let mut rng = Rng::seed_from(case.seed);
            let x = random_matrix(&mut rng, case.rows, case.d);
            let map = RandomMaclaurin::sample(
                &Exponential::new(1.0),
                case.d,
                case.n_feat,
                RmConfig::default(),
                &mut rng,
            );
            let fg = feature_gram_threads(&map, &x, 1);
            let kernel = Exponential::new(1.0);
            let kg = rfdot::kernels::gram_threads(&kernel, &x, 1);
            for t in THREADS {
                if feature_gram_threads(&map, &x, t) != fg {
                    return Err(format!("feature_gram differs at {t} threads"));
                }
                if rfdot::kernels::gram_threads(&kernel, &x, t) != kg {
                    return Err(format!("kernel gram differs at {t} threads"));
                }
            }
            Ok(())
        },
    );
}

/// Nyström is data-dependent, so it gets a deterministic one-off rather
/// than a property: fit once, then compare thread counts exactly.
#[test]
fn nystrom_batch_bit_identical() {
    let mut rng = Rng::seed_from(5);
    let x = random_matrix(&mut rng, 40, 6);
    let ny = Nystrom::fit(Box::new(Exponential::new(1.0)), &x, 16, &mut rng).unwrap();
    let serial = ny.transform_batch_threads(&x, 1);
    for t in THREADS {
        assert_eq!(ny.transform_batch_threads(&x, t), serial, "nystrom differs at {t} threads");
    }
}

/// The public entry points (no explicit thread count) must agree with
/// the serial path whatever the global knob happens to be.
#[test]
fn global_knob_entry_points_match_serial() {
    let mut rng = Rng::seed_from(11);
    let a = random_matrix(&mut rng, 33, 17);
    let b = random_matrix(&mut rng, 17, 29);
    assert_eq!(a.matmul(&b).unwrap(), a.matmul_threads(&b, 1).unwrap());
    let map =
        RandomMaclaurin::sample(&Polynomial::new(4, 1.0), 17, 64, RmConfig::default(), &mut rng);
    assert_eq!(map.transform_batch(&a), map.transform_batch_threads(&a, 1));
    assert_eq!(
        rfdot::features::feature_gram(&map, &a),
        feature_gram_threads(&map, &a, 1)
    );
}
