//! The zero-allocation serving contract (ISSUE 5 tentpole): with a
//! reused [`rfdot::features::Scratch`] arena, the steady-state
//! per-input transform hot loop performs **no heap allocation** — for
//! every map family, dense and CSR inputs alike — and the scratch entry
//! points are bit-identical to the plain ones.
//!
//! Allocation counting uses a wrapping global allocator with a
//! *per-thread* counter, so the libtest harness running other threads
//! concurrently cannot perturb the measurement. This file deliberately
//! contains only these tests: the allocator wrapper is binary-global.
//!
//! The hot paths measured here carry [`rfdot::obs`] tracing spans
//! (ISSUE 7), so the zero counts below also pin the span guards'
//! contract: allocation-free when tracing is disabled (the default)
//! *and* in the steady state when it is enabled (CI re-runs this suite
//! under `RFDOT_TRACE=1`; the per-thread ring pre-allocates its full
//! capacity at registration).

use rfdot::features::{FeatureMap, Scratch};
use rfdot::kernels::{Exponential, Polynomial};
use rfdot::linalg::{Matrix, SparseMatrix};
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rff::RandomFourier;
use rfdot::rng::Rng;
use rfdot::structured::ProjectionKind;
use rfdot::tensorsketch::TensorSketch;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    /// Allocations performed by *this* thread (const-initialized, no
    /// destructor, so the allocator may touch it at any point of the
    /// thread's life without recursing or panicking).
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocations performed by the current thread while running `f`.
fn allocations(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.with(|c| c.get());
    f();
    ALLOCS.with(|c| c.get()) - before
}

/// Every map family under test, with names for failure messages. The
/// structured variants cover the FWHT pad / Fastfood chain scratch; the
/// H0/1 variant covers the exact-prefix path.
fn family_zoo(d: usize) -> Vec<(&'static str, Box<dyn FeatureMap>)> {
    vec![
        (
            "maclaurin-dense",
            Box::new(RandomMaclaurin::sample(
                &Exponential::new(1.0),
                d,
                64,
                RmConfig::default(),
                &mut Rng::seed_from(11),
            )),
        ),
        (
            "maclaurin-structured-h01",
            Box::new(RandomMaclaurin::sample(
                &Polynomial::new(5, 1.0),
                d,
                48,
                RmConfig::default().with_h01(true).with_projection(ProjectionKind::Structured),
                &mut Rng::seed_from(12),
            )),
        ),
        (
            "fourier-dense",
            Box::new(RandomFourier::sample(0.7, d, 56, &mut Rng::seed_from(13))),
        ),
        (
            "fourier-structured",
            Box::new(RandomFourier::sample_with(
                0.7,
                d,
                56,
                ProjectionKind::Structured,
                &mut Rng::seed_from(14),
            )),
        ),
        (
            "tensorsketch",
            Box::new(TensorSketch::sample(3, 1.0, d, 64, &mut Rng::seed_from(15))),
        ),
    ]
}

/// A deterministic input with holes, plus its CSR form.
fn input_pair(d: usize) -> (Vec<f32>, SparseMatrix) {
    let mut x = vec![0.0f32; d];
    for (k, v) in x.iter_mut().enumerate() {
        if k % 3 != 1 {
            *v = ((k + 1) as f32 * 0.31).sin();
        }
    }
    let m = Matrix::from_rows(&[x.clone()]).unwrap();
    (x, SparseMatrix::from_dense(&m))
}

#[test]
fn scratch_paths_are_bit_identical_to_plain_paths() {
    let d = 19;
    let (x, sm) = input_pair(d);
    for (name, map) in family_zoo(d) {
        let plain = map.transform(&x);
        let mut scratch = Scratch::new();
        let mut out = vec![0.0f32; map.output_dim()];
        map.transform_into_scratch(&x, &mut out, &mut scratch);
        assert_eq!(out, plain, "{name}: scratch dense != plain dense");
        // A second call with the (now stale) scratch must not leak
        // state between inputs.
        map.transform_into_scratch(&x, &mut out, &mut scratch);
        assert_eq!(out, plain, "{name}: scratch reuse changed the result");
        let mut sparse_out = vec![f32::NAN; map.output_dim()];
        map.transform_sparse_into_scratch(sm.row(0), &mut sparse_out, &mut scratch);
        assert_eq!(sparse_out, plain, "{name}: scratch sparse != plain dense");
    }
}

#[test]
fn steady_state_scratch_transforms_do_not_allocate() {
    let d = 19;
    let (x, sm) = input_pair(d);
    for (name, map) in family_zoo(d) {
        let mut scratch = Scratch::new();
        let mut out = vec![0.0f32; map.output_dim()];
        // Warm up: grows the arena and initializes any lazy map state
        // (the dense Rademacher expansion behind a OnceLock).
        map.transform_into_scratch(&x, &mut out, &mut scratch);
        map.transform_sparse_into_scratch(sm.row(0), &mut out, &mut scratch);

        let n = allocations(|| {
            for _ in 0..32 {
                map.transform_into_scratch(&x, &mut out, &mut scratch);
            }
        });
        assert_eq!(n, 0, "{name}: dense steady state allocated {n} times in 32 calls");

        let row = sm.row(0);
        let n = allocations(|| {
            for _ in 0..32 {
                map.transform_sparse_into_scratch(row, &mut out, &mut scratch);
            }
        });
        assert_eq!(n, 0, "{name}: sparse steady state allocated {n} times in 32 calls");
    }
}

#[test]
fn span_guards_do_not_allocate() {
    // Disabled (the default): one relaxed atomic load and an inert
    // guard. Enabled (the RFDOT_TRACE=1 CI pass): recording pushes
    // into the ring's pre-allocated buffer. Either way the steady
    // state is allocation-free — the warm-up span registers this
    // thread's ring (which does allocate, once, when tracing is on).
    {
        let _warm = rfdot::obs::span("test.alloc.warmup");
    }
    let n = allocations(|| {
        for _ in 0..64 {
            let _span = rfdot::obs::span("test.alloc.steady");
        }
    });
    assert_eq!(n, 0, "span guards allocated {n} times over 64 spans");
}

#[test]
fn v3_artifact_load_is_one_payload_allocation_with_no_per_weight_copies() {
    // The zero-copy artifact contract: loading an RFDM0003 blob is
    // header-validate + one read into one aligned allocation. With the
    // counting allocator, `MapArtifact::from_bytes` on a v3 blob must
    // cost a *size-independent* handful of allocations — the payload
    // region plus its `Arc` control block — and in particular zero
    // per-weight/per-section copies: a ~64× larger map must load with
    // exactly the same count.
    use rfdot::artifact::MapArtifact;

    let encode = |d: usize, features: usize, seed: u64| {
        let map = RandomMaclaurin::sample(
            &Exponential::new(1.0),
            d,
            features,
            RmConfig::default().with_projection(ProjectionKind::Structured),
            &mut Rng::seed_from(seed),
        );
        MapArtifact::from_map(&map).expect("encode artifact").as_bytes().to_vec()
    };
    let small = encode(8, 16, 21);
    let large = encode(64, 512, 22);
    assert!(large.len() > 32 * small.len(), "fixture sizes must differ by >32x");

    // Warm up the obs registry (counter/gauge entries allocate on first
    // lookup, once per process) and any lazy allocator state.
    MapArtifact::from_bytes(&small).expect("warmup load");

    let count = |blob: &[u8]| {
        let mut n = 0;
        let mut keep = None;
        let got = allocations(|| {
            keep = Some(MapArtifact::from_bytes(blob).expect("load"));
        });
        n += got;
        drop(keep);
        n
    };
    let n_small = count(&small);
    let n_large = count(&large);
    assert_eq!(
        n_small, n_large,
        "v3 load allocation count must be size-independent \
         (small: {n_small}, large: {n_large}) — a per-weight copy crept in"
    );
    // One aligned payload region + one Arc control block (+ nothing
    // else): keep a small safety margin so a harmless change to e.g.
    // error formatting doesn't flake, while still catching any
    // per-section copy (which would add at least 4 and scale).
    assert!(
        n_small <= 4,
        "v3 load performed {n_small} allocations; expected the payload region + Arc only"
    );
}

#[test]
fn plain_transform_still_allocates_only_transiently() {
    // Sanity check on the measurement itself: the throwaway-scratch
    // plain path *does* allocate (so a zero count above is a property
    // of the reused arena, not a broken counter).
    let d = 19;
    let (x, _) = input_pair(d);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        64,
        RmConfig::default(),
        &mut Rng::seed_from(11),
    );
    let mut out = vec![0.0f32; map.output_dim()];
    map.transform_into(&x, &mut out); // warm the OnceLock expansion
    let n = allocations(|| {
        for _ in 0..4 {
            map.transform_into(&x, &mut out);
        }
    });
    assert!(n > 0, "plain transform_into should allocate its projection buffer");
}
