//! The report subsystem's contract, end to end (ISSUE 4 satellite):
//!
//! 1. `REPORT.json` deserializes into the declared schema
//!    ([`rfdot::report::parse_report`]).
//! 2. Every requested grid cell is present — `ok` or *explicitly*
//!    `skipped` with a reason. Nothing is silently dropped.
//! 3. Regenerating with the same seed and run-log is byte-identical
//!    (resume reuses every cached cell, including wall-clock timings),
//!    and the seed-deterministic statistics agree even across *fresh*
//!    runs (per-cell RNG streams are order-independent).

use rfdot::config::ReportConfig;
use rfdot::report::{self, CellStatus, RowOutcome, FAMILIES};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// A fresh temp dir per test invocation (unique per process).
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rfdot_report_schema_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick_config(out: &std::path::Path) -> ReportConfig {
    let mut cfg = ReportConfig::quick();
    cfg.out_dir = out.to_str().unwrap().to_string();
    cfg.seed = 7;
    cfg
}

#[test]
fn quick_grid_schema_coverage_and_byte_identical_regeneration() {
    let dir = temp_dir("main");
    let cfg = quick_config(&dir);
    let report = report::run(&cfg).unwrap();

    // --- 1. Full coverage: the output contains exactly the declared
    // grid, in declaration order, each cell ok or skipped-with-reason.
    let specs = report::grid(&cfg);
    assert_eq!(report.cells.len(), specs.len(), "every declared cell must be present");
    let mut ok = 0;
    let mut skipped = 0;
    for (spec, cell) in specs.iter().zip(&report.cells) {
        assert_eq!(spec.id(), cell.id, "cells must come back in grid order");
        match &cell.status {
            CellStatus::Ok(stats) => {
                ok += 1;
                assert!(stats.output_dim > 0, "{}: zero output_dim", cell.id);
                assert_eq!(stats.err.n, cfg.runs, "{}: wrong envelope width", cell.id);
                assert!(stats.err.mean.is_finite() && stats.err.mean >= 0.0);
                assert!(stats.secs_per_vec > 0.0);
                // v4: the stage breakdown is measured, not defaulted.
                assert!(stats.stages.sample_s.is_finite() && stats.stages.sample_s >= 0.0);
                assert!(stats.stages.gram_s.is_finite() && stats.stages.gram_s >= 0.0);
                assert!(stats.stages.transform_s > 0.0, "{}: unmeasured transform stage", cell.id);
            }
            CellStatus::Skipped { reason } => {
                skipped += 1;
                assert!(!reason.is_empty(), "{}: skip must carry a reason", cell.id);
            }
        }
    }
    assert!(ok > 0, "grid must have live cells");
    assert!(skipped > 0, "grid must surface inapplicable combinations explicitly");

    // Dense/sparse twin cells sample the same maps (storage-blind RNG
    // streams), so the sparse parity contract is visible in the report:
    // equal error envelopes across the storage axis.
    let mut twin_pairs = 0;
    for cell in &report.cells {
        if cell.storage != "sparse" {
            continue;
        }
        let CellStatus::Ok(sparse_stats) = &cell.status else { continue };
        let twin_id = cell.id.replace("|sparse|", "|dense|");
        let twin = report.cells.iter().find(|c| c.id == twin_id).expect("dense twin declared");
        let CellStatus::Ok(dense_stats) = &twin.status else {
            panic!("{}: dense twin must be live too", twin_id)
        };
        assert_eq!(
            dense_stats.err, sparse_stats.err,
            "{}: sparse error envelope must equal its dense twin's",
            cell.id
        );
        twin_pairs += 1;
    }
    assert!(twin_pairs > 0, "no dense/sparse twin pairs compared");

    // The accuracy section obeys the same no-silent-drop rule.
    assert!(!report.accuracy.is_empty());
    assert!(report.accuracy.iter().any(|r| matches!(r.outcome, RowOutcome::Ok { .. })));
    for row in &report.accuracy {
        if let RowOutcome::Ok { accuracy, .. } = row.outcome {
            assert!((0.0..=1.0).contains(&accuracy), "{}: bad accuracy", row.variant);
        }
    }
    assert_eq!(report.threads.len(), cfg.threads_sweep.len());

    // The serving panel covers every worker count with both topologies
    // (shared-only at 1 worker, where the topologies coincide).
    let expected_serving: usize =
        cfg.threads_sweep.iter().map(|&w| if w > 1 { 2 } else { 1 }).sum();
    assert_eq!(report.serving.len(), expected_serving);
    for p in &report.serving {
        assert!(p.reqs_per_s > 0.0, "serving point must have measured throughput");
        assert!(p.shards == 1 || p.shards == p.workers);
        if p.shards == 1 {
            assert_eq!(p.steals, 0, "one shard has no one to steal from");
        }
    }

    // --- 2. REPORT.json round-trips through the declared schema.
    let json1 = std::fs::read_to_string(dir.join("REPORT.json")).unwrap();
    let parsed = report::parse_report(&json1).unwrap();
    assert_eq!(parsed.cells.len(), report.cells.len());
    assert_eq!(parsed.fingerprint, cfg.fingerprint());
    assert_eq!(parsed.mode, "quick");
    assert_eq!(parsed.seed, 7);

    // SVG assets exist for every feature-map family in-tree.
    for family in FAMILIES {
        for kind in ["error", "speedup"] {
            let path = dir.join("report").join(format!("{kind}_{}.svg", family.id()));
            assert!(path.exists(), "missing asset {path:?}");
            let svg = std::fs::read_to_string(&path).unwrap();
            assert!(svg.starts_with("<svg"), "{path:?} is not svg");
        }
    }
    assert!(dir.join("report/threads.svg").exists());
    assert!(dir.join("report/serving.svg").exists());

    // --- 3a. Regenerating against the same run-log is byte-identical
    // (all cells, rows and sweeps are reused, timings included).
    let md1 = std::fs::read_to_string(dir.join("REPORT.md")).unwrap();
    report::run(&cfg).unwrap();
    assert_eq!(std::fs::read_to_string(dir.join("REPORT.json")).unwrap(), json1);
    assert_eq!(std::fs::read_to_string(dir.join("REPORT.md")).unwrap(), md1);

    // --- 3b. A *fresh* run with the same seed reproduces every
    // seed-deterministic statistic (errors, accuracies) even though
    // timings are re-measured: cell RNG streams depend only on
    // (seed, cell id), never on execution order or cached state.
    let dir2 = temp_dir("fresh");
    let report2 = report::run(&quick_config(&dir2)).unwrap();
    let errs1: BTreeMap<&str, _> = report
        .cells
        .iter()
        .filter_map(|c| match &c.status {
            CellStatus::Ok(stats) => Some((c.id.as_str(), stats.err)),
            CellStatus::Skipped { .. } => None,
        })
        .collect();
    for c in &report2.cells {
        if let CellStatus::Ok(stats) = &c.status {
            assert_eq!(
                errs1.get(c.id.as_str()),
                Some(&stats.err),
                "{}: error envelope must be seed-deterministic",
                c.id
            );
        }
    }
    let acc1: Vec<f64> = report
        .accuracy
        .iter()
        .filter_map(|r| match r.outcome {
            RowOutcome::Ok { accuracy, .. } => Some(accuracy),
            RowOutcome::Skipped { .. } => None,
        })
        .collect();
    let acc2: Vec<f64> = report2
        .accuracy
        .iter()
        .filter_map(|r| match r.outcome {
            RowOutcome::Ok { accuracy, .. } => Some(accuracy),
            RowOutcome::Skipped { .. } => None,
        })
        .collect();
    assert_eq!(acc1, acc2, "accuracy rows must be seed-deterministic");

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

#[test]
fn stale_fingerprints_never_leak_into_a_report() {
    // A run-log from a different grid (here: different seed) must be
    // ignored, not resumed into wrong results.
    let dir = temp_dir("stale");
    let mut cfg = quick_config(&dir);
    // Shrink far below the default quick grid: this test only exercises
    // the run-log guard, not the measurements.
    cfg.kernels = vec!["poly:2:1".into()];
    cfg.d_sweep = vec![8];
    cfg.points = 8;
    cfg.runs = 1;
    cfg.threads_sweep = vec![1];
    cfg.accuracy_features = 16;
    cfg.scale = 0.01;
    report::run(&cfg).unwrap();
    let log1 = std::fs::read_to_string(dir.join("report_runlog.json")).unwrap();

    let mut reseeded = cfg.clone();
    reseeded.seed = 8;
    let report2 = report::run(&reseeded).unwrap();
    assert_eq!(report2.seed, 8);
    let log2 = std::fs::read_to_string(dir.join("report_runlog.json")).unwrap();
    assert_ne!(log1, log2, "a reseeded run must rebuild the log");
    let parsed =
        report::parse_report(&std::fs::read_to_string(dir.join("REPORT.json")).unwrap()).unwrap();
    assert_eq!(parsed.fingerprint, reseeded.fingerprint());
    std::fs::remove_dir_all(&dir).ok();
}
