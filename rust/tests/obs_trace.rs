//! Enabled-path tracing integration (ISSUE 7 tentpole): this binary
//! flips the process-wide trace flag, so it lives apart from the unit
//! suite — everything here shares one test function because the flag,
//! the thread rings and the drain are process-global.
//!
//! Covered end to end: RAII spans (nested, cross-thread), the
//! transform hot-path instrumentation, the Chrome `trace_event`
//! export, its `check_balanced` gate, and the metrics snapshot.

use rfdot::kernels::Polynomial;
use rfdot::linalg::Matrix;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::obs::{self, trace};
use rfdot::rng::Rng;

fn sphere_points(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from(seed);
    let rows: Vec<Vec<f32>> = (0..n).map(|_| rfdot::prop::gens::unit_vec(&mut rng, d)).collect();
    Matrix::from_rows(&rows).unwrap()
}

#[test]
fn enabled_tracing_records_exports_and_validates() {
    obs::set_enabled(true);
    assert!(obs::enabled());
    // Start from a clean slate (rings may hold events from test setup).
    let _ = trace::drain();

    // Nested spans on this thread, a marker, and spans on worker
    // threads — every shape the serving stack produces.
    {
        let _outer = obs::span("test.outer");
        {
            let _inner = obs::span("test.inner");
        }
        trace::mark("test.mark");
    }
    let workers: Vec<_> = (0..3)
        .map(|_| {
            std::thread::spawn(|| {
                for _ in 0..10 {
                    let _span = obs::span("test.worker");
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    // The transform hot path emits its family span.
    let x = sphere_points(8, 16, 1);
    let mut rng = Rng::seed_from(2);
    let map = RandomMaclaurin::sample(&Polynomial::new(3, 1.0), 16, 32, RmConfig::default(), &mut rng);
    use rfdot::features::FeatureMap;
    let _z = map.transform_batch(&x);

    let threads = trace::drain();
    let total: usize = threads.iter().map(|t| t.events.len()).sum();
    // 3 local spans (outer, inner, mark) + 30 worker spans + at least
    // one transform.rm span, two events each.
    assert!(total >= 2 * (3 + 30 + 1), "expected >= 68 events, got {total}");
    assert!(
        threads
            .iter()
            .flat_map(|t| &t.events)
            .any(|e| e.name == "transform.rm"),
        "transform hot path must be traced"
    );
    // Worker rings survive their threads (kept alive by the registry).
    let worker_tids: usize = threads
        .iter()
        .filter(|t| t.events.iter().any(|e| e.name == "test.worker"))
        .count();
    assert_eq!(worker_tids, 3, "each worker thread gets its own ring");

    // Export round-trips through the parser and passes the balance
    // gate `rfdot trace-check` runs in CI.
    let doc = trace::chrome_trace(&threads);
    let text = doc.pretty();
    let parsed = rfdot::config::json::Json::parse(&text).unwrap();
    let check = trace::check_balanced(&parsed).unwrap();
    assert!(check.spans * 2 == check.events, "B/E events pair exactly");
    assert!(check.threads >= 4, "main + 3 workers, got {}", check.threads);
    assert!(text.contains("\"transform.rm\""));
    assert!(text.contains("\"displayTimeUnit\": \"ms\""));

    // A drain empties the rings; tracing continues afterwards.
    let empty: usize = trace::drain().iter().map(|t| t.events.len()).sum();
    assert_eq!(empty, 0, "drain must empty every ring");
    {
        let _s = obs::span("test.after_drain");
    }
    let after: usize = trace::drain().iter().map(|t| t.events.len()).sum();
    assert_eq!(after, 2, "rings keep recording after a drain");

    // The metrics side is always on: resolving the SIMD dispatch sets
    // its gauges, which the snapshot then carries.
    let _ = rfdot::simd::mode();
    let snap = obs::MetricsSnapshot::collect();
    assert!(snap.gauges.contains_key("simd.mode"), "gauges: {:?}", snap.gauges.keys());
    let json = snap.to_json().pretty();
    rfdot::config::json::Json::parse(&json).unwrap();
}
