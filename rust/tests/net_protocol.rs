//! Wire-protocol torture suite for the `RFNP` framing (mirrors
//! `serialize_malformed.rs` for the RFDM records): every-byte
//! truncation sweeps over every frame type, oversized-length
//! allocation-bomb guards, bad magic/version/reserved bytes, ragged
//! sparse frames — each rejected with a *named* error, never a panic,
//! over-read or unbounded allocation. The socket-level half then pins
//! the connection state machine: recoverable frame errors answer with
//! a named error frame and leave the connection usable; fatal framing
//! errors answer once and close; and the server survives the whole
//! sweep.

use rfdot::artifact::MapArtifact;
use rfdot::coordinator::CoordinatorConfig;
use rfdot::kernels::Exponential;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::net::protocol::{
    decode_frame, encode_frame, encode_header, ErrorCode, ErrorFrame, Frame, FrameType,
    ModelEntry, Request, SparseRequest, HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION,
};
use rfdot::net::{NetClient, NetConfig, NetServer, Registry};
use rfdot::rng::Rng;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Input dim of the fixture model (small so sweeps stay fast).
const D: usize = 6;

fn artifact(seed: u64) -> Arc<MapArtifact> {
    let mut rng = Rng::seed_from(seed);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        D,
        16,
        RmConfig::default().with_max_order(6),
        &mut rng,
    );
    Arc::new(MapArtifact::from_map(&map).expect("encode artifact"))
}

fn start_server(model: &str) -> (NetServer, Arc<Registry>) {
    let registry = Arc::new(Registry::new(CoordinatorConfig {
        workers: 1,
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        ..CoordinatorConfig::default()
    }));
    registry.insert(model, artifact(17)).unwrap();
    let server = NetServer::start(
        registry.clone(),
        NetConfig {
            heartbeat: Duration::from_millis(200),
            // The sweeps hold many short connections open; liveness is
            // exercised separately (net_server.rs).
            max_missed: 100,
            ..NetConfig::default()
        },
    )
    .unwrap();
    (server, registry)
}

/// Every client→server frame kind, as wire bytes.
fn client_frames(model: &str) -> Vec<(&'static str, Vec<u8>)> {
    vec![
        ("ping", encode_frame(&Frame::Ping { token: b"abc".to_vec() })),
        ("heartbeat", encode_frame(&Frame::Heartbeat)),
        ("list-models", encode_frame(&Frame::ListModels)),
        (
            "dense",
            encode_frame(&Frame::Dense(Request {
                req_id: 5,
                model: model.into(),
                values: vec![0.5; D],
            })),
        ),
        (
            "sparse",
            encode_frame(&Frame::Sparse(SparseRequest {
                req_id: 6,
                model: model.into(),
                indices: vec![0, 2, 4],
                values: vec![1.0, 2.0, 3.0],
            })),
        ),
    ]
}

/// Every server→client frame kind, as wire bytes.
fn server_frames() -> Vec<(&'static str, Vec<u8>)> {
    vec![
        ("pong", encode_frame(&Frame::Pong { token: b"abc".to_vec() })),
        (
            "models",
            encode_frame(&Frame::Models(vec![ModelEntry {
                name: "m".into(),
                version: 2,
                input_dim: D as u32,
                output_dim: 16,
            }])),
        ),
        ("reply", encode_frame(&Frame::Reply { req_id: 5, values: vec![1.0, 2.0] })),
        (
            "error",
            encode_frame(&Frame::Error(ErrorFrame {
                req_id: 5,
                code: ErrorCode::Coordinator,
                retryable: true,
                message: "queue full (backpressure)".into(),
            })),
        ),
    ]
}

fn patch_u16(buf: &mut [u8], off: usize, v: u16) {
    buf[off..off + 2].copy_from_slice(&v.to_le_bytes());
}

fn patch_u32(buf: &mut [u8], off: usize, v: u32) {
    buf[off..off + 4].copy_from_slice(&v.to_le_bytes());
}

fn decode_err(bytes: &[u8]) -> String {
    decode_frame(bytes).expect_err("malformed frame must error").message
}

// ---------------------------------------------------------------- codec

#[test]
fn every_truncation_of_every_frame_type_errors_cleanly() {
    let mut frames = client_frames("m");
    frames.extend(server_frames());
    for (kind, bytes) in frames {
        // Positive control: the untouched frame decodes and consumes
        // exactly its own bytes.
        let (_, used) = decode_frame(&bytes)
            .unwrap_or_else(|e| panic!("valid {kind} frame must decode: {e}"));
        assert_eq!(used, bytes.len(), "{kind}");
        for cut in 0..bytes.len() {
            assert!(
                decode_frame(&bytes[..cut]).is_err(),
                "{kind}: truncation to {cut}/{} bytes must error, not parse",
                bytes.len()
            );
        }
    }
}

#[test]
fn trailing_payload_bytes_are_rejected_per_frame_type() {
    // Ping/pong payloads are opaque tokens; every other frame has an
    // exact layout and must reject a padded payload by name.
    let mut frames: Vec<(&str, Vec<u8>)> = client_frames("m")
        .into_iter()
        .chain(server_frames())
        .filter(|(kind, _)| *kind != "ping" && *kind != "pong")
        .collect();
    for (kind, bytes) in frames.iter_mut() {
        bytes.push(0);
        let len = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        patch_u32(bytes, 8, len + 1);
        let msg = decode_err(bytes);
        assert!(msg.contains("trailing"), "{kind}: {msg}");
    }
}

#[test]
fn bad_magic_version_reserved_and_frame_type_are_fatal() {
    let valid = encode_frame(&Frame::Heartbeat);

    let mut bad = valid.clone();
    bad[..4].copy_from_slice(b"XXXX");
    let e = decode_frame(&bad).expect_err("bad magic must error");
    assert!(e.fatal && e.message.contains("magic"), "{e}");

    let mut bad = valid.clone();
    bad[4] = VERSION + 1;
    let e = decode_frame(&bad).expect_err("bad version must error");
    assert!(e.fatal && e.message.contains("version"), "{e}");

    let mut bad = valid.clone();
    bad[6] = 1;
    let e = decode_frame(&bad).expect_err("non-zero reserved must error");
    assert!(e.fatal && e.message.contains("reserved"), "{e}");

    let mut bad = valid.clone();
    bad[5] = 0x7f;
    let e = decode_frame(&bad).expect_err("unknown frame type must error");
    assert!(e.fatal && e.message.contains("frame type"), "{e}");
}

#[test]
fn oversized_length_claims_are_rejected_before_allocation() {
    // Header claims are checked against MAX_PAYLOAD before any payload
    // allocation, and per-field counts are proven against the bytes
    // actually present before `Vec::with_capacity`.
    let mut bytes = encode_frame(&Frame::Heartbeat);
    patch_u32(&mut bytes, 8, MAX_PAYLOAD + 1);
    let e = decode_frame(&bytes).expect_err("oversized length must error");
    assert!(e.fatal && e.message.contains("exceeds"), "{e}");

    let mut bytes = encode_frame(&Frame::Heartbeat);
    patch_u32(&mut bytes, 8, u32::MAX);
    assert!(decode_frame(&bytes).is_err(), "u32::MAX length must error");
}

/// Payload offsets for a dense/sparse frame with a 1-byte model name:
/// `req_id` at +0, name length at +8, name at +10, counts after.
const NAME_LEN_OFF: usize = HEADER_LEN + 8;
const DENSE_DIM_OFF: usize = HEADER_LEN + 8 + 2 + 1;
const SPARSE_NIDX_OFF: usize = HEADER_LEN + 8 + 2 + 1;
const SPARSE_NVAL_OFF: usize = SPARSE_NIDX_OFF + 4;

fn dense_bytes() -> Vec<u8> {
    encode_frame(&Frame::Dense(Request {
        req_id: 5,
        model: "m".into(),
        values: vec![0.5; D],
    }))
}

fn sparse_bytes() -> Vec<u8> {
    encode_frame(&Frame::Sparse(SparseRequest {
        req_id: 6,
        model: "m".into(),
        indices: vec![0, 2, 4],
        values: vec![1.0, 2.0, 3.0],
    }))
}

#[test]
fn oversized_counts_cannot_force_allocation() {
    let mut bad = dense_bytes();
    patch_u32(&mut bad, DENSE_DIM_OFF, u32::MAX);
    let msg = decode_err(&bad);
    assert!(msg.contains("dense values"), "{msg}");

    let mut bad = sparse_bytes();
    patch_u32(&mut bad, SPARSE_NIDX_OFF, u32::MAX);
    patch_u32(&mut bad, SPARSE_NVAL_OFF, u32::MAX);
    let msg = decode_err(&bad);
    assert!(msg.contains("sparse indices"), "{msg}");

    let mut bad = encode_frame(&Frame::Reply { req_id: 5, values: vec![1.0, 2.0] });
    patch_u32(&mut bad, HEADER_LEN + 8, u32::MAX);
    let msg = decode_err(&bad);
    assert!(msg.contains("reply values"), "{msg}");

    let mut bad = encode_frame(&Frame::Models(vec![]));
    patch_u32(&mut bad, HEADER_LEN, u32::MAX);
    let msg = decode_err(&bad);
    assert!(msg.contains("model count"), "{msg}");
}

#[test]
fn ragged_sparse_frames_are_named_errors() {
    // Index/value counts disagree.
    let mut bad = sparse_bytes();
    patch_u32(&mut bad, SPARSE_NVAL_OFF, 4);
    let msg = decode_err(&bad);
    assert!(msg.contains("mismatch"), "{msg}");

    // Non-ascending indices (descending pair).
    let bad = encode_frame(&Frame::Sparse(SparseRequest {
        req_id: 6,
        model: "m".into(),
        indices: vec![2, 0],
        values: vec![1.0, 2.0],
    }));
    let msg = decode_err(&bad);
    assert!(msg.contains("ascending"), "{msg}");

    // Duplicate indices count as non-ascending too.
    let bad = encode_frame(&Frame::Sparse(SparseRequest {
        req_id: 6,
        model: "m".into(),
        indices: vec![1, 1],
        values: vec![1.0, 2.0],
    }));
    let msg = decode_err(&bad);
    assert!(msg.contains("ascending"), "{msg}");
}

#[test]
fn per_field_corruptions_are_named() {
    // Model name length runs past the payload.
    let mut bad = dense_bytes();
    patch_u16(&mut bad, NAME_LEN_OFF, 200);
    let msg = decode_err(&bad);
    assert!(msg.contains("model name"), "{msg}");

    // Model name is not UTF-8.
    let mut bad = dense_bytes();
    bad[NAME_LEN_OFF + 2] = 0xFF;
    let msg = decode_err(&bad);
    assert!(msg.contains("UTF-8"), "{msg}");

    // Unknown error code byte.
    let mut bad = encode_frame(&Frame::Error(ErrorFrame {
        req_id: 1,
        code: ErrorCode::Data,
        retryable: false,
        message: "x".into(),
    }));
    bad[HEADER_LEN + 8] = 200;
    let msg = decode_err(&bad);
    assert!(msg.contains("error code"), "{msg}");

    // Retryable flag outside {0, 1}.
    let mut bad = encode_frame(&Frame::Error(ErrorFrame {
        req_id: 1,
        code: ErrorCode::Data,
        retryable: false,
        message: "x".into(),
    }));
    bad[HEADER_LEN + 9] = 2;
    let msg = decode_err(&bad);
    assert!(msg.contains("retryable"), "{msg}");
}

// --------------------------------------------------------------- socket

/// Read one frame off a raw socket (panics on timeout — tests bound
/// every read with a socket timeout so a hung connection fails, not
/// wedges).
fn read_frame_raw(s: &mut TcpStream) -> Frame {
    let mut header = [0u8; HEADER_LEN];
    s.read_exact(&mut header).expect("read frame header");
    let (ty, len) = rfdot::net::protocol::decode_header(&header).expect("decode header");
    let mut payload = vec![0u8; len as usize];
    s.read_exact(&mut payload).expect("read frame payload");
    rfdot::net::protocol::decode_payload(ty, &payload).expect("decode payload")
}

fn connect_raw(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

#[test]
fn socket_truncation_sweep_leaves_the_server_alive() {
    let (server, _registry) = start_server("t");
    let addr = server.local_addr();
    for (kind, bytes) in client_frames("t") {
        for cut in 0..bytes.len() {
            let mut s = connect_raw(addr);
            s.write_all(&bytes[..cut]).expect("send truncated frame");
            s.shutdown(std::net::Shutdown::Write).expect("half-close");
            // The server must reach a defined state: either silently
            // close (mid-frame EOF) or answer with frames and close.
            // Either way the read drains to EOF instead of hanging.
            let mut sink = Vec::new();
            s.read_to_end(&mut sink)
                .unwrap_or_else(|e| panic!("{kind} cut={cut}: connection wedged: {e}"));
        }
    }
    // The server survived ~150 mangled connections: a full round trip
    // still works.
    let mut client = NetClient::connect(addr, Duration::from_secs(10)).unwrap();
    client.ping().unwrap();
    let y = client.transform("t", &vec![0.25; D]).unwrap();
    assert_eq!(y.len(), 16);
}

#[test]
fn fatal_framing_errors_answer_once_and_close() {
    let (server, _registry) = start_server("t2");
    let addr = server.local_addr();
    let cases: Vec<(&str, Vec<u8>, &str)> = vec![
        {
            let mut h = encode_header(FrameType::Ping, 0).to_vec();
            h[..4].copy_from_slice(b"XXXX");
            ("bad magic", h, "magic")
        },
        {
            let mut h = encode_header(FrameType::Ping, 0).to_vec();
            h[4] = VERSION + 9;
            ("bad version", h, "version")
        },
        {
            let mut h = encode_header(FrameType::Ping, 0).to_vec();
            h[7] = 3;
            ("reserved bytes", h, "reserved")
        },
        {
            let mut h = encode_header(FrameType::Dense, 0).to_vec();
            patch_u32(&mut h, 8, u32::MAX);
            ("oversized length", h, "exceeds")
        },
    ];
    for (kind, bytes, needle) in cases {
        assert_eq!(bytes[..4] == MAGIC, kind != "bad magic");
        let mut s = connect_raw(addr);
        s.write_all(&bytes).expect("send mangled header");
        match read_frame_raw(&mut s) {
            Frame::Error(e) => {
                assert_eq!(e.code, ErrorCode::Protocol, "{kind}");
                assert!(e.message.contains(needle), "{kind}: {}", e.message);
            }
            f => panic!("{kind}: expected error frame, got {:?}", f.frame_type()),
        }
        // Fatal => the server closes after the error frame.
        let mut probe = [0u8; 1];
        assert_eq!(
            s.read(&mut probe).expect("post-error read"),
            0,
            "{kind}: connection must be closed after a fatal framing error"
        );
    }
}

#[test]
fn recoverable_frame_errors_keep_the_connection_usable() {
    let (server, _registry) = start_server("t3");
    let addr = server.local_addr();
    let mut s = connect_raw(addr);
    let x = vec![0.25; D];

    // 1. Ragged sparse frame: named error frame echoing the req id,
    //    connection stays open.
    let mut ragged = encode_frame(&Frame::Sparse(SparseRequest {
        req_id: 41,
        model: "t3".into(),
        indices: vec![0, 2, 4],
        values: vec![1.0, 2.0, 3.0],
    }));
    // Value-count offset for the 2-byte name "t3": header + req_id +
    // name_len + name + index count.
    patch_u32(&mut ragged, HEADER_LEN + 8 + 2 + 2 + 4, 9);
    s.write_all(&ragged).unwrap();
    match read_frame_raw(&mut s) {
        Frame::Error(e) => {
            assert_eq!(e.code, ErrorCode::Protocol);
            assert_eq!(e.req_id, 41, "recoverable request errors echo the req id");
            assert!(e.message.contains("mismatch"), "{}", e.message);
        }
        f => panic!("expected error frame, got {:?}", f.frame_type()),
    }

    // 2. Non-ascending sparse indices: named error, still open.
    s.write_all(&encode_frame(&Frame::Sparse(SparseRequest {
        req_id: 42,
        model: "t3".into(),
        indices: vec![3, 1],
        values: vec![1.0, 2.0],
    })))
    .unwrap();
    match read_frame_raw(&mut s) {
        Frame::Error(e) => assert!(e.message.contains("ascending"), "{}", e.message),
        f => panic!("expected error frame, got {:?}", f.frame_type()),
    }

    // 3. Unknown model: its own error code, still open.
    s.write_all(&encode_frame(&Frame::Dense(Request {
        req_id: 43,
        model: "nope".into(),
        values: x.clone(),
    })))
    .unwrap();
    match read_frame_raw(&mut s) {
        Frame::Error(e) => {
            assert_eq!(e.code, ErrorCode::UnknownModel);
            assert_eq!(e.req_id, 43);
        }
        f => panic!("expected error frame, got {:?}", f.frame_type()),
    }

    // 4. Wrong dense dim: the coordinator's shape error over the wire.
    s.write_all(&encode_frame(&Frame::Dense(Request {
        req_id: 44,
        model: "t3".into(),
        values: vec![0.5; D + 1],
    })))
    .unwrap();
    match read_frame_raw(&mut s) {
        Frame::Error(e) => {
            assert_eq!(e.code, ErrorCode::Shape);
            assert_eq!(e.req_id, 44);
        }
        f => panic!("expected error frame, got {:?}", f.frame_type()),
    }

    // 5. Out-of-range sparse index: decodes fine, the coordinator's
    //    Data-taxonomy rejection comes back as an error frame.
    s.write_all(&encode_frame(&Frame::Sparse(SparseRequest {
        req_id: 45,
        model: "t3".into(),
        indices: vec![0, D as u32],
        values: vec![1.0, 2.0],
    })))
    .unwrap();
    match read_frame_raw(&mut s) {
        Frame::Error(e) => {
            assert_eq!(e.code, ErrorCode::Data);
            assert!(e.message.contains("out of range"), "{}", e.message);
        }
        f => panic!("expected error frame, got {:?}", f.frame_type()),
    }

    // After five rejected frames, the same connection still serves a
    // real request — the defined-state guarantee.
    s.write_all(&encode_frame(&Frame::Dense(Request {
        req_id: 46,
        model: "t3".into(),
        values: x,
    })))
    .unwrap();
    match read_frame_raw(&mut s) {
        Frame::Reply { req_id, values } => {
            assert_eq!(req_id, 46);
            assert_eq!(values.len(), 16);
        }
        f => panic!("expected reply, got {:?}", f.frame_type()),
    }
}

#[test]
fn unexpected_server_frames_at_the_server_are_rejected_not_fatal() {
    let (server, _registry) = start_server("t4");
    let addr = server.local_addr();
    let mut s = connect_raw(addr);
    s.write_all(&encode_frame(&Frame::Reply { req_id: 9, values: vec![1.0] })).unwrap();
    match read_frame_raw(&mut s) {
        Frame::Error(e) => assert!(e.message.contains("unexpected"), "{}", e.message),
        f => panic!("expected error frame, got {:?}", f.frame_type()),
    }
    // Recoverable: a ping still round-trips on the same connection.
    s.write_all(&encode_frame(&Frame::Ping { token: b"x".to_vec() })).unwrap();
    match read_frame_raw(&mut s) {
        Frame::Pong { token } => assert_eq!(token, b"x".to_vec()),
        f => panic!("expected pong, got {:?}", f.frame_type()),
    }
}
