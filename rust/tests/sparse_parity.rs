//! The sparse parity contract, end to end (the CSR sibling of
//! `parallel_identity.rs`): every sparse fast path must produce output
//! **equal** to the dense path on the densified data. Sparsity, like
//! threading, is scheduling — never semantics.
//!
//! The pipeline under test is the paper's actual workload shape:
//! LIBSVM text → CSR parse (no densify) → row normalization → feature
//! transform (Random Maclaurin / Random Fourier / TensorSketch) → Gram
//! / linear SVM → decisions. At every stage the sparse route is
//! compared against a densified twin with exact equality (`==` on
//! `f32`, which ignores only the sign of zeros — the one difference the
//! two routes can legally produce).

use rfdot::data::{libsvm, Dataset};
use rfdot::features::{feature_gram, feature_gram_sparse, transform_dataset, FeatureMap};
use rfdot::kernels::{Exponential, Polynomial};
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rff::RandomFourier;
use rfdot::rng::Rng;
use rfdot::structured::ProjectionKind;
use rfdot::svm::{Classifier, LinearSvm, LinearSvmParams};
use rfdot::tensorsketch::TensorSketch;

/// Deterministic synthetic LIBSVM text: `n` rows over `d` features at
/// roughly `keep` density, unique ascending 1-based indices.
fn libsvm_text(n: usize, d: usize, keep: f64, seed: u64) -> String {
    let mut rng = Rng::seed_from(seed);
    let mut out = String::new();
    for i in 0..n {
        out.push_str(if i % 2 == 0 { "+1" } else { "-1" });
        let mut any = false;
        for j in 1..=d {
            if rng.f64() < keep {
                out.push_str(&format!(" {}:{:.4}", j, rng.f32() - 0.5));
                any = true;
            }
        }
        if !any {
            // Keep every row non-empty so normalization is non-trivial.
            out.push_str(" 1:0.5");
        }
        out.push('\n');
    }
    out
}

/// Parse into the CSR pipeline and build its densified twin.
fn parsed_pair(n: usize, d: usize, keep: f64, seed: u64) -> (Dataset, Dataset) {
    let text = libsvm_text(n, d, keep, seed);
    let mut sparse = libsvm::parse_str("parity", &text, Some(d)).unwrap();
    assert!(sparse.is_sparse(), "parse_str must yield CSR storage");
    let mut dense = sparse.clone().into_dense();
    sparse.normalize_rows();
    dense.normalize_rows();
    (sparse, dense)
}

#[test]
fn parse_then_normalize_is_storage_invariant() {
    let (sparse, dense) = parsed_pair(40, 31, 0.12, 1);
    assert_eq!(sparse.x(), dense.x(), "normalized dense views must match");
    assert_eq!(sparse.y, dense.y);
    assert!(sparse.nnz() < 40 * 31 / 2, "test data must actually be sparse");
}

/// Every map family with a sparse fast path (plus the densifying
/// structured fallback): batch, per-row and threaded outputs all equal
/// the dense route.
#[test]
fn transforms_are_bit_identical_across_storage() {
    let (sparse, dense) = parsed_pair(30, 47, 0.15, 2);
    let d = dense.dim();
    let maps: Vec<(String, Box<dyn FeatureMap>)> = vec![
        (
            "maclaurin".into(),
            Box::new(RandomMaclaurin::sample(
                &Exponential::new(1.0),
                d,
                96,
                RmConfig::default(),
                &mut Rng::seed_from(10),
            )),
        ),
        (
            "maclaurin-h01".into(),
            Box::new(RandomMaclaurin::sample(
                &Polynomial::new(7, 1.0),
                d,
                64,
                RmConfig::default().with_h01(true),
                &mut Rng::seed_from(11),
            )),
        ),
        (
            "maclaurin-structured".into(),
            Box::new(RandomMaclaurin::sample(
                &Exponential::new(1.0),
                d,
                64,
                RmConfig::default().with_projection(ProjectionKind::Structured),
                &mut Rng::seed_from(12),
            )),
        ),
        (
            "fourier".into(),
            Box::new(RandomFourier::sample(0.7, d, 80, &mut Rng::seed_from(13))),
        ),
        (
            "fourier-structured".into(),
            Box::new(RandomFourier::sample_with(
                0.7,
                d,
                80,
                ProjectionKind::Structured,
                &mut Rng::seed_from(14),
            )),
        ),
        (
            "tensorsketch".into(),
            Box::new(TensorSketch::sample(3, 1.0, d, 128, &mut Rng::seed_from(15))),
        ),
    ];

    let sx = sparse.sparse().expect("sparse storage");
    for (name, map) in &maps {
        let z_dense = map.transform_batch(dense.x());
        // Batch CSR path, across thread counts.
        for threads in [1usize, 2, 8] {
            assert_eq!(
                map.transform_batch_sparse_threads(sx, threads),
                z_dense,
                "{name}: batch sparse != dense at {threads} threads"
            );
        }
        // Per-row CSR path.
        let mut row_out = vec![0.0f32; map.output_dim()];
        for i in 0..sparse.len() {
            map.transform_sparse_into(sx.row(i), &mut row_out);
            assert_eq!(&row_out[..], z_dense.row(i), "{name}: row {i} sparse != dense");
        }
        // The storage-dispatching helper agrees with both.
        assert_eq!(transform_dataset(map.as_ref(), &sparse), z_dense, "{name}: dispatch");
    }
}

#[test]
fn feature_gram_is_storage_invariant() {
    let (sparse, dense) = parsed_pair(25, 29, 0.2, 3);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        dense.dim(),
        72,
        RmConfig::default(),
        &mut Rng::seed_from(20),
    );
    let g_dense = feature_gram(&map, dense.x());
    let g_sparse = feature_gram_sparse(&map, sparse.sparse().unwrap());
    assert_eq!(g_dense, g_sparse);
}

/// The LIBLINEAR-style sparse dual coordinate descent follows the dense
/// trajectory exactly: equal weights, bias, epochs and decisions on
/// LIBSVM-parsed data.
#[test]
fn sparse_svm_training_matches_dense() {
    let (sparse, dense) = parsed_pair(120, 23, 0.25, 4);
    let params = LinearSvmParams::default();
    let m_sparse = LinearSvm::train(&sparse, params).unwrap();
    let m_dense = LinearSvm::train(&dense, params).unwrap();
    assert_eq!(m_sparse.weights(), m_dense.weights());
    assert_eq!(m_sparse.bias(), m_dense.bias());
    assert_eq!(m_sparse.epochs, m_dense.epochs);
    for i in 0..dense.len() {
        assert_eq!(
            m_sparse.decision(dense.x().row(i)),
            m_dense.decision(dense.x().row(i)),
            "decision {i}"
        );
    }
}

/// The full Table-1 shape: CSR data → sparse transform → linear SVM →
/// decisions, against the dense twin of every stage.
#[test]
fn end_to_end_decisions_match() {
    let (sparse, dense) = parsed_pair(80, 37, 0.15, 5);
    let map = RandomMaclaurin::sample(
        &Polynomial::new(5, 1.0),
        dense.dim(),
        128,
        RmConfig::default(),
        &mut Rng::seed_from(30),
    );
    let z_sparse = transform_dataset(&map, &sparse);
    let z_dense = map.transform_batch(dense.x());
    assert_eq!(z_sparse, z_dense);
    let zd_sparse = Dataset::new("zs", z_sparse, sparse.y.clone()).unwrap();
    let zd_dense = Dataset::new("zd", z_dense, dense.y.clone()).unwrap();
    let m_sparse = LinearSvm::train(&zd_sparse, LinearSvmParams::default()).unwrap();
    let m_dense = LinearSvm::train(&zd_dense, LinearSvmParams::default()).unwrap();
    assert_eq!(m_sparse.weights(), m_dense.weights());
    assert_eq!(m_sparse.bias(), m_dense.bias());
    assert_eq!(m_sparse.accuracy_on(&zd_sparse), m_dense.accuracy_on(&zd_dense));
}

/// Serving parity: a LIBSVM-parsed row submitted as CSR pairs gets the
/// exact reply of the dense submission (same exactly-once machinery).
#[test]
fn coordinator_sparse_submission_matches_dense() {
    use rfdot::coordinator::{Coordinator, CoordinatorConfig, NativeFactory};
    use std::sync::Arc;

    let (sparse, dense) = parsed_pair(8, 19, 0.3, 6);
    let map = Arc::new(RandomMaclaurin::sample(
        &Exponential::new(1.0),
        dense.dim(),
        32,
        RmConfig::default(),
        &mut Rng::seed_from(40),
    ));
    let coord =
        Coordinator::start(Arc::new(NativeFactory::new(map)), CoordinatorConfig::default());
    let sx = sparse.sparse().unwrap();
    for i in 0..sparse.len() {
        let row = sx.row(i);
        let zs = coord
            .submit_sparse(row.indices.to_vec(), row.values.to_vec())
            .unwrap()
            .wait()
            .unwrap();
        let zd = coord.transform(dense.x().row(i).to_vec()).unwrap();
        assert_eq!(zs, zd, "row {i}");
    }
}
