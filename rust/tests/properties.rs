//! Property-based tests over the library's core invariants, driven by
//! the in-tree `prop` harness (random generation + shrink-lite).

use rfdot::config::json::Json;
use rfdot::data::libsvm;
use rfdot::kernels::{DotProductKernel, Exponential, Homogeneous, Polynomial, VovkReal};
use rfdot::linalg::{fwht, norm1, scale, Matrix};
use rfdot::features::FeatureMap;
use rfdot::maclaurin::{serialize, RandomMaclaurin, RmConfig};
use rfdot::prop::{forall, gens, PropConfig};
use rfdot::rng::Rng;
use rfdot::simd::{self, SimdPath};
use rfdot::structured::ProjectionKind;

/// A random built-in kernel.
fn random_kernel(rng: &mut Rng) -> Box<dyn DotProductKernel> {
    match rng.below(4) {
        0 => Box::new(Polynomial::new(1 + rng.below(10) as u32, 0.25 + rng.f64())),
        1 => Box::new(Homogeneous::new(1 + rng.below(6) as u32)),
        2 => Box::new(Exponential::new(0.5 + 2.0 * rng.f64())),
        _ => Box::new(VovkReal::new(2 + rng.below(5) as u32)),
    }
}

#[derive(Debug)]
struct MapCase {
    kernel_name: String,
    d: usize,
    n_feat: usize,
    h01: bool,
    projection: ProjectionKind,
    seed: u64,
}

fn gen_map_case(rng: &mut Rng, size: usize) -> MapCase {
    let k = random_kernel(rng);
    MapCase {
        kernel_name: k.name(),
        d: 1 + rng.below(1 + size as u64 / 2) as usize,
        n_feat: 1 + rng.below(1 + size as u64 * 2) as usize,
        h01: rng.bernoulli(0.5),
        projection: if rng.bernoulli(0.5) {
            ProjectionKind::Structured
        } else {
            ProjectionKind::Dense
        },
        seed: rng.next_u64(),
    }
}

fn rebuild_kernel(name: &str) -> Box<dyn DotProductKernel> {
    // Parse back from the canonical name (tests keep kernels simple).
    if let Some(rest) = name.strip_prefix("polynomial(p=") {
        let parts: Vec<&str> = rest.trim_end_matches(')').split(", r=").collect();
        return Box::new(Polynomial::new(parts[0].parse().unwrap(), parts[1].parse().unwrap()));
    }
    if let Some(rest) = name.strip_prefix("homogeneous(p=") {
        return Box::new(Homogeneous::new(rest.trim_end_matches(')').parse().unwrap()));
    }
    if let Some(rest) = name.strip_prefix("exponential(sigma2=") {
        return Box::new(Exponential::new(rest.trim_end_matches(')').parse().unwrap()));
    }
    if let Some(rest) = name.strip_prefix("vovk-real(p=") {
        return Box::new(VovkReal::new(rest.trim_end_matches(')').parse().unwrap()));
    }
    panic!("unknown kernel name {name}");
}

/// Lemma 8 as a property: for every sampled map and points in the L1
/// unit ball, `D·|Z_i(x)Z_i(y)| ≤ p/(p−1)·f(pR²)`.
#[test]
fn prop_estimator_bound_holds() {
    forall(
        PropConfig { cases: 60, seed: 0xB0B, max_size: 24 },
        gen_map_case,
        |case| {
            let kernel = rebuild_kernel(&case.kernel_name);
            let mut rng = Rng::seed_from(case.seed);
            let map = RandomMaclaurin::sample(
                kernel.as_ref(),
                case.d,
                case.n_feat,
                RmConfig::default()
                    .with_h01(case.h01 && kernel.coeff(0) + kernel.coeff(1) > 0.0)
                    .with_projection(case.projection),
                &mut rng,
            );
            let bound = kernel.estimator_bound(2.0, 1.0) + 1e-6;
            for trial in 0..4 {
                let mut x = gens::unit_vec(&mut Rng::seed_from(case.seed ^ trial), case.d);
                let mut y =
                    gens::unit_vec(&mut Rng::seed_from(case.seed ^ (trial + 100)), case.d);
                scale(1.0 / norm1(&x).max(1e-9), &mut x);
                scale(1.0 / norm1(&y).max(1e-9), &mut y);
                let zx = map.transform(&x);
                let zy = map.transform(&y);
                // Random block only (H0/1 prefix is exact, not estimated).
                let off = map.output_dim() - map.n_random();
                for i in 0..map.n_random() {
                    let v = (zx[off + i] * zy[off + i]).abs() as f64 * map.n_random() as f64;
                    if v > bound * (1.0 + 1e-4) {
                        return Err(format!(
                            "feature {i}: {v} > bound {bound} for {}",
                            case.kernel_name
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Serialization is lossless for arbitrary maps.
#[test]
fn prop_serialization_roundtrip() {
    forall(
        PropConfig { cases: 40, seed: 0x5E41, max_size: 32 },
        gen_map_case,
        |case| {
            let kernel = rebuild_kernel(&case.kernel_name);
            let mut rng = Rng::seed_from(case.seed);
            let map = RandomMaclaurin::sample(
                kernel.as_ref(),
                case.d,
                case.n_feat,
                RmConfig::default().with_h01(case.h01).with_projection(case.projection),
                &mut rng,
            );
            let bytes = serialize::to_bytes(&map);
            let map2 = serialize::from_bytes(&bytes).map_err(|e| e.to_string())?;
            let x = gens::unit_vec(&mut rng, case.d);
            if map.transform(&x) != map2.transform(&x) {
                return Err("transform mismatch after roundtrip".into());
            }
            // Reserialization is canonical for both record kinds (the
            // structured kind stores only seed + layout).
            if serialize::to_bytes(&map2) != bytes {
                return Err("reserialized bytes differ".into());
            }
            Ok(())
        },
    );
}

/// Batch and single-vector transforms agree for arbitrary maps/batches.
#[test]
fn prop_batch_equals_single() {
    forall(
        PropConfig { cases: 40, seed: 0xBA7C, max_size: 24 },
        gen_map_case,
        |case| {
            let kernel = rebuild_kernel(&case.kernel_name);
            let mut rng = Rng::seed_from(case.seed);
            let map = RandomMaclaurin::sample(
                kernel.as_ref(),
                case.d,
                case.n_feat,
                RmConfig::default().with_h01(case.h01).with_projection(case.projection),
                &mut rng,
            );
            let b = 1 + rng.below(6) as usize;
            let rows: Vec<Vec<f32>> =
                (0..b).map(|_| gens::f32_vec(&mut rng, case.d)).collect();
            let x = Matrix::from_rows(&rows).map_err(|e| e.to_string())?;
            let zb = map.transform_batch(&x);
            for i in 0..b {
                let zi = map.transform(x.row(i));
                for (a, bb) in zb.row(i).iter().zip(&zi) {
                    if (a - bb).abs() > 1e-4 * (1.0 + bb.abs()) {
                        return Err(format!("row {i} mismatch: {a} vs {bb}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// FWHT invariants on random inputs at every power-of-two length up to
/// 128: involution up to the `1/n` scale, Parseval's `‖Hx‖² = n‖x‖²`,
/// and exact agreement with the naive O(n²) Hadamard multiply
/// (`H[i, k] = (−1)^{popcount(i & k)}`).
#[test]
fn prop_fwht_invariants() {
    #[derive(Debug)]
    struct Case {
        log_n: u32,
        seed: u64,
    }
    forall(
        PropConfig { cases: 80, seed: 0xFA57, max_size: 7 },
        |rng: &mut Rng, size: usize| Case {
            log_n: rng.below(size.min(7) as u64 + 1) as u32,
            seed: rng.next_u64(),
        },
        |case| {
            let n = 1usize << case.log_n;
            let mut rng = Rng::seed_from(case.seed);
            let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let naive: Vec<f64> = (0..n)
                .map(|i| {
                    (0..n)
                        .map(|k| {
                            let v = x[k] as f64;
                            if (i & k).count_ones() % 2 == 0 {
                                v
                            } else {
                                -v
                            }
                        })
                        .sum()
                })
                .collect();
            let mut y = x.clone();
            fwht(&mut y);
            for k in 0..n {
                if (y[k] as f64 - naive[k]).abs() > 1e-3 {
                    return Err(format!("n={n} k={k}: fwht {} vs naive {}", y[k], naive[k]));
                }
            }
            let sq = |v: &[f32]| v.iter().map(|&a| (a as f64) * a as f64).sum::<f64>();
            let (ex, ey) = (sq(&x), sq(&y));
            if (ey - n as f64 * ex).abs() > 1e-3 * (1.0 + ey) {
                return Err(format!("Parseval violated at n={n}: {ey} vs {}", n as f64 * ex));
            }
            fwht(&mut y);
            for k in 0..n {
                if (y[k] / n as f32 - x[k]).abs() > 1e-3 {
                    return Err(format!("involution violated at n={n} k={k}"));
                }
            }
            Ok(())
        },
    );
}

/// Bit patterns of a float slice, for bitwise-equality assertions.
fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every runtime-dispatched kernel agrees with the scalar oracle on
/// every length `0..=67` — the range covers empty input, the vector
/// bodies, and every remainder class of the 32-lane (AVX2), 16-lane
/// (NEON) and 4-lane (scalar) strides. `dot` and `axpy` reassociate
/// and fuse, so they get the shared rounding envelope; `scale` and the
/// FWHT butterfly are pure lanewise IEEE mul/add/sub, so they must be
/// bitwise identical; the cosine activation swaps libm for the
/// polynomial on vector paths, so it gets the polynomial's error
/// budget. Uses the explicit `_with(path)` API only — the process
/// global dispatch mode is never touched, so this test is safe to run
/// concurrently with everything else in the binary.
#[test]
fn prop_simd_kernels_match_scalar_oracle() {
    forall(
        PropConfig { cases: 40, seed: 0x51D0, max_size: 8 },
        |rng: &mut Rng, _size: usize| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::seed_from(seed);
            for n in 0..=67usize {
                let a = gens::f32_vec(&mut rng, n);
                let b = gens::f32_vec(&mut rng, n);
                let alpha = rng.f32() * 2.0 - 1.0;
                let scale = rng.f32() * 2.0 - 1.0;
                for &path in &simd::available_paths() {
                    // dot: both sides may reassociate; the ULP bound is
                    // the contract shared with `linalg::dot`'s tests.
                    let want = simd::dot_with(SimdPath::Scalar, &a, &b);
                    let got = simd::dot_with(path, &a, &b);
                    if (got - want).abs() > simd::dot_ulp_bound(&a, &b) {
                        return Err(format!("dot n={n} {path:?}: {got} vs scalar {want}"));
                    }
                    // axpy: elementwise, fused vs unfused differ by at
                    // most one rounding of the product per element.
                    let mut want = b.clone();
                    simd::axpy_with(SimdPath::Scalar, alpha, &a, &mut want);
                    let mut got = b.clone();
                    simd::axpy_with(path, alpha, &a, &mut got);
                    for k in 0..n {
                        let tol = 4.0 * f32::EPSILON * ((alpha * a[k]).abs() + b[k].abs());
                        if (got[k] - want[k]).abs() > tol {
                            return Err(format!(
                                "axpy n={n} k={k} {path:?}: {} vs scalar {}",
                                got[k], want[k]
                            ));
                        }
                    }
                    // scale: one IEEE multiply per lane — bitwise.
                    let mut want = a.clone();
                    simd::scale_with(SimdPath::Scalar, scale, &mut want);
                    let mut got = a.clone();
                    simd::scale_with(path, scale, &mut got);
                    if bits(&got) != bits(&want) {
                        return Err(format!("scale n={n} {path:?} not bitwise"));
                    }
                    // FWHT butterfly: one add + one sub per lane — bitwise.
                    let (mut wa, mut wb) = (a.clone(), b.clone());
                    simd::fwht_butterfly_with(SimdPath::Scalar, &mut wa, &mut wb);
                    let (mut ga, mut gb) = (a.clone(), b.clone());
                    simd::fwht_butterfly_with(path, &mut ga, &mut gb);
                    if bits(&ga) != bits(&wa) || bits(&gb) != bits(&wb) {
                        return Err(format!("fwht butterfly n={n} {path:?} not bitwise"));
                    }
                    // cos activation: vector paths use the Cody-Waite
                    // polynomial (~1e-6 absolute) instead of libm.
                    let mut want = a.clone();
                    simd::cos_activate_with(SimdPath::Scalar, &mut want, &b, scale);
                    let mut got = a.clone();
                    simd::cos_activate_with(path, &mut got, &b, scale);
                    for k in 0..n {
                        if (got[k] - want[k]).abs() > 1e-5 * scale.abs().max(1.0) {
                            return Err(format!(
                                "cos n={n} k={k} {path:?}: {} vs scalar {}",
                                got[k], want[k]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// The sparse kernels mirror their dense counterparts *per path*: on
/// the same dispatch path, a sparse row must produce bitwise the same
/// dot / self-dot / axpy results as its zero-padded dense form. This
/// is the invariant that keeps CSR and dense pipelines byte-identical
/// (zeros contribute exactly `±0.0` to every lane, and the sparse
/// mirrors replicate each path's lane discipline by column position).
#[test]
fn prop_sparse_mirrors_match_dense_kernels_per_path() {
    forall(
        PropConfig { cases: 40, seed: 0x5BA5, max_size: 8 },
        |rng: &mut Rng, _size: usize| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::seed_from(seed);
            for n in 0..=67usize {
                let mut indices = Vec::new();
                let mut values = Vec::new();
                let mut dense = vec![0.0f32; n];
                for k in 0..n {
                    if rng.bernoulli(0.4) {
                        let v = rng.f32() * 2.0 - 1.0;
                        if v != 0.0 {
                            indices.push(k as u32);
                            values.push(v);
                            dense[k] = v;
                        }
                    }
                }
                let w = gens::f32_vec(&mut rng, n);
                let alpha = rng.f32() * 2.0 - 1.0;
                for &path in &simd::available_paths() {
                    let got = simd::sparse_dot_dense_with(path, &indices, &values, &w);
                    let want = simd::dot_with(path, &dense, &w);
                    if got.to_bits() != want.to_bits() {
                        return Err(format!("sparse dot n={n} {path:?}: {got} vs dense {want}"));
                    }
                    let got = simd::sparse_self_dot_with(path, &indices, &values, n);
                    let want = simd::dot_with(path, &dense, &dense);
                    if got.to_bits() != want.to_bits() {
                        return Err(format!(
                            "sparse self-dot n={n} {path:?}: {got} vs dense {want}"
                        ));
                    }
                    let mut got_w = w.clone();
                    simd::sparse_axpy_with(path, alpha, &indices, &values, &mut got_w);
                    let mut want_w = w.clone();
                    simd::axpy_with(path, alpha, &dense, &mut want_w);
                    if bits(&got_w) != bits(&want_w) {
                        return Err(format!("sparse axpy n={n} {path:?} not bitwise"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// JSON display/parse round-trips arbitrary JSON trees.
#[test]
fn prop_json_roundtrip() {
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0 * rng.f64()).round() / 8.0),
            3 => {
                let len = rng.below(8) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => {
                let len = rng.below(4) as usize;
                Json::Arr((0..len).map(|_| gen_json(rng, depth - 1)).collect())
            }
            _ => {
                let len = rng.below(4) as usize;
                Json::Obj(
                    (0..len)
                        .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    forall(
        PropConfig { cases: 120, seed: 0x7507, max_size: 4 },
        |rng: &mut Rng, size: usize| gen_json(rng, size.min(3)),
        |v| {
            let text = v.to_string();
            let back = Json::parse(&text).map_err(|e| format!("{e} on {text:?}"))?;
            if &back != v {
                return Err(format!("roundtrip mismatch: {v} vs {back}"));
            }
            Ok(())
        },
    );
}

/// LIBSVM serialization round-trips arbitrary sparse-ish datasets.
#[test]
fn prop_libsvm_roundtrip() {
    #[derive(Debug)]
    struct Case {
        n: usize,
        d: usize,
        seed: u64,
    }
    forall(
        PropConfig { cases: 50, seed: 0x11B5, max_size: 24 },
        |rng: &mut Rng, size: usize| Case {
            n: 1 + rng.below(size as u64 + 1) as usize,
            d: 1 + rng.below(size as u64 + 1) as usize,
            seed: rng.next_u64(),
        },
        |case| {
            let mut rng = Rng::seed_from(case.seed);
            let mut x = Matrix::zeros(case.n, case.d);
            for i in 0..case.n {
                for j in 0..case.d {
                    if rng.bernoulli(0.4) {
                        // Quantized values survive the decimal round trip.
                        x.set(i, j, (rng.range(-8, 8) as f32) * 0.25);
                    }
                }
            }
            let y: Vec<f32> =
                (0..case.n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
            let ds = rfdot::data::Dataset::new("p", x, y).map_err(|e| e.to_string())?;
            let text = libsvm::to_string(&ds);
            let ds2 =
                libsvm::parse_str("p", &text, Some(case.d)).map_err(|e| e.to_string())?;
            if ds.x() != ds2.x() || ds.y != ds2.y {
                return Err("libsvm roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

/// The linear SVM never diverges: for arbitrary (tiny) datasets the
/// trained weights are finite and the dual violation is finite.
#[test]
fn prop_linear_svm_stable() {
    #[derive(Debug)]
    struct Case {
        n: usize,
        d: usize,
        seed: u64,
    }
    forall(
        PropConfig { cases: 40, seed: 0x57AB, max_size: 20 },
        |rng: &mut Rng, size: usize| Case {
            n: 2 + rng.below(size as u64 * 4 + 1) as usize,
            d: 1 + rng.below(size as u64 + 1) as usize,
            seed: rng.next_u64(),
        },
        |case| {
            let mut rng = Rng::seed_from(case.seed);
            let rows: Vec<Vec<f32>> =
                (0..case.n).map(|_| gens::f32_vec(&mut rng, case.d)).collect();
            let y: Vec<f32> =
                (0..case.n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
            let ds = rfdot::data::Dataset::new(
                "p",
                Matrix::from_rows(&rows).map_err(|e| e.to_string())?,
                y,
            )
            .map_err(|e| e.to_string())?;
            let model = rfdot::svm::LinearSvm::train(
                &ds,
                rfdot::svm::LinearSvmParams { max_epochs: 50, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            if !model.weights().iter().all(|w| w.is_finite()) || !model.bias().is_finite() {
                return Err("non-finite weights".into());
            }
            Ok(())
        },
    );
}
