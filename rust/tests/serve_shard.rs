//! The sharded serving contract, end to end (the coordinator sibling of
//! `parallel_identity.rs` / `sparse_parity.rs`'s serve coverage):
//!
//! 1. **Topology parity** — shard count is scheduling, never semantics:
//!    for the same sampled map, every reply is bit-identical to the
//!    direct `FeatureMap::transform`, whatever the worker/shard layout
//!    and however many batches were stolen.
//! 2. **Exactly-once under stealing** — many concurrent submitters ×
//!    ragged batches × a deliberately slow straggler worker (forcing
//!    steals): replies are never duplicated, dropped, or cross-wired.
//! 3. **Shutdown never hangs** — queued-but-unserved tickets (a worker
//!    died mid-run) are failed with an explicit shutdown error.
//! 4. **Batcher-death survival** (ISSUE 10) — a batcher killed by an
//!    injected panic still closes the shard queues (no shutdown hang)
//!    and a single dead ingress lane never fails submissions while
//!    other lanes are live.

use rfdot::coordinator::{
    Backend, BackendSpec, ClosureFactory, Coordinator, CoordinatorConfig, NativeFactory,
};
use rfdot::features::FeatureMap;
use rfdot::kernels::Exponential;
use rfdot::linalg::Matrix;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;
use rfdot::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Serializes every test in this binary: the batcher-death regressions
/// arm process-global fault plans on `coord.*` sites, which the other
/// tests' coordinators would hit if they ran concurrently.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    let g = SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
    rfdot::faults::clear();
    g
}

fn sample_map(d: usize, n_feat: usize, seed: u64) -> Arc<RandomMaclaurin> {
    Arc::new(RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        n_feat,
        RmConfig::default(),
        &mut Rng::seed_from(seed),
    ))
}

#[test]
fn replies_bit_identical_across_shard_topologies() {
    let _serial = serial();
    // The serving parity pin: the same seeded map served through every
    // topology — shared queue, one shard per worker, more shards than
    // workers — answers every input with exactly transform(x).
    let d = 7;
    let map = sample_map(d, 40, 5);
    let mut rng = Rng::seed_from(6);
    let inputs: Vec<Vec<f32>> =
        (0..60).map(|_| (0..d).map(|_| rng.f32() - 0.5).collect()).collect();
    let expected: Vec<Vec<f32>> = inputs.iter().map(|x| map.transform(x)).collect();
    for (workers, shards) in [(1usize, 1usize), (2, 1), (2, 2), (3, 5), (4, 2)] {
        let coord = Coordinator::start(
            Arc::new(NativeFactory::new(map.clone())),
            CoordinatorConfig {
                workers,
                shards,
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                ..Default::default()
            },
        );
        let tickets: Vec<_> =
            inputs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
        for ((t, want), i) in tickets.into_iter().zip(&expected).zip(0..) {
            assert_eq!(
                &t.wait().unwrap(),
                want,
                "workers={workers} shards={shards}: reply {i} diverged"
            );
        }
    }
}

/// A backend wrapper that makes the first-built worker a straggler
/// (every batch sleeps), so the remaining fast workers must steal from
/// its shard to keep the pool busy.
struct MaybeSlow {
    map: Arc<RandomMaclaurin>,
    slow: bool,
}

impl Backend for MaybeSlow {
    fn spec(&self) -> BackendSpec {
        BackendSpec {
            input_dim: self.map.input_dim(),
            output_dim: self.map.output_dim(),
            max_batch: usize::MAX,
            fixed_batch: false,
        }
    }

    fn run_batch(&self, x: &Matrix) -> Result<Matrix> {
        if self.slow {
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(self.map.transform_batch_threads(x, 1))
    }
}

#[test]
fn stress_exactly_once_replies_under_forced_stealing() {
    let _serial = serial();
    let d = 6;
    let map = sample_map(d, 32, 7);
    let built = Arc::new(AtomicUsize::new(0));
    let spec = BackendSpec {
        input_dim: d,
        output_dim: map.output_dim(),
        max_batch: usize::MAX,
        fixed_batch: false,
    };
    let factory = {
        let map = map.clone();
        let built = built.clone();
        Arc::new(ClosureFactory {
            spec,
            f: move || {
                let slow = built.fetch_add(1, Ordering::SeqCst) == 0;
                Ok(Box::new(MaybeSlow { map: map.clone(), slow }) as Box<dyn Backend>)
            },
        })
    };
    let coord = Arc::new(Coordinator::start(
        factory,
        CoordinatorConfig {
            workers: 3,
            shards: 0, // one shard per worker
            max_batch: 4,
            max_wait: Duration::from_micros(300),
            queue_depth: 4096,
            ..Default::default()
        },
    ));

    // 6 submitters × ragged client batches × all three submission
    // surfaces; every reply must be the transform of its own input.
    let clients = 6usize;
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        let map = map.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from(100 + c as u64);
            let mut accepted = 0usize;
            for round in 0..30 {
                let k = 1 + (rng.below(5) as usize); // ragged 1..=5
                let xs: Vec<Vec<f32>> = (0..k)
                    .map(|_| (0..d).map(|_| rng.f32() - 0.5).collect())
                    .collect();
                match round % 3 {
                    0 => {
                        // Per-request tickets (backpressure may reject;
                        // pair each accepted ticket with its own input).
                        let pairs: Vec<_> = xs
                            .iter()
                            .filter_map(|x| coord.submit(x.clone()).ok().map(|t| (x, t)))
                            .collect();
                        accepted += pairs.len();
                        for (x, t) in pairs {
                            assert_eq!(
                                t.wait().unwrap(),
                                map.transform(x),
                                "client {c}: cross-wired reply"
                            );
                        }
                    }
                    1 => {
                        // One shared-channel batch.
                        let ticket = coord.submit_batch(xs.clone()).unwrap();
                        accepted += ticket.accepted();
                        for (x, r) in xs.iter().zip(ticket.wait()) {
                            if let Ok(z) = r {
                                assert_eq!(
                                    z,
                                    map.transform(x),
                                    "client {c}: batch reply cross-wired"
                                );
                            }
                        }
                    }
                    _ => {
                        // CSR pairs over the same machinery.
                        for x in &xs {
                            let indices: Vec<u32> = (0..d as u32)
                                .filter(|&k| x[k as usize] != 0.0)
                                .collect();
                            let values: Vec<f32> =
                                indices.iter().map(|&k| x[k as usize]).collect();
                            if let Ok(t) = coord.submit_sparse(indices, values) {
                                accepted += 1;
                                assert_eq!(
                                    t.wait().unwrap(),
                                    map.transform(x),
                                    "client {c}: sparse reply cross-wired"
                                );
                            }
                        }
                    }
                }
            }
            accepted
        }));
    }
    let accepted: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();

    // Exactly once: everything accepted was completed (no duplicates,
    // no drops), and the pool-wide per-shard accounting agrees.
    let stats = coord.stats();
    assert_eq!(accepted as u64, stats.submitted.load(Ordering::Relaxed));
    assert_eq!(accepted as u64, stats.completed.load(Ordering::Relaxed));
    let snaps = coord.shard_snapshots();
    let items: u64 = snaps.iter().map(|s| s.items).sum();
    assert_eq!(items, stats.batched_items.load(Ordering::Relaxed));
    // The straggler forced actual work stealing.
    let steals: u64 = snaps.iter().map(|s| s.steals).sum();
    assert!(steals > 0, "no batches were stolen from the straggler ({snaps:?})");
}

/// A backend that blocks inside `run_batch` until told to go, then
/// panics — the deterministic way to kill a worker while later batches
/// are provably queued behind it.
struct PanicWhenTold {
    go: std::sync::mpsc::Receiver<()>,
}

impl Backend for PanicWhenTold {
    fn spec(&self) -> BackendSpec {
        BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false }
    }

    fn run_batch(&self, _x: &Matrix) -> Result<Matrix> {
        let _ = self.go.recv();
        panic!("injected backend panic (serve_shard shutdown test)");
    }
}

fn panic_when_told_coordinator() -> (Coordinator, std::sync::mpsc::Sender<()>) {
    let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
    let go_rx = std::sync::Mutex::new(Some(go_rx));
    let factory = Arc::new(ClosureFactory {
        spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false },
        f: move || {
            let go = go_rx.lock().unwrap().take().expect("single worker builds once");
            Ok(Box::new(PanicWhenTold { go }) as Box<dyn Backend>)
        },
    });
    let coord = Coordinator::start(
        factory,
        CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    );
    (coord, go_tx)
}

#[test]
fn shutdown_fails_queued_unserved_tickets_explicitly() {
    let _serial = serial();
    // Regression (ISSUE 5 satellite): a queued-but-unserved request's
    // `Ticket::wait` used to hang until shutdown (or forever) when its
    // worker died. It must now be failed with an explicit error — at
    // worker death (the guard's drain) or, as the backstop, in
    // `shutdown` — never left hanging.
    let (mut coord, go_tx) = panic_when_told_coordinator();
    // A is picked up by the worker, which then blocks inside run_batch.
    let t_a = coord.submit(vec![0.1, 0.2]).unwrap();
    // B queues behind it; wait until the batcher has formed both
    // batches (B's lands in the shard deque the worker will never
    // drain), then let the worker die.
    let t_b = coord.submit(vec![0.3, 0.4]).unwrap();
    while coord.stats().batches.load(Ordering::Relaxed) < 2 {
        std::thread::sleep(Duration::from_millis(1));
    }
    std::thread::sleep(Duration::from_millis(10));
    go_tx.send(()).unwrap();
    coord.shutdown();

    // B was queued but never served: explicit, prompt error — the
    // dying worker's drain ("no live workers") or the shutdown sweep
    // ("shut down before served"), depending on who got there first.
    let err_b = t_b.wait().unwrap_err();
    let msg = err_b.to_string();
    assert!(
        msg.contains("no live workers") || msg.contains("shut down before the request"),
        "want an explicit unserved-at-teardown error, got: {err_b}"
    );
    // A was in flight when the worker panicked: answered with an error
    // (the Job drop guard), never a hang and never a success.
    assert!(t_a.wait().is_err());
    // Either way, nothing submits anymore.
    assert!(coord.submit(vec![0.0, 0.0]).is_err());
}

#[test]
fn callbacks_fire_even_when_the_worker_panics() {
    let _serial = serial();
    // The exactly-once contract for the callback surface on the
    // worker-death path: the callback must still be invoked (with an
    // error), not silently dropped with the unwound batch.
    let (coord, go_tx) = panic_when_told_coordinator();
    let (cb_tx, cb_rx) = std::sync::mpsc::channel();
    coord
        .submit_callback(vec![0.1, 0.2], move |reply| {
            let _ = cb_tx.send(reply);
        })
        .unwrap();
    go_tx.send(()).unwrap();
    let reply = cb_rx.recv_timeout(Duration::from_secs(10)).expect("callback never fired");
    assert!(reply.is_err(), "a panicked batch cannot produce a success reply");
}

#[test]
fn submitting_after_worker_death_still_answers() {
    let _serial = serial();
    // With every worker dead, newly accepted requests must be answered
    // by the batcher's no-live-workers route instead of queueing
    // forever.
    let (coord, go_tx) = panic_when_told_coordinator();
    // Kill the only worker and wait until its demise is observable
    // (the in-flight reply drops during the unwind; the liveness
    // counter decrements moments later).
    let t_killer = coord.submit(vec![0.5, 0.5]).unwrap();
    go_tx.send(()).unwrap();
    assert!(t_killer.wait().is_err());
    std::thread::sleep(Duration::from_millis(50));
    // Enough submissions to exceed the batch-queue bound — none may
    // hang, whether they are failed by the push path or the drain.
    let tickets: Vec<_> =
        (0..8).filter_map(|_| coord.submit(vec![1.0, 1.0]).ok()).collect();
    assert!(!tickets.is_empty());
    for t in tickets {
        let err = t.wait_timeout(Duration::from_secs(10)).unwrap_err();
        assert!(
            !err.to_string().contains("timed out"),
            "request hung instead of failing fast: {err}"
        );
    }
}

#[test]
fn batcher_panic_still_closes_the_shard_queues() {
    let _serial = serial();
    // Regression (ISSUE 10 audit): a batcher that panicked mid-batch
    // never counted itself out of `batchers_alive`, so the last-out
    // `ShardQueues::close` never fired — workers blocked on `work_cv`
    // forever and `shutdown` hung joining them. The `BatcherGuard`
    // drop guard closes the queues on the unwind path too.
    rfdot::faults::install_spec("coord.batch_form=panic").expect("arm the batcher panic");
    let map = sample_map(4, 8, 21);
    let coord = Coordinator::start(
        Arc::new(NativeFactory::new(map)),
        CoordinatorConfig {
            workers: 1,
            shards: 1,
            max_batch: 2,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    );
    let t = coord.submit(vec![0.1; 4]).unwrap();
    // The formed batch is answered by `Job::drop` during the unwind —
    // exactly once, as an error, never a hang.
    assert!(t.wait().is_err(), "a panicked batch cannot produce a success reply");
    rfdot::faults::clear();
    // The hang regression: teardown must complete promptly.
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        drop(coord); // Drop runs shutdown: close lanes, join threads.
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("shutdown hung after a batcher panic (shard queues never closed)");
}

#[test]
fn submissions_survive_a_dead_batcher_lane() {
    let _serial = serial();
    // Regression (ISSUE 10 audit): `enqueue` reported "coordinator is
    // shut down" on the FIRST disconnected lane it scanned, so one
    // dead batcher failed roughly half of all submissions while the
    // other lane was perfectly healthy. A dead lane must be skipped
    // like a full one; only all-lanes-dead means shut down.
    let map = sample_map(4, 8, 22);
    rfdot::faults::install_spec("coord.batch_form=panic").expect("arm the batcher panic");
    let coord = Coordinator::start(
        Arc::new(NativeFactory::new(map.clone())),
        CoordinatorConfig {
            workers: 2,
            shards: 2, // two ingress lanes, one batcher each
            max_batch: 2,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    );
    // Kill exactly one batcher: the single submitted job lands on one
    // lane, whose batcher panics forming the batch; disarm before the
    // other lane ever sees a job.
    let killer = coord.submit(vec![0.9; 4]).unwrap();
    assert!(killer.wait().is_err(), "the sacrificial job dies with its batcher");
    rfdot::faults::clear();
    // Give the panicked batcher time to finish unwinding (its lane
    // receiver drops at the end of the unwind).
    std::thread::sleep(Duration::from_millis(50));
    // Every submission must now route around the dead lane — before
    // the fix, the round-robin scan failed whenever it started there.
    for i in 0..8 {
        let x = vec![0.1 * (i as f32 + 1.0); 4];
        let t = coord
            .submit(x.clone())
            .unwrap_or_else(|e| panic!("submission {i} failed around the dead lane: {e}"));
        assert_eq!(
            t.wait().unwrap(),
            map.transform(&x),
            "submission {i}: the surviving lane must serve exact replies"
        );
    }
    // Teardown still completes with one batcher already gone.
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        drop(coord);
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("shutdown hung with a dead batcher lane");
}
