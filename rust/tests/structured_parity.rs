//! Structured-vs-dense parity: statistical correctness of the FWHT
//! projection subsystem against the exact kernel Gram (the Figure-1
//! error machinery), and the end-to-end `--projection structured`
//! chain: config → sampling → serving via the coordinator's native
//! backend → serialize/deserialize bit-identity.

use rfdot::config::ExperimentConfig;
use rfdot::coordinator::{Coordinator, CoordinatorConfig, NativeFactory};
use rfdot::features::{feature_gram, FeatureMap};
use rfdot::kernels::{gram, mean_abs_gram_error, Polynomial};
use rfdot::linalg::Matrix;
use rfdot::maclaurin::{serialize, RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;
use rfdot::simd::{self, SimdMode, SimdPath};
use rfdot::structured::ProjectionKind;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// One test in this binary flips the process-global kernel dispatch
/// mode, and every other test's bit-identity assertions implicitly
/// assume the mode holds still while they run. All tests here
/// serialize on this lock so the harness's default test parallelism
/// can never interleave a mode flip with a parity check.
static DISPATCH: Mutex<()> = Mutex::new(());

fn dispatch_lock() -> MutexGuard<'static, ()> {
    DISPATCH.lock().unwrap_or_else(PoisonError::into_inner)
}

fn unit_points(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from(seed);
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            rfdot::linalg::normalize(&mut v);
            v
        })
        .collect();
    Matrix::from_rows(&rows).unwrap()
}

/// Mean Gram error at feature count `dd`, averaged over 3 maps.
fn err_at(kind: ProjectionKind, dd: usize, x: &Matrix, exact: &Matrix, rng: &mut Rng) -> f64 {
    let kernel = Polynomial::new(3, 1.0);
    (0..3)
        .map(|_| {
            let map = RandomMaclaurin::sample(
                &kernel,
                x.cols(),
                dd,
                RmConfig::default().with_projection(kind),
                rng,
            );
            mean_abs_gram_error(exact, &feature_gram(&map, x))
        })
        .sum::<f64>()
        / 3.0
}

/// Both projection kinds concentrate toward the exact Gram at the same
/// 1/sqrt(D) rate (the Figure-1 assertion, applied per kind), and at
/// matched D their errors sit in the same envelope: structured pays at
/// most a small constant factor for its intra-block correlations.
#[test]
fn gram_errors_share_the_figure1_envelope() {
    let _dispatch = dispatch_lock();
    let d = 16;
    let x = unit_points(30, d, 1);
    let exact = gram(&Polynomial::new(3, 1.0), &x);
    let mut rng = Rng::seed_from(2);

    let dense_small = err_at(ProjectionKind::Dense, 32, &x, &exact, &mut rng);
    let dense_big = err_at(ProjectionKind::Dense, 512, &x, &exact, &mut rng);
    let structured_small = err_at(ProjectionKind::Structured, 32, &x, &exact, &mut rng);
    let structured_big = err_at(ProjectionKind::Structured, 512, &x, &exact, &mut rng);

    // Same decay assertion the dense Figure-1 test makes (16x features
    // should cut the error well past 2x), for each kind.
    assert!(dense_big < dense_small / 2.0, "dense: {dense_small} -> {dense_big}");
    assert!(
        structured_big < structured_small / 2.0,
        "structured: {structured_small} -> {structured_big}"
    );
    // Matched-D envelope: within a small constant factor of each other,
    // both ways (the small absolute slack covers the ~0.1-scale errors
    // these shapes produce).
    assert!(
        structured_big < 3.0 * dense_big + 0.02,
        "structured err {structured_big} far above dense {dense_big}"
    );
    assert!(
        dense_big < 3.0 * structured_big + 0.02,
        "dense err {dense_big} far above structured {structured_big}"
    );
}

/// The full `--projection structured` chain: a config-parsed projection
/// kind drives sampling; the sampled map serves through the
/// coordinator's `NativeBackend` bit-identically to direct transforms;
/// and the serialized record reconstructs the identical map.
#[test]
fn structured_end_to_end_config_serve_serialize() {
    let _dispatch = dispatch_lock();
    // config → sampling
    let cfg = ExperimentConfig::from_json(
        r#"{"projection": "structured", "n_features": 64, "kernel": {"kind": "exponential", "sigma2": 1.0}}"#,
    )
    .unwrap();
    assert_eq!(cfg.projection, ProjectionKind::Structured);
    let d = 10usize;
    let kernel = cfg.kernel.build(1.0);
    let mut rng = Rng::seed_from(cfg.seed);
    let map = Arc::new(RandomMaclaurin::sample(
        kernel.as_ref(),
        d,
        cfg.n_features,
        RmConfig::default().with_projection(cfg.projection),
        &mut rng,
    ));
    assert!(map.is_structured());

    // serve via Coordinator/NativeBackend
    let coord = Coordinator::start(
        Arc::new(NativeFactory::new(map.clone())),
        CoordinatorConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
            workers: 2,
            intra_op_threads: 1,
            ..Default::default()
        },
    );
    let mut client_rng = Rng::seed_from(99);
    for _ in 0..32 {
        let x: Vec<f32> = (0..d).map(|_| client_rng.f32() - 0.5).collect();
        let served = coord.submit(x.clone()).unwrap().wait().unwrap();
        assert_eq!(served, map.transform(&x), "served features must be bit-identical");
    }

    // serialize → deserialize → transform, bit-identical (file path)
    let dir = std::env::temp_dir().join("rfdot_structured_parity");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("map.rfdm");
    serialize::save(&map, &path).unwrap();
    let map2 = serialize::load(&path).unwrap();
    assert!(map2.is_structured());
    let batch = unit_points(7, d, 3);
    let z1 = map.transform_batch(&batch);
    let z2 = map2.transform_batch(&batch);
    assert_eq!(z1, z2, "roundtripped structured map must transform bit-identically");
    // ... and thread counts never change the result.
    for threads in [2usize, 4, 16] {
        assert_eq!(map2.transform_batch_threads(&batch, threads), z1);
    }
    std::fs::remove_file(&path).ok();
}

/// Forcing the scalar oracle (`--simd scalar` / `RFDOT_SIMD=scalar`)
/// end to end is statistically indistinguishable from auto dispatch:
/// with the map-sampling RNG reseeded identically, the two runs build
/// the same maps and transform the same points, so their mean Gram
/// errors may differ only by per-kernel rounding (reassociated FMA
/// dots, polynomial vs libm cosine) — parts in 1e-6, far inside the
/// 1e-4 envelope asserted here. On a host with no vector path the two
/// runs are the same code and the difference is exactly zero.
#[test]
fn forced_scalar_matches_auto_dispatch_end_to_end() {
    let _dispatch = dispatch_lock();
    // Restore auto dispatch even if an assertion below panics, so a
    // failure here can never leak a forced-scalar mode into later
    // tests in this binary.
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            simd::set_mode(SimdMode::Auto);
        }
    }
    let _restore = Restore;

    let d = 16;
    let x = unit_points(30, d, 21);
    let exact = gram(&Polynomial::new(3, 1.0), &x);

    simd::set_mode(SimdMode::Auto);
    let auto_path = simd::selected();
    let mut rng = Rng::seed_from(5);
    let auto_dense = err_at(ProjectionKind::Dense, 256, &x, &exact, &mut rng);
    let mut rng = Rng::seed_from(6);
    let auto_structured = err_at(ProjectionKind::Structured, 256, &x, &exact, &mut rng);

    simd::set_mode(SimdMode::Scalar);
    assert_eq!(simd::selected(), SimdPath::Scalar);
    let mut rng = Rng::seed_from(5);
    let scalar_dense = err_at(ProjectionKind::Dense, 256, &x, &exact, &mut rng);
    let mut rng = Rng::seed_from(6);
    let scalar_structured = err_at(ProjectionKind::Structured, 256, &x, &exact, &mut rng);

    assert!(
        (auto_dense - scalar_dense).abs() < 1e-4,
        "dense: auto ({auto_path:?}) err {auto_dense} vs forced-scalar err {scalar_dense}"
    );
    assert!(
        (auto_structured - scalar_structured).abs() < 1e-4,
        "structured: auto ({auto_path:?}) err {auto_structured} vs forced-scalar err {scalar_structured}"
    );
}

/// Structured H0/1 maps keep their exact prefix and their random block
/// riding the FWHT path end to end.
#[test]
fn structured_h01_prefix_stays_exact() {
    let _dispatch = dispatch_lock();
    let kernel = Polynomial::new(10, 1.0);
    let d = 6;
    let mut rng = Rng::seed_from(7);
    let map = RandomMaclaurin::sample(
        &kernel,
        d,
        32,
        RmConfig::default().with_h01(true).with_projection(ProjectionKind::Structured),
        &mut rng,
    );
    let x = unit_points(1, d, 8);
    let z = map.transform(x.row(0));
    assert_eq!(z.len(), 1 + d + 32);
    // a_0 = 1, a_1 = 10 for (1 + t)^10.
    assert!((z[0] - 1.0).abs() < 1e-6);
    for j in 0..d {
        assert!((z[1 + j] - (10.0f32).sqrt() * x.row(0)[j]).abs() < 1e-5);
    }
}
