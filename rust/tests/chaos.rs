//! Chaos suite for the serving stack (ISSUE 10 tentpole): every fault
//! site from [`rfdot::faults::SITES`] is swept against a live loopback
//! server under a seeded fault plan, asserting the survival contract:
//!
//! * no panic ever escapes to the test (drop guards everywhere),
//! * every request gets **exactly one** answer — a reply or an error,
//! * every successful reply is **bitwise-equal** to the offline oracle
//!   (`serving.map().transform`), during the storm and after it,
//! * artifact resident bytes return to baseline after teardown,
//! * the same seed replays the identical client-visible schedule.
//!
//! Plus the deadline / load-shed / drain / client-timeout semantics
//! that make the survival story usable from the client side.
//!
//! Fault plans are process-global, so every test here serializes on
//! one mutex and clears the plan on exit (panic-safe via `ChaosGuard`).

use rfdot::artifact::MapArtifact;
use rfdot::coordinator::CoordinatorConfig;
use rfdot::kernels::Exponential;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::net::{ClientConfig, NetClient, NetConfig, NetServer, Registry};
use rfdot::rng::Rng;
use std::collections::BTreeSet;
use std::net::TcpListener;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

static SERIAL: Mutex<()> = Mutex::new(());

/// Serializes the tests (fault plans and obs counters are global) and
/// guarantees the plan is disarmed however the test exits.
struct ChaosGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        rfdot::faults::clear();
    }
}

fn chaos() -> ChaosGuard {
    let g = SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
    rfdot::faults::clear();
    ChaosGuard(g)
}

fn artifact(seed: u64, d: usize, feats: usize) -> Arc<MapArtifact> {
    let mut rng = Rng::seed_from(seed);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        feats,
        RmConfig::default().with_max_order(6),
        &mut rng,
    );
    Arc::new(MapArtifact::from_map(&map).expect("encode artifact"))
}

fn coord_config(workers: usize, max_wait: Duration) -> CoordinatorConfig {
    CoordinatorConfig { workers, max_batch: 64, max_wait, ..CoordinatorConfig::default() }
}

fn bitwise_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

const D: usize = 6;
const FEATS: usize = 16;
const REQS: usize = 12;

/// One storm: arm `site=error:0.5`, drive client traffic (reconnecting
/// on failure, the way the `net-client` CLI loop does), interleave the
/// admin paths (hot-swap, artifact file load) so the non-request sites
/// get real hits, then disarm and prove the world is intact. Returns
/// how many requests succeeded (their replies were oracle-checked).
fn run_site_storm(site: &str) -> usize {
    // Everything that decodes an RFDM container is built *before* the
    // plan goes in: the storm must hit serving, not test setup.
    let art = artifact(31, D, FEATS);
    let art2 = artifact(32, D, FEATS);
    let oracle = art.instantiate().expect("instantiate oracle");
    let tmp = std::env::temp_dir().join(format!("rfdot-chaos-{}-{site}.rfdm", std::process::id()));
    art.save(&tmp).expect("write tmp artifact");

    let registry = Arc::new(Registry::new(coord_config(2, Duration::from_micros(200))));
    registry.insert("chaos", art.clone()).expect("insert primary model");
    registry.insert("swapme", art2.clone()).expect("insert swap target");

    rfdot::faults::install_spec(&format!("seed=11,{site}=error:0.5")).expect("install plan");
    let server = NetServer::start(registry.clone(), NetConfig::default()).expect("start server");
    let addr = server.local_addr();
    let cfg = || ClientConfig::default().with_timeout(Duration::from_secs(10)).with_retries(3);

    let mut client = NetClient::connect_with(addr, cfg()).ok();
    let mut ok = 0usize;
    for i in 0..REQS {
        // Admin chaos rides along mid-storm: a hot-swap (registry.swap
        // / drain / retire hits) and a file load (artifact.load /
        // artifact.read / rfdm.decode hits). Failures are the point;
        // the live version and the request path must shrug them off.
        if i == 4 {
            let _ = registry.insert("swapme", art2.clone());
        }
        if i == 8 {
            let _ = MapArtifact::load(&tmp);
        }
        let mut rng = Rng::seed_from(1000 + i as u64);
        let x: Vec<f32> = (0..D).map(|_| rng.f32() - 0.5).collect();
        if client.is_none() {
            client = NetClient::connect_with(addr, cfg()).ok();
        }
        let c = match client.as_mut() {
            Some(c) => c,
            None => continue,
        };
        match c.transform("chaos", &x) {
            Ok(y) => {
                assert!(
                    bitwise_eq(&y, &oracle.transform(&x)),
                    "site {site}: a reply that survived the storm must be bitwise-exact"
                );
                ok += 1;
            }
            // Injected server errors and dead connections both land
            // here; a fresh connection is the client's recovery move.
            Err(_) => client = None,
        }
    }

    // Disarm and prove full recovery on a fresh connection.
    rfdot::faults::clear();
    let mut fresh = NetClient::connect(addr, Duration::from_secs(10))
        .unwrap_or_else(|e| panic!("site {site}: post-storm connect failed: {e}"));
    let x = vec![0.25; D];
    let y = fresh
        .transform("chaos", &x)
        .unwrap_or_else(|e| panic!("site {site}: post-storm request failed: {e}"));
    assert!(
        bitwise_eq(&y, &oracle.transform(&x)),
        "site {site}: post-storm replies must be bitwise-equal to the no-fault oracle"
    );

    drop(fresh);
    drop(client);
    let mut server = server;
    server.shutdown();
    registry.shutdown();
    let _ = std::fs::remove_file(&tmp);
    ok
}

#[test]
fn chaos_sweep_every_fault_site() {
    let _g = chaos();
    let baseline = rfdot::artifact::resident_bytes();
    let injected_before = rfdot::obs::counter("faults.injected").get();
    let mut total_ok = 0usize;
    for site in rfdot::faults::SITES {
        total_ok += run_site_storm(site);
        assert_eq!(
            rfdot::artifact::resident_bytes(),
            baseline,
            "site {site}: teardown must release every artifact weight region"
        );
    }
    assert!(
        rfdot::obs::counter("faults.injected").get() > injected_before,
        "the sweep must actually inject faults (counter never moved)"
    );
    assert!(total_ok > 0, "some requests must survive the storms");
}

#[test]
fn same_seed_replays_the_same_client_visible_schedule() {
    let _g = chaos();
    // One sequential client, one reply write per request: the net.write
    // hit ordinals are exactly the request sequence, so the ok/err
    // pattern the client sees is a pure function of the seed.
    let run = || -> Vec<bool> {
        let art = artifact(41, 5, 8);
        let registry = Arc::new(Registry::new(coord_config(1, Duration::from_micros(200))));
        registry.insert("replay", art).expect("insert model");
        rfdot::faults::install_spec("seed=3,net.write=error:0.5").expect("install plan");
        let mut server =
            NetServer::start(registry.clone(), NetConfig::default()).expect("start server");
        let addr = server.local_addr();
        let mut client = NetClient::connect(addr, Duration::from_secs(10)).ok();
        let mut pattern = Vec::with_capacity(20);
        for _ in 0..20 {
            if client.is_none() {
                client = Some(
                    NetClient::connect(addr, Duration::from_secs(10))
                        .expect("reconnect (accept path is not under fault)"),
                );
            }
            let c = client.as_mut().unwrap();
            match c.transform("replay", &vec![0.5; 5]) {
                Ok(_) => pattern.push(true),
                Err(_) => {
                    pattern.push(false);
                    client = None; // the injected write killed the conn
                }
            }
        }
        rfdot::faults::clear();
        drop(client);
        server.shutdown();
        registry.shutdown();
        pattern
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed + same spec must replay the identical schedule");
    assert!(a.contains(&false), "p=0.5 over 20 writes must kill some (seeded, so stable)");
    assert!(a.contains(&true), "p=0.5 over 20 writes must spare some (seeded, so stable)");
}

#[test]
fn corrupted_wire_frames_surface_as_errors_never_panics_or_hangs() {
    let _g = chaos();
    let art = artifact(51, D, FEATS);
    let oracle = art.instantiate().expect("instantiate oracle");
    let registry = Arc::new(Registry::new(coord_config(1, Duration::from_micros(200))));
    registry.insert("wire", art).expect("insert model");
    rfdot::faults::install_spec("seed=13,net.write=corrupt").expect("install plan");
    let mut server = NetServer::start(registry.clone(), NetConfig::default()).expect("start");
    let addr = server.local_addr();
    // A corrupted length field desynchronizes the stream; the short
    // client timeout bounds how long that costs before the reconnect.
    let cfg = || ClientConfig::default().with_timeout(Duration::from_millis(500));
    let t0 = Instant::now();
    let mut client = NetClient::connect_with(addr, cfg()).ok();
    for _ in 0..8 {
        if client.is_none() {
            client = NetClient::connect_with(addr, cfg()).ok();
        }
        let c = match client.as_mut() {
            Some(c) => c,
            None => continue,
        };
        // Every outbound frame has one flipped byte: the client must
        // come back with *something* — a decode error, a framing error,
        // a timeout, or (when the flip landed in the payload floats) a
        // reply — without panicking or hanging.
        if c.transform("wire", &vec![0.125; D]).is_err() {
            client = None;
        }
    }
    assert!(t0.elapsed() < Duration::from_secs(30), "corruption must never hang the client");
    rfdot::faults::clear();
    let mut fresh = NetClient::connect(addr, Duration::from_secs(10)).expect("reconnect");
    let x = vec![0.375; D];
    let y = fresh.transform("wire", &x).expect("clean request after the storm");
    assert!(bitwise_eq(&y, &oracle.transform(&x)), "post-storm parity");
    drop(fresh);
    server.shutdown();
    registry.shutdown();
}

#[test]
fn silent_server_times_out_instead_of_hanging_the_client() {
    let _g = chaos();
    // ISSUE 10 satellite: a server that accepts and then never writes a
    // byte. Before unconditional socket deadlines the client hung in
    // read_exact forever; now it errors within the configured timeout.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let hold = thread::spawn(move || {
        let _conn = listener.accept().expect("accept");
        let _ = done_rx.recv(); // keep the socket open, silently
    });
    let mut client = NetClient::connect_with(
        addr,
        ClientConfig::default().with_timeout(Duration::from_millis(200)),
    )
    .expect("connect");
    let t0 = Instant::now();
    let err = client.ping().expect_err("a silent server must be an error, not a hang");
    assert!(t0.elapsed() < Duration::from_secs(5), "timeout must bound the wait");
    assert!(
        err.to_string().contains("read frame header"),
        "the error must name the stalled read, got: {err}"
    );
    let _ = done_tx.send(());
    let _ = hold.join();
}

#[test]
fn saturation_sheds_retryably_with_exactly_one_answer_per_request() {
    let _g = chaos();
    let shed_before = rfdot::obs::counter("net.shed").get();
    let art = artifact(61, 4, 64);
    let oracle = art.instantiate().expect("instantiate oracle");
    // One worker with a long coalescing window: the first admitted
    // request holds in-flight ≥ 1 for ~60ms while the rest of the
    // burst arrives and must shed.
    let registry = Arc::new(Registry::new(coord_config(1, Duration::from_millis(60))));
    registry.insert("shed", art).expect("insert model");
    let mut server = NetServer::start(
        registry.clone(),
        NetConfig { shed_inflight: 1, ..NetConfig::default() },
    )
    .expect("start server");
    let mut client =
        NetClient::connect(server.local_addr(), Duration::from_secs(10)).expect("connect");

    const BURST: usize = 6;
    let x = vec![0.25; 4];
    let ids: Vec<u64> =
        (0..BURST).map(|_| client.send_dense("shed", x.clone()).expect("send")).collect();
    let mut answered = BTreeSet::new();
    let mut replies = 0usize;
    let mut sheds = 0usize;
    for _ in 0..BURST {
        match client.recv_outcome().expect("transport must stay healthy") {
            Ok((req_id, values)) => {
                assert!(answered.insert(req_id), "duplicate reply for {req_id}");
                assert!(bitwise_eq(&values, &oracle.transform(&x)), "shed-survivor parity");
                replies += 1;
            }
            Err(e) => {
                assert!(answered.insert(e.req_id), "duplicate answer for {}", e.req_id);
                assert!(e.retryable, "shed frames must be retryable: {}", e.message);
                assert!(e.message.contains("load shed"), "{}", e.message);
                sheds += 1;
            }
        }
    }
    assert_eq!(answered, ids.into_iter().collect::<BTreeSet<_>>(), "exactly-once accounting");
    assert!(replies >= 1, "the admitted request must still be answered");
    assert!(sheds >= 1, "the burst beyond the in-flight limit must shed");
    assert!(rfdot::obs::counter("net.shed").get() - shed_before >= sheds as u64);

    // The burst has drained, so a synchronous retrying client gets a
    // real answer even against a shedding server.
    let mut retrier = NetClient::connect_with(
        server.local_addr(),
        ClientConfig::default().with_timeout(Duration::from_secs(10)).with_retries(5),
    )
    .expect("connect retrier");
    let y = retrier.transform("shed", &x).expect("retry must eventually get through");
    assert!(bitwise_eq(&y, &oracle.transform(&x)));
    drop(retrier);
    drop(client);
    server.shutdown();
    registry.shutdown();
}

#[test]
fn late_replies_downgrade_to_retryable_deadline_errors() {
    let _g = chaos();
    let exceeded_before = rfdot::obs::counter("net.deadline_exceeded").get();
    let art = artifact(71, 4, 8);
    // The 50ms coalescing window guarantees every answer misses a 1ms
    // deadline.
    let registry = Arc::new(Registry::new(coord_config(1, Duration::from_millis(50))));
    registry.insert("late", art).expect("insert model");
    let mut server = NetServer::start(
        registry.clone(),
        NetConfig { request_deadline: Duration::from_millis(1), ..NetConfig::default() },
    )
    .expect("start server");
    let mut client =
        NetClient::connect(server.local_addr(), Duration::from_secs(10)).expect("connect");
    let id = client.send_dense("late", vec![0.5; 4]).expect("send");
    match client.recv_outcome().expect("transport must stay healthy") {
        Ok((req_id, _)) => panic!("request {req_id} must have missed the 1ms deadline"),
        Err(e) => {
            assert_eq!(e.req_id, id, "exactly one frame, for the right request");
            assert!(e.retryable, "deadline overruns must be retryable");
            assert!(e.message.contains("deadline exceeded"), "{}", e.message);
        }
    }
    assert!(rfdot::obs::counter("net.deadline_exceeded").get() > exceeded_before);

    // A retrying client exhausts its budget — every answer is late —
    // and surfaces the deadline error instead of succeeding spuriously.
    let mut retrier = NetClient::connect_with(
        server.local_addr(),
        ClientConfig::default().with_timeout(Duration::from_secs(10)).with_retries(2),
    )
    .expect("connect retrier");
    let err = retrier.transform("late", &vec![0.5; 4]).expect_err("every answer is late");
    assert!(err.to_string().contains("deadline exceeded"), "{err}");
    drop(retrier);
    drop(client);
    server.shutdown();
    registry.shutdown();
}

#[test]
fn shutdown_drains_in_flight_replies_before_closing_sockets() {
    let _g = chaos();
    let forced_before = rfdot::obs::counter("net.drain_forced").get();
    let art = artifact(81, 4, 8);
    let oracle = art.instantiate().expect("instantiate oracle");
    // The 80ms window keeps the request in flight when shutdown lands.
    let registry = Arc::new(Registry::new(coord_config(1, Duration::from_millis(80))));
    registry.insert("drain", art).expect("insert model");
    let mut server = NetServer::start(registry.clone(), NetConfig::default()).expect("start");
    let mut client =
        NetClient::connect(server.local_addr(), Duration::from_secs(10)).expect("connect");
    let x = vec![0.75; 4];
    let id = client.send_dense("drain", x.clone()).expect("send");
    thread::sleep(Duration::from_millis(20)); // let admission happen
    server.shutdown(); // phase 1 closes read halves; the reply must still flush
    let (req_id, values) =
        client.recv_reply().expect("the in-flight reply must reach the wire during drain");
    assert_eq!(req_id, id);
    assert!(bitwise_eq(&values, &oracle.transform(&x)), "drained reply parity");
    assert_eq!(
        rfdot::obs::counter("net.drain_forced").get(),
        forced_before,
        "a clean drain must not force-close any socket"
    );
    drop(client);
    registry.shutdown();
}
