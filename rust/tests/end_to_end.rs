//! End-to-end integration tests over the public API: every layer that
//! does not require PJRT artifacts (those live in pjrt_roundtrip.rs).

use rfdot::config::{ExperimentConfig, KernelSpec};
use rfdot::data::{libsvm, Dataset, UciSurrogate};
use rfdot::kernels::{DotProductKernel, Exponential, Polynomial, VovkReal};
use rfdot::linalg::Matrix;
use rfdot::features::FeatureMap;
use rfdot::maclaurin::{serialize, CompositionalMaclaurin, RandomMaclaurin, RmConfig};
use rfdot::rff::RffScalarFactory;
use rfdot::rng::Rng;
use rfdot::svm::{Classifier, LinearSvm, LinearSvmParams};

/// The headline pipeline on every kernel family: surrogate data →
/// random features → linear SVM → sane accuracy.
#[test]
fn pipeline_works_for_every_kernel_family() {
    let kernels: Vec<KernelSpec> = vec![
        KernelSpec::Polynomial { degree: 10, offset: 1.0 },
        KernelSpec::Homogeneous { degree: 3 },
        KernelSpec::Exponential { sigma2: 0.0 },
        KernelSpec::VovkReal { degree: 5 },
        KernelSpec::VovkInfinite { scale: 4.0 },
    ];
    for kernel in kernels {
        let config = ExperimentConfig {
            dataset: "nursery".into(),
            scale: 0.03,
            kernel: kernel.clone(),
            n_features: 200,
            seed: 9,
            ..Default::default()
        };
        let prep = rfdot::bench::experiment::prepare(&config).unwrap();
        let cell = rfdot::bench::experiment::run_random_features(&prep, 200, false, 0);
        assert!(
            cell.accuracy > 0.7,
            "{kernel:?}: accuracy {} too low",
            cell.accuracy
        );
    }
}

/// §4.2 truncated maps: truncation + sampling behaves like the exact
/// kernel up to the tail bound + sampling noise.
#[test]
fn truncated_map_pipeline() {
    let kernel = Exponential::new(1.0);
    let mut rng = Rng::seed_from(21);
    let (map, truncation) =
        RandomMaclaurin::truncated(&kernel, 1.0, 1e-3, 6, 2048, RmConfig::default(), &mut rng);
    assert!(truncation.order >= 2);
    assert!(!truncation.saturated);
    // Approximation check at a few points.
    for s in 0..5 {
        let x = rfdot::prop::gens::unit_vec(&mut Rng::seed_from(100 + s), 6);
        let y = rfdot::prop::gens::unit_vec(&mut Rng::seed_from(200 + s), 6);
        let exact = kernel.eval(&x, &y);
        let approx =
            rfdot::linalg::dot(&map.transform(&x), &map.transform(&y)) as f64;
        assert!(
            (exact - approx).abs() < 0.25,
            "truncated map too far: {exact} vs {approx}"
        );
    }
}

/// Map serialization round-trips through disk inside a full experiment.
#[test]
fn serialized_map_is_identical_engine() {
    let kernel = Polynomial::new(5, 0.5);
    let mut rng = Rng::seed_from(33);
    let map = RandomMaclaurin::sample(&kernel, 12, 128, RmConfig::default(), &mut rng);
    let dir = std::env::temp_dir().join("rfdot_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("map.rfdm");
    serialize::save(&map, &path).unwrap();
    let map2 = serialize::load(&path).unwrap();
    let x = rfdot::prop::gens::unit_vec(&mut rng, 12);
    assert_eq!(map.transform(&x), map2.transform(&x));
    std::fs::remove_file(path).ok();
}

/// LIBSVM-format data flows through the whole feature + learn pipeline.
#[test]
fn libsvm_roundtrip_pipeline() {
    // Build a small xor-ish dataset, export, re-import, learn on
    // quadratic RM features.
    let mut rng = Rng::seed_from(4);
    let mut rows = Vec::new();
    let mut y = Vec::new();
    for _ in 0..400 {
        let a = rng.f32() * 2.0 - 1.0;
        let b = rng.f32() * 2.0 - 1.0;
        rows.push(vec![a, b]);
        y.push(if a * b >= 0.0 { 1.0 } else { -1.0 });
    }
    let ds = Dataset::new("xor", Matrix::from_rows(&rows).unwrap(), y).unwrap();
    let text = libsvm::to_string(&ds);
    let ds2 = libsvm::parse_str("xor", &text, Some(2)).unwrap();
    assert_eq!(ds.len(), ds2.len());

    let kernel = rfdot::kernels::Homogeneous::new(2);
    let map = RandomMaclaurin::sample(&kernel, 2, 128, RmConfig::default(), &mut rng);
    let z = map.transform_batch(ds2.x());
    let zds = Dataset::new("z", z, ds2.y.clone()).unwrap();
    let model = LinearSvm::train(&zds, LinearSvmParams::default()).unwrap();
    assert!(model.accuracy_on(&zds) > 0.9);
}

/// Compositional maps compose with the SVM pipeline (Algorithm 2 end to
/// end).
#[test]
fn compositional_pipeline() {
    let mut rng = Rng::seed_from(5);
    let d = 4;
    // Radial labels.
    let mut rows = Vec::new();
    let mut y = Vec::new();
    for i in 0..600 {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let target = if i % 2 == 0 { 0.4f32 } else { 0.9 };
        let n = rfdot::linalg::norm2(&v).max(1e-6);
        for vi in v.iter_mut() {
            *vi *= target / n;
        }
        rows.push(v);
        y.push(if target < 0.6 { 1.0 } else { -1.0 });
    }
    let ds = Dataset::new("rings", Matrix::from_rows(&rows).unwrap(), y).unwrap();
    let outer = Exponential::new(2.0);
    let map = CompositionalMaclaurin::sample(
        &outer,
        RffScalarFactory::new(1.0, d),
        256,
        RmConfig::default(),
        &mut rng,
    );
    let z = map.transform_batch(ds.x());
    let zds = Dataset::new("z", z, ds.y.clone()).unwrap();
    let model = LinearSvm::train(&zds, LinearSvmParams::default()).unwrap();
    assert!(model.accuracy_on(&zds) > 0.9, "acc {}", model.accuracy_on(&zds));
}

/// All six surrogates generate, split and train without panics at tiny
/// scale (smoke over the whole data substrate).
#[test]
fn all_surrogates_smoke() {
    for u in UciSurrogate::ALL {
        let ds = u.load(0.01, 1);
        assert!(ds.len() >= 200, "{:?} too small", u);
        let mut rng = Rng::seed_from(2);
        let (tr, te) = ds.split(0.6, 20_000, &mut rng);
        assert!(!tr.is_empty() && !te.is_empty());
        let model = LinearSvm::train(&tr, LinearSvmParams::default()).unwrap();
        // Labels are balanced; any trained model should beat 40%.
        assert!(model.accuracy_on(&te) > 0.4, "{:?}", u);
    }
}

/// VovkReal pipeline exercises a kernel with unit coefficients.
#[test]
fn vovk_real_gram_approximation() {
    let kernel = VovkReal::new(6);
    let mut rng = Rng::seed_from(8);
    let rows: Vec<Vec<f32>> =
        (0..30).map(|i| rfdot::prop::gens::unit_vec(&mut Rng::seed_from(i), 10)).collect();
    let x = Matrix::from_rows(&rows).unwrap();
    let exact = rfdot::kernels::gram(&kernel, &x);
    let map = RandomMaclaurin::sample(&kernel, 10, 4096, RmConfig::default(), &mut rng);
    let approx = rfdot::features::feature_gram(&map, &x);
    let err = rfdot::kernels::mean_abs_gram_error(&exact, &approx);
    assert!(err < 0.25, "gram err {err}");
}
