//! The artifact sharing contract, end to end:
//!
//! 1. **Owned/artifact parity** — for every feature-map family ×
//!    projection × storage combination, a map instantiated from its
//!    zero-copy [`rfdot::artifact::MapArtifact`] transforms bitwise
//!    identically to the owned map it was encoded from (dense rows,
//!    sparse rows, and batches).
//! 2. **Shared-state concurrency** — ≥ 4 coordinator workers serving
//!    through *one* `Arc<MapArtifact>` concurrently produce replies
//!    bitwise identical to the single-worker owned-map path.
//! 3. **Serialization closure** — `deserialize(serialize(m))` preserves
//!    transforms bit-for-bit for all three record kinds, including the
//!    recycled maps that only `RFDM0003` can carry.

use rfdot::artifact::MapArtifact;
use rfdot::coordinator::{Coordinator, CoordinatorConfig, MapArtifactFactory, NativeFactory};
use rfdot::features::FeatureMap;
use rfdot::kernels::{DotProductKernel, Exponential, Polynomial};
use rfdot::linalg::{Matrix, SparseRow};
use rfdot::maclaurin::{serialize, RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;
use rfdot::structured::ProjectionKind;
use std::sync::Arc;
use std::time::Duration;

const D_IN: usize = 19;
const D_OUT: usize = 48;

/// Every (kernel, projection, h01, recycle) cell of the map grid.
fn grid() -> Vec<(String, RandomMaclaurin)> {
    let kernels: [(&str, Box<dyn DotProductKernel>); 2] = [
        ("poly", Box::new(Polynomial::new(4, 0.5))),
        ("exp", Box::new(Exponential::new(1.0))),
    ];
    let mut maps = Vec::new();
    for (kname, kernel) in &kernels {
        for projection in [ProjectionKind::Dense, ProjectionKind::Structured] {
            for h01 in [false, true] {
                for recycle in [false, true] {
                    if recycle && projection == ProjectionKind::Dense {
                        continue; // recycling is a structured-pool knob
                    }
                    let mut rng = Rng::seed_from(0xA57 ^ (h01 as u64) << 3 ^ (recycle as u64));
                    let map = RandomMaclaurin::sample(
                        kernel.as_ref(),
                        D_IN,
                        D_OUT,
                        RmConfig::default()
                            .with_h01(h01)
                            .with_projection(projection)
                            .with_recycle(recycle),
                        &mut rng,
                    );
                    maps.push((
                        format!("{kname}/{projection:?}/h01={h01}/recycle={recycle}"),
                        map,
                    ));
                }
            }
        }
    }
    maps
}

fn probe(seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from(seed);
    (0..D_IN).map(|_| rng.f32() - 0.5).collect()
}

#[test]
fn artifact_backed_maps_match_owned_maps_bitwise_across_the_grid() {
    for (label, owned) in grid() {
        let art = MapArtifact::from_map(&owned).expect("encode artifact");
        let borrowed = art.instantiate().expect("instantiate artifact");

        // Dense rows.
        for seed in 0..8u64 {
            let x = probe(seed);
            assert_eq!(owned.transform(&x), borrowed.transform(&x), "dense row: {label}");
        }

        // Sparse rows (every other coordinate stored).
        let x = probe(99);
        let indices: Vec<u32> = (0..D_IN as u32).step_by(2).collect();
        let values: Vec<f32> = indices.iter().map(|&i| x[i as usize]).collect();
        let row = SparseRow { dim: D_IN, indices: &indices, values: &values };
        let mut a = vec![0.0f32; owned.output_dim()];
        let mut b = vec![0.0f32; borrowed.output_dim()];
        owned.transform_sparse_into(row, &mut a);
        borrowed.transform_sparse_into(row, &mut b);
        assert_eq!(a, b, "sparse row: {label}");

        // Batches.
        let rows: Vec<Vec<f32>> = (0..5).map(probe).collect();
        let mut batch = Matrix::zeros(rows.len(), D_IN);
        for (i, r) in rows.iter().enumerate() {
            batch.row_mut(i).copy_from_slice(r);
        }
        assert_eq!(
            owned.transform_batch(&batch),
            borrowed.transform_batch(&batch),
            "batch: {label}"
        );
    }
}

#[test]
fn serialize_roundtrip_is_bit_identical_for_every_record_kind() {
    for (label, map) in grid() {
        let reloaded = serialize::from_bytes(&serialize::to_bytes(&map))
            .unwrap_or_else(|e| panic!("roundtrip {label}: {e}"));
        for seed in 0..4u64 {
            let x = probe(seed);
            assert_eq!(map.transform(&x), reloaded.transform(&x), "roundtrip: {label}");
        }
    }
}

#[test]
fn four_workers_through_one_artifact_match_the_single_worker_owned_path() {
    let mut rng = Rng::seed_from(77);
    let owned = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        D_IN,
        64,
        RmConfig::default().with_projection(ProjectionKind::Structured),
        &mut rng,
    );
    let artifact = Arc::new(MapArtifact::from_map(&owned).expect("encode"));

    let requests: Vec<Vec<f32>> = (0..200).map(|i| probe(1000 + i as u64)).collect();

    // Reference: one worker over the owned map.
    let reference: Vec<Vec<f32>> = {
        let coord = Coordinator::start(
            Arc::new(NativeFactory::new(Arc::new(owned.clone()))),
            CoordinatorConfig {
                workers: 1,
                max_batch: 16,
                max_wait: Duration::from_micros(200),
                ..Default::default()
            },
        );
        requests
            .iter()
            .map(|x| coord.transform(x.clone()).expect("owned reply"))
            .collect()
    };

    // ≥ 4 workers, all borrowing one shared read-only artifact region,
    // hammered from 4 client threads concurrently.
    let coord = Arc::new(Coordinator::start(
        Arc::new(MapArtifactFactory::new(artifact.clone()).expect("factory")),
        CoordinatorConfig {
            workers: 4,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    ));
    let mut handles = Vec::new();
    for c in 0..4usize {
        let coord = coord.clone();
        let requests = requests.clone();
        handles.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            for (i, x) in requests.iter().enumerate() {
                if i % 4 == c {
                    got.push((i, coord.transform(x.clone()).expect("shared reply")));
                }
            }
            got
        }));
    }
    for h in handles {
        for (i, reply) in h.join().expect("client thread") {
            assert_eq!(
                reply, reference[i],
                "shared-artifact reply {i} must be bitwise identical to the owned path"
            );
        }
    }

    // Direct transform agrees too, and the factory really shares: the
    // region is still referenced by our handle plus the factory's map.
    for (x, want) in requests.iter().zip(&reference) {
        assert_eq!(&owned.transform(x), want);
    }
    assert!(Arc::strong_count(&artifact) >= 2, "factory must hold the same artifact");
}

#[test]
fn recycled_artifacts_are_smaller_and_still_exact() {
    let sample = |recycle: bool| {
        let mut rng = Rng::seed_from(31);
        RandomMaclaurin::sample(
            &Polynomial::new(4, 0.5),
            D_IN,
            64,
            RmConfig::default()
                .with_projection(ProjectionKind::Structured)
                .with_recycle(recycle),
            &mut rng,
        )
    };
    let plain = MapArtifact::from_map(&sample(false)).unwrap();
    let recycled = MapArtifact::from_map(&sample(true)).unwrap();
    assert!(
        recycled.total_bytes() < plain.total_bytes(),
        "recycling must shrink the container ({} vs {})",
        recycled.total_bytes(),
        plain.total_bytes()
    );
    let map = sample(true);
    let reloaded = recycled.instantiate().unwrap();
    for seed in 0..4u64 {
        let x = probe(seed);
        assert_eq!(map.transform(&x), reloaded.transform(&x));
    }
}
