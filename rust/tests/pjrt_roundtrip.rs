//! Cross-engine integration tests: the PJRT artifact path must agree
//! with the native Rust engine on identical sampled maps.
//!
//! Requires `make artifacts` to have populated `artifacts/` — tests
//! skip (with a loud message) if the artifacts are missing so plain
//! `cargo test` stays runnable before the python step.

use rfdot::coordinator::{
    Backend, Coordinator, CoordinatorConfig, PjrtScoreFactory, PjrtTransformBackend,
    PjrtTransformFactory,
};
use rfdot::kernels::Exponential;
use rfdot::linalg::Matrix;
use rfdot::features::FeatureMap;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;
use rfdot::runtime::{ArtifactMeta, Engine};
use rfdot::svm::{Classifier, LinearSvm, LinearSvmParams};
use std::sync::Arc;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn have_artifact(name: &str) -> bool {
    let ok = artifact_dir().join(format!("{name}.hlo.txt")).exists();
    if !ok {
        eprintln!("SKIP: artifact {name} missing — run `make artifacts`");
    }
    ok
}

/// Sample the map that matches an artifact's static shapes.
fn map_for(meta_name: &str, seed: u64) -> (RandomMaclaurin, usize, usize) {
    let meta = ArtifactMeta::parse(
        &std::fs::read_to_string(artifact_dir().join(format!("{meta_name}.json"))).unwrap(),
    )
    .unwrap();
    let d = meta.inputs[0].shape[1];
    let batch = meta.batch();
    let n_max = meta.inputs[1].shape[0] as u32;
    let features = meta.inputs[1].shape[2];
    let mut rng = Rng::seed_from(seed);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        features,
        RmConfig::default().with_max_order(n_max),
        &mut rng,
    );
    (map, batch, d)
}

fn random_batch(batch: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from(seed);
    let mut x = Matrix::zeros(batch, d);
    for i in 0..batch {
        for j in 0..d {
            x.set(i, j, rng.f32() - 0.5);
        }
        rfdot::linalg::normalize(x.row_mut(i));
    }
    x
}

#[test]
fn transform_artifact_matches_native_engine() {
    if !have_artifact("transform_quickstart") {
        return;
    }
    let (map, batch, d) = map_for("transform_quickstart", 11);
    let engine = Engine::cpu(artifact_dir()).unwrap();
    let loaded = engine.load("transform_quickstart").unwrap();
    let backend = PjrtTransformBackend::new(loaded, &map).unwrap();

    let x = random_batch(batch, d, 5);
    let z_pjrt = backend.run_batch(&x).unwrap();
    let z_native = map.transform_batch(&x);

    assert_eq!(z_pjrt.rows(), z_native.rows());
    let max_diff = z_pjrt.max_abs_diff(&z_native);
    assert!(max_diff < 1e-4, "engines disagree: max |Δ| = {max_diff}");
}

#[test]
fn coordinator_over_pjrt_serves_correct_features() {
    if !have_artifact("transform_quickstart") {
        return;
    }
    let (map, _batch, d) = map_for("transform_quickstart", 13);
    let map = Arc::new(map);
    let factory = Arc::new(
        PjrtTransformFactory::new(artifact_dir(), "transform_quickstart", map.clone()).unwrap(),
    );
    let coord = Coordinator::start(
        factory,
        CoordinatorConfig { workers: 1, ..Default::default() },
    );
    let mut rng = Rng::seed_from(3);
    for _ in 0..5 {
        let mut x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        rfdot::linalg::normalize(&mut x);
        let z = coord.transform(x.clone()).unwrap();
        let expected = map.transform(&x);
        for (a, b) in z.iter().zip(&expected) {
            assert!((a - b).abs() < 1e-4, "coordinator/native mismatch: {a} vs {b}");
        }
    }
}

#[test]
fn score_artifact_matches_native_linear_model() {
    if !have_artifact("score_serve") {
        return;
    }
    let (map, batch, d) = map_for("score_serve", 17);
    // Train a small linear model on native features so w is realistic.
    let x_train = random_batch(200, d, 7);
    let mut rng = Rng::seed_from(9);
    let y: Vec<f32> =
        (0..200).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
    let z_train = map.transform_batch(&x_train);
    let zds = rfdot::data::Dataset::new("t", z_train, y).unwrap();
    let model = LinearSvm::train(
        &zds,
        LinearSvmParams { bias_scale: 0.0, max_epochs: 5, ..Default::default() },
    )
    .unwrap();

    let engine = Engine::cpu(artifact_dir()).unwrap();
    let loaded = engine.load("score_serve").unwrap();
    let backend = rfdot::coordinator::PjrtScoreBackend::new(
        loaded,
        &map,
        model.weights().to_vec(),
        model.bias(),
    )
    .unwrap();

    let x = random_batch(batch, d, 21);
    let scores = backend.run_batch(&x).unwrap();
    for i in 0..batch {
        let native = model.decision(&map.transform(x.row(i)));
        let pjrt = scores.get(i, 0);
        assert!(
            (native - pjrt).abs() < 1e-3 * (1.0 + native.abs()),
            "row {i}: native {native} vs pjrt {pjrt}"
        );
    }
}

#[test]
fn score_factory_spec_comes_from_manifest() {
    if !have_artifact("score_serve") {
        return;
    }
    let (map, batch, d) = map_for("score_serve", 23);
    let features = map.n_random();
    let factory = PjrtScoreFactory::new(
        artifact_dir(),
        "score_serve",
        Arc::new(map),
        vec![0.0; features],
        0.0,
    )
    .unwrap();
    use rfdot::coordinator::BackendFactory;
    let spec = factory.spec();
    assert_eq!(spec.input_dim, d);
    assert_eq!(spec.output_dim, 1);
    assert_eq!(spec.max_batch, batch);
    assert!(spec.fixed_batch);
}

#[test]
fn train_step_artifact_descends() {
    if !have_artifact("train_step") {
        return;
    }
    use rfdot::runtime::Tensor;
    let engine = Engine::cpu(artifact_dir()).unwrap();
    let loaded = engine.load("train_step").unwrap();
    let meta = &loaded.meta;
    let features = meta.inputs[0].shape[0];
    let batch = meta.inputs[2].shape[0];

    // Separable synthetic features.
    let mut rng = Rng::seed_from(31);
    let mut z = vec![0.0f32; batch * features];
    for v in z.iter_mut() {
        *v = rng.f32() - 0.5;
    }
    let true_w: Vec<f32> = (0..features).map(|_| rng.f32() - 0.5).collect();
    let y: Vec<f32> = (0..batch)
        .map(|i| {
            let s: f32 =
                (0..features).map(|j| z[i * features + j] * true_w[j]).sum();
            if s >= 0.0 {
                1.0
            } else {
                -1.0
            }
        })
        .collect();

    let mut w = Tensor::new(vec![features], vec![0.0; features]).unwrap();
    let mut b = Tensor::scalar(0.0);
    let z_t = Tensor::new(vec![batch, features], z).unwrap();
    let y_t = Tensor::new(vec![batch], y).unwrap();
    let lr = Tensor::scalar(0.5);
    let reg = Tensor::scalar(1e-4);

    let mut losses = Vec::new();
    for _ in 0..30 {
        let out = loaded
            .execute(&[w.clone(), b.clone(), z_t.clone(), y_t.clone(), lr.clone(), reg.clone()])
            .unwrap();
        let mut it = out.into_iter();
        w = it.next().unwrap();
        b = it.next().unwrap();
        losses.push(it.next().unwrap().data()[0]);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "train_step did not descend: {} -> {}",
        losses[0],
        losses.last().unwrap()
    );
}
