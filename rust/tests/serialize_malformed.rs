//! Hardening regression tests for the RFDM record readers: malformed
//! input — truncated payloads, oversized count fields, non-canonical
//! padding/trailing bytes — must come back as [`rfdot::Error`], never a
//! panic, over-read, or unbounded allocation. One named test per
//! hardened field, across all three record kinds (`RFDM0001` dense,
//! `RFDM0002` structured seed-only, `RFDM0003` zero-copy artifact).
//!
//! Every test starts from a *valid* record produced by the real writer
//! and corrupts exactly one thing, so a reader change that loosens a
//! check fails the matching test by name.

use rfdot::kernels::Polynomial;
use rfdot::maclaurin::serialize::{from_bytes, to_bytes};
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;
use rfdot::structured::ProjectionKind;

/// Fixed legacy-header field offsets (RFDM0001/0002 share the layout).
const LEGACY_D: usize = 8;
const LEGACY_NFEAT: usize = 12;
const LEGACY_KLEN: usize = 37;
const LEGACY_BODY: usize = 41; // kname starts here; orders at 41 + klen

/// RFDM0003 header field offsets (see `rfdot::artifact`).
const V3_FLAGS: usize = 8;
const V3_HEADER_PAD: usize = 29;
const V3_KLEN: usize = 52;
const V3_HEADER: usize = 56;

fn sample(projection: ProjectionKind, recycle: bool, seed: u64) -> RandomMaclaurin {
    let mut rng = Rng::seed_from(seed);
    RandomMaclaurin::sample(
        &Polynomial::new(4, 0.5),
        17,
        40,
        RmConfig::default().with_projection(projection).with_recycle(recycle),
        &mut rng,
    )
}

fn dense_record() -> Vec<u8> {
    to_bytes(&sample(ProjectionKind::Dense, false, 11))
}

fn structured_record() -> Vec<u8> {
    to_bytes(&sample(ProjectionKind::Structured, false, 12))
}

fn v3_record() -> Vec<u8> {
    // Recycled structured maps are exactly the maps whose canonical
    // record kind is RFDM0003.
    to_bytes(&sample(ProjectionKind::Structured, true, 13))
}

fn v3_dense_record() -> Vec<u8> {
    rfdot::artifact::MapArtifact::from_map(&sample(ProjectionKind::Dense, false, 14))
        .expect("encode dense artifact")
        .as_bytes()
        .to_vec()
}

fn patch_u32(buf: &mut [u8], off: usize, v: u32) {
    buf[off..off + 4].copy_from_slice(&v.to_le_bytes());
}

fn patch_u64(buf: &mut [u8], off: usize, v: u64) {
    buf[off..off + 8].copy_from_slice(&v.to_le_bytes());
}

fn read_u32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

/// Byte offset of the RFDM0003 section table (after the zero-padded
/// kernel name and the `nsec` + pad words).
fn v3_table_start(buf: &[u8]) -> usize {
    let klen = read_u32(buf, V3_KLEN) as usize;
    (V3_HEADER + klen).div_ceil(8) * 8 + 8
}

#[test]
fn every_truncation_of_every_record_kind_errors_cleanly() {
    for record in [dense_record(), structured_record(), v3_record(), v3_dense_record()] {
        // Positive control: the untouched record parses.
        from_bytes(&record).expect("valid record must parse");
        for cut in 0..record.len() {
            assert!(
                from_bytes(&record[..cut]).is_err(),
                "truncation to {cut}/{} bytes must error, not parse",
                record.len()
            );
        }
    }
}

#[test]
fn trailing_bytes_are_rejected_per_record_kind() {
    for record in [dense_record(), structured_record(), v3_record(), v3_dense_record()] {
        let mut extended = record.clone();
        extended.push(0);
        let err = from_bytes(&extended).expect_err("trailing byte must be rejected");
        assert!(err.to_string().contains("trailing"), "{err}");
    }
}

#[test]
fn bad_magic_is_rejected() {
    let mut record = dense_record();
    record[..8].copy_from_slice(b"RFDM9999");
    let err = from_bytes(&record).expect_err("unknown magic must be rejected");
    assert!(err.to_string().contains("magic"), "{err}");
}

#[test]
fn legacy_oversized_klen_is_rejected() {
    for record in [dense_record(), structured_record()] {
        let mut record = record;
        patch_u32(&mut record, LEGACY_KLEN, u32::MAX);
        assert!(from_bytes(&record).is_err(), "klen past the buffer must error");
    }
}

#[test]
fn legacy_oversized_feature_count_cannot_force_allocation() {
    // A crafted D claims u32::MAX features; the reader must prove the
    // payload bytes exist before reserving, so this errors immediately
    // instead of attempting a multi-gigabyte `Vec::with_capacity`.
    for record in [dense_record(), structured_record()] {
        let mut record = record;
        patch_u32(&mut record, LEGACY_NFEAT, u32::MAX);
        let err = from_bytes(&record).expect_err("bogus feature count must error");
        assert!(err.to_string().contains("payload missing"), "{err}");
    }
}

#[test]
fn dense_rows_field_mismatching_order_sum_is_rejected() {
    let record = dense_record();
    let klen = read_u32(&record, LEGACY_KLEN) as usize;
    let n_feat = read_u32(&record, LEGACY_NFEAT) as usize;
    let rows_off = LEGACY_BODY + klen + 8 * n_feat;
    let rows = read_u32(&record, rows_off);
    let mut bad = record;
    patch_u32(&mut bad, rows_off, rows + 1);
    let err = from_bytes(&bad).expect_err("rows/order-sum mismatch must error");
    assert!(err.to_string().contains("order sum"), "{err}");
}

#[test]
fn dense_truncated_sign_payload_is_rejected() {
    let record = dense_record();
    let err = from_bytes(&record[..record.len() - 8])
        .expect_err("missing sign words must error");
    assert!(err.to_string().contains("sign payload"), "{err}");
}

#[test]
fn structured_order_above_declared_max_order_is_rejected() {
    let record = structured_record();
    let klen = read_u32(&record, LEGACY_KLEN) as usize;
    let mut bad = record;
    // First entry of the orders array, set above the header's max_order.
    patch_u32(&mut bad, LEGACY_BODY + klen, 10_000);
    let err = from_bytes(&bad).expect_err("order above max_order must error");
    assert!(err.to_string().contains("max_order"), "{err}");
}

#[test]
fn structured_reconstruction_bomb_is_rejected() {
    // Seeded reconstruction means a ~100-byte structured record could
    // otherwise demand gigabytes of FWHT state via a huge `d`.
    let mut record = structured_record();
    patch_u32(&mut record, LEGACY_D, 1 << 30);
    let err = from_bytes(&record).expect_err("reconstruction bomb must error");
    assert!(err.to_string().contains("budget"), "{err}");
}

#[test]
fn v3_unknown_flag_bits_are_rejected() {
    let mut record = v3_record();
    record[V3_FLAGS] |= 0x80;
    let err = from_bytes(&record).expect_err("unknown flag bit must error");
    assert!(err.to_string().contains("flags"), "{err}");
}

#[test]
fn v3_recycled_flag_on_a_dense_record_is_rejected() {
    let mut record = v3_dense_record();
    assert_eq!(read_u32(&record, V3_FLAGS), 0, "dense artifact must carry no flags");
    patch_u32(&mut record, V3_FLAGS, 2); // FLAG_RECYCLED without FLAG_STRUCTURED
    assert!(from_bytes(&record).is_err(), "recycled dense record must error");
}

#[test]
fn v3_nonzero_header_padding_is_rejected() {
    let mut record = v3_record();
    record[V3_HEADER_PAD] = 1;
    let err = from_bytes(&record).expect_err("non-zero header padding must error");
    assert!(err.to_string().contains("padding"), "{err}");
}

#[test]
fn v3_nonzero_kernel_name_padding_is_rejected() {
    let record = v3_record();
    let klen = read_u32(&record, V3_KLEN) as usize;
    let name_end = V3_HEADER + klen;
    let padded = name_end.div_ceil(8) * 8;
    assert!(padded > name_end, "fixture kernel name must need padding");
    let mut bad = record;
    bad[name_end] = 7;
    let err = from_bytes(&bad).expect_err("non-zero name padding must error");
    assert!(err.to_string().contains("padding"), "{err}");
}

#[test]
fn v3_non_canonical_section_offset_is_rejected() {
    let mut record = v3_record();
    let off_field = v3_table_start(&record) + 8;
    let off = u64::from_le_bytes(record[off_field..off_field + 8].try_into().unwrap());
    patch_u64(&mut record, off_field, off + 8);
    let err = from_bytes(&record).expect_err("non-canonical offset must error");
    assert!(err.to_string().contains("offset"), "{err}");
}

#[test]
fn v3_oversized_section_length_is_rejected() {
    let mut record = v3_record();
    let elems_field = v3_table_start(&record) + 16;
    patch_u64(&mut record, elems_field, 1 << 40);
    let err = from_bytes(&record).expect_err("oversized section must error");
    assert!(err.to_string().contains("truncated"), "{err}");
}

#[test]
fn v3_section_size_overflow_is_rejected() {
    let mut record = v3_record();
    let elems_field = v3_table_start(&record) + 16;
    patch_u64(&mut record, elems_field, u64::MAX);
    let err = from_bytes(&record).expect_err("section size overflow must error");
    assert!(err.to_string().contains("overflow"), "{err}");
}

#[test]
fn container_truncation_at_every_byte_errors_cleanly() {
    // The container-level twin of the legacy-reader sweep (ISSUE 10):
    // `MapArtifact::from_bytes` is the path every serving artifact
    // takes — native v3 parse plus the legacy up-convert — and a
    // truncation at ANY byte must be a named error, never a panic,
    // over-read, or parse of a half record.
    for record in [v3_record(), v3_dense_record(), dense_record(), structured_record()] {
        rfdot::artifact::MapArtifact::from_bytes(&record)
            .expect("valid container must load");
        for cut in 0..record.len() {
            assert!(
                rfdot::artifact::MapArtifact::from_bytes(&record[..cut]).is_err(),
                "container truncated to {cut}/{} bytes must error, not parse",
                record.len()
            );
        }
    }
}

#[test]
fn container_single_byte_corruption_never_panics() {
    // A seeded sweep of random single-byte flips over valid v3 records
    // (the bit-rot model `faults::mangle` injects at `artifact.read`):
    // structural corruption must come back as a named [`rfdot::Error`];
    // a flip landing in the weight floats is data, not structure, and
    // may parse — but then the artifact must still instantiate without
    // panicking. Either way: no panic, no unbounded allocation.
    for record in [v3_record(), v3_dense_record()] {
        let mut rng = Rng::seed_from(99);
        for _ in 0..400 {
            let pos = rng.below(record.len() as u64) as usize;
            let mask = (rng.below(255) + 1) as u8; // never the identity flip
            let mut bad = record.clone();
            bad[pos] ^= mask;
            match rfdot::artifact::MapArtifact::from_bytes(&bad) {
                Ok(art) => {
                    let _ = art.instantiate();
                }
                Err(e) => {
                    assert!(
                        !e.to_string().is_empty(),
                        "corruption at byte {pos} must produce a named error"
                    );
                }
            }
        }
    }
}

#[test]
fn v3_reader_round_trips_the_untouched_records_bit_for_bit() {
    // The hardening must not disturb the canonical path: a valid v3
    // record parses, instantiates, and re-encodes byte-identically.
    for record in [v3_record(), v3_dense_record()] {
        let art = rfdot::artifact::MapArtifact::from_bytes(&record).unwrap();
        assert_eq!(art.as_bytes(), &record[..], "parse must hold the exact bytes");
        let map = art.instantiate().unwrap();
        let re = rfdot::artifact::MapArtifact::from_map(&map).unwrap();
        assert_eq!(re.as_bytes(), &record[..], "re-encode must be byte-identical");
    }
}
