//! Failure-injection tests for the runtime layer: corrupted artifacts,
//! manifest/shape mismatches, and the coordinator's behaviour when the
//! backend misbehaves. PJRT-dependent cases skip when artifacts are
//! missing.

use rfdot::runtime::{ArtifactMeta, Engine, Tensor};

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn have_quickstart() -> bool {
    artifact_dir().join("transform_quickstart.hlo.txt").exists()
}

#[test]
fn corrupted_hlo_text_is_a_clean_error() {
    let dir = std::env::temp_dir().join("rfdot_fail_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule bad\n\nENTRY %oops {").unwrap();
    std::fs::write(
        dir.join("bad.json"),
        r#"{"name":"bad","config":{"kind":"transform"},"inputs":[],"outputs":[]}"#,
    )
    .unwrap();
    let engine = match Engine::cpu(&dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    match engine.load("bad") {
        Err(e) => assert!(e.to_string().contains("bad"), "unexpected error text: {e}"),
        Ok(_) => panic!("corrupted HLO must not load"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_manifest_is_a_clean_error() {
    let dir = std::env::temp_dir().join("rfdot_fail_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("m.hlo.txt"), "HloModule m\n").unwrap();
    std::fs::write(dir.join("m.json"), "{not json").unwrap();
    let engine = match Engine::cpu(&dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    assert!(engine.load("m").is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn execute_rejects_wrong_shapes_and_arity() {
    if !have_quickstart() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu(artifact_dir()).unwrap();
    let loaded = engine.load("transform_quickstart").unwrap();
    // Wrong arity.
    assert!(loaded.execute(&[]).is_err());
    // Right arity, wrong shape on x.
    let specs = &loaded.meta.inputs;
    let mut inputs: Vec<Tensor> =
        specs.iter().map(|s| Tensor::zeros(s.shape.clone())).collect();
    inputs[0] = Tensor::zeros(vec![1, 1]);
    let err = loaded.execute(&inputs).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}

#[test]
fn manifest_batch_and_element_counts() {
    let text = r#"{
      "name": "t", "config": {"kind": "transform"},
      "inputs": [
        {"name": "x", "shape": [32, 7], "dtype": "f32"},
        {"name": "omega", "shape": [4, 7, 64], "dtype": "f32"}
      ],
      "outputs": [{"name": "z", "shape": [32, 64], "dtype": "f32"}]
    }"#;
    let meta = ArtifactMeta::parse(text).unwrap();
    assert_eq!(meta.batch(), 32);
    assert_eq!(meta.inputs[1].element_count(), 4 * 7 * 64);
}

#[test]
fn pjrt_backend_construction_rejects_mismatched_map() {
    if !have_quickstart() {
        return;
    }
    use rfdot::kernels::Exponential;
    use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
    use rfdot::rng::Rng;
    let engine = Engine::cpu(artifact_dir()).unwrap();
    let loaded = engine.load("transform_quickstart").unwrap();
    // Wrong d: quickstart artifact is d=16; build a d=5 map.
    let mut rng = Rng::seed_from(1);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        5,
        256,
        RmConfig::default().with_max_order(8),
        &mut rng,
    );
    assert!(rfdot::coordinator::PjrtTransformBackend::new(loaded.clone(), &map).is_err());
    // H0/1 maps are rejected for transform artifacts.
    let mut rng = Rng::seed_from(2);
    let map_h01 = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        16,
        256,
        RmConfig::default().with_max_order(8).with_h01(true),
        &mut rng,
    );
    assert!(rfdot::coordinator::PjrtTransformBackend::new(loaded, &map_h01).is_err());
}
