//! Table 1 reproduction: the paper's headline evaluation. For each of
//! the six UCI-surrogate datasets and both kernels, compare
//!   K + SMO (exact kernel SVM, the LIBSVM column),
//!   RF + linear SVM (D = 500/1000 like the paper),
//!   H0/1 + linear SVM (D = 50..200 like the paper),
//! reporting accuracy, train time, test time and speedups.
//!
//! Run: `cargo bench --bench table1 [-- poly|exp]`
//! Env: RFDOT_SCALE (default 0.05 — the paper's full sizes via 1.0),
//!      RFDOT_SEED, RFDOT_DATASETS (comma list to subset).

use rfdot::bench::{experiment, RowResult};
use rfdot::cli::commands::print_rows;
use rfdot::config::{ExperimentConfig, KernelSpec};

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The paper's per-dataset D choices (Table 1): (dataset, D_rf, D_h01).
const GRID: [(&str, usize, usize); 6] = [
    ("nursery", 500, 100),
    ("spambase", 500, 50),
    ("cod-rna", 500, 50),
    ("adult", 500, 100),
    ("ijcnn", 1000, 200),
    ("covertype", 1000, 100),
];

fn main() {
    // Keep only our filter words (cargo bench injects flags like --bench).
    let args: Vec<String> =
        std::env::args().skip(1).filter(|a| a == "poly" || a == "exp").collect();
    let want_poly = args.is_empty() || args.iter().any(|a| a == "poly");
    let want_exp = args.is_empty() || args.iter().any(|a| a == "exp");
    let scale = env_f64("RFDOT_SCALE", 0.05);
    let seed = env_f64("RFDOT_SEED", 42.0) as u64;
    let subset: Option<Vec<String>> = std::env::var("RFDOT_DATASETS")
        .ok()
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect());

    let mut tables: Vec<(&str, KernelSpec)> = Vec::new();
    if want_poly {
        tables.push(("Table 1a: polynomial (1+<x,y>)^10", KernelSpec::Polynomial {
            degree: 10,
            offset: 1.0,
        }));
    }
    if want_exp {
        tables.push(("Table 1b: exponential exp(<x,y>/sigma^2)", KernelSpec::Exponential {
            sigma2: 0.0,
        }));
    }

    for (title, kernel) in tables {
        println!("\n==== {title} (scale {scale}) ====");
        let mut rows: Vec<RowResult> = Vec::new();
        for (dataset, d_rf, d_h01) in GRID {
            if let Some(ref only) = subset {
                if !only.iter().any(|s| s == dataset) {
                    continue;
                }
            }
            let config = ExperimentConfig {
                dataset: dataset.into(),
                kernel: kernel.clone(),
                scale,
                n_features: d_rf,
                seed,
                ..Default::default()
            };
            eprintln!("  running {dataset} ...");
            match experiment::run_row(&config, d_rf, d_h01) {
                Ok(row) => rows.push(row),
                Err(e) => eprintln!("  {dataset} failed: {e}"),
            }
        }
        print_rows(&rows);
    }
    println!("\npaper shape: RF within ~1% of K accuracy at D=500-1000; H0/1 within");
    println!("a few % at 5-10x fewer features; trn speedups 2-50x, tst 1.3-74x,");
    println!("growing with training set size (the curse of support).");
}
