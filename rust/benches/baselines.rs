//! Extension benches beyond the paper's tables:
//!
//! 1. **Approximation-family comparison** — Random Maclaurin vs
//!    TensorSketch (Pham & Pagh 2013) vs Nyström (Bach & Jordan 2005,
//!    named in the paper's §2) at equal output dimension, on the
//!    polynomial kernel both can represent.
//! 2. **Curse of support** (paper §1) — support-vector count and test
//!    cost of the exact kernel SVM vs training-set size, against the
//!    size-independent cost of the RM + linear pipeline.
//!
//! Run: `cargo bench --bench baselines`

use rfdot::bench::{fmt_duration, time_once, Table};
use rfdot::data::UciSurrogate;
use rfdot::kernels::{gram, mean_abs_gram_error, Polynomial};
use rfdot::linalg::Matrix;
use rfdot::features::{feature_gram, FeatureMap};
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::nystrom::Nystrom;
use rfdot::rng::Rng;
use rfdot::svm::{Classifier, KernelSvm, LinearSvm, LinearSvmParams, SmoParams};
use rfdot::tensorsketch::TensorSketch;

fn sphere_points(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from(seed);
    let rows: Vec<Vec<f32>> =
        (0..n).map(|_| rfdot::prop::gens::unit_vec(&mut rng, d)).collect();
    Matrix::from_rows(&rows).unwrap()
}

fn approximation_families() {
    println!("== approximation families on (1 + <x,y>)^3, d=16, 60 points ==");
    let kernel = Polynomial::new(3, 1.0);
    let d = 16;
    let x = sphere_points(60, d, 1);
    let exact = gram(&kernel, &x);
    let mut table = Table::new(&["D", "RandomMaclaurin", "TensorSketch", "Nystrom"]);
    for n_feat in [32usize, 128, 512, 2048] {
        let mut rng = Rng::seed_from(100 + n_feat as u64);
        let rm = RandomMaclaurin::sample(&kernel, d, n_feat, RmConfig::default(), &mut rng);
        let ts = TensorSketch::sample(3, 1.0, d, n_feat, &mut rng);
        let ny_err = if n_feat <= x.rows() {
            let ny = Nystrom::fit(Box::new(kernel), &x, n_feat, &mut rng).unwrap();
            format!("{:.5}", mean_abs_gram_error(&exact, &feature_gram(&ny, &x)))
        } else {
            "n/a (m>n)".to_string()
        };
        table.row(&[
            format!("{n_feat}"),
            format!("{:.5}", mean_abs_gram_error(&exact, &feature_gram(&rm, &x))),
            format!("{:.5}", mean_abs_gram_error(&exact, &feature_gram(&ts, &x))),
            ny_err,
        ]);
    }
    table.print();
    println!("expected: TensorSketch tightest for pure polynomials; Nystrom excellent");
    println!("at m close to n (data-dependent); RandomMaclaurin is the only one that");
    println!("generalizes to arbitrary dot product kernels.");
}

fn curse_of_support() {
    println!("\n== curse of support (paper §1): exact SVM cost vs training size ==");
    let mut table = Table::new(&[
        "n_train", "n_sv", "sv frac", "K tst(1k)", "RF tst(1k)", "tst speedup",
    ]);
    let kernel = Polynomial::new(10, 1.0);
    for &scale in &[0.01f64, 0.02, 0.05, 0.1] {
        let ds = UciSurrogate::CodRna.load(scale, 7);
        let mut rng = Rng::seed_from(8);
        let (train, test) = ds.split(0.6, 20_000, &mut rng);
        let test_1k = {
            let n = test.len().min(1000);
            rfdot::data::Dataset::new(
                "t",
                test.x().slice_rows(0, n),
                test.y[..n].to_vec(),
            )
            .unwrap()
        };
        let model =
            KernelSvm::train(&train, Box::new(kernel), SmoParams::default()).unwrap();
        let (_, k_tst) = time_once(|| model.accuracy_on(&test_1k));

        let map = RandomMaclaurin::sample(&kernel, train.dim(), 500, RmConfig::default(), &mut rng);
        let z_train = map.transform_batch(train.x());
        let zds = rfdot::data::Dataset::new("z", z_train, train.y.clone()).unwrap();
        let lin = LinearSvm::train(&zds, LinearSvmParams::default()).unwrap();
        let (_, rf_tst) = time_once(|| {
            let z = map.transform_batch(test_1k.x());
            lin.accuracy(&z, &test_1k.y)
        });

        table.row(&[
            format!("{}", train.len()),
            format!("{}", model.n_support()),
            format!("{:.0}%", 100.0 * model.n_support() as f64 / train.len() as f64),
            fmt_duration(k_tst),
            fmt_duration(rf_tst),
            format!("{:.1}x", k_tst / rf_tst.max(1e-9)),
        ]);
    }
    table.print();
    println!("expected: n_sv grows with n (Steinwart 2003) so exact test cost grows");
    println!("without bound; the RF pipeline's cost is independent of n.");
}

fn main() {
    approximation_families();
    curse_of_support();
}
