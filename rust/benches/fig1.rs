//! Figure 1 reproduction: mean absolute Gram-matrix error of the Random
//! Maclaurin features vs the number of random features D, for the
//! paper's three kernels (homogeneous `⟨x,y⟩^10`, polynomial
//! `(1+⟨x,y⟩)^10`, exponential `exp(⟨x,y⟩/σ²)`), several input
//! dimensions d, with and without H0/1 (Figures 1a-1c).
//!
//! Protocol (paper §6.2): 100 random points from the unit ball, error =
//! average absolute difference between exact and approximate kernel
//! matrices, averaged over 5 runs.
//!
//! Run: `cargo bench --bench fig1`
//! Env: RFDOT_POINTS (default 100), RFDOT_RUNS (default 5),
//!      RFDOT_DMAX (default 5000).

use rfdot::bench::Table;
use rfdot::kernels::{
    gram, mean_abs_gram_error, DotProductKernel, Exponential, Homogeneous, Polynomial,
};
use rfdot::linalg::{mean, Matrix};
use rfdot::features::feature_gram;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn unit_ball_points(n: usize, d: usize, rng: &mut Rng) -> Matrix {
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let mut v = rfdot::prop::gens::unit_vec(rng, d);
        // Random radius keeps points *inside* the ball like the paper.
        let r = rng.f32().powf(1.0 / d as f32);
        rfdot::linalg::scale(r, &mut v);
        rows.push(v);
    }
    Matrix::from_rows(&rows).expect("uniform rows")
}

fn error_at(
    kernel: &dyn DotProductKernel,
    x: &Matrix,
    exact: &Matrix,
    n_feat: usize,
    h01: bool,
    runs: usize,
    rng: &mut Rng,
) -> f64 {
    let errs: Vec<f64> = (0..runs)
        .map(|_| {
            let map = RandomMaclaurin::sample(
                kernel,
                x.cols(),
                n_feat,
                RmConfig::default().with_h01(h01),
                rng,
            );
            mean_abs_gram_error(exact, &feature_gram(&map, x))
        })
        .collect();
    mean(&errs)
}

fn main() {
    let n_pts = env_usize("RFDOT_POINTS", 100);
    let runs = env_usize("RFDOT_RUNS", 5);
    let d_max = env_usize("RFDOT_DMAX", 5000);
    let d_grid: Vec<usize> =
        [10usize, 50, 200, 1000, 5000].into_iter().filter(|&v| v <= d_max).collect();
    let dims = [10usize, 50, 200];

    let kernels: Vec<(Box<dyn DotProductKernel>, &str, bool)> = vec![
        (Box::new(Homogeneous::new(10)), "fig1a homogeneous <x,y>^10", false),
        (Box::new(Polynomial::new(10, 1.0)), "fig1b polynomial (1+<x,y>)^10", true),
        (Box::new(Exponential::new(1.0)), "fig1c exponential e^<x,y>", true),
    ];

    for (kernel, title, h01_applies) in &kernels {
        println!("\n== {title} ==  ({n_pts} points, {runs} runs)");
        let mut table = Table::new(&["d", "D", "RF err", "H0/1 err"]);
        for &d in &dims {
            let mut rng = Rng::seed_from(0xF160 + d as u64);
            let x = unit_ball_points(n_pts, d, &mut rng);
            let exact = gram(kernel.as_ref(), &x);
            for &n_feat in &d_grid {
                let e_rf = error_at(kernel.as_ref(), &x, &exact, n_feat, false, runs, &mut rng);
                let e_h01 = if *h01_applies {
                    format!(
                        "{:.5}",
                        error_at(kernel.as_ref(), &x, &exact, n_feat, true, runs, &mut rng)
                    )
                } else {
                    "n/a".to_string()
                };
                table.row(&[format!("{d}"), format!("{n_feat}"), format!("{e_rf:.5}"), e_h01]);
            }
        }
        table.print();
    }
    println!("\npaper shape: error drops ~1/sqrt(D); H0/1 (thick plots) drops faster;");
    println!("error magnitude ordering K_poly >> K_exp > K_hom (range-driven, §6.2).");

    if std::env::args().any(|a| a == "ablation") {
        ablation_support_restriction(n_pts, runs);
    }
}

/// Ablation: the raw external measure of §4 vs the support-restricted
/// (renormalized) measure this implementation defaults to. Both are
/// unbiased; the difference is pure variance. The homogeneous kernel is
/// the extreme case: the raw measure lands on the single informative
/// order with probability 2^-(p+1).
fn ablation_support_restriction(n_pts: usize, runs: usize) {
    println!("\n== ablation: raw measure (paper §4) vs support-restricted ==");
    let kernel = Homogeneous::new(10);
    let d = 20;
    let mut rng = Rng::seed_from(0xAB1A);
    let x = unit_ball_points(n_pts, d, &mut rng);
    // Use points on the sphere so K is not identically ~0.
    let mut rows = Vec::new();
    for i in 0..x.rows() {
        let mut v = x.row(i).to_vec();
        rfdot::linalg::normalize(&mut v);
        rows.push(v);
    }
    let x = Matrix::from_rows(&rows).unwrap();
    let exact = gram(&kernel, &x);
    let mut table = Table::new(&["D", "raw measure err", "restricted err"]);
    for n_feat in [50usize, 200, 1000, 5000] {
        let raw: Vec<f64> = (0..runs)
            .map(|_| {
                let map = RandomMaclaurin::sample(
                    &kernel,
                    d,
                    n_feat,
                    RmConfig::default().with_restrict_support(false),
                    &mut rng,
                );
                mean_abs_gram_error(&exact, &feature_gram(&map, &x))
            })
            .collect();
        let restricted: Vec<f64> = (0..runs)
            .map(|_| {
                let map =
                    RandomMaclaurin::sample(&kernel, d, n_feat, RmConfig::default(), &mut rng);
                mean_abs_gram_error(&exact, &feature_gram(&map, &x))
            })
            .collect();
        table.row(&[
            format!("{n_feat}"),
            format!("{:.5}", mean(&raw)),
            format!("{:.5}", mean(&restricted)),
        ]);
    }
    table.print();
}
