//! Figure 2 reproduction: H0/1 vs plain RF as a function of D on four
//! dataset/kernel pairs — (a) test accuracy, (b) training time,
//! (c) testing time.
//!
//! Paper columns: Spambase+polynomial, Nursery+polynomial,
//! IJCNN+exponential, Cod-RNA+exponential.
//!
//! Run: `cargo bench --bench fig2`
//! Env: RFDOT_SCALE (default 0.05 of the paper's dataset sizes),
//!      RFDOT_SEED.

use rfdot::bench::{experiment, fmt_duration, Table};
use rfdot::config::{ExperimentConfig, KernelSpec};

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = env_f64("RFDOT_SCALE", 0.05);
    let seed = env_f64("RFDOT_SEED", 42.0) as u64;
    let d_grid = [10usize, 25, 50, 100, 200, 400];

    let cases: [(&str, KernelSpec); 4] = [
        ("spambase", KernelSpec::Polynomial { degree: 10, offset: 1.0 }),
        ("nursery", KernelSpec::Polynomial { degree: 10, offset: 1.0 }),
        ("ijcnn", KernelSpec::Exponential { sigma2: 0.0 }),
        ("cod-rna", KernelSpec::Exponential { sigma2: 0.0 }),
    ];

    for (dataset, kernel) in cases {
        let config = ExperimentConfig {
            dataset: dataset.into(),
            kernel: kernel.clone(),
            scale,
            seed,
            ..Default::default()
        };
        let prep = match experiment::prepare(&config) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skip {dataset}: {e}");
                continue;
            }
        };
        println!(
            "\n== fig2: {dataset} + {} (train {}, test {}, scale {scale}) ==",
            prep.kernel.name(),
            prep.train.len(),
            prep.test.len()
        );
        let mut table =
            Table::new(&["D", "variant", "acc (fig2a)", "trn (fig2b)", "tst (fig2c)"]);
        for &n_feat in &d_grid {
            for h01 in [false, true] {
                let cell = experiment::run_random_features(&prep, n_feat, h01, n_feat as u64);
                table.row(&[
                    format!("{n_feat}"),
                    cell.label.clone(),
                    format!("{:.2}%", cell.accuracy * 100.0),
                    fmt_duration(cell.train_s),
                    fmt_duration(cell.test_s),
                ]);
            }
        }
        table.print();
    }
    println!("\npaper shape (fig 2): at small D, H0/1 accuracy >> RF accuracy;");
    println!("H0/1 gap narrows as D grows; H0/1 test time overtakes RF at large D.");
}
