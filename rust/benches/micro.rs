//! Hot-path microbenchmarks (the §Perf instrumentation):
//!
//! * native feature-map application throughput across (d, D) shapes,
//! * the threads = {1, 2, 4, 8} scaling sweep over `transform_batch`
//!   and `matmul` (recorded to `BENCH_parallel.json` at the repo root),
//! * the dense-vs-structured (FWHT) projection sweep over
//!   `transform_batch` (recorded to `BENCH_structured.json`),
//! * bit-packed vs dense-f32 Rademacher projection,
//! * PJRT artifact execution latency/throughput per batch,
//! * coordinator end-to-end round trip under load,
//! * the serve-throughput sweep over workers × shard-vs-shared queue
//!   topology × client batch size (recorded to `BENCH_serve.json`),
//! * the net-roundtrip sweep — TCP loopback request/reply through the
//!   `RFNP` front-end over clients × pipeline depth (recorded to
//!   `BENCH_net.json`),
//! * the artifact-load sweep — cold-load latency + resident bytes for
//!   owned vs zero-copy vs recycled map records (recorded to
//!   `BENCH_artifact.json`),
//! * the simd-kernels sweep — scalar vs runtime-detected path for every
//!   dispatched kernel across remainder-heavy widths (recorded to
//!   `BENCH_simd.json`),
//! * the observability primitives' per-call cost (histogram record,
//!   tracing span with the flag off and on),
//! * SVM solver throughput on surrogate data.
//!
//! Run:  `cargo bench --bench micro`
//! Args: `-- --quick` trims iteration counts (same as RFDOT_MICRO_FAST=1);
//!       `-- --only <substr>` runs only the sections whose name matches
//!       (e.g. `-- --quick --only structured`, the CI smoke invocation).

use rfdot::bench::{bench, fmt_duration, Table};
use rfdot::coordinator::{Coordinator, CoordinatorConfig, NativeFactory, PjrtTransformFactory};
use rfdot::features::FeatureMap;
use rfdot::kernels::Exponential;
use rfdot::linalg::Matrix;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rff::RandomFourier;
use rfdot::rng::{RademacherMatrix, Rng};
use rfdot::runtime::{ArtifactMeta, Engine};
use rfdot::structured::ProjectionKind;
use std::sync::Arc;
use std::time::Duration;

fn fast() -> bool {
    std::env::var("RFDOT_MICRO_FAST").is_ok()
}

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn batch(rows: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from(seed);
    let mut x = Matrix::zeros(rows, d);
    for i in 0..rows {
        for j in 0..d {
            x.set(i, j, rng.f32() - 0.5);
        }
        rfdot::linalg::normalize(x.row_mut(i));
    }
    x
}

fn bench_native_transform() {
    println!("\n== native transform throughput ==");
    let kernel = Exponential::new(1.0);
    let mut table = Table::new(&["d", "D", "batch", "time/batch", "vectors/s"]);
    let iters = if fast() { 3 } else { 10 };
    for (d, n_feat) in [(8usize, 100usize), (22, 512), (54, 1000), (123, 500)] {
        let mut rng = Rng::seed_from(1);
        let map = RandomMaclaurin::sample(&kernel, d, n_feat, RmConfig::default(), &mut rng);
        let x = batch(1024, d, 2);
        let m = bench("native", 2, iters, || map.transform_batch(&x));
        let per = m.mean_s();
        table.row(&[
            format!("{d}"),
            format!("{n_feat}"),
            "1024".into(),
            fmt_duration(per),
            format!("{:.0}", 1024.0 / per),
        ]);
    }
    table.print();
}

/// The threads = {1, 2, 4, 8} scaling sweep over the two parallelized
/// hot paths, recorded as the machine-readable baseline in
/// `BENCH_parallel.json` at the repo root.
fn bench_parallel_sweep() {
    println!("\n== parallel sweep: transform_batch / matmul vs threads ==");
    let threads_axis = [1usize, 2, 4, 8];
    let iters = if fast() { 3 } else { 10 };

    // transform_batch: d=22 → D=512 on a 1024-row batch (≥ 512 rows, the
    // regime the tentpole's 2x-at-4-threads target is stated for).
    let (d, n_feat, rows) = (22usize, 512usize, 1024usize);
    let mut rng = Rng::seed_from(21);
    let map =
        RandomMaclaurin::sample(&Exponential::new(1.0), d, n_feat, RmConfig::default(), &mut rng);
    let x = batch(rows, d, 22);

    // matmul: 512 x 512 by 512 x 512.
    let (m, k, n) = (512usize, 512usize, 512usize);
    let mut rng = Rng::seed_from(23);
    let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.f32() - 0.5).collect()).unwrap();
    let b = Matrix::from_vec(k, n, (0..k * n).map(|_| rng.f32() - 0.5).collect()).unwrap();

    let mut table = Table::new(&["threads", "transform_batch", "speedup", "matmul", "speedup"]);
    let mut tb_secs = Vec::new();
    let mut mm_secs = Vec::new();
    for &t in &threads_axis {
        let tb = bench("transform", 2, iters, || map.transform_batch_threads(&x, t)).mean_s();
        let mm = bench("matmul", 2, iters, || a.matmul_threads(&b, t).unwrap()).mean_s();
        table.row(&[
            format!("{t}"),
            fmt_duration(tb),
            format!("{:.2}x", tb_secs.first().copied().unwrap_or(tb) / tb),
            fmt_duration(mm),
            format!("{:.2}x", mm_secs.first().copied().unwrap_or(mm) / mm),
        ]);
        tb_secs.push(tb);
        mm_secs.push(mm);
    }
    table.print();

    // Machine-readable baseline (schema shared with BENCH_parallel.json).
    let series = |secs: &[f64]| -> String {
        threads_axis
            .iter()
            .zip(secs)
            .map(|(t, s)| {
                format!(
                    r#"{{"threads": {t}, "secs": {s:.6}, "speedup": {:.3}}}"#,
                    secs[0] / s
                )
            })
            .collect::<Vec<_>>()
            .join(", ")
    };
    let json = format!(
        "{{\n  \"bench\": \"parallel_sweep\",\n  \"status\": \"measured\",\n  \
         \"generated_by\": \"cargo bench --bench micro\",\n  \
         \"transform_batch\": {{\"d\": {d}, \"features\": {n_feat}, \"batch\": {rows}, \
         \"samples\": [{}]}},\n  \
         \"matmul\": {{\"m\": {m}, \"k\": {k}, \"n\": {n}, \"samples\": [{}]}}\n}}\n",
        series(&tb_secs),
        series(&mm_secs),
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_parallel.json");
    match std::fs::write(&path, json) {
        Ok(()) => println!("   baseline recorded to {}", path.display()),
        Err(e) => println!("   (could not write {}: {e})", path.display()),
    }
}

/// Dense vs structured (FWHT/HD) projections through `transform_batch`
/// for both map families, at the acceptance shape d = 512 → D = 4096,
/// single-threaded and composed with threads = 4. Recorded as the
/// machine-readable baseline in `BENCH_structured.json` at the repo
/// root (target: structured ≥ 3× dense on `transform_batch` at one
/// thread, with the ratio surviving the 4-thread fan-out).
fn bench_structured_sweep() {
    println!("\n== structured (FWHT) vs dense projections: transform_batch ==");
    let (d, n_feat, rows) = (512usize, 4096usize, 256usize);
    let iters = if fast() { 2 } else { 8 };
    let kernel = Exponential::new(1.0);
    let rm_dense =
        RandomMaclaurin::sample(&kernel, d, n_feat, RmConfig::default(), &mut Rng::seed_from(41));
    let rm_structured = RandomMaclaurin::sample(
        &kernel,
        d,
        n_feat,
        RmConfig::default().with_projection(ProjectionKind::Structured),
        &mut Rng::seed_from(41),
    );
    let rff_dense = RandomFourier::sample(0.5, d, n_feat, &mut Rng::seed_from(43));
    let rff_structured = RandomFourier::sample_with(
        0.5,
        d,
        n_feat,
        ProjectionKind::Structured,
        &mut Rng::seed_from(43),
    );
    let x = batch(rows, d, 42);

    let mut table =
        Table::new(&["map", "threads", "dense", "structured", "structured speedup"]);
    // (family, threads, dense secs, structured secs)
    let mut samples: Vec<(&str, usize, f64, f64)> = Vec::new();
    for &t in &[1usize, 4] {
        let rm_d =
            bench("rm-dense", 2, iters, || rm_dense.transform_batch_threads(&x, t)).mean_s();
        let rm_s = bench("rm-structured", 2, iters, || {
            rm_structured.transform_batch_threads(&x, t)
        })
        .mean_s();
        table.row(&[
            "maclaurin".into(),
            format!("{t}"),
            fmt_duration(rm_d),
            fmt_duration(rm_s),
            format!("{:.2}x", rm_d / rm_s),
        ]);
        samples.push(("maclaurin", t, rm_d, rm_s));
    }
    let rff_d =
        bench("rff-dense", 2, iters, || rff_dense.transform_batch_threads(&x, 1)).mean_s();
    let rff_s = bench("rff-structured", 2, iters, || {
        rff_structured.transform_batch_threads(&x, 1)
    })
    .mean_s();
    table.row(&[
        "fourier".into(),
        "1".into(),
        fmt_duration(rff_d),
        fmt_duration(rff_s),
        format!("{:.2}x", rff_d / rff_s),
    ]);
    samples.push(("fourier", 1, rff_d, rff_s));
    table.print();

    let json_samples = samples
        .iter()
        .map(|(family, t, dense, structured)| {
            format!(
                r#"{{"map": "{family}", "threads": {t}, "dense_secs": {dense:.6}, "structured_secs": {structured:.6}, "speedup": {:.3}}}"#,
                dense / structured
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    // A --quick run exercises the regeneration path end to end, but its
    // 2-iteration timings are noise: label them "smoke" AND divert them
    // to the temp dir, so the checked-in acceptance baseline at the
    // repo root is only ever overwritten by a full measured run.
    let (status, invocation, path) = if fast() {
        (
            "smoke",
            "cargo bench --bench micro -- --quick --only structured",
            std::env::temp_dir().join("BENCH_structured.smoke.json"),
        )
    } else {
        (
            "measured",
            "cargo bench --bench micro -- --only structured",
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_structured.json"),
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"structured_sweep\",\n  \"status\": \"{status}\",\n  \
         \"generated_by\": \"{invocation}\",\n  \
         \"transform_batch\": {{\"d\": {d}, \"features\": {n_feat}, \"batch\": {rows}, \
         \"samples\": [\n    {json_samples}\n  ]}}\n}}\n"
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("   baseline recorded to {}", path.display()),
        Err(e) => println!("   (could not write {}: {e})", path.display()),
    }
}

/// Sparse (CSR) vs dense per-input transform across sparsity levels,
/// for the three map families with sparse fast paths. Recorded as the
/// machine-readable baseline in `BENCH_sparse.json` at the repo root
/// (target: ≥ 5× per-input transform speedup at ≥ 95% sparsity — the
/// dense path burns `O(d)` on scanning zeros per factor while the CSR
/// path touches only the `nnz` stored entries).
fn bench_sparse_transform() {
    println!("\n== sparse (CSR) vs dense per-input transform ==");
    let (d, n_feat, rows) = (8192usize, 64usize, 32usize);
    let iters = if fast() { 2 } else { 10 };
    let kernel = Exponential::new(1.0);
    let rm =
        RandomMaclaurin::sample(&kernel, d, n_feat, RmConfig::default(), &mut Rng::seed_from(71));
    let rff = RandomFourier::sample(0.5, d, n_feat, &mut Rng::seed_from(72));
    let ts = rfdot::tensorsketch::TensorSketch::sample(2, 1.0, d, n_feat, &mut Rng::seed_from(73));
    let maps: [(&str, &dyn FeatureMap); 3] =
        [("maclaurin", &rm), ("fourier", &rff), ("tensorsketch", &ts)];

    let sparsity_axis = [0.5f64, 0.9, 0.95, 0.99];
    let mut table =
        Table::new(&["map", "sparsity", "nnz/row", "dense/vec", "sparse/vec", "speedup"]);
    // (family, sparsity, dense secs/vec, sparse secs/vec)
    let mut samples: Vec<(&str, f64, f64, f64)> = Vec::new();
    for &sparsity in &sparsity_axis {
        // Synthetic batch at the target sparsity: shuffled index sets so
        // the stored entries are spread across the row.
        let nnz = ((d as f64) * (1.0 - sparsity)).round().max(1.0) as usize;
        let mut rng = Rng::seed_from(74);
        let mut x = Matrix::zeros(rows, d);
        let mut cols: Vec<usize> = (0..d).collect();
        for i in 0..rows {
            rng.shuffle(&mut cols);
            for &j in &cols[..nnz] {
                x.set(i, j, rng.f32() - 0.5);
            }
        }
        let sx = rfdot::linalg::SparseMatrix::from_dense(&x);
        for (name, map) in maps {
            let mut out = vec![0.0f32; map.output_dim()];
            let dense = bench("dense", 2, iters, || {
                for i in 0..rows {
                    map.transform_into(x.row(i), &mut out);
                }
            })
            .mean_s()
                / rows as f64;
            let mut out2 = vec![0.0f32; map.output_dim()];
            let sparse = bench("sparse", 2, iters, || {
                for i in 0..rows {
                    map.transform_sparse_into(sx.row(i), &mut out2);
                }
            })
            .mean_s()
                / rows as f64;
            assert_eq!(out, out2, "sparse parity violated in the bench itself");
            table.row(&[
                name.into(),
                format!("{sparsity:.2}"),
                format!("{nnz}"),
                fmt_duration(dense),
                fmt_duration(sparse),
                format!("{:.2}x", dense / sparse),
            ]);
            samples.push((name, sparsity, dense, sparse));
        }
    }
    table.print();

    let json_samples = samples
        .iter()
        .map(|(family, sparsity, dense, sparse)| {
            format!(
                r#"{{"map": "{family}", "sparsity": {sparsity}, "dense_secs_per_vec": {dense:.9}, "sparse_secs_per_vec": {sparse:.9}, "speedup": {:.3}}}"#,
                dense / sparse
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    // Same policy as the structured sweep: --quick runs exercise the
    // regeneration path but divert their noisy timings to the temp dir;
    // only full measured runs overwrite the checked-in baseline.
    let (status, invocation, path) = if fast() {
        (
            "smoke",
            "cargo bench --bench micro -- --quick --only sparse",
            std::env::temp_dir().join("BENCH_sparse.smoke.json"),
        )
    } else {
        (
            "measured",
            "cargo bench --bench micro -- --only sparse",
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sparse.json"),
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"sparse_sweep\",\n  \"status\": \"{status}\",\n  \
         \"generated_by\": \"{invocation}\",\n  \
         \"per_input_transform\": {{\"d\": {d}, \"features\": {n_feat}, \"batch\": {rows}, \
         \"samples\": [\n    {json_samples}\n  ]}}\n}}\n"
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("   baseline recorded to {}", path.display()),
        Err(e) => println!("   (could not write {}: {e})", path.display()),
    }
}

fn bench_rademacher_projection() {
    println!("\n== rademacher projection: packed bits vs dense f32 ==");
    let mut table = Table::new(&["d", "rows", "packed", "dense-f32", "packed/dense"]);
    let iters = if fast() { 5 } else { 20 };
    for d in [64usize, 128, 512] {
        let rows = 1024;
        let mut rng = Rng::seed_from(3);
        let m = RademacherMatrix::sample(rows, d, &mut rng);
        let dense = Matrix::from_vec(rows, d, m.to_dense()).unwrap();
        let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.1).sin()).collect();
        let mut out = vec![0.0f32; rows];
        let packed = bench("packed", 3, iters, || m.project_all(&x, &mut out));
        let mut out2 = vec![0.0f32; rows];
        let densem = bench("dense", 3, iters, || {
            for i in 0..rows {
                out2[i] = rfdot::linalg::dot(dense.row(i), &x);
            }
        });
        table.row(&[
            format!("{d}"),
            format!("{rows}"),
            fmt_duration(packed.mean_s()),
            fmt_duration(densem.mean_s()),
            format!("{:.2}x", packed.mean_s() / densem.mean_s()),
        ]);
    }
    table.print();
}

fn bench_pjrt_execute() {
    println!("\n== pjrt artifact execution (transform_serve) ==");
    let name = "transform_serve";
    if !artifact_dir().join(format!("{name}.hlo.txt")).exists() {
        println!("   (skipped: run `make artifacts`)");
        return;
    }
    let meta = ArtifactMeta::parse(
        &std::fs::read_to_string(artifact_dir().join(format!("{name}.json"))).unwrap(),
    )
    .unwrap();
    let d = meta.inputs[0].shape[1];
    let b = meta.batch();
    let n_max = meta.inputs[1].shape[0] as u32;
    let features = meta.inputs[1].shape[2];
    let mut rng = Rng::seed_from(5);
    let map = RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        features,
        RmConfig::default().with_max_order(n_max),
        &mut rng,
    );
    let engine = Engine::cpu(artifact_dir()).unwrap();
    let loaded = engine.load(name).unwrap();
    let backend =
        rfdot::coordinator::PjrtTransformBackend::new(loaded, &map).unwrap();
    use rfdot::coordinator::Backend;
    let x = batch(b, d, 6);
    let iters = if fast() { 5 } else { 30 };
    let m = bench("pjrt", 3, iters, || backend.run_batch(&x).unwrap());
    println!(
        "   batch {b} x d={d} -> D={features}: {} per batch = {:.0} vectors/s",
        fmt_duration(m.mean_s()),
        b as f64 / m.mean_s()
    );

    // Native engine on identical shapes, for the engine-vs-engine ratio.
    let mnat = bench("native", 2, iters, || map.transform_batch(&x));
    println!(
        "   native same shapes: {} per batch = {:.0} vectors/s ({}x vs pjrt)",
        fmt_duration(mnat.mean_s()),
        b as f64 / mnat.mean_s(),
        (m.mean_s() / mnat.mean_s()).round()
    );
}

fn bench_coordinator_roundtrip() {
    println!("\n== coordinator end-to-end (native backend) ==");
    let mut rng = Rng::seed_from(7);
    let map = Arc::new(RandomMaclaurin::sample(
        &Exponential::new(1.0),
        22,
        512,
        RmConfig::default(),
        &mut rng,
    ));
    let coord = Arc::new(Coordinator::start(
        Arc::new(NativeFactory::new(map)),
        CoordinatorConfig {
            max_batch: 128,
            max_wait: Duration::from_micros(200),
            queue_depth: 8192,
            workers: 2,
            intra_op_threads: 1,
            ..Default::default()
        },
    ));
    let requests = if fast() { 500 } else { 5000 };
    let clients = 4;
    let sw = rfdot::metrics::Stopwatch::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from(100 + c as u64);
            for _ in 0..requests / clients {
                let x: Vec<f32> = (0..22).map(|_| rng.f32() - 0.5).collect();
                if let Ok(t) = coord.submit(x) {
                    let _ = t.wait();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = sw.elapsed_secs();
    println!("   {requests} requests in {} = {:.0} req/s", fmt_duration(dt), requests as f64 / dt);
    println!("   {}", coord.stats().summary());
}

/// The serving-path acceptance sweep: coordinator throughput over
/// workers × queue topology (shared single queue vs per-worker shards
/// with work stealing) × client submission batch size (per-request
/// tickets vs `submit_batch`). Recorded as the machine-readable
/// baseline in `BENCH_serve.json` at the repo root (target: the sharded
/// topology at 4 workers beats the shared queue, and batch submission
/// beats per-request submission at equal load).
fn bench_serve_throughput() {
    println!("\n== serve throughput: workers x shard-vs-shared x batch ==");
    let (d, n_feat) = (22usize, 512usize);
    let requests = if fast() { 400 } else { 4000 };
    let clients = 4usize;
    let mut rng = Rng::seed_from(91);
    let map = Arc::new(RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        n_feat,
        RmConfig::default(),
        &mut rng,
    ));

    let mut table =
        Table::new(&["workers", "shards", "submit batch", "req/s", "secs/req", "steals"]);
    // (workers, shards, batch, reqs_per_s, secs_per_req, steals)
    let mut samples: Vec<(usize, usize, usize, f64, f64, u64)> = Vec::new();
    for &workers in &[1usize, 2, 4] {
        let mut topologies = vec![1usize];
        if workers > 1 {
            topologies.push(workers);
        }
        for &shards in &topologies {
            for &batch in &[1usize, 32] {
                let coord = Arc::new(Coordinator::start(
                    Arc::new(NativeFactory::new(map.clone())),
                    CoordinatorConfig {
                        max_batch: 128,
                        max_wait: Duration::from_micros(200),
                        queue_depth: 8192,
                        workers,
                        intra_op_threads: 1,
                        shards,
                    },
                ));
                let sw = rfdot::metrics::Stopwatch::start();
                let mut handles = Vec::new();
                for c in 0..clients {
                    let coord = coord.clone();
                    handles.push(std::thread::spawn(move || {
                        let mut rng = Rng::seed_from(300 + c as u64);
                        let mut ok = 0usize;
                        let mut left = requests / clients;
                        while left > 0 {
                            let take = left.min(batch);
                            left -= take;
                            if take == 1 {
                                let x: Vec<f32> =
                                    (0..d).map(|_| rng.f32() - 0.5).collect();
                                if let Ok(t) = coord.submit(x) {
                                    ok += usize::from(t.wait().is_ok());
                                }
                            } else {
                                let xs: Vec<Vec<f32>> = (0..take)
                                    .map(|_| (0..d).map(|_| rng.f32() - 0.5).collect())
                                    .collect();
                                if let Ok(t) = coord.submit_batch(xs) {
                                    ok += t.wait().iter().filter(|r| r.is_ok()).count();
                                }
                            }
                        }
                        ok
                    }));
                }
                let completed: usize =
                    handles.into_iter().map(|h| h.join().unwrap()).sum();
                let dt = sw.elapsed_secs().max(1e-9);
                let steals: u64 =
                    coord.shard_snapshots().iter().map(|s| s.steals).sum();
                let reqs_per_s = completed as f64 / dt;
                let secs_per_req = dt / completed.max(1) as f64;
                table.row(&[
                    format!("{workers}"),
                    format!("{shards}"),
                    format!("{batch}"),
                    format!("{reqs_per_s:.0}"),
                    fmt_duration(secs_per_req),
                    format!("{steals}"),
                ]);
                samples.push((workers, shards, batch, reqs_per_s, secs_per_req, steals));
            }
        }
    }
    table.print();

    let json_samples = samples
        .iter()
        .map(|(workers, shards, batch, rps, spr, steals)| {
            format!(
                r#"{{"workers": {workers}, "shards": {shards}, "batch": {batch}, "reqs_per_s": {rps:.1}, "secs_per_req": {spr:.9}, "steals": {steals}}}"#
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    // Same policy as the structured/sparse sweeps: --quick runs exercise
    // the regeneration path but divert their noisy timings to the temp
    // dir; only full measured runs overwrite the checked-in baseline.
    let (status, invocation, path) = if fast() {
        (
            "smoke",
            "cargo bench --bench micro -- --quick --only serve-throughput",
            std::env::temp_dir().join("BENCH_serve.smoke.json"),
        )
    } else {
        (
            "measured",
            "cargo bench --bench micro -- --only serve-throughput",
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json"),
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"serve_sweep\",\n  \"status\": \"{status}\",\n  \
         \"generated_by\": \"{invocation}\",\n  \
         \"serve\": {{\"d\": {d}, \"features\": {n_feat}, \"requests\": {requests}, \
         \"clients\": {clients}, \
         \"samples\": [\n    {json_samples}\n  ]}}\n}}\n"
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("   baseline recorded to {}", path.display()),
        Err(e) => println!("   (could not write {}: {e})", path.display()),
    }
}

/// Scalar vs runtime-detected SIMD for every dispatched kernel in
/// [`rfdot::simd`], across a remainder-heavy width axis (15 and 67
/// exercise the vector tails; 1024/4096 the steady state). Recorded as
/// the machine-readable baseline in `BENCH_simd.json` at the repo
/// root; its top-level `simd` field names the detected path, which
/// `rfdot bench-diff` uses to refuse to gate across runs recorded on
/// different paths.
fn bench_simd_kernels() {
    use rfdot::simd;
    use std::hint::black_box;
    println!("\n== simd kernels: scalar vs detected, per kernel x width ==");
    let paths = simd::available_paths();
    let detected = simd::detected();
    println!("   detected path: {}", detected.as_str());
    let widths: &[usize] =
        if fast() { &[15, 67, 1024] } else { &[15, 64, 67, 256, 1024, 4096] };
    let iters = if fast() { 3 } else { 12 };

    let mut table =
        Table::new(&["kernel", "n", "scalar/call", "detected/call", "speedup"]);
    // (kernel, path name, n, secs per call, speedup vs scalar)
    let mut samples: Vec<(&str, &'static str, usize, f64, f64)> = Vec::new();
    for kernel in ["dot", "axpy", "scale", "fwht", "cos", "sparse-dot"] {
        for &n in widths {
            let mut rng = Rng::seed_from(131 + n as u64);
            let a: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let bv: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            // ~40% density CSR mirror of `a` for the sparse gather.
            let (idx, vals): (Vec<u32>, Vec<f32>) = a
                .iter()
                .enumerate()
                .filter(|(i, _)| i % 5 < 2)
                .map(|(i, &v)| (i as u32, v))
                .unzip();
            // Equalize work across widths: ~64k elements per timed call.
            let reps = (65_536 / n.max(1)).max(1);
            let mut per_path: Vec<f64> = Vec::new();
            for &path in &paths {
                let mut x = a.clone();
                let mut y = bv.clone();
                let m = bench(kernel, 1, iters, || match kernel {
                    "dot" => {
                        let mut s = 0.0f32;
                        for _ in 0..reps {
                            s += simd::dot_with(path, black_box(&a), black_box(&bv));
                        }
                        black_box(s);
                    }
                    "axpy" => {
                        for _ in 0..reps {
                            simd::axpy_with(path, 1.0e-6, &a, &mut y);
                        }
                        black_box(y[0]);
                    }
                    "scale" => {
                        for _ in 0..reps {
                            simd::scale_with(path, 0.999_999, &mut x);
                        }
                        black_box(x[0]);
                    }
                    "fwht" => {
                        // Butterfly magnitudes double per pass and
                        // saturate to ±inf; IEEE add/sub carries no
                        // inf/NaN penalty on the targeted ISAs, so the
                        // timing stays representative.
                        for _ in 0..reps {
                            simd::fwht_butterfly_with(path, &mut x, &mut y);
                        }
                        black_box(x[0]);
                    }
                    "cos" => {
                        for _ in 0..reps {
                            simd::cos_activate_with(path, &mut x, &bv, 0.5);
                        }
                        black_box(x[0]);
                    }
                    _ => {
                        let mut s = 0.0f32;
                        for _ in 0..reps {
                            s += simd::sparse_dot_dense_with(
                                path,
                                black_box(&idx),
                                black_box(&vals),
                                black_box(&bv),
                            );
                        }
                        black_box(s);
                    }
                });
                per_path.push(m.mean_s() / reps as f64);
            }
            // available_paths() always leads with the scalar oracle.
            let scalar = per_path[0];
            for (&path, &secs) in paths.iter().zip(&per_path) {
                samples.push((kernel, path.as_str(), n, secs, scalar / secs));
            }
            let (det_cell, speedup_cell) = if paths.len() > 1 {
                (fmt_duration(per_path[1]), format!("{:.2}x", scalar / per_path[1]))
            } else {
                ("-".into(), "-".into())
            };
            table.row(&[
                kernel.into(),
                format!("{n}"),
                fmt_duration(scalar),
                det_cell,
                speedup_cell,
            ]);
        }
    }
    table.print();

    let json_samples = samples
        .iter()
        .map(|(kernel, p, n, secs, speedup)| {
            format!(
                r#"{{"kernel": "{kernel}", "simd": "{p}", "n": {n}, "secs_per_call": {secs:.12}, "speedup_vs_scalar": {speedup:.3}}}"#
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    // Same policy as the structured/sparse/serve sweeps: --quick runs
    // exercise the regeneration path but divert their noisy timings to
    // the temp dir; only full measured runs overwrite the checked-in
    // baseline.
    let (status, invocation, out_path) = if fast() {
        (
            "smoke",
            "cargo bench --bench micro -- --quick --only simd-kernels",
            std::env::temp_dir().join("BENCH_simd.smoke.json"),
        )
    } else {
        (
            "measured",
            "cargo bench --bench micro -- --only simd-kernels",
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_simd.json"),
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"simd_kernels\",\n  \"status\": \"{status}\",\n  \
         \"generated_by\": \"{invocation}\",\n  \
         \"simd\": \"{}\",\n  \
         \"kernels\": {{\"samples\": [\n    {json_samples}\n  ]}}\n}}\n",
        detected.as_str(),
    );
    match std::fs::write(&out_path, json) {
        Ok(()) => println!("   baseline recorded to {}", out_path.display()),
        Err(e) => println!("   (could not write {}: {e})", out_path.display()),
    }
}

fn bench_pjrt_coordinator() {
    println!("\n== coordinator end-to-end (pjrt backend) ==");
    let name = "transform_serve";
    if !artifact_dir().join(format!("{name}.hlo.txt")).exists() {
        println!("   (skipped: run `make artifacts`)");
        return;
    }
    let meta = ArtifactMeta::parse(
        &std::fs::read_to_string(artifact_dir().join(format!("{name}.json"))).unwrap(),
    )
    .unwrap();
    let d = meta.inputs[0].shape[1];
    let n_max = meta.inputs[1].shape[0] as u32;
    let features = meta.inputs[1].shape[2];
    let mut rng = Rng::seed_from(9);
    let map = Arc::new(RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        features,
        RmConfig::default().with_max_order(n_max),
        &mut rng,
    ));
    let coord = Arc::new(Coordinator::start(
        Arc::new(PjrtTransformFactory::new(artifact_dir(), name, map).unwrap()),
        CoordinatorConfig {
            max_batch: meta.batch(),
            max_wait: Duration::from_millis(4),
            queue_depth: 8192,
            workers: 2,
            intra_op_threads: 1,
            ..Default::default()
        },
    ));
    let requests = if fast() { 400 } else { 4000 };
    let clients = 8;
    let sw = rfdot::metrics::Stopwatch::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from(200 + c as u64);
            for _ in 0..requests / clients {
                let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
                if let Ok(t) = coord.submit(x) {
                    let _ = t.wait();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = sw.elapsed_secs();
    println!("   {requests} requests in {} = {:.0} req/s", fmt_duration(dt), requests as f64 / dt);
    println!("   {}", coord.stats().summary());
}

fn bench_pjrt_bucketed_coordinator() {
    println!("\n== coordinator end-to-end (pjrt BUCKETED backend: 16/64/256) ==");
    let names = ["transform_serve_b16", "transform_serve_b64", "transform_serve"];
    if !names.iter().all(|n| artifact_dir().join(format!("{n}.hlo.txt")).exists()) {
        println!("   (skipped: run `make artifacts`)");
        return;
    }
    let meta = ArtifactMeta::parse(
        &std::fs::read_to_string(artifact_dir().join("transform_serve.json")).unwrap(),
    )
    .unwrap();
    let d = meta.inputs[0].shape[1];
    let n_max = meta.inputs[1].shape[0] as u32;
    let features = meta.inputs[1].shape[2];
    let mut rng = Rng::seed_from(9);
    let map = Arc::new(RandomMaclaurin::sample(
        &Exponential::new(1.0),
        d,
        features,
        RmConfig::default().with_max_order(n_max),
        &mut rng,
    ));
    let factory = rfdot::coordinator::PjrtBucketedFactory::new(
        artifact_dir(),
        names.iter().map(|s| s.to_string()).collect(),
        map,
    )
    .unwrap();
    let coord = Arc::new(Coordinator::start(
        Arc::new(factory),
        CoordinatorConfig {
            max_batch: meta.batch(),
            max_wait: Duration::from_millis(4),
            queue_depth: 8192,
            workers: 2,
            intra_op_threads: 1,
            ..Default::default()
        },
    ));
    let requests = if fast() { 400 } else { 4000 };
    let clients = 8;
    let sw = rfdot::metrics::Stopwatch::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from(200 + c as u64);
            for _ in 0..requests / clients {
                let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
                if let Ok(t) = coord.submit(x) {
                    let _ = t.wait();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = sw.elapsed_secs();
    println!("   {requests} requests in {} = {:.0} req/s", fmt_duration(dt), requests as f64 / dt);
    println!("   {}", coord.stats().summary());
}

/// Cost of the observability primitives (the ISSUE 7 overhead story):
/// a histogram record (paid on every serving reply), a disabled span
/// (one relaxed atomic load + an inert guard — the always-on price in
/// every transform/projection hot path) and an enabled span (two ring
/// pushes). These per-call numbers back the "tracing off must not
/// regress serve throughput" gate.
fn bench_obs_overhead() {
    use std::hint::black_box;
    println!("\n== obs primitives: histogram record / span off / span on ==");
    let iters = if fast() { 5 } else { 20 };
    let mut table = Table::new(&["primitive", "per call"]);

    let reps = 100_000u64;
    let hist = rfdot::obs::histogram("bench.obs.hist");
    let m = bench("histogram", 2, iters, || {
        for i in 0..reps {
            hist.record(black_box(i & 0xFFFF));
        }
    });
    table.row(&["histogram.record".into(), fmt_duration(m.mean_s() / reps as f64)]);

    let was = rfdot::obs::enabled();
    rfdot::obs::set_enabled(false);
    let m = bench("span-off", 2, iters, || {
        for _ in 0..reps {
            let span = rfdot::obs::span("bench.obs.span");
            black_box(&span);
        }
    });
    table.row(&["span (disabled)".into(), fmt_duration(m.mean_s() / reps as f64)]);

    // Enabled path: smaller rep count so 2 events/span fit the ring,
    // drained at the start of each timed call (the drain is part of
    // the measurement, amortized over 16k spans — the real serving
    // loop pays the same drain in its exporter).
    rfdot::obs::set_enabled(true);
    let reps_on = 16_384u64;
    let m = bench("span-on", 2, iters, || {
        let _ = rfdot::obs::trace::drain();
        for _ in 0..reps_on {
            let span = rfdot::obs::span("bench.obs.span");
            black_box(&span);
        }
    });
    table.row(&["span (enabled)".into(), fmt_duration(m.mean_s() / reps_on as f64)]);
    rfdot::obs::set_enabled(was);
    let _ = rfdot::obs::trace::drain();
    table.print();
}

/// Cold-load latency and resident footprint of serialized maps across
/// the three load paths: `owned` (the legacy seed-reconstructing
/// `RFDM0002` record), `artifact` (the zero-copy `RFDM0003` container),
/// and `recycled` (`RFDM0003` with the shared randomness pool).
/// Recorded as the machine-readable baseline in `BENCH_artifact.json`
/// at the repo root (targets: artifact load beats seeded
/// reconstruction at scale, and recycling shrinks both the record and
/// the resident bytes).
fn bench_artifact_load() {
    use rfdot::artifact::MapArtifact;
    use rfdot::maclaurin::serialize;

    println!("\n== artifact load: owned vs zero-copy vs recycled ==");
    let shapes: &[(usize, usize)] =
        if fast() { &[(22, 256)] } else { &[(22, 256), (64, 1024), (128, 4096)] };
    let iters = if fast() { 3 } else { 20 };

    let mut table = Table::new(&[
        "d", "D", "variant", "record bytes", "resident bytes", "cold load",
    ]);
    // (d, D, variant, record_bytes, resident_bytes, load_s)
    let mut samples: Vec<(usize, usize, &str, usize, i64, f64)> = Vec::new();
    for &(d, n_feat) in shapes {
        let sample_map = |recycle: bool| {
            let mut rng = Rng::seed_from(0xA21F);
            RandomMaclaurin::sample(
                &Exponential::new(1.0),
                d,
                n_feat,
                RmConfig::default()
                    .with_projection(ProjectionKind::Structured)
                    .with_recycle(recycle),
                &mut rng,
            )
        };
        let legacy = serialize::to_bytes(&sample_map(false));
        let v3 = MapArtifact::from_map(&sample_map(false)).unwrap().as_bytes().to_vec();
        let v3_recycled =
            MapArtifact::from_map(&sample_map(true)).unwrap().as_bytes().to_vec();

        for (variant, record) in
            [("owned", &legacy), ("artifact", &v3), ("recycled", &v3_recycled)]
        {
            // Cold load end to end: bytes -> usable FeatureMap. The
            // owned path reconstructs the projection from its seed; the
            // artifact paths validate + copy once and borrow.
            let load_s = bench(variant, 1, iters, || {
                serialize::from_bytes(record).expect("bench record loads")
            })
            .mean_s();
            // Resident delta while one loaded map is held: the aligned
            // region for artifact-backed maps, nothing tracked for the
            // legacy owned path (its weights live in untracked Vecs —
            // report the expanded owned footprint instead).
            let resident = if variant == "owned" {
                MapArtifact::from_bytes(record).unwrap().info().expanded_weight_bytes as i64
            } else {
                let before = rfdot::artifact::resident_bytes();
                let held = serialize::from_bytes(record).expect("bench record loads");
                let delta = rfdot::artifact::resident_bytes() - before;
                drop(held);
                delta
            };
            table.row(&[
                format!("{d}"),
                format!("{n_feat}"),
                variant.into(),
                format!("{}", record.len()),
                format!("{resident}"),
                fmt_duration(load_s),
            ]);
            samples.push((d, n_feat, variant, record.len(), resident, load_s));
        }
    }
    table.print();

    let json_samples = samples
        .iter()
        .map(|(d, n_feat, variant, bytes, resident, load_s)| {
            format!(
                r#"{{"d": {d}, "features": {n_feat}, "variant": "{variant}", "record_bytes": {bytes}, "resident_bytes": {resident}, "load_s": {load_s:.9}}}"#
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    // Same policy as the structured/sparse/serve sweeps: --quick runs
    // exercise the regeneration path but divert their noisy timings to
    // the temp dir; only full measured runs overwrite the baseline.
    let (status, invocation, path) = if fast() {
        (
            "smoke",
            "cargo bench --bench micro -- --quick --only artifact-load",
            std::env::temp_dir().join("BENCH_artifact.smoke.json"),
        )
    } else {
        (
            "measured",
            "cargo bench --bench micro -- --only artifact-load",
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_artifact.json"),
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"artifact_load\",\n  \"status\": \"{status}\",\n  \
         \"generated_by\": \"{invocation}\",\n  \
         \"artifact\": {{\"samples\": [\n    {json_samples}\n  ]}}\n}}\n"
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("   baseline recorded to {}", path.display()),
        Err(e) => println!("   (could not write {}: {e})", path.display()),
    }
}

/// TCP round-trip throughput over loopback: the network serving tier
/// end to end (client → RFNP framing → registry → coordinator → reply
/// frame), across clients × pipeline depth. Depth 1 is the synchronous
/// request/reply cost; depth 16 keeps the wire and the batcher busy and
/// amortizes the per-frame syscalls. Recorded as the machine-readable
/// baseline in `BENCH_net.json` at the repo root (gated on the
/// secs_per_req column by `rfdot bench-diff`), together with the
/// faults-disabled failpoint overhead probe — the "chaos hooks off
/// must cost one relaxed load" gate from the fault-injection tier.
fn bench_net_roundtrip() {
    use rfdot::net::{NetClient, NetConfig, NetServer, Registry};
    println!("\n== net round trip: clients x pipeline depth over loopback ==");
    let (d, n_feat) = (22usize, 512usize);
    let requests = if fast() { 200 } else { 2000 };
    let mut rng = Rng::seed_from(77);
    let map =
        RandomMaclaurin::sample(&Exponential::new(1.0), d, n_feat, RmConfig::default(), &mut rng);
    let artifact = Arc::new(rfdot::artifact::MapArtifact::from_map(&map).unwrap());
    let registry = Arc::new(Registry::new(CoordinatorConfig {
        max_batch: 128,
        max_wait: Duration::from_micros(200),
        queue_depth: 8192,
        workers: 2,
        intra_op_threads: 1,
        ..Default::default()
    }));
    registry.insert("bench", artifact).unwrap();
    let mut server = NetServer::start(registry.clone(), NetConfig::default()).unwrap();
    let addr = server.local_addr();

    let mut table = Table::new(&["clients", "depth", "req/s", "secs/req"]);
    // (clients, pipeline depth, reqs_per_s, secs_per_req)
    let mut samples: Vec<(usize, usize, f64, f64)> = Vec::new();
    for &clients in &[1usize, 4] {
        for &depth in &[1usize, 16] {
            let sw = rfdot::metrics::Stopwatch::start();
            let mut handles = Vec::new();
            for c in 0..clients {
                handles.push(std::thread::spawn(move || {
                    let mut client =
                        NetClient::connect(addr, Duration::from_secs(30)).unwrap();
                    let mut rng = Rng::seed_from(500 + c as u64);
                    let mut ok = 0usize;
                    let mut left = requests / clients;
                    while left > 0 {
                        let take = left.min(depth);
                        left -= take;
                        for _ in 0..take {
                            let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
                            client.send_dense("bench", x).unwrap();
                        }
                        for _ in 0..take {
                            ok += usize::from(client.recv_reply().is_ok());
                        }
                    }
                    ok
                }));
            }
            let completed: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
            let dt = sw.elapsed_secs().max(1e-9);
            let reqs_per_s = completed as f64 / dt;
            let secs_per_req = dt / completed.max(1) as f64;
            table.row(&[
                format!("{clients}"),
                format!("{depth}"),
                format!("{reqs_per_s:.0}"),
                fmt_duration(secs_per_req),
            ]);
            samples.push((clients, depth, reqs_per_s, secs_per_req));
        }
    }
    table.print();
    server.shutdown();
    drop(server);
    registry.shutdown();

    // Faults-disabled overhead probe: every request above crossed the
    // serving tier's failpoints (accept/read/write, submit, reply, ...)
    // with no plan installed, and the contract is that each such
    // crossing costs one relaxed atomic load. Pin that price against a
    // raw `AtomicU8` relaxed-load baseline, plus the armed-elsewhere
    // cost (plan installed, some OTHER site armed — the plan-lookup
    // price a production `--faults` run pays on unarmed sites). The
    // disabled cost lands in `BENCH_net.json` so `rfdot bench-diff`
    // gates it like any other timing.
    use std::hint::black_box;
    use std::sync::atomic::{AtomicU8, Ordering};
    println!("\n   faults overhead: disabled failpoint vs raw relaxed load");
    rfdot::faults::clear();
    let iters = if fast() { 5 } else { 20 };
    let reps = 1_000_000u64;
    static RAW: AtomicU8 = AtomicU8::new(1);
    let raw_s = bench("faults-atomic-load", 2, iters, || {
        for _ in 0..reps {
            black_box(RAW.load(Ordering::Relaxed));
        }
    })
    .mean_s()
        / reps as f64;
    let off_s = bench("faults-failpoint-off", 2, iters, || {
        for _ in 0..reps {
            let _ = black_box(rfdot::faults::failpoint("net.write"));
        }
    })
    .mean_s()
        / reps as f64;
    rfdot::faults::install_spec("seed=1,net.accept=error").unwrap();
    let armed_s = bench("faults-armed-elsewhere", 2, iters, || {
        for _ in 0..reps {
            let _ = black_box(rfdot::faults::failpoint("net.write"));
        }
    })
    .mean_s()
        / reps as f64;
    rfdot::faults::clear();
    let mut ftable = Table::new(&["probe", "per call"]);
    ftable.row(&["raw relaxed load (baseline)".into(), fmt_duration(raw_s)]);
    ftable.row(&["failpoint (disabled)".into(), fmt_duration(off_s)]);
    ftable.row(&["failpoint (armed elsewhere)".into(), fmt_duration(armed_s)]);
    ftable.print();

    let json_samples = samples
        .iter()
        .map(|(clients, depth, rps, spr)| {
            format!(
                r#"{{"clients": {clients}, "batch": {depth}, "reqs_per_s": {rps:.1}, "secs_per_req": {spr:.9}}}"#
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    // Same policy as the other sweeps: --quick runs exercise the
    // regeneration path but divert their noisy timings to the temp dir;
    // only full measured runs overwrite the checked-in baseline.
    let (status, invocation, path) = if fast() {
        (
            "smoke",
            "cargo bench --bench micro -- --quick --only net-roundtrip",
            std::env::temp_dir().join("BENCH_net.smoke.json"),
        )
    } else {
        (
            "measured",
            "cargo bench --bench micro -- --only net-roundtrip",
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_net.json"),
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"net_roundtrip\",\n  \"status\": \"{status}\",\n  \
         \"generated_by\": \"{invocation}\",\n  \
         \"net\": {{\"d\": {d}, \"features\": {n_feat}, \"requests\": {requests}, \
         \"samples\": [\n    {json_samples}\n  ],\n    \
         \"faults_overhead\": {{\"atomic_load_secs_per_call\": {raw_s:.12}, \
         \"failpoint_off_secs_per_call\": {off_s:.12}, \
         \"failpoint_armed_other_site_secs_per_call\": {armed_s:.12}}}}}\n}}\n"
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("   baseline recorded to {}", path.display()),
        Err(e) => println!("   (could not write {}: {e})", path.display()),
    }
}

fn bench_solvers() {
    println!("\n== svm solver throughput (nursery surrogate, scale 0.05) ==");
    use rfdot::data::UciSurrogate;
    use rfdot::svm::{KernelSvm, LinearSvm, LinearSvmParams, SmoParams};
    let ds = UciSurrogate::Nursery.load(0.05, 11);
    let mut rng = Rng::seed_from(12);
    let (train, _) = ds.split(0.6, 20_000, &mut rng);
    let kernel = rfdot::kernels::Polynomial::new(10, 1.0);

    let (model, t) = rfdot::bench::time_once(|| {
        KernelSvm::train(&train, Box::new(kernel), SmoParams::default()).unwrap()
    });
    println!(
        "   SMO: {} for {} examples ({} SVs, {} iters)",
        fmt_duration(t),
        train.len(),
        model.n_support(),
        model.iterations
    );

    let map = RandomMaclaurin::sample(&kernel, train.dim(), 500, RmConfig::default(), &mut rng);
    let z = map.transform_batch(train.x());
    let zds = rfdot::data::Dataset::new("z", z, train.y.clone()).unwrap();
    let (lin, t) = rfdot::bench::time_once(|| {
        LinearSvm::train(&zds, LinearSvmParams::default()).unwrap()
    });
    println!(
        "   DCD (D=500): {} for {} examples ({} epochs)",
        fmt_duration(t),
        zds.len(),
        lin.epochs
    );
}

fn main() {
    // `cargo bench --bench micro -- [--quick] [--only <substr>]`.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut only: Option<String> = None;
    let mut it = argv.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--quick" => std::env::set_var("RFDOT_MICRO_FAST", "1"),
            "--only" => match it.next() {
                Some(pat) => only = Some(pat.clone()),
                None => {
                    eprintln!("--only requires a section-name pattern");
                    std::process::exit(2);
                }
            },
            "--bench" | "--nocapture" => {} // libtest-style passthrough noise
            other => eprintln!("warning: unknown bench arg {other:?} ignored"),
        }
    }

    let sections: [(&str, fn()); 15] = [
        ("native-transform", bench_native_transform),
        ("parallel-sweep", bench_parallel_sweep),
        ("structured-sweep", bench_structured_sweep),
        ("sparse-transform", bench_sparse_transform),
        ("rademacher-projection", bench_rademacher_projection),
        ("simd-kernels", bench_simd_kernels),
        ("pjrt-execute", bench_pjrt_execute),
        ("coordinator-roundtrip", bench_coordinator_roundtrip),
        ("serve-throughput", bench_serve_throughput),
        ("net-roundtrip", bench_net_roundtrip),
        ("artifact-load", bench_artifact_load),
        ("pjrt-coordinator", bench_pjrt_coordinator),
        ("pjrt-bucketed-coordinator", bench_pjrt_bucketed_coordinator),
        ("obs-overhead", bench_obs_overhead),
        ("solvers", bench_solvers),
    ];
    let mut ran = 0;
    for (name, f) in sections {
        if only.as_deref().map_or(true, |pat| name.contains(pat)) {
            f();
            ran += 1;
        }
    }
    if ran == 0 {
        eprintln!("no bench section matches --only {:?}", only.as_deref().unwrap_or(""));
        std::process::exit(2);
    }
}
