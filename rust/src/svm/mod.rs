//! Support vector machine substrates.
//!
//! The paper benchmarks random feature maps by replacing
//! `kernel + LIBSVM` with `features + LIBLINEAR`. Neither library is
//! reachable in this environment, so both solvers are implemented here:
//!
//! * [`smo`] — a working-set SMO dual solver with an LRU kernel-row
//!   cache, the LIBSVM algorithm family. Its prediction cost is
//!   `O(n_sv · d)` per example — the paper's *curse of support* that the
//!   random features eliminate.
//! * [`linear`] — dual coordinate descent for linear SVMs
//!   (Hsieh et al., ICML 2008), the LIBLINEAR algorithm. Training is
//!   `O(nnz)` per epoch and prediction is a single dot product.
//!
//! Both expose [`Classifier`] so the bench harness can time
//! `train`/`predict` uniformly.

pub mod linear;
pub mod smo;

pub use linear::{LinearLoss, LinearSvm, LinearSvmParams};
pub use smo::{KernelSvm, SmoParams};

use crate::data::Dataset;
use crate::linalg::Matrix;

/// A trained binary classifier.
pub trait Classifier: Send + Sync {
    /// Decision value for one example (sign = predicted label).
    fn decision(&self, x: &[f32]) -> f32;

    /// Approximate mul-adds per [`Classifier::decision`] call on a
    /// `input_dim`-dimensional example — sizes the parallel fan-out in
    /// [`Classifier::accuracy`]. Defaults to one dot product (linear
    /// models); kernel machines override with their `O(n_sv · d)` cost.
    fn decision_cost(&self, input_dim: usize) -> usize {
        input_dim
    }

    /// Predicted label in {−1, +1}.
    fn predict(&self, x: &[f32]) -> f32 {
        if self.decision(x) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fraction of correct predictions on a labeled set. Predictions
    /// are independent and the reduction is an integer count, so the
    /// rows fan out over the [`crate::parallel`] worker budget with
    /// exactly the serial result.
    fn accuracy(&self, x: &Matrix, y: &[f32]) -> f64 {
        assert_eq!(x.rows(), y.len());
        if y.is_empty() {
            return 0.0;
        }
        let work = x.rows().saturating_mul(self.decision_cost(x.cols()).max(1));
        let threads = crate::parallel::resolve_threads_for_work(0, x.rows(), work);
        let correct = crate::parallel::par_sum_usize(threads, x.rows(), |range| {
            range.filter(|&i| self.predict(x.row(i)) == y[i]).count()
        });
        correct as f64 / y.len() as f64
    }

    /// Accuracy on a [`Dataset`] (sparse datasets score through their
    /// cached dense view; training is where the sparse fast paths live).
    fn accuracy_on(&self, ds: &Dataset) -> f64 {
        self.accuracy(ds.x(), &ds.y)
    }
}

#[cfg(test)]
pub(crate) mod testdata {
    use crate::data::Dataset;
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    /// Linearly separable 2-D blobs with margin.
    pub fn blobs(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from(seed);
        let mut rows = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            let cx = if label > 0.0 { 1.5 } else { -1.5 };
            rows.push(vec![cx + 0.5 * rng.normal() as f32, 0.5 * rng.normal() as f32]);
            y.push(label);
        }
        Dataset::new("blobs", Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    /// XOR-like dataset: not linearly separable, easy for a quadratic
    /// kernel.
    pub fn xor(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from(seed);
        let mut rows = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.f32() * 2.0 - 1.0;
            let b = rng.f32() * 2.0 - 1.0;
            rows.push(vec![a, b]);
            y.push(if a * b >= 0.0 { 1.0 } else { -1.0 });
        }
        Dataset::new("xor", Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }
}
