//! Linear SVM via dual coordinate descent — the LIBLINEAR stand-in.
//!
//! Implements Hsieh et al., *"A Dual Coordinate Descent Method for
//! Large-scale Linear SVM"* (ICML 2008): the algorithm behind
//! LIBLINEAR's default L2-loss dual solver, with random permutation of
//! coordinates each epoch and the projected-gradient stopping rule.
//!
//! This is what the paper pairs with the random feature maps: training
//! touches each example O(1) times per epoch with `O(d)` work, and
//! prediction is a single `O(d)` dot product — no support set, no curse.
//!
//! A bias term is handled the standard LIBLINEAR way: an appended
//! constant feature with value `bias_scale` (0 disables it).

use super::Classifier;
use crate::data::{Dataset, Storage};
use crate::linalg::{dot, Matrix, SparseMatrix};
use crate::rng::Rng;
use crate::{Error, Result};

/// Loss flavor for the dual solver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinearLoss {
    /// L1-loss (hinge): box constraint `0 ≤ α ≤ C`.
    Hinge,
    /// L2-loss (squared hinge): diagonal regularization, `α ≥ 0`.
    SquaredHinge,
}

/// Hyper-parameters for [`LinearSvm`].
#[derive(Clone, Copy, Debug)]
pub struct LinearSvmParams {
    pub c: f64,
    pub loss: LinearLoss,
    /// Stop when the maximal projected gradient spread falls below this.
    pub tol: f64,
    pub max_epochs: usize,
    /// Appended-constant bias feature value; 0 disables the bias.
    pub bias_scale: f32,
    /// RNG seed for the per-epoch coordinate permutation.
    pub seed: u64,
}

impl Default for LinearSvmParams {
    fn default() -> Self {
        LinearSvmParams {
            c: 1.0,
            loss: LinearLoss::SquaredHinge,
            tol: 1e-3,
            max_epochs: 200,
            bias_scale: 1.0,
            seed: 0x5EED,
        }
    }
}

/// A trained linear SVM `sign(wᵀx + b)`.
pub struct LinearSvm {
    w: Vec<f32>,
    b: f32,
    /// Epochs the solver ran.
    pub epochs: usize,
    /// Final projected-gradient spread (convergence diagnostic).
    pub final_violation: f64,
}

/// The solver's view of the training rows: dense rows use the 4-lane
/// [`dot`] / [`crate::linalg::axpy`] pair, CSR rows the LIBLINEAR-style
/// `O(nnz)` walk over stored entries. The sparse reductions replicate
/// the dense lane structure by column position
/// ([`crate::linalg::SparseRow::dot_dense`]), so the two storages run
/// the *same* optimization trajectory — equal weights, bias and epoch
/// count for equal data (pinned by `rust/tests/sparse_parity.rs`).
enum RowsView<'a> {
    Dense(&'a Matrix),
    Sparse(&'a SparseMatrix),
}

impl RowsView<'_> {
    /// `‖x_i‖²` with the dense path's accumulation structure.
    fn self_dot(&self, i: usize) -> f64 {
        match self {
            RowsView::Dense(x) => {
                let r = x.row(i);
                dot(r, r) as f64
            }
            RowsView::Sparse(s) => s.row(i).self_dot() as f64,
        }
    }

    /// `⟨w, x_i⟩` with the dense path's accumulation structure.
    fn dot_w(&self, i: usize, w: &[f32]) -> f32 {
        match self {
            RowsView::Dense(x) => dot(w, x.row(i)),
            RowsView::Sparse(s) => s.row(i).dot_dense(w),
        }
    }

    /// `w += delta · x_i` (`O(d)` dense, `O(nnz)` sparse).
    fn axpy(&self, i: usize, delta: f32, w: &mut [f32]) {
        match self {
            RowsView::Dense(x) => crate::linalg::axpy(delta, x.row(i), w),
            RowsView::Sparse(s) => s.row(i).axpy_into(delta, w),
        }
    }

    /// Approximate mul-adds per row touch (scheduling hint).
    fn unit_work(&self, n: usize, d: usize) -> usize {
        match self {
            RowsView::Dense(_) => d.max(1),
            RowsView::Sparse(s) => (s.nnz() / n.max(1)).max(1),
        }
    }
}

impl LinearSvm {
    /// Train with dual coordinate descent. Dispatches on the dataset's
    /// [`Storage`]: CSR training touches only the stored entries of
    /// each row (LIBLINEAR's sparse formulation) yet follows the exact
    /// trajectory of the dense solver, so the fitted model is equal for
    /// equal data whichever storage carries it.
    pub fn train(ds: &Dataset, params: LinearSvmParams) -> Result<Self> {
        let n = ds.len();
        if n == 0 {
            return Err(Error::Solver("empty training set".into()));
        }
        if !(params.c > 0.0) {
            return Err(Error::Config(format!("C must be positive, got {}", params.c)));
        }
        let d = ds.dim();
        let use_bias = params.bias_scale != 0.0;
        let y = &ds.y;
        let x = match ds.storage() {
            Storage::Dense(m) => RowsView::Dense(m),
            Storage::Sparse(s) => RowsView::Sparse(s),
        };

        // Diagonal shift and upper bound per loss (Hsieh et al. Table 1).
        let (diag, upper) = match params.loss {
            LinearLoss::Hinge => (0.0, params.c),
            LinearLoss::SquaredHinge => (0.5 / params.c, f64::INFINITY),
        };

        let mut w = vec![0.0f32; d];
        let mut b = 0.0f32;
        let mut alpha = vec![0.0f64; n];
        // ||x_i||^2 (+ bias^2) + diag, precomputed. Rows are independent
        // so this fans out over the parallel worker budget. The epochs
        // below stay sequential on purpose: each coordinate update reads
        // the `w` left by the previous one, so any parallel reordering
        // would change the trajectory and break the solver's bit-exact
        // reproducibility for a fixed seed.
        let bias2 =
            if use_bias { (params.bias_scale * params.bias_scale) as f64 } else { 0.0 };
        let qii_threads = crate::parallel::resolve_threads_for_work(
            0,
            n,
            n.saturating_mul(x.unit_work(n, d)),
        );
        let qii: Vec<f64> =
            crate::parallel::par_map(qii_threads, n, |i| x.self_dot(i) + bias2 + diag);

        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seed_from(params.seed);
        let mut epochs = 0usize;
        let mut final_violation = f64::INFINITY;

        for epoch in 0..params.max_epochs {
            epochs = epoch + 1;
            rng.shuffle(&mut order);
            let mut pg_max = f64::NEG_INFINITY;
            let mut pg_min = f64::INFINITY;
            for &i in &order {
                let yi = y[i] as f64;
                // G = y_i (w·x_i + b·s) − 1 + diag·α_i
                let g = yi * (x.dot_w(i, &w) as f64 + (b * params.bias_scale) as f64) - 1.0
                    + diag * alpha[i];
                // Projected gradient.
                let pg = if alpha[i] <= 0.0 {
                    g.min(0.0)
                } else if alpha[i] >= upper {
                    g.max(0.0)
                } else {
                    g
                };
                // A zero projected gradient means the coordinate is at
                // its box and stays put — it only contributes its zero
                // to the spread.
                pg_max = pg_max.max(pg);
                pg_min = pg_min.min(pg);
                if pg != 0.0 {
                    // Newton step on the coordinate, clipped to the box.
                    let old = alpha[i];
                    alpha[i] = (old - g / qii[i]).clamp(0.0, upper);
                    let delta = ((alpha[i] - old) * yi) as f32;
                    if delta != 0.0 {
                        x.axpy(i, delta, &mut w);
                        if use_bias {
                            b += delta * params.bias_scale;
                        }
                    }
                }
            }
            final_violation = pg_max - pg_min;
            if final_violation < params.tol {
                break;
            }
        }

        Ok(LinearSvm { w, b: b * params.bias_scale, epochs, final_violation })
    }

    /// Weight vector.
    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Bias term.
    pub fn bias(&self) -> f32 {
        self.b
    }
}

impl Classifier for LinearSvm {
    fn decision(&self, x: &[f32]) -> f32 {
        dot(&self.w, x) + self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::testdata::{blobs, xor};

    #[test]
    fn separable_blobs_converge() {
        let ds = blobs(400, 1);
        let model = LinearSvm::train(&ds, LinearSvmParams::default()).unwrap();
        assert!(model.accuracy_on(&ds) > 0.97, "acc {}", model.accuracy_on(&ds));
        assert!(model.final_violation < 1e-2);
    }

    #[test]
    fn hinge_and_squared_hinge_agree_on_easy_data() {
        let ds = blobs(300, 2);
        let h = LinearSvm::train(
            &ds,
            LinearSvmParams { loss: LinearLoss::Hinge, ..Default::default() },
        )
        .unwrap();
        let s = LinearSvm::train(&ds, LinearSvmParams::default()).unwrap();
        assert!(h.accuracy_on(&ds) > 0.97);
        assert!(s.accuracy_on(&ds) > 0.97);
    }

    #[test]
    fn xor_is_not_linearly_solvable() {
        let ds = xor(400, 3);
        let model = LinearSvm::train(&ds, LinearSvmParams::default()).unwrap();
        assert!(model.accuracy_on(&ds) < 0.7, "xor acc {}", model.accuracy_on(&ds));
    }

    #[test]
    fn bias_matters_for_shifted_data() {
        // Both blobs on the same side of the origin: without bias a
        // homogeneous hyperplane through 0 cannot separate them.
        let mut rows = Vec::new();
        let mut y = Vec::new();
        let mut rng = crate::rng::Rng::seed_from(4);
        for i in 0..300 {
            let label = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            let cx = if label > 0.0 { 3.0 } else { 1.5 };
            rows.push(vec![cx + 0.2 * rng.normal() as f32, 1.0 + 0.2 * rng.normal() as f32]);
            y.push(label);
        }
        let ds = crate::data::Dataset::new(
            "shifted",
            crate::linalg::Matrix::from_rows(&rows).unwrap(),
            y,
        )
        .unwrap();
        let with_bias = LinearSvm::train(&ds, LinearSvmParams::default()).unwrap();
        let without = LinearSvm::train(
            &ds,
            LinearSvmParams { bias_scale: 0.0, ..Default::default() },
        )
        .unwrap();
        assert!(with_bias.accuracy_on(&ds) > 0.95, "with bias {}", with_bias.accuracy_on(&ds));
        assert!(
            with_bias.accuracy_on(&ds) >= without.accuracy_on(&ds),
            "bias should not hurt"
        );
    }

    #[test]
    fn dual_feasibility() {
        // After training, alphas are feasible by construction; check the
        // primal-side consequence: w is a combination of training
        // examples => ||w|| is bounded by C * sum ||x_i||.
        let ds = blobs(100, 5);
        let model = LinearSvm::train(&ds, LinearSvmParams::default()).unwrap();
        let bound: f32 = (0..ds.len())
            .map(|i| crate::linalg::norm2(ds.x().row(i)))
            .sum::<f32>();
        assert!(crate::linalg::norm2(model.weights()) <= bound);
    }

    #[test]
    fn rejects_bad_config() {
        let ds = blobs(10, 6);
        assert!(LinearSvm::train(&ds, LinearSvmParams { c: -1.0, ..Default::default() }).is_err());
        let empty = crate::data::Dataset::new(
            "e",
            crate::linalg::Matrix::zeros(0, 2),
            vec![],
        )
        .unwrap();
        assert!(LinearSvm::train(&empty, LinearSvmParams::default()).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = blobs(200, 7);
        let m1 = LinearSvm::train(&ds, LinearSvmParams::default()).unwrap();
        let m2 = LinearSvm::train(&ds, LinearSvmParams::default()).unwrap();
        assert_eq!(m1.weights(), m2.weights());
        assert_eq!(m1.bias(), m2.bias());
    }

    #[test]
    fn zero_pg_arm_bookkeeping_is_honest() {
        // Regression for the dead `g = g.max(g); let _ = g;` no-op
        // branch: the simplified bookkeeping must still count a zero
        // projected gradient into the spread only as a zero, and the
        // boxed arm must actually be exercised. Hinge loss with a tiny C
        // saturates alphas at the box, so later epochs hit pg == 0 on
        // both clamps; the solver must still converge deterministically.
        let ds = blobs(150, 9);
        let params = LinearSvmParams {
            loss: LinearLoss::Hinge,
            c: 0.01,
            ..Default::default()
        };
        let m1 = LinearSvm::train(&ds, params).unwrap();
        let m2 = LinearSvm::train(&ds, params).unwrap();
        assert_eq!(m1.weights(), m2.weights(), "zero-pg arm must not perturb the trajectory");
        assert_eq!(m1.bias(), m2.bias());
        assert_eq!(m1.epochs, m2.epochs);
        assert!(m1.final_violation < params.tol, "violation {}", m1.final_violation);
        assert!(m1.accuracy_on(&ds) > 0.9);
    }

    #[test]
    fn sparse_training_matches_dense_exactly() {
        // CSR rows follow the dense trajectory step for step: equal
        // weights, bias, epoch count and decisions.
        let dense = blobs(180, 13);
        let sparse = dense.clone().into_sparse();
        for loss in [LinearLoss::Hinge, LinearLoss::SquaredHinge] {
            let params = LinearSvmParams { loss, ..Default::default() };
            let md = LinearSvm::train(&dense, params).unwrap();
            let ms = LinearSvm::train(&sparse, params).unwrap();
            assert_eq!(md.weights(), ms.weights(), "{loss:?}");
            assert_eq!(md.bias(), ms.bias(), "{loss:?}");
            assert_eq!(md.epochs, ms.epochs, "{loss:?}");
            for i in 0..dense.len() {
                assert_eq!(md.decision(dense.x().row(i)), ms.decision(dense.x().row(i)));
            }
        }
    }

    #[test]
    fn rf_features_make_xor_linear() {
        // The paper's whole point: xor + quadratic-kernel RM features
        // become linearly separable.
        use crate::kernels::Homogeneous;
        use crate::features::FeatureMap;
        use crate::maclaurin::{RandomMaclaurin, RmConfig};
        let mut ds = xor(600, 8);
        ds.normalize_rows();
        let mut rng = crate::rng::Rng::seed_from(9);
        let map = RandomMaclaurin::sample(&Homogeneous::new(2), 2, 128, RmConfig::default(), &mut rng);
        let z = map.transform_batch(ds.x());
        let zds = crate::data::Dataset::new("xor-rf", z, ds.y.clone()).unwrap();
        let model = LinearSvm::train(&zds, LinearSvmParams::default()).unwrap();
        let acc = model.accuracy_on(&zds);
        assert!(acc > 0.93, "rf-linear acc on xor {acc}");
    }
}
