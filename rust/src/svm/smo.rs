//! Kernel SVM via SMO with working-set selection — the LIBSVM stand-in.
//!
//! Solves the C-SVM dual
//! `min ½ αᵀQα − eᵀα  s.t. 0 ≤ α_i ≤ C, yᵀα = 0` with
//! `Q_ij = y_i y_j K(x_i, x_j)` using LIBSVM's WSS-1 (maximal violating
//! pair) selection, an LRU kernel-row cache and shrinking-free plain
//! iteration (our problem sizes after the paper's 20k training cap make
//! the cache the part that matters).
//!
//! The trained model predicts with
//! `sign(Σ_{i ∈ SV} α_i y_i K(x_i, x))` — `O(n_sv · d)` per test point,
//! which is exactly the *curse of support* (§1) the Random Maclaurin
//! features are designed to remove.

use super::Classifier;
use crate::data::Dataset;
use crate::kernels::DotProductKernel;
use crate::linalg::Matrix;
use crate::{Error, Result};

/// SMO hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct SmoParams {
    /// Soft-margin parameter `C`.
    pub c: f64,
    /// KKT violation tolerance (LIBSVM default 1e-3).
    pub tol: f64,
    /// Hard cap on optimization iterations.
    pub max_iter: usize,
    /// Kernel cache budget in rows (LRU).
    pub cache_rows: usize,
}

impl Default for SmoParams {
    fn default() -> Self {
        SmoParams { c: 1.0, tol: 1e-3, max_iter: 200_000, cache_rows: 512 }
    }
}

/// LRU cache of kernel matrix rows.
struct RowCache {
    /// slot -> (owner index, row values)
    slots: Vec<(usize, Vec<f32>)>,
    /// example index -> slot + recency stamp
    lookup: Vec<Option<(usize, u64)>>,
    clock: u64,
    capacity: usize,
}

impl RowCache {
    fn new(n: usize, capacity: usize) -> Self {
        RowCache {
            slots: Vec::new(),
            lookup: vec![None; n],
            clock: 0,
            capacity: capacity.max(2),
        }
    }

    /// Fetch row `i`, computing it with `compute` on a miss.
    fn get(&mut self, i: usize, compute: impl FnOnce() -> Vec<f32>) -> &[f32] {
        self.clock += 1;
        if let Some((slot, _)) = self.lookup[i] {
            self.lookup[i] = Some((slot, self.clock));
            return &self.slots[slot].1;
        }
        let row = compute();
        let slot = if self.slots.len() < self.capacity {
            self.slots.push((i, row));
            self.slots.len() - 1
        } else {
            // Evict the least recently used slot.
            let victim = self
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, (owner, _))| {
                    self.lookup[*owner].map(|(_, t)| t).unwrap_or(0)
                })
                .map(|(s, _)| s)
                .expect("cache is non-empty");
            let old_owner = self.slots[victim].0;
            self.lookup[old_owner] = None;
            self.slots[victim] = (i, row);
            victim
        };
        self.lookup[i] = Some((slot, self.clock));
        &self.slots[slot].1
    }
}

/// A trained kernel SVM model.
pub struct KernelSvm {
    /// Support vectors (rows).
    sv: Matrix,
    /// `α_i y_i` per support vector.
    sv_coef: Vec<f32>,
    /// Decision bias `b` (decision = Σ coef·K(sv, x) + b). For a free
    /// SV the KKT conditions give `b = −y_i·grad_i`.
    bias: f64,
    kernel: Box<dyn DotProductKernel>,
    /// Iterations the solver used.
    pub iterations: usize,
}

impl KernelSvm {
    /// Train on a dataset with SMO.
    pub fn train(
        ds: &Dataset,
        kernel: Box<dyn DotProductKernel>,
        params: SmoParams,
    ) -> Result<Self> {
        let n = ds.len();
        if n < 2 {
            return Err(Error::Solver("need at least 2 training examples".into()));
        }
        if !(params.c > 0.0) {
            return Err(Error::Config(format!("C must be positive, got {}", params.c)));
        }
        let y = &ds.y;
        let x = ds.x();

        // Gradient of the dual objective: g_i = (Qα)_i − 1; starts at −1.
        let mut alpha = vec![0.0f64; n];
        let mut grad = vec![-1.0f64; n];
        let mut cache = RowCache::new(n, params.cache_rows);

        let kernel_row = |i: usize| -> Vec<f32> {
            (0..n).map(|j| kernel.eval(x.row(i), x.row(j)) as f32).collect()
        };

        let mut iterations = 0usize;
        loop {
            // WSS-1: i = argmax over "up" set of −y_i g_i,
            //        j = argmin over "down" set of −y_j g_j.
            let mut g_max = f64::NEG_INFINITY;
            let mut g_min = f64::INFINITY;
            let mut i_sel = usize::MAX;
            let mut j_sel = usize::MAX;
            for t in 0..n {
                let yg = -y[t] as f64 * grad[t];
                let up = (y[t] > 0.0 && alpha[t] < params.c) || (y[t] < 0.0 && alpha[t] > 0.0);
                let down = (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < params.c);
                if up && yg > g_max {
                    g_max = yg;
                    i_sel = t;
                }
                if down && yg < g_min {
                    g_min = yg;
                    j_sel = t;
                }
            }
            if i_sel == usize::MAX || j_sel == usize::MAX || g_max - g_min < params.tol {
                break;
            }
            if iterations >= params.max_iter {
                break;
            }
            iterations += 1;

            let (i, j) = (i_sel, j_sel);
            let k_ii = kernel.eval(x.row(i), x.row(i));
            let k_jj = kernel.eval(x.row(j), x.row(j));
            let k_ij = kernel.eval(x.row(i), x.row(j));
            let eta = (k_ii + k_jj - 2.0 * k_ij).max(1e-12);

            // Working-set sub-problem (classic two-variable update).
            let yi = y[i] as f64;
            let yj = y[j] as f64;
            let delta = (-yi * grad[i] + yj * grad[j]) / eta;
            let (old_ai, old_aj) = (alpha[i], alpha[j]);
            let mut ai = old_ai + yi * delta;
            // Clip to the box along the equality constraint.
            let sum = yi * old_ai + yj * old_aj;
            ai = ai.clamp(0.0, params.c);
            let mut aj = yj * (sum - yi * ai);
            aj = aj.clamp(0.0, params.c);
            ai = yi * (sum - yj * aj);
            ai = ai.clamp(0.0, params.c);
            alpha[i] = ai;
            alpha[j] = aj;

            // Gradient update with the two touched rows.
            let (d_i, d_j) = (alpha[i] - old_ai, alpha[j] - old_aj);
            if d_i != 0.0 {
                let row_i = cache.get(i, || kernel_row(i));
                for t in 0..n {
                    grad[t] += d_i * yi * y[t] as f64 * row_i[t] as f64;
                }
            }
            if d_j != 0.0 {
                let row_j = cache.get(j, || kernel_row(j));
                for t in 0..n {
                    grad[t] += d_j * yj * y[t] as f64 * row_j[t] as f64;
                }
            }
        }

        // Bias: average of −y_i g_i over free vectors, else midpoint of
        // the feasible interval.
        let mut bias_sum = 0.0;
        let mut bias_cnt = 0usize;
        let (mut ub, mut lb) = (f64::INFINITY, f64::NEG_INFINITY);
        for t in 0..n {
            let yg = -(y[t] as f64) * grad[t];
            if alpha[t] > 1e-12 && alpha[t] < params.c - 1e-12 {
                bias_sum += yg;
                bias_cnt += 1;
            }
            let up = (y[t] > 0.0 && alpha[t] < params.c) || (y[t] < 0.0 && alpha[t] > 0.0);
            let down = (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < params.c);
            if up {
                ub = ub.min(yg);
            }
            if down {
                lb = lb.max(yg);
            }
        }
        let bias = if bias_cnt > 0 { bias_sum / bias_cnt as f64 } else { (ub + lb) / 2.0 };

        // Collect support vectors.
        let mut sv_rows = Vec::new();
        let mut sv_coef = Vec::new();
        for t in 0..n {
            if alpha[t] > 1e-12 {
                sv_rows.push(x.row(t).to_vec());
                sv_coef.push((alpha[t] * y[t] as f64) as f32);
            }
        }
        if sv_rows.is_empty() {
            return Err(Error::Solver("no support vectors found".into()));
        }
        Ok(KernelSvm {
            sv: Matrix::from_rows(&sv_rows).expect("uniform rows"),
            sv_coef,
            bias,
            kernel,
            iterations,
        })
    }

    /// Number of support vectors — the prediction cost driver.
    pub fn n_support(&self) -> usize {
        self.sv_coef.len()
    }

    /// Maximal KKT violation of a (re-)evaluated model on its training
    /// set — exposed for convergence tests.
    pub fn kkt_violation(&self, ds: &Dataset, c: f64) -> f64 {
        // Recompute functional margins; violation per point:
        //   alpha = 0   requires y f(x) >= 1
        //   0 < a < C   requires y f(x) == 1
        //   alpha = C   requires y f(x) <= 1
        // We do not retain alphas per training point here, so measure the
        // weaker (but sufficient for our tests) hinge-KKT residual on
        // margin violations of non-SVs:
        let mut worst = 0.0f64;
        for i in 0..ds.len() {
            let m = ds.y[i] as f64 * self.decision(ds.x().row(i)) as f64;
            // Any point with margin < 1 must be "paying" at most C; the
            // residual we can check without alphas is margin deficit
            // beyond the soft-margin allowance:
            if m < -1.0 - c {
                worst = worst.max(-1.0 - c - m);
            }
        }
        worst
    }
}

impl Classifier for KernelSvm {
    fn decision(&self, x: &[f32]) -> f32 {
        let mut acc = 0.0f64;
        for (i, &coef) in self.sv_coef.iter().enumerate() {
            acc += coef as f64 * self.kernel.eval(self.sv.row(i), x);
        }
        (acc + self.bias) as f32
    }

    /// The curse of support: every decision walks all support vectors.
    fn decision_cost(&self, input_dim: usize) -> usize {
        self.n_support().saturating_mul(input_dim.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Homogeneous, Polynomial};
    use crate::svm::testdata::{blobs, xor};

    /// A linear dot-product kernel for baseline checks.
    #[derive(Clone, Copy, Debug)]
    struct LinearK;
    impl DotProductKernel for LinearK {
        fn name(&self) -> String {
            "linear".into()
        }
        fn coeff(&self, n: u32) -> f64 {
            if n == 1 {
                1.0
            } else {
                0.0
            }
        }
        fn f(&self, t: f64) -> f64 {
            t
        }
        fn f_prime(&self, _t: f64) -> f64 {
            1.0
        }
        fn max_order(&self) -> Option<u32> {
            Some(1)
        }
    }

    #[test]
    fn separable_blobs_linear_kernel() {
        let ds = blobs(200, 1);
        let model = KernelSvm::train(&ds, Box::new(LinearK), SmoParams::default()).unwrap();
        assert!(model.accuracy_on(&ds) > 0.97, "acc {}", model.accuracy_on(&ds));
        assert!(model.n_support() < ds.len(), "not all points should be SVs");
    }

    #[test]
    fn xor_needs_nonlinear_kernel() {
        let ds = xor(300, 2);
        let lin = KernelSvm::train(&ds, Box::new(LinearK), SmoParams::default()).unwrap();
        // XOR has points arbitrarily close to the decision boundary, so a
        // weakly-regularized margin (larger C) is needed to pin them.
        let quad = KernelSvm::train(
            &ds,
            Box::new(Homogeneous::new(2)),
            SmoParams { c: 100.0, ..Default::default() },
        )
        .unwrap();
        let (acc_lin, acc_quad) = (lin.accuracy_on(&ds), quad.accuracy_on(&ds));
        assert!(acc_lin < 0.75, "linear should fail on xor, got {acc_lin}");
        assert!(acc_quad > 0.95, "quadratic should solve xor, got {acc_quad}");
    }

    #[test]
    fn poly_kernel_generalizes() {
        let mut ds = xor(600, 3);
        ds.normalize_rows();
        let (tr, te) = ds.split(0.5, 10_000, &mut crate::rng::Rng::seed_from(4));
        let model = KernelSvm::train(
            &tr,
            Box::new(Polynomial::new(3, 1.0)),
            SmoParams { c: 100.0, ..Default::default() },
        )
        .unwrap();
        let acc = model.accuracy_on(&te);
        assert!(acc > 0.88, "test acc {acc}");
    }

    #[test]
    fn respects_max_iter() {
        let ds = xor(200, 5);
        let params = SmoParams { max_iter: 3, ..Default::default() };
        let model = KernelSvm::train(&ds, Box::new(Homogeneous::new(2)), params).unwrap();
        assert!(model.iterations <= 3);
    }

    #[test]
    fn rejects_degenerate_input() {
        let ds = blobs(200, 1);
        assert!(KernelSvm::train(
            &ds,
            Box::new(LinearK),
            SmoParams { c: 0.0, ..Default::default() }
        )
        .is_err());
        let tiny = blobs(2, 1);
        assert!(KernelSvm::train(&tiny, Box::new(LinearK), SmoParams::default()).is_ok());
    }

    #[test]
    fn decision_sign_flips_with_labels() {
        let ds = blobs(100, 7);
        let model = KernelSvm::train(&ds, Box::new(LinearK), SmoParams::default()).unwrap();
        let d_pos = model.decision(&[2.0, 0.0]);
        let d_neg = model.decision(&[-2.0, 0.0]);
        assert!(d_pos > 0.0 && d_neg < 0.0);
    }

    #[test]
    fn cache_eviction_is_correct() {
        // Tiny cache forces eviction; results must not change.
        let ds = xor(150, 9);
        let small = SmoParams { cache_rows: 2, ..Default::default() };
        let big = SmoParams { cache_rows: 1024, ..Default::default() };
        let m1 = KernelSvm::train(&ds, Box::new(Homogeneous::new(2)), small).unwrap();
        let m2 = KernelSvm::train(&ds, Box::new(Homogeneous::new(2)), big).unwrap();
        // Same optimization path -> same support count and accuracy.
        assert_eq!(m1.n_support(), m2.n_support());
        assert_eq!(m1.accuracy_on(&ds), m2.accuracy_on(&ds));
    }
}
