//! Random Fourier Features (Rahimi & Recht, NIPS 2007).
//!
//! The construction the paper builds on and compares against: for a
//! translation invariant kernel `K(x, y) = k(x − y)` with spectral
//! density `μ` (Bochner's theorem), draw `w ~ μ`, `b ~ U[0, 2π)` and use
//! `W(x) = √2 · cos(w^T x + b)`; then `E[W(x)W(y)] = k(x − y)`.
//!
//! Two roles here:
//! * a `D`-dimensional [`FeatureMap`] ([`RandomFourier`]) for the
//!   Gaussian RBF kernel — a baseline in the benches;
//! * the black-box *scalar* feature map factory
//!   ([`RffScalarFactory`]) that Algorithm 2 (compositional kernels)
//!   consumes: each draw is one `(w, b)` pair, bounded by `√2`
//!   (`C_W = 2`) and Lipschitz on expectation — exactly the assumptions
//!   of the paper's §5.

use crate::features::{FeatureMap, Scratch};
use crate::maclaurin::compositional::{ScalarMap, ScalarMapFactory};
use crate::rng::Rng;
use crate::structured::{DenseProjection, Projection, ProjectionKind, StructuredProjection};

/// Gaussian RBF kernel `K(x, y) = exp(−γ ‖x − y‖²)` (helper for tests
/// and benches; the spectral density is `N(0, 2γ I)`).
pub fn rbf(gamma: f64, x: &[f32], y: &[f32]) -> f64 {
    let d2: f32 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
    (-gamma * d2 as f64).exp()
}

/// One scalar Fourier feature `W(x) = √2 cos(w^T x + b)`.
#[derive(Clone, Debug)]
pub struct FourierScalar {
    w: Vec<f32>,
    b: f32,
}

impl ScalarMap for FourierScalar {
    fn eval(&self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.w.len());
        let t = crate::linalg::dot(&self.w, x) + self.b;
        std::f32::consts::SQRT_2 * t.cos()
    }

    fn bound(&self) -> f64 {
        std::f64::consts::SQRT_2
    }
}

/// Factory drawing scalar RBF Fourier features: `w ~ N(0, 2γ I)`,
/// `b ~ U[0, 2π)`.
#[derive(Clone, Copy, Debug)]
pub struct RffScalarFactory {
    pub gamma: f64,
    pub dim: usize,
}

impl RffScalarFactory {
    pub fn new(gamma: f64, dim: usize) -> Self {
        assert!(gamma > 0.0 && dim > 0);
        RffScalarFactory { gamma, dim }
    }
}

impl ScalarMapFactory for RffScalarFactory {
    type Map = FourierScalar;

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn sample_scalar(&self, rng: &mut Rng) -> FourierScalar {
        let std = (2.0 * self.gamma).sqrt();
        let w = (0..self.dim).map(|_| (std * rng.normal()) as f32).collect();
        let b = (rng.f64() * 2.0 * std::f64::consts::PI) as f32;
        FourierScalar { w, b }
    }

    /// `E[W(x)W(y)]` — the inner kernel the factory realizes.
    fn kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        rbf(self.gamma, x, y)
    }

    /// `sup |W| = √2`, so `C_W = 2`.
    fn bound(&self) -> f64 {
        std::f64::consts::SQRT_2
    }
}

/// The frequency stack behind a [`RandomFourier`] map: a dense Gaussian
/// matrix or the Fastfood-style FWHT chain
/// ([`StructuredProjection::gaussian_stack`], marginally exactly
/// `N(0, 2γI)` rows).
#[derive(Clone, Debug)]
enum FreqStack {
    Dense(DenseProjection),
    Structured(StructuredProjection),
}

impl FreqStack {
    fn as_projection(&self) -> &dyn Projection {
        match self {
            FreqStack::Dense(p) => p,
            FreqStack::Structured(p) => p,
        }
    }
}

/// A `D`-dimensional Random Fourier feature map for the Gaussian RBF
/// kernel: `Z(x) = √(2/D) · cos(W x + b)` with rows `w_i ~ N(0, 2γI)`,
/// realized through the [`crate::structured::Projection`] subsystem
/// (dense `O(D·d)` or structured `O(D·log d)` per input; every row's
/// marginal law is exactly `N(0, 2γI)` in both modes, so the Bochner
/// unbiasedness argument is untouched — structured rows within one HD
/// block are merely correlated).
#[derive(Clone, Debug)]
pub struct RandomFourier {
    freqs: FreqStack,
    b: Vec<f32>,
    gamma: f64,
}

impl RandomFourier {
    /// Sample a dense map (the classic construction).
    pub fn sample(gamma: f64, d: usize, n_features: usize, rng: &mut Rng) -> Self {
        Self::sample_with(gamma, d, n_features, ProjectionKind::Dense, rng)
    }

    /// Sample with an explicit projection kind (`--projection` knob).
    pub fn sample_with(
        gamma: f64,
        d: usize,
        n_features: usize,
        projection: ProjectionKind,
        rng: &mut Rng,
    ) -> Self {
        Self::sample_with_opts(gamma, d, n_features, projection, false, rng)
    }

    /// [`Self::sample_with`] plus the randomness-recycling knob
    /// (`--recycle`): structured stacks share one `(Π, G)` pool across
    /// their Fastfood blocks
    /// ([`StructuredProjection::gaussian_stack_opts`]) — exactly
    /// unbiased, `O(n)` Gaussian state. `recycle = false` is
    /// bit-identical to [`Self::sample_with`]; dense maps ignore the
    /// knob.
    pub fn sample_with_opts(
        gamma: f64,
        d: usize,
        n_features: usize,
        projection: ProjectionKind,
        recycle: bool,
        rng: &mut Rng,
    ) -> Self {
        assert!(gamma > 0.0 && d > 0 && n_features > 0);
        let std = (2.0 * gamma).sqrt();
        let freqs = match projection {
            ProjectionKind::Dense => {
                let mut w = crate::linalg::Matrix::zeros(n_features, d);
                for i in 0..n_features {
                    for j in 0..d {
                        w.set(i, j, (std * rng.normal()) as f32);
                    }
                }
                FreqStack::Dense(DenseProjection::from_rows_matrix(&w))
            }
            ProjectionKind::Structured => FreqStack::Structured(
                StructuredProjection::gaussian_stack_opts(d, n_features, std, recycle, rng),
            ),
        };
        let b = (0..n_features)
            .map(|_| (rng.f64() * 2.0 * std::f64::consts::PI) as f32)
            .collect();
        RandomFourier { freqs, b, gamma }
    }

    pub fn gamma(&self) -> f64 {
        self.gamma
    }

    /// True when the frequencies are the FWHT-backed structured stack.
    pub fn is_structured(&self) -> bool {
        matches!(self.freqs, FreqStack::Structured(_))
    }

    #[inline]
    fn scale(&self) -> f32 {
        (2.0 / self.output_dim() as f64).sqrt() as f32
    }
}

impl FeatureMap for RandomFourier {
    fn input_dim(&self) -> usize {
        self.freqs.as_projection().input_dim()
    }

    fn output_dim(&self) -> usize {
        self.freqs.as_projection().rows()
    }

    fn transform_into(&self, x: &[f32], out: &mut [f32]) {
        self.transform_into_scratch(x, out, &mut Scratch::new());
    }

    /// Allocation-free hot path: the projection buffer doubles as the
    /// output buffer, and the structured (Fastfood) chain's FWHT pads
    /// live in the caller's reusable [`Scratch`] (dense frequency
    /// stacks need no workspace at all). Bit-identical to
    /// [`FeatureMap::transform_into`].
    fn transform_into_scratch(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        let _span = crate::obs::span("transform.rff");
        assert_eq!(x.len(), self.input_dim());
        assert_eq!(out.len(), self.output_dim());
        let p = self.freqs.as_projection();
        p.project_into_scratch(x, out, scratch.one(p.scratch_len()));
        crate::simd::cos_activate(out, &self.b, self.scale());
    }

    /// Batch override: one pass through the projection stack (blocked
    /// GEMM / row-chunked FWHT chains), then the cosine activation —
    /// both fanned over `threads` scoped workers with the crate's
    /// bit-identical-per-row contract.
    fn transform_batch_threads(
        &self,
        x: &crate::linalg::Matrix,
        threads: usize,
    ) -> crate::linalg::Matrix {
        let _span = crate::obs::span("transform.rff");
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        let mut out = self.freqs.as_projection().project_batch(x, threads);
        let (b, dd) = (out.rows(), out.cols());
        if b == 0 || dd == 0 {
            return out;
        }
        let scale = self.scale();
        // ~4 flops per cosine coordinate.
        let work = b.saturating_mul(dd).saturating_mul(4);
        let threads = crate::parallel::resolve_threads_for_work(threads, b, work);
        // Hoist the dispatch choice out of the worker closure so every
        // row runs the identical kernel (the per-row bit-parity
        // contract; the activation itself is the same one the
        // single-vector paths call).
        let path = crate::simd::selected();
        crate::parallel::par_chunks(threads, dd, out.as_mut_slice(), |_, block| {
            for row in block.chunks_mut(dd) {
                crate::simd::cos_activate_with(path, row, &self.b, scale);
            }
        });
        out
    }

    /// Sparse single-vector fast path: `O(D·nnz)` through the frequency
    /// stack's sparse projection, then the identical cosine activation —
    /// equal to the dense path on the densified row.
    fn transform_sparse_into(&self, x: crate::linalg::SparseRow<'_>, out: &mut [f32]) {
        self.transform_sparse_into_scratch(x, out, &mut Scratch::new());
    }

    /// CSR twin of [`FeatureMap::transform_into_scratch`] (same
    /// contract: bit-identical, allocation-free with a reused scratch).
    fn transform_sparse_into_scratch(
        &self,
        x: crate::linalg::SparseRow<'_>,
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let _span = crate::obs::span("transform.rff");
        assert_eq!(x.dim, self.input_dim(), "input dim mismatch");
        assert_eq!(out.len(), self.output_dim(), "output dim mismatch");
        let p = self.freqs.as_projection();
        p.project_sparse_into_scratch(x, out, scratch.one(p.scratch_len()));
        crate::simd::cos_activate(out, &self.b, self.scale());
    }

    /// Sparse batch override: one sparse projection pass, then the same
    /// batched cosine activation as the dense override; bit-identical
    /// per row to the dense batch for any thread count.
    fn transform_batch_sparse_threads(
        &self,
        x: &crate::linalg::SparseMatrix,
        threads: usize,
    ) -> crate::linalg::Matrix {
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        let mut out = self.freqs.as_projection().project_batch_sparse(x, threads);
        let (b, dd) = (out.rows(), out.cols());
        if b == 0 || dd == 0 {
            return out;
        }
        let scale = self.scale();
        let work = b.saturating_mul(dd).saturating_mul(4);
        let threads = crate::parallel::resolve_threads_for_work(threads, b, work);
        let path = crate::simd::selected();
        crate::parallel::par_chunks(threads, dd, out.as_mut_slice(), |_, block| {
            for row in block.chunks_mut(dd) {
                crate::simd::cos_activate_with(path, row, &self.b, scale);
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        crate::linalg::normalize(&mut v);
        v
    }

    #[test]
    fn rff_approximates_rbf() {
        let mut rng = Rng::seed_from(1);
        let gamma = 0.7;
        let d = 6;
        let map = RandomFourier::sample(gamma, d, 4096, &mut rng);
        for s in 0..5 {
            let x = unit_vec(d, 10 + s);
            let y = unit_vec(d, 20 + s);
            let exact = rbf(gamma, &x, &y);
            let approx = crate::linalg::dot(&map.transform(&x), &map.transform(&y)) as f64;
            assert!((exact - approx).abs() < 0.06, "exact {exact} approx {approx}");
        }
    }

    #[test]
    fn rff_self_similarity_is_one() {
        // K(x, x) = 1 for RBF; Z(x)·Z(x) concentrates around 1.
        let mut rng = Rng::seed_from(2);
        let map = RandomFourier::sample(1.0, 4, 4096, &mut rng);
        let x = unit_vec(4, 3);
        let z = map.transform(&x);
        let v = crate::linalg::dot(&z, &z) as f64;
        assert!((v - 1.0).abs() < 0.05, "self-sim {v}");
    }

    #[test]
    fn structured_rff_approximates_rbf() {
        // Fastfood-chain frequencies have exactly the right marginal
        // law, so the Bochner estimate concentrates like the dense one
        // (correlations within HD blocks only perturb the constant).
        let mut rng = Rng::seed_from(11);
        let gamma = 0.7;
        let d = 6;
        let map =
            RandomFourier::sample_with(gamma, d, 4096, ProjectionKind::Structured, &mut rng);
        assert!(map.is_structured());
        assert_eq!(map.output_dim(), 4096);
        for s in 0..5 {
            let x = unit_vec(d, 30 + s);
            let y = unit_vec(d, 40 + s);
            let exact = rbf(gamma, &x, &y);
            let approx = crate::linalg::dot(&map.transform(&x), &map.transform(&y)) as f64;
            assert!((exact - approx).abs() < 0.12, "exact {exact} approx {approx}");
        }
    }

    #[test]
    fn rff_batch_matches_single_bitwise() {
        for kind in [ProjectionKind::Dense, ProjectionKind::Structured] {
            let mut rng = Rng::seed_from(12);
            let map = RandomFourier::sample_with(1.0, 5, 64, kind, &mut rng);
            let rows: Vec<Vec<f32>> = (0..7).map(|i| unit_vec(5, 50 + i)).collect();
            let x = crate::linalg::Matrix::from_rows(&rows).unwrap();
            let zb = map.transform_batch(&x);
            for i in 0..7 {
                assert_eq!(zb.row(i), &map.transform(x.row(i))[..], "{kind:?} row {i}");
            }
            for threads in [2usize, 3, 16] {
                assert_eq!(map.transform_batch_threads(&x, threads), zb, "{kind:?}");
            }
        }
    }

    #[test]
    fn sparse_rff_matches_dense_bitwise() {
        for kind in [ProjectionKind::Dense, ProjectionKind::Structured] {
            let mut rng = Rng::seed_from(21);
            let d = 13;
            let map = RandomFourier::sample_with(0.8, d, 48, kind, &mut rng);
            let mut data_rng = Rng::seed_from(22);
            let mut x = crate::linalg::Matrix::zeros(6, d);
            for i in 0..6 {
                for j in 0..d {
                    if data_rng.f64() < 0.3 {
                        x.set(i, j, data_rng.f32() - 0.5);
                    }
                }
            }
            let sx = crate::linalg::SparseMatrix::from_dense(&x);
            let dense = map.transform_batch_threads(&x, 1);
            for i in 0..6 {
                let mut got = vec![0.0f32; map.output_dim()];
                map.transform_sparse_into(sx.row(i), &mut got);
                assert_eq!(&got[..], dense.row(i), "{kind:?} row {i}");
            }
            for threads in [1usize, 2, 8] {
                assert_eq!(map.transform_batch_sparse_threads(&sx, threads), dense, "{kind:?}");
            }
        }
    }

    #[test]
    fn scalar_factory_unbiased() {
        let mut rng = Rng::seed_from(3);
        let gamma = 1.1;
        let d = 5;
        let factory = RffScalarFactory::new(gamma, d);
        let x = unit_vec(d, 4);
        let y = unit_vec(d, 5);
        let exact = factory.kernel(&x, &y);
        let trials = 200_000;
        let mean: f64 = (0..trials)
            .map(|_| {
                let w = factory.sample_scalar(&mut rng);
                (w.eval(&x) * w.eval(&y)) as f64
            })
            .sum::<f64>()
            / trials as f64;
        assert!((mean - exact).abs() < 0.01, "mean {mean} exact {exact}");
    }

    #[test]
    fn scalar_is_bounded() {
        let mut rng = Rng::seed_from(4);
        let factory = RffScalarFactory::new(2.0, 3);
        let x = unit_vec(3, 6);
        for _ in 0..1000 {
            let w = factory.sample_scalar(&mut rng);
            assert!(w.eval(&x).abs() as f64 <= w.bound() + 1e-6);
        }
    }
}
