//! Random Fourier Features (Rahimi & Recht, NIPS 2007).
//!
//! The construction the paper builds on and compares against: for a
//! translation invariant kernel `K(x, y) = k(x − y)` with spectral
//! density `μ` (Bochner's theorem), draw `w ~ μ`, `b ~ U[0, 2π)` and use
//! `W(x) = √2 · cos(w^T x + b)`; then `E[W(x)W(y)] = k(x − y)`.
//!
//! Two roles here:
//! * a `D`-dimensional [`FeatureMap`] ([`RandomFourier`]) for the
//!   Gaussian RBF kernel — a baseline in the benches;
//! * the black-box *scalar* feature map factory
//!   ([`RffScalarFactory`]) that Algorithm 2 (compositional kernels)
//!   consumes: each draw is one `(w, b)` pair, bounded by `√2`
//!   (`C_W = 2`) and Lipschitz on expectation — exactly the assumptions
//!   of the paper's §5.

use crate::features::FeatureMap;
use crate::maclaurin::compositional::{ScalarMap, ScalarMapFactory};
use crate::rng::Rng;

/// Gaussian RBF kernel `K(x, y) = exp(−γ ‖x − y‖²)` (helper for tests
/// and benches; the spectral density is `N(0, 2γ I)`).
pub fn rbf(gamma: f64, x: &[f32], y: &[f32]) -> f64 {
    let d2: f32 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
    (-gamma * d2 as f64).exp()
}

/// One scalar Fourier feature `W(x) = √2 cos(w^T x + b)`.
#[derive(Clone, Debug)]
pub struct FourierScalar {
    w: Vec<f32>,
    b: f32,
}

impl ScalarMap for FourierScalar {
    fn eval(&self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.w.len());
        let t = crate::linalg::dot(&self.w, x) + self.b;
        std::f32::consts::SQRT_2 * t.cos()
    }

    fn bound(&self) -> f64 {
        std::f64::consts::SQRT_2
    }
}

/// Factory drawing scalar RBF Fourier features: `w ~ N(0, 2γ I)`,
/// `b ~ U[0, 2π)`.
#[derive(Clone, Copy, Debug)]
pub struct RffScalarFactory {
    pub gamma: f64,
    pub dim: usize,
}

impl RffScalarFactory {
    pub fn new(gamma: f64, dim: usize) -> Self {
        assert!(gamma > 0.0 && dim > 0);
        RffScalarFactory { gamma, dim }
    }
}

impl ScalarMapFactory for RffScalarFactory {
    type Map = FourierScalar;

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn sample_scalar(&self, rng: &mut Rng) -> FourierScalar {
        let std = (2.0 * self.gamma).sqrt();
        let w = (0..self.dim).map(|_| (std * rng.normal()) as f32).collect();
        let b = (rng.f64() * 2.0 * std::f64::consts::PI) as f32;
        FourierScalar { w, b }
    }

    /// `E[W(x)W(y)]` — the inner kernel the factory realizes.
    fn kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        rbf(self.gamma, x, y)
    }

    /// `sup |W| = √2`, so `C_W = 2`.
    fn bound(&self) -> f64 {
        std::f64::consts::SQRT_2
    }
}

/// A `D`-dimensional Random Fourier feature map for the Gaussian RBF
/// kernel: `Z(x) = √(2/D) · cos(W x + b)` with rows `w_i ~ N(0, 2γI)`.
#[derive(Clone, Debug)]
pub struct RandomFourier {
    /// `D × d` frequency matrix, row-major.
    w: crate::linalg::Matrix,
    b: Vec<f32>,
    gamma: f64,
}

impl RandomFourier {
    pub fn sample(gamma: f64, d: usize, n_features: usize, rng: &mut Rng) -> Self {
        assert!(gamma > 0.0 && d > 0 && n_features > 0);
        let std = (2.0 * gamma).sqrt();
        let mut w = crate::linalg::Matrix::zeros(n_features, d);
        for i in 0..n_features {
            for j in 0..d {
                w.set(i, j, (std * rng.normal()) as f32);
            }
        }
        let b = (0..n_features)
            .map(|_| (rng.f64() * 2.0 * std::f64::consts::PI) as f32)
            .collect();
        RandomFourier { w, b, gamma }
    }

    pub fn gamma(&self) -> f64 {
        self.gamma
    }
}

impl FeatureMap for RandomFourier {
    fn input_dim(&self) -> usize {
        self.w.cols()
    }

    fn output_dim(&self) -> usize {
        self.w.rows()
    }

    fn transform_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.input_dim());
        assert_eq!(out.len(), self.output_dim());
        let scale = (2.0 / self.w.rows() as f64).sqrt() as f32;
        for i in 0..self.w.rows() {
            let t = crate::linalg::dot(self.w.row(i), x) + self.b[i];
            out[i] = scale * t.cos();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        crate::linalg::normalize(&mut v);
        v
    }

    #[test]
    fn rff_approximates_rbf() {
        let mut rng = Rng::seed_from(1);
        let gamma = 0.7;
        let d = 6;
        let map = RandomFourier::sample(gamma, d, 4096, &mut rng);
        for s in 0..5 {
            let x = unit_vec(d, 10 + s);
            let y = unit_vec(d, 20 + s);
            let exact = rbf(gamma, &x, &y);
            let approx = crate::linalg::dot(&map.transform(&x), &map.transform(&y)) as f64;
            assert!((exact - approx).abs() < 0.06, "exact {exact} approx {approx}");
        }
    }

    #[test]
    fn rff_self_similarity_is_one() {
        // K(x, x) = 1 for RBF; Z(x)·Z(x) concentrates around 1.
        let mut rng = Rng::seed_from(2);
        let map = RandomFourier::sample(1.0, 4, 4096, &mut rng);
        let x = unit_vec(4, 3);
        let z = map.transform(&x);
        let v = crate::linalg::dot(&z, &z) as f64;
        assert!((v - 1.0).abs() < 0.05, "self-sim {v}");
    }

    #[test]
    fn scalar_factory_unbiased() {
        let mut rng = Rng::seed_from(3);
        let gamma = 1.1;
        let d = 5;
        let factory = RffScalarFactory::new(gamma, d);
        let x = unit_vec(d, 4);
        let y = unit_vec(d, 5);
        let exact = factory.kernel(&x, &y);
        let trials = 200_000;
        let mean: f64 = (0..trials)
            .map(|_| {
                let w = factory.sample_scalar(&mut rng);
                (w.eval(&x) * w.eval(&y)) as f64
            })
            .sum::<f64>()
            / trials as f64;
        assert!((mean - exact).abs() < 0.01, "mean {mean} exact {exact}");
    }

    #[test]
    fn scalar_is_bounded() {
        let mut rng = Rng::seed_from(4);
        let factory = RffScalarFactory::new(2.0, 3);
        let x = unit_vec(3, 6);
        for _ in 0..1000 {
            let w = factory.sample_scalar(&mut rng);
            assert!(w.eval(&x).abs() as f64 <= w.bound() + 1e-6);
        }
    }
}
