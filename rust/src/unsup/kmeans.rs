//! Lloyd's k-means (k-means++ seeding) in feature space.
//!
//! Run on Random Maclaurin features this *is* approximate kernel
//! k-means, with O(k·D) assignment per point instead of the exact
//! method's O(n) kernel evaluations — the curse-of-support fix for
//! clustering the paper's intro promises.

use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::{Error, Result};

/// k-means hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct KMeansParams {
    pub k: usize,
    pub max_iters: usize,
    /// Relative inertia improvement below which iteration stops.
    pub tol: f64,
}

impl Default for KMeansParams {
    fn default() -> Self {
        KMeansParams { k: 8, max_iters: 100, tol: 1e-4 }
    }
}

/// A fitted clustering.
pub struct KMeansModel {
    /// `k × D` centroid matrix.
    pub centroids: Matrix,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
    /// Lloyd iterations used.
    pub iterations: usize,
}

impl KMeansModel {
    /// Index of the nearest centroid.
    pub fn assign(&self, z: &[f32]) -> usize {
        let mut best = 0;
        let mut best_d = f32::INFINITY;
        for c in 0..self.centroids.rows() {
            let row = self.centroids.row(c);
            let d: f32 = row.iter().zip(z).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        best
    }

    /// Assign every row.
    pub fn assign_batch(&self, z: &Matrix) -> Vec<usize> {
        (0..z.rows()).map(|i| self.assign(z.row(i))).collect()
    }
}

/// Lloyd's algorithm with k-means++ seeding on the rows of `z`.
pub fn kmeans(z: &Matrix, params: KMeansParams, rng: &mut Rng) -> Result<KMeansModel> {
    let n = z.rows();
    let d = z.cols();
    if params.k == 0 || n < params.k {
        return Err(Error::Config(format!("kmeans needs n >= k > 0 (n={n}, k={})", params.k)));
    }

    // k-means++ seeding.
    let mut centroids = Matrix::zeros(params.k, d);
    let first = rng.below(n as u64) as usize;
    centroids.row_mut(0).copy_from_slice(z.row(first));
    let mut dist2 = vec![f32::INFINITY; n];
    for c in 1..params.k {
        for i in 0..n {
            let prev = centroids.row(c - 1);
            let di: f32 = prev.iter().zip(z.row(i)).map(|(a, b)| (a - b) * (a - b)).sum();
            dist2[i] = dist2[i].min(di);
        }
        let total: f64 = dist2.iter().map(|&v| v as f64).sum();
        let mut target = rng.f64() * total;
        let mut chosen = n - 1;
        for i in 0..n {
            target -= dist2[i] as f64;
            if target <= 0.0 {
                chosen = i;
                break;
            }
        }
        centroids.row_mut(c).copy_from_slice(z.row(chosen));
    }

    // Lloyd iterations.
    let mut assign = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..params.max_iters {
        iterations = it + 1;
        // Assignment step.
        let mut new_inertia = 0.0f64;
        for i in 0..n {
            let zi = z.row(i);
            let mut best = 0;
            let mut best_d = f32::INFINITY;
            for c in 0..params.k {
                let row = centroids.row(c);
                let di: f32 = row.iter().zip(zi).map(|(a, b)| (a - b) * (a - b)).sum();
                if di < best_d {
                    best_d = di;
                    best = c;
                }
            }
            assign[i] = best;
            new_inertia += best_d as f64;
        }
        // Update step.
        let mut counts = vec![0usize; params.k];
        let mut sums = Matrix::zeros(params.k, d);
        for i in 0..n {
            counts[assign[i]] += 1;
            crate::linalg::axpy(1.0, z.row(i), sums.row_mut(assign[i]));
        }
        for c in 0..params.k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f32;
                let src: Vec<f32> = sums.row(c).iter().map(|v| v * inv).collect();
                centroids.row_mut(c).copy_from_slice(&src);
            } else {
                // Re-seed empty clusters at a random point.
                let j = rng.below(n as u64) as usize;
                centroids.row_mut(c).copy_from_slice(z.row(j));
            }
        }
        let improved = (inertia - new_inertia) / inertia.max(1e-12);
        inertia = new_inertia;
        if improved.abs() < params.tol && it > 0 {
            break;
        }
    }

    Ok(KMeansModel { centroids, inertia, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_blobs(n_per: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::seed_from(seed);
        let centers = [[0.0f32, 0.0], [5.0, 5.0], [-5.0, 5.0]];
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for (ci, c) in centers.iter().enumerate() {
            for _ in 0..n_per {
                rows.push(vec![
                    c[0] + 0.4 * rng.normal() as f32,
                    c[1] + 0.4 * rng.normal() as f32,
                ]);
                labels.push(ci);
            }
        }
        (Matrix::from_rows(&rows).unwrap(), labels)
    }

    /// Clustering accuracy up to label permutation (k=3 brute force).
    fn permuted_accuracy(pred: &[usize], truth: &[usize]) -> f64 {
        let perms: [[usize; 3]; 6] =
            [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]];
        perms
            .iter()
            .map(|perm| {
                pred.iter()
                    .zip(truth)
                    .filter(|&(&p, &t)| perm[p] == t)
                    .count() as f64
                    / pred.len() as f64
            })
            .fold(0.0, f64::max)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (x, truth) = three_blobs(60, 1);
        let mut rng = Rng::seed_from(2);
        let model = kmeans(&x, KMeansParams { k: 3, ..Default::default() }, &mut rng).unwrap();
        let pred = model.assign_batch(&x);
        let acc = permuted_accuracy(&pred, &truth);
        assert!(acc > 0.95, "blob clustering acc {acc}");
        assert!(model.inertia < 100.0);
    }

    #[test]
    fn inertia_never_increases_with_more_clusters() {
        let (x, _) = three_blobs(40, 3);
        let at = |k: usize| {
            let mut rng = Rng::seed_from(4);
            kmeans(&x, KMeansParams { k, ..Default::default() }, &mut rng).unwrap().inertia
        };
        assert!(at(6) <= at(3) * 1.05);
        assert!(at(3) <= at(1) * 1.05);
    }

    #[test]
    fn rejects_bad_k() {
        let (x, _) = three_blobs(2, 5);
        let mut rng = Rng::seed_from(6);
        assert!(kmeans(&x, KMeansParams { k: 0, ..Default::default() }, &mut rng).is_err());
        assert!(kmeans(&x, KMeansParams { k: 1000, ..Default::default() }, &mut rng).is_err());
    }
}
