//! PCA in feature space (= approximate kernel PCA over random features).
//!
//! Components are extracted by orthogonal (deflated) power iteration on
//! the centered covariance, so only `O(n·D)` memory is needed — no
//! `n × n` Gram matrix, no support set at projection time.

use crate::linalg::Matrix;
use crate::{Error, Result};

/// A fitted PCA basis.
pub struct PcaModel {
    /// Feature-space mean (length D).
    pub mean: Vec<f32>,
    /// `k × D` principal directions (rows, orthonormal).
    pub components: Matrix,
    /// Explained variance per component (descending).
    pub variances: Vec<f64>,
}

impl PcaModel {
    /// Project one feature vector onto the basis.
    pub fn project(&self, z: &[f32]) -> Vec<f32> {
        assert_eq!(z.len(), self.mean.len());
        let centered: Vec<f32> = z.iter().zip(&self.mean).map(|(a, m)| a - m).collect();
        (0..self.components.rows())
            .map(|c| crate::linalg::dot(self.components.row(c), &centered))
            .collect()
    }

    /// Project every row.
    pub fn project_batch(&self, z: &Matrix) -> Matrix {
        let rows: Vec<Vec<f32>> = (0..z.rows()).map(|i| self.project(z.row(i))).collect();
        Matrix::from_rows(&rows).expect("uniform projection width")
    }
}

/// Fit `k` principal components of the rows of `z` by deflated power
/// iteration (`iters` steps per component).
pub fn pca(z: &Matrix, k: usize, iters: usize) -> Result<PcaModel> {
    let n = z.rows();
    let d = z.cols();
    if n < 2 || k == 0 || k > d {
        return Err(Error::Config(format!("pca needs n >= 2, 0 < k <= D (n={n}, k={k}, D={d})")));
    }

    // Center.
    let mut mean = vec![0.0f32; d];
    for i in 0..n {
        crate::linalg::axpy(1.0, z.row(i), &mut mean);
    }
    crate::linalg::scale(1.0 / n as f32, &mut mean);
    let mut centered = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            centered.set(i, j, z.get(i, j) - mean[j]);
        }
    }

    // Deflated power iteration on C = X^T X / (n-1) without forming C:
    // v <- X^T (X v), renormalized, orthogonalized against found comps.
    let mut components = Matrix::zeros(k, d);
    let mut variances = Vec::with_capacity(k);
    let mut seed_rng = crate::rng::Rng::seed_from(0x9CA ^ 0x9E37);
    for c in 0..k {
        let mut v: Vec<f32> = (0..d).map(|_| seed_rng.normal() as f32).collect();
        crate::linalg::normalize(&mut v);
        for _ in 0..iters {
            // w = X^T (X v)
            let xv = centered.matvec(&v)?;
            let mut w = vec![0.0f32; d];
            for i in 0..n {
                crate::linalg::axpy(xv[i], centered.row(i), &mut w);
            }
            // Deflate against earlier components.
            for p in 0..c {
                let proj = crate::linalg::dot(components.row(p), &w);
                let comp = components.row(p).to_vec();
                crate::linalg::axpy(-proj, &comp, &mut w);
            }
            if crate::linalg::normalize(&mut w) == 0.0 {
                break; // rank exhausted
            }
            v = w;
        }
        // Rayleigh quotient = explained variance.
        let xv = centered.matvec(&v)?;
        let var = xv.iter().map(|&t| (t as f64) * (t as f64)).sum::<f64>() / (n as f64 - 1.0);
        components.row_mut(c).copy_from_slice(&v);
        variances.push(var);
    }

    Ok(PcaModel { mean, components, variances })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Data stretched along a known direction.
    fn stretched(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        let dir = [3.0f32, 1.0, 0.0];
        let mut rows = Vec::new();
        for _ in 0..n {
            let t = rng.normal() as f32 * 4.0;
            let noise: Vec<f32> = (0..3).map(|_| 0.2 * rng.normal() as f32).collect();
            rows.push(vec![
                t * dir[0] + noise[0] + 1.0,
                t * dir[1] + noise[1] - 2.0,
                noise[2],
            ]);
        }
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn finds_dominant_direction() {
        let x = stretched(300, 1);
        let model = pca(&x, 2, 50).unwrap();
        let c0 = model.components.row(0);
        // Dominant direction ∝ (3, 1, 0)/sqrt(10).
        let expected = [3.0f32, 1.0, 0.0].map(|v| v / 10f32.sqrt());
        let cosine: f32 = c0.iter().zip(&expected).map(|(a, b)| a * b).sum();
        assert!(cosine.abs() > 0.99, "cos {cosine}");
        assert!(model.variances[0] > 10.0 * model.variances[1]);
    }

    #[test]
    fn components_are_orthonormal() {
        let x = stretched(200, 2);
        let model = pca(&x, 3, 60).unwrap();
        for p in 0..3 {
            for q in 0..3 {
                let dot = crate::linalg::dot(model.components.row(p), model.components.row(q));
                let expect = if p == q { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-3, "({p},{q}): {dot}");
            }
        }
    }

    #[test]
    fn projection_centers_data() {
        let x = stretched(150, 3);
        let model = pca(&x, 2, 40).unwrap();
        let proj = model.project_batch(&x);
        // Projected data has ~zero mean per component.
        for c in 0..2 {
            let mean: f64 =
                (0..proj.rows()).map(|i| proj.get(i, c) as f64).sum::<f64>() / proj.rows() as f64;
            assert!(mean.abs() < 0.5, "component {c} mean {mean}");
        }
    }

    #[test]
    fn rejects_bad_shapes() {
        let x = stretched(10, 4);
        assert!(pca(&x, 0, 10).is_err());
        assert!(pca(&x, 4, 10).is_err()); // k > D = 3
        assert!(pca(&Matrix::zeros(1, 3), 1, 10).is_err());
    }
}
