//! Unsupervised kernel algorithms over explicit feature maps.
//!
//! The paper's introduction argues the curse of support afflicts *all*
//! representer-theorem algorithms — kernel k-means cluster centers and
//! kernel PCA principal components live in the span of the training
//! maps, so evaluating them on new points costs `O(n·d)` kernel
//! evaluations. Random Maclaurin features fix this identically to the
//! SVM case: run the *linear* algorithm in `R^D`. This module provides
//! those linear algorithms plus exact-kernel counterparts for the
//! comparison benches.

pub mod kmeans;
pub mod pca;

pub use kmeans::{kmeans, KMeansModel, KMeansParams};
pub use pca::{pca, PcaModel};
