//! Deterministic, seeded fault injection for the serving stack.
//!
//! A *failpoint* is a named site threaded through production code —
//! `faults::failpoint("net.write")?` — that does nothing until a fault
//! plan is installed, then injects errors, panics, delays or byte
//! corruption on a schedule that is a **pure function of the seed**.
//! The disabled hot path is one relaxed atomic load (the same
//! [`crate::obs::enabled`] pattern; `benches/micro.rs` pins the cost),
//! so the sites stay compiled into release binaries.
//!
//! # Spec grammar
//!
//! Plans come from `--faults SPEC`, the `RFDOT_FAULTS` environment
//! variable, or a config file's `"faults"` key. A SPEC is a
//! comma-separated list of entries:
//!
//! ```text
//! seed=7,net.write=error:0.1,coord.reply=panic:0.05:100,net.read=delay-20
//! ```
//!
//! Each entry is `site=action[:prob][:after_n]` where `action` is one
//! of `error`, `panic`, `corrupt`, or `delay-<ms>`; `prob` is the
//! per-hit firing probability (default 1); `after_n` skips the first
//! *n* hits of the site (default 0). `seed=N` is a pseudo-entry naming
//! the schedule seed (default 0). Sites must come from [`SITES`] —
//! unknown names are config errors, so typos fail loudly.
//!
//! # Determinism
//!
//! Each site keeps a hit ordinal (an atomic counter). Whether hit
//! number *n* of site *s* fires rule *r* is decided by hashing
//! `(seed, s, r, n)` through [`crate::rng::splitmix64`] — no shared
//! RNG stream, no lock, no dependence on thread interleaving. Two runs
//! with the same seed and the same per-site hit counts inject the
//! identical fault schedule, which is what lets `tests/chaos.rs`
//! replay a chaos run bit-identically.

use crate::error::{Error, Result};
use crate::obs;
use crate::rng::splitmix64;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, RwLock};
use std::time::Duration;

/// The registered fault-site catalogue. `parse_spec` validates against
/// this list; `tests/chaos.rs` sweeps it. Keep in sync with the
/// `failpoint`/`mangle` call sites (ARCHITECTURE.md documents each).
pub const SITES: &[&str] = &[
    "artifact.load",
    "artifact.read",
    "rfdm.decode",
    "coord.submit",
    "coord.batch_form",
    "coord.steal",
    "coord.reply",
    "coord.worker_panic",
    "registry.swap",
    "registry.drain",
    "registry.retire",
    "net.accept",
    "net.read",
    "net.write",
];

/// What an armed rule does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Return an [`Error::Runtime`] naming the site.
    Error,
    /// Panic with a message naming the site (exercises drop guards).
    Panic,
    /// Sleep for the given number of milliseconds, then proceed.
    Delay(u64),
    /// Flip one deterministic byte of the buffer passed to [`mangle`]
    /// (a no-op at pure [`failpoint`] sites, which carry no bytes).
    Corrupt,
}

/// One parsed `site=action[:prob][:after_n]` entry.
#[derive(Clone, Debug)]
pub struct FaultRule {
    /// Canonical site name (an entry of [`SITES`]).
    pub site: &'static str,
    pub action: FaultAction,
    /// Per-hit firing probability in (0, 1].
    pub prob: f64,
    /// Skip the first `after` hits of the site.
    pub after: u64,
}

/// An installed fault plan: the rules plus the per-site hit ordinals
/// that drive the deterministic schedule.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    ordinals: Vec<AtomicU64>, // parallel to SITES
}

impl FaultPlan {
    fn new(seed: u64, rules: Vec<FaultRule>) -> FaultPlan {
        FaultPlan {
            seed,
            rules,
            ordinals: SITES.iter().map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// The schedule seed this plan was installed with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The parsed rules, in spec order.
    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }
}

/// Process-wide enable flag. 0 = unresolved (consult `RFDOT_FAULTS` on
/// first use), 1 = off, 2 = on. The disabled failpoint path is exactly
/// one relaxed load of this flag.
static ENABLED: AtomicU8 = AtomicU8::new(0);
static PLAN: RwLock<Option<Arc<FaultPlan>>> = RwLock::new(None);
/// Serializes env resolution and install/clear (never touched on the
/// disabled hot path).
static INIT: Mutex<()> = Mutex::new(());

fn lock_init() -> MutexGuard<'static, ()> {
    INIT.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Is fault injection armed? One relaxed atomic load once resolved;
/// the first call consults `RFDOT_FAULTS` (an invalid spec there is
/// reported to stderr and ignored — the env var must never turn a
/// serving process into a config crash-loop).
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => resolve_env(),
    }
}

#[cold]
fn resolve_env() -> bool {
    let _g = lock_init();
    match ENABLED.load(Ordering::Relaxed) {
        2 => return true,
        1 => return false,
        _ => {}
    }
    let armed = match std::env::var("RFDOT_FAULTS") {
        Ok(s) if !s.trim().is_empty() => match parse_spec(&s) {
            Ok(plan) => {
                *write_plan() = Some(Arc::new(plan));
                true
            }
            Err(e) => {
                eprintln!("rfdot: ignoring invalid RFDOT_FAULTS: {e}");
                false
            }
        },
        _ => false,
    };
    ENABLED.store(if armed { 2 } else { 1 }, Ordering::Relaxed);
    armed
}

fn write_plan() -> std::sync::RwLockWriteGuard<'static, Option<Arc<FaultPlan>>> {
    PLAN.write().unwrap_or_else(PoisonError::into_inner)
}

fn read_plan() -> Option<Arc<FaultPlan>> {
    PLAN.read().unwrap_or_else(PoisonError::into_inner).clone()
}

/// Install a fault plan and arm every threaded site. Replaces any
/// previously installed plan (hit ordinals restart at zero).
pub fn install(plan: FaultPlan) {
    let _g = lock_init();
    *write_plan() = Some(Arc::new(plan));
    ENABLED.store(2, Ordering::Relaxed);
}

/// Parse `spec` and install it (the `--faults` / config `"faults"`
/// entry points).
pub fn install_spec(spec: &str) -> Result<()> {
    install(parse_spec(spec)?);
    Ok(())
}

/// Disarm every site and drop the plan. Subsequent failpoint hits cost
/// one relaxed load again.
pub fn clear() {
    let _g = lock_init();
    *write_plan() = None;
    ENABLED.store(1, Ordering::Relaxed);
}

/// The currently installed plan, if any (tests inspect seeds/rules).
pub fn current_plan() -> Option<Arc<FaultPlan>> {
    if !enabled() {
        return None;
    }
    read_plan()
}

fn site_index(site: &str) -> Option<usize> {
    SITES.iter().position(|s| *s == site)
}

/// FNV-1a, the per-site stream discriminator.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The deterministic per-hit decision: does rule `r` of `site` fire on
/// hit `ordinal`? Pure function of `(seed, site, r, ordinal)`; the
/// second value is extra seeded entropy for the action (corrupt
/// position / flip mask).
fn fire(seed: u64, site: &str, rule_idx: usize, rule: &FaultRule, ordinal: u64) -> Option<u64> {
    if ordinal < rule.after {
        return None;
    }
    let mut s = seed ^ fnv1a(site).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (rule_idx as u64) << 56;
    s = s.wrapping_add(ordinal.wrapping_mul(0xD129_0D3B_3153_07FF));
    let u = splitmix64(&mut s);
    let unit = (u >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    if unit < rule.prob {
        Some(splitmix64(&mut s))
    } else {
        None
    }
}

/// Consume one hit of `site` and return the first firing rule's action
/// plus its entropy word. `None` when disabled or nothing fires.
fn decide(site: &'static str) -> Option<(FaultAction, u64)> {
    if !enabled() {
        return None;
    }
    let plan = read_plan()?;
    let idx = site_index(site)?;
    debug_assert!(site_index(site).is_some(), "unregistered fault site {site}");
    let ordinal = plan.ordinals[idx].fetch_add(1, Ordering::Relaxed);
    for (rule_idx, rule) in plan.rules.iter().enumerate() {
        if rule.site != site {
            continue;
        }
        if let Some(entropy) = fire(plan.seed, site, rule_idx, rule, ordinal) {
            obs::counter("faults.injected").add(1);
            obs::counter(&format!("faults.{site}")).add(1);
            return Some((rule.action, entropy));
        }
    }
    None
}

fn injected_error(site: &str) -> Error {
    Error::Runtime(format!("injected fault at {site}"))
}

/// The failpoint: no-op (one relaxed load) unless a plan is armed and
/// this hit's rule fires. `error` returns [`Error::Runtime`] naming
/// the site, `panic` unwinds with the site in the message, `delay-ms`
/// sleeps then proceeds. `corrupt` rules are no-ops here — corruption
/// needs bytes, so it only applies at [`mangle`] sites.
pub fn failpoint(site: &'static str) -> Result<()> {
    if !enabled() {
        return Ok(());
    }
    match decide(site) {
        Some((FaultAction::Error, _)) => Err(injected_error(site)),
        Some((FaultAction::Panic, _)) => panic!("injected panic at {site}"),
        Some((FaultAction::Delay(ms), _)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some((FaultAction::Corrupt, _)) | None => Ok(()),
    }
}

/// A byte-carrying failpoint: like [`failpoint`], but `corrupt` rules
/// flip one deterministically chosen byte of `bytes` in place (the
/// position and flip mask come from the seeded schedule, so replays
/// corrupt the same byte the same way). Empty buffers are left alone.
pub fn mangle(site: &'static str, bytes: &mut [u8]) -> Result<()> {
    if !enabled() {
        return Ok(());
    }
    match decide(site) {
        Some((FaultAction::Error, _)) => Err(injected_error(site)),
        Some((FaultAction::Panic, _)) => panic!("injected panic at {site}"),
        Some((FaultAction::Delay(ms), _)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some((FaultAction::Corrupt, entropy)) => {
            if !bytes.is_empty() {
                let pos = (entropy % bytes.len() as u64) as usize;
                // Ensure the flip is never the identity.
                let mask = ((entropy >> 56) as u8) | 1;
                bytes[pos] ^= mask;
            }
            Ok(())
        }
        None => Ok(()),
    }
}

fn parse_action(s: &str, entry: &str) -> Result<FaultAction> {
    match s {
        "error" => Ok(FaultAction::Error),
        "panic" => Ok(FaultAction::Panic),
        "corrupt" => Ok(FaultAction::Corrupt),
        _ => {
            if let Some(ms) = s.strip_prefix("delay-") {
                let ms: u64 = ms.parse().map_err(|_| {
                    Error::Config(format!("faults: bad delay in {entry:?} (want delay-<ms>)"))
                })?;
                return Ok(FaultAction::Delay(ms));
            }
            Err(Error::Config(format!(
                "faults: unknown action {s:?} in {entry:?} (want error|panic|corrupt|delay-<ms>)"
            )))
        }
    }
}

/// Parse a fault SPEC (see the module docs for the grammar) without
/// installing it. Unknown sites, malformed actions, and out-of-range
/// probabilities are [`Error::Config`]s.
pub fn parse_spec(spec: &str) -> Result<FaultPlan> {
    let mut seed = 0u64;
    let mut rules = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (key, value) = entry.split_once('=').ok_or_else(|| {
            Error::Config(format!("faults: {entry:?} is not site=action[:prob][:after_n]"))
        })?;
        let key = key.trim();
        if key == "seed" {
            seed = value.trim().parse().map_err(|_| {
                Error::Config(format!("faults: bad seed {:?} (want a u64)", value.trim()))
            })?;
            continue;
        }
        let site = *SITES.iter().find(|s| **s == key).ok_or_else(|| {
            Error::Config(format!(
                "faults: unknown site {key:?} (known: {})",
                SITES.join(", ")
            ))
        })?;
        let mut parts = value.split(':');
        let action = parse_action(parts.next().unwrap_or("").trim(), entry)?;
        let mut prob = 1.0f64;
        if let Some(p) = parts.next() {
            prob = p.trim().parse().map_err(|_| {
                Error::Config(format!("faults: bad probability {:?} in {entry:?}", p.trim()))
            })?;
            if !(prob > 0.0 && prob <= 1.0) {
                return Err(Error::Config(format!(
                    "faults: probability {prob} in {entry:?} must be in (0, 1]"
                )));
            }
        }
        let mut after = 0u64;
        if let Some(n) = parts.next() {
            after = n.trim().parse().map_err(|_| {
                Error::Config(format!("faults: bad after_n {:?} in {entry:?}", n.trim()))
            })?;
        }
        if let Some(extra) = parts.next() {
            return Err(Error::Config(format!(
                "faults: trailing field {extra:?} in {entry:?}"
            )));
        }
        rules.push(FaultRule { site, action, prob, after });
    }
    Ok(FaultPlan::new(seed, rules))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// install/clear mutate process-global state; serialize the tests.
    /// These tests arm only `net.*` sites: the lib test binary runs
    /// its other unit tests (coordinator, registry, serialize) in
    /// parallel threads, and those hit `coord.*` / `registry.*` /
    /// `rfdm.decode` failpoints — arming such a site here would fire
    /// inside an unrelated concurrent test. No lib unit test reaches
    /// the net server loops, so `net.*` plans are contamination-free.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn serial() -> MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn spec_parses_sites_actions_probabilities_and_seed() {
        let plan = parse_spec(
            "seed=7, net.write=error:0.25, coord.reply=panic:0.5:10, net.read=delay-20, \
             artifact.read=corrupt",
        )
        .unwrap();
        assert_eq!(plan.seed(), 7);
        let r = plan.rules();
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].site, "net.write");
        assert_eq!(r[0].action, FaultAction::Error);
        assert!((r[0].prob - 0.25).abs() < 1e-12);
        assert_eq!(r[1].action, FaultAction::Panic);
        assert_eq!(r[1].after, 10);
        assert_eq!(r[2].action, FaultAction::Delay(20));
        assert_eq!(r[3].action, FaultAction::Corrupt);
    }

    #[test]
    fn spec_rejects_unknown_sites_and_malformed_entries() {
        for bad in [
            "net.wrte=error",
            "net.write",
            "net.write=explode",
            "net.write=error:2.0",
            "net.write=error:0",
            "net.write=error:0.5:1:9",
            "seed=banana",
            "net.read=delay-",
        ] {
            let e = parse_spec(bad).unwrap_err();
            assert!(
                matches!(e, Error::Config(_)),
                "{bad:?} must be a config error, got {e:?}"
            );
        }
    }

    #[test]
    fn disabled_failpoints_are_noops() {
        let _g = serial();
        clear();
        for site in SITES {
            assert!(failpoint(*site).is_ok());
        }
        let mut b = [1u8, 2, 3];
        mangle("net.write", &mut b).unwrap();
        assert_eq!(b, [1, 2, 3]);
    }

    #[test]
    fn always_on_error_rule_fires_and_counts() {
        let _g = serial();
        let before = obs::counter("faults.injected").get();
        install(parse_spec("seed=1,net.accept=error").unwrap());
        let e = failpoint("net.accept").unwrap_err();
        assert!(e.to_string().contains("net.accept"), "{e}");
        // Other sites stay clean.
        assert!(failpoint("net.read").is_ok());
        clear();
        assert!(failpoint("net.accept").is_ok());
        assert!(obs::counter("faults.injected").get() > before);
    }

    #[test]
    fn after_n_skips_the_first_hits() {
        let _g = serial();
        install(parse_spec("net.accept=error:1:3").unwrap());
        for _ in 0..3 {
            assert!(failpoint("net.accept").is_ok());
        }
        assert!(failpoint("net.accept").is_err());
        clear();
    }

    #[test]
    fn corrupt_flips_exactly_one_byte_deterministically() {
        let _g = serial();
        install(parse_spec("seed=9,net.write=corrupt").unwrap());
        let clean = vec![0u8; 64];
        let mut a = clean.clone();
        mangle("net.write", &mut a).unwrap();
        let diffs: Vec<usize> = (0..64).filter(|&i| a[i] != clean[i]).collect();
        assert_eq!(diffs.len(), 1, "exactly one byte must flip");
        // Hit 0 replays identically after a reinstall with the same seed.
        install(parse_spec("seed=9,net.write=corrupt").unwrap());
        let mut b = clean.clone();
        mangle("net.write", &mut b).unwrap();
        assert_eq!(a, b, "same seed, same hit, same corruption");
        // A different seed corrupts differently (position or mask).
        install(parse_spec("seed=10,net.write=corrupt").unwrap());
        let mut c = clean.clone();
        mangle("net.write", &mut c).unwrap();
        assert_ne!(a, c, "seed must steer the corruption");
        // Empty buffers are tolerated.
        install(parse_spec("seed=9,net.write=corrupt").unwrap());
        mangle("net.write", &mut []).unwrap();
        clear();
    }

    #[test]
    fn probabilistic_schedule_is_a_pure_function_of_the_seed() {
        let _g = serial();
        let run = || -> Vec<bool> {
            install(parse_spec("seed=42,net.write=error:0.3").unwrap());
            (0..200).map(|_| failpoint("net.write").is_err()).collect()
        };
        let a = run();
        let b = run();
        clear();
        assert_eq!(a, b, "same seed must replay the identical schedule");
        let fired = a.iter().filter(|x| **x).count();
        assert!(
            (20..=100).contains(&fired),
            "p=0.3 over 200 hits should fire roughly 60 times, got {fired}"
        );
    }

    #[test]
    fn concurrent_hits_fire_the_same_total_schedule() {
        let _g = serial();
        const HITS: usize = 400;
        install(parse_spec("seed=5,net.read=error:0.25").unwrap());
        let serial_fired: usize =
            (0..HITS).filter(|_| failpoint("net.read").is_err()).count();
        // Re-arm (ordinals restart) and consume the same hit count from
        // four racing threads: the set of firing ordinals is fixed by
        // the seed, so the total must match exactly.
        install(parse_spec("seed=5,net.read=error:0.25").unwrap());
        let fired = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..HITS / 4 {
                        if failpoint("net.read").is_err() {
                            fired.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        clear();
        assert_eq!(fired.load(Ordering::Relaxed), serial_fired);
    }

    #[test]
    fn delay_rules_sleep_then_proceed() {
        let _g = serial();
        install(parse_spec("net.read=delay-10").unwrap());
        let t0 = std::time::Instant::now();
        assert!(failpoint("net.read").is_ok());
        clear();
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn panic_rules_unwind_with_the_site_name() {
        let _g = serial();
        install(parse_spec("net.accept=panic").unwrap());
        let r = std::panic::catch_unwind(|| failpoint("net.accept"));
        clear();
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("net.accept"), "{msg}");
    }
}
