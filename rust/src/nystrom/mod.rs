//! Nyström low-rank kernel approximation — the "directly approximate
//! the Gram matrix" family the paper's related work (§2, Bach & Jordan)
//! contrasts with random feature maps.
//!
//! Given `m` landmark points `S`, the feature map is
//! `Z(x) = K_mm^{-1/2} · [K(x, s_1) .. K(x, s_m)]ᵀ`, so
//! `⟨Z(x), Z(y)⟩ = K_xS K_mm^{-1} K_Sy` — the best rank-`m`
//! approximation within the landmarks' span. Unlike Random Maclaurin
//! maps it is *data-dependent* (needs a training sample) and its
//! features cost `O(m·d)` kernel evaluations each; the benches use it
//! as the accuracy-per-dimension baseline.

use crate::features::FeatureMap;
use crate::kernels::DotProductKernel;
use crate::linalg::{inv_sqrt_psd, Matrix};
use crate::rng::Rng;
use crate::{Error, Result};

/// A fitted Nyström feature map.
pub struct Nystrom {
    landmarks: Matrix,
    /// `m × m` normalizer `K_mm^{-1/2}`.
    normalizer: Matrix,
    kernel: Box<dyn DotProductKernel>,
}

impl Nystrom {
    /// Fit on `m` landmarks sampled uniformly from `data` rows.
    pub fn fit(
        kernel: Box<dyn DotProductKernel>,
        data: &Matrix,
        m: usize,
        rng: &mut Rng,
    ) -> Result<Nystrom> {
        if m == 0 || data.rows() == 0 {
            return Err(Error::Config("nystrom needs m > 0 landmarks and data".into()));
        }
        let m = m.min(data.rows());
        let idx = rng.sample_indices(data.rows(), m);
        let rows: Vec<Vec<f32>> = idx.iter().map(|&i| data.row(i).to_vec()).collect();
        let landmarks = Matrix::from_rows(&rows)?;
        // K_mm + jitter for numerical stability.
        let mut kmm = crate::kernels::gram(kernel.as_ref(), &landmarks);
        for i in 0..m {
            kmm.set(i, i, kmm.get(i, i) + 1e-6);
        }
        let normalizer = inv_sqrt_psd(&kmm, 1e-10);
        Ok(Nystrom { landmarks, normalizer, kernel })
    }

    /// Number of landmarks (= output dimension).
    pub fn n_landmarks(&self) -> usize {
        self.landmarks.rows()
    }
}

impl FeatureMap for Nystrom {
    fn input_dim(&self) -> usize {
        self.landmarks.cols()
    }

    fn output_dim(&self) -> usize {
        self.landmarks.rows()
    }

    fn transform_into(&self, x: &[f32], out: &mut [f32]) {
        let _span = crate::obs::span("transform.nystrom");
        assert_eq!(x.len(), self.input_dim());
        assert_eq!(out.len(), self.output_dim());
        let m = self.landmarks.rows();
        let kx: Vec<f32> =
            (0..m).map(|i| self.kernel.eval(self.landmarks.row(i), x) as f32).collect();
        for i in 0..m {
            out[i] = crate::linalg::dot(self.normalizer.row(i), &kx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::feature_gram;
    use crate::kernels::{gram, mean_abs_gram_error, Exponential, Polynomial};

    fn sphere_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        let rows: Vec<Vec<f32>> =
            (0..n).map(|_| crate::prop::gens::unit_vec(&mut rng, d)).collect();
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn approximates_gram_with_enough_landmarks() {
        let x = sphere_points(60, 6, 1);
        let kernel = Exponential::new(1.0);
        let mut rng = Rng::seed_from(2);
        let ny = Nystrom::fit(Box::new(kernel), &x, 40, &mut rng).unwrap();
        let exact = gram(&Exponential::new(1.0), &x);
        let approx = feature_gram(&ny, &x);
        let err = mean_abs_gram_error(&exact, &approx);
        assert!(err < 0.05, "nystrom gram err {err}");
    }

    #[test]
    fn more_landmarks_is_better() {
        let x = sphere_points(80, 8, 3);
        let exact = gram(&Polynomial::new(4, 1.0), &x);
        let err_at = |m: usize| {
            let mut rng = Rng::seed_from(4);
            let ny = Nystrom::fit(Box::new(Polynomial::new(4, 1.0)), &x, m, &mut rng).unwrap();
            mean_abs_gram_error(&exact, &feature_gram(&ny, &x))
        };
        let e_small = err_at(5);
        let e_big = err_at(60);
        assert!(e_big < e_small, "m=5: {e_small}, m=60: {e_big}");
    }

    #[test]
    fn output_dim_is_landmark_count() {
        let x = sphere_points(30, 4, 5);
        let mut rng = Rng::seed_from(6);
        let ny = Nystrom::fit(Box::new(Exponential::new(1.0)), &x, 12, &mut rng).unwrap();
        assert_eq!(ny.output_dim(), 12);
        assert_eq!(ny.transform(x.row(0)).len(), 12);
        // m capped at data size
        let ny2 = Nystrom::fit(Box::new(Exponential::new(1.0)), &x, 1000, &mut rng).unwrap();
        assert_eq!(ny2.n_landmarks(), 30);
    }

    #[test]
    fn rejects_empty() {
        let mut rng = Rng::seed_from(7);
        assert!(Nystrom::fit(
            Box::new(Exponential::new(1.0)),
            &Matrix::zeros(0, 3),
            4,
            &mut rng
        )
        .is_err());
    }
}
