//! Crate-level feature-embedding layer.
//!
//! The cross-cutting abstraction of the whole reproduction: a
//! [`FeatureMap`] is any (possibly randomized, already-sampled)
//! embedding `Z: R^d → R^D` with `⟨Z(x), Z(y)⟩ ≈ K(x, y)`. Four peer
//! families implement it:
//!
//! * [`crate::maclaurin`] — Random Maclaurin maps (the paper's
//!   Algorithm 1/2, H0/1, truncated variant);
//! * [`crate::rff`] — Random Fourier Features (Rahimi & Recht);
//! * [`crate::tensorsketch`] — TensorSketch (Pham & Pagh);
//! * [`crate::nystrom`] — data-dependent Nyström features.
//!
//! Consumers (`svm`, `bench`, `cli`, `coordinator`, the examples) import
//! the trait from here; `maclaurin` re-exports it for source
//! compatibility with the original layout, where the trait lived inside
//! the Random Maclaurin module even though its siblings implemented it.
//!
//! Batch plumbing is data-parallel: [`FeatureMap::transform_batch`] and
//! [`feature_gram`] fan row blocks out over the scoped worker pool in
//! [`crate::parallel`]. Each output row is produced by the same serial
//! routine regardless of the thread count, so parallel results are
//! bit-identical to serial ones (enforced by
//! `rust/tests/parallel_identity.rs`).
//!
//! Sparse inputs get the same treatment:
//! [`FeatureMap::transform_sparse_into`] /
//! [`FeatureMap::transform_batch_sparse`] accept CSR rows
//! ([`crate::linalg::SparseRow`] / [`crate::linalg::SparseMatrix`]).
//! The defaults densify each row and delegate (always correct); the
//! projection-backed families (`DenseProjection` behind Random
//! Maclaurin and Random Fourier, TensorSketch's count sketch) override
//! them with genuine `O(D·nnz)` kernels. Either way the outputs equal
//! the dense path's — the sparse kernels accumulate the stored entries
//! in the exact order the dense kernels visit the nonzeros, the crate's
//! sparse parity contract (`rust/tests/sparse_parity.rs`).
//!
//! The serving hot path additionally gets allocation-free transforms:
//! [`FeatureMap::transform_into_scratch`] /
//! [`FeatureMap::transform_sparse_into_scratch`] take a reusable
//! per-worker [`Scratch`] arena for the map's internal workspace, and
//! the batch defaults create one scratch per row block — so the
//! steady-state per-input loop performs no heap allocation (asserted
//! with a counting allocator in `rust/tests/alloc_free_transform.rs`).

use crate::data::{Dataset, Storage};
use crate::linalg::{Matrix, SparseMatrix, SparseRow};

/// A reusable per-worker scratch arena for the transform hot paths.
///
/// Every map family needs some workspace per input — the projection
/// vector and FWHT pads of Random Maclaurin, the Fastfood chains of
/// structured Random Fourier, TensorSketch's count-sketch accumulators.
/// Allocating that workspace per call is what made the serving hot loop
/// allocate per input; a `Scratch` owns one growable backing buffer and
/// hands out disjoint slices of it, so after the first (warm-up) call
/// the steady state performs **zero heap allocation per input**
/// (asserted by `rust/tests/alloc_free_transform.rs` with a counting
/// allocator).
///
/// Ownership rule: a `Scratch` belongs to exactly one worker (thread)
/// at a time — the batch paths create one per row block, the
/// coordinator's backends one per worker. Slice contents are
/// **unspecified** on entry (stale data from the previous input);
/// callers must fully overwrite what they read.
#[derive(Debug, Default)]
pub struct Scratch {
    buf: Vec<f32>,
}

impl Scratch {
    /// An empty arena (no allocation until first use).
    pub fn new() -> Scratch {
        Scratch { buf: Vec::new() }
    }

    /// Grow the backing buffer to at least `n` elements. `resize` only
    /// ever grows, so steady-state calls never touch the allocator.
    fn ensure(&mut self, n: usize) {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
    }

    /// One scratch slice of length `n` (contents unspecified).
    pub fn one(&mut self, n: usize) -> &mut [f32] {
        self.ensure(n);
        &mut self.buf[..n]
    }

    /// Two disjoint scratch slices (contents unspecified).
    pub fn two(&mut self, a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
        self.ensure(a + b);
        let (x, rest) = self.buf.split_at_mut(a);
        (x, &mut rest[..b])
    }

    /// Four disjoint scratch slices (contents unspecified).
    pub fn four(
        &mut self,
        a: usize,
        b: usize,
        c: usize,
        d: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32], &mut [f32]) {
        self.ensure(a + b + c + d);
        let (x, rest) = self.buf.split_at_mut(a);
        let (y, rest) = rest.split_at_mut(b);
        let (z, rest) = rest.split_at_mut(c);
        (x, y, z, &mut rest[..d])
    }

    /// Current backing capacity in elements (diagnostics/tests).
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }
}

/// A (possibly randomized, already-sampled) feature embedding
/// `R^input_dim → R^output_dim`.
///
/// The contract is the paper's estimator property (Kar & Karnick,
/// Lemma 7): over the sampling randomness,
/// `E[⟨Z(x), Z(y)⟩] = K(x, y)` for the kernel the map was built for —
/// which by Schoenberg's characterization (paper Theorem 1 via
/// [`crate::kernels::DotProductKernel`]) covers *every* positive
/// definite dot product kernel. Concentration in the output dimension
/// (paper Lemma 9 / Theorem 12: deviations shrink like `1/√D` given the
/// Lemma 8 bound `|Z_i(x)Z_i(y)| ≤ C_Ω/D`) is what the Figure-1
/// experiments and `rfdot report`'s error-vs-D curves measure.
pub trait FeatureMap: Send + Sync {
    /// Input dimensionality `d`.
    fn input_dim(&self) -> usize;

    /// Output dimensionality — the paper's `D`, the knob the
    /// `1/√D`-concentration (Theorem 12) is stated in. H0/1 maps
    /// (§6.1) report `1 + d + D`: the exact constant/linear prefix
    /// plus the random block.
    fn output_dim(&self) -> usize;

    /// Apply the map to one vector, writing into `out`
    /// (`out.len() == output_dim()`). This is one draw of the paper's
    /// Algorithm 1 output (or a sibling family's equivalent), *not* a
    /// fresh sample: maps are immutable after sampling, so repeated
    /// calls are deterministic.
    fn transform_into(&self, x: &[f32], out: &mut [f32]);

    /// Apply the map to one vector.
    fn transform(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.transform_into(x, &mut out);
        out
    }

    /// [`FeatureMap::transform_into`] with a caller-owned [`Scratch`]
    /// arena for the map's per-input workspace. Bit-identical to
    /// `transform_into` — the scratch only replaces where the
    /// intermediate buffers live, never what is computed. Families with
    /// internal workspace override this so that steady-state calls with
    /// a reused `Scratch` perform no heap allocation per input; the
    /// default ignores the scratch and delegates.
    fn transform_into_scratch(&self, x: &[f32], out: &mut [f32], _scratch: &mut Scratch) {
        self.transform_into(x, out);
    }

    /// [`FeatureMap::transform_sparse_into`] with a caller-owned
    /// [`Scratch`] arena (same contract as
    /// [`FeatureMap::transform_into_scratch`]).
    fn transform_sparse_into_scratch(
        &self,
        x: SparseRow<'_>,
        out: &mut [f32],
        _scratch: &mut Scratch,
    ) {
        self.transform_sparse_into(x, out);
    }

    /// Apply the map to every row of `x`, using the global
    /// [`crate::parallel`] worker budget.
    fn transform_batch(&self, x: &Matrix) -> Matrix {
        self.transform_batch_threads(x, 0)
    }

    /// Apply the map to every row of `x` with an explicit worker count
    /// (`0` = the global knob). Rows are independent, so any thread
    /// count yields bit-identical output.
    fn transform_batch_threads(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        let (rows, dd) = (x.rows(), self.output_dim());
        let mut out = Matrix::zeros(rows, dd);
        if rows == 0 || dd == 0 {
            return out;
        }
        // Per-row cost is at least D·d mul-adds for every map family.
        let work = rows.saturating_mul(dd).saturating_mul(self.input_dim().max(1));
        let threads = crate::parallel::resolve_threads_for_work(threads, rows, work);
        crate::parallel::par_chunks(threads, dd, out.as_mut_slice(), |row0, block| {
            // One scratch per worker block: the per-row loop is
            // allocation-free in steady state.
            let mut scratch = Scratch::new();
            for (i, out_row) in block.chunks_mut(dd).enumerate() {
                // Row blocks are disjoint; each row is one serial call.
                self.transform_into_scratch(x.row(row0 + i), out_row, &mut scratch);
            }
        });
        out
    }

    /// Apply the map to one CSR row, writing into `out`. The default
    /// densifies the row and delegates to
    /// [`FeatureMap::transform_into`] — always equal to the dense path
    /// by construction. Maps with an `O(D·nnz)` kernel override this.
    fn transform_sparse_into(&self, x: SparseRow<'_>, out: &mut [f32]) {
        assert_eq!(x.dim, self.input_dim(), "input dim mismatch");
        let dense = x.to_dense();
        self.transform_into(&dense, out);
    }

    /// Apply the map to every row of a CSR matrix, using the global
    /// [`crate::parallel`] worker budget.
    fn transform_batch_sparse(&self, x: &SparseMatrix) -> Matrix {
        self.transform_batch_sparse_threads(x, 0)
    }

    /// [`FeatureMap::transform_batch_sparse`] with an explicit worker
    /// count (`0` = the global knob). Rows are independent, so any
    /// thread count yields bit-identical output; each output row also
    /// equals the dense [`FeatureMap::transform_batch`] row on the
    /// densified input (the sparse parity contract).
    fn transform_batch_sparse_threads(&self, x: &SparseMatrix, threads: usize) -> Matrix {
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        let (rows, dd) = (x.rows(), self.output_dim());
        let mut out = Matrix::zeros(rows, dd);
        if rows == 0 || dd == 0 {
            return out;
        }
        // Per-row cost is ~D·nnz mul-adds for the sparse fast paths.
        let work = x.nnz().max(rows).saturating_mul(dd);
        let threads = crate::parallel::resolve_threads_for_work(threads, rows, work);
        crate::parallel::par_chunks(threads, dd, out.as_mut_slice(), |row0, block| {
            let mut scratch = Scratch::new();
            for (i, out_row) in block.chunks_mut(dd).enumerate() {
                self.transform_sparse_into_scratch(x.row(row0 + i), out_row, &mut scratch);
            }
        });
        out
    }
}

/// Apply `map` to every example of `ds`, dispatching on the dataset's
/// [`Storage`]: CSR storage routes through the `O(D·nnz)` sparse batch
/// path, dense storage through the GEMM-backed dense one. Equal results
/// either way (the sparse parity contract); only the cost changes.
pub fn transform_dataset(map: &dyn FeatureMap, ds: &Dataset) -> Matrix {
    match ds.storage() {
        Storage::Dense(x) => map.transform_batch(x),
        Storage::Sparse(x) => map.transform_batch_sparse(x),
    }
}

/// Approximate Gram matrix `⟨Z(x_i), Z(x_j)⟩` of a feature map over the
/// rows of `x` — compared entrywise against [`crate::kernels::gram`]
/// (via [`crate::kernels::mean_abs_gram_error`]) in the Figure 1
/// experiments: by Lemma 7 each entry is an unbiased estimate of
/// `K(x_i, x_j)`, and by Theorem 12 the uniform error decays like
/// `1/√D`. Uses the global worker budget.
pub fn feature_gram(map: &dyn FeatureMap, x: &Matrix) -> Matrix {
    feature_gram_threads(map, x, 0)
}

/// [`feature_gram`] with an explicit worker count (`0` = the global
/// knob). Each entry is one independent `O(D)` dot product of feature
/// rows, so the triangular fill parallelizes bit-identically (see
/// [`crate::linalg::symmetric_from_lower`]).
pub fn feature_gram_threads(map: &dyn FeatureMap, x: &Matrix, threads: usize) -> Matrix {
    let z = map.transform_batch_threads(x, threads);
    crate::linalg::symmetric_from_lower(z.rows(), threads, map.output_dim(), |i, j| {
        crate::linalg::dot(z.row(i), z.row(j))
    })
}

/// [`feature_gram`] over CSR inputs: the feature rows come from the
/// `O(D·nnz)` sparse batch path, the triangular dot-product fill is
/// unchanged (feature rows are dense whatever the input storage). Equal
/// to [`feature_gram`] on the densified input.
pub fn feature_gram_sparse(map: &dyn FeatureMap, x: &SparseMatrix) -> Matrix {
    feature_gram_sparse_threads(map, x, 0)
}

/// [`feature_gram_sparse`] with an explicit worker count (`0` = the
/// global knob).
pub fn feature_gram_sparse_threads(
    map: &dyn FeatureMap,
    x: &SparseMatrix,
    threads: usize,
) -> Matrix {
    let z = map.transform_batch_sparse_threads(x, threads);
    crate::linalg::symmetric_from_lower(z.rows(), threads, map.output_dim(), |i, j| {
        crate::linalg::dot(z.row(i), z.row(j))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal deterministic map: `Z(x) = [x, 2x]` (output_dim = 2d).
    struct DoubleMap {
        d: usize,
    }

    impl FeatureMap for DoubleMap {
        fn input_dim(&self) -> usize {
            self.d
        }

        fn output_dim(&self) -> usize {
            2 * self.d
        }

        fn transform_into(&self, x: &[f32], out: &mut [f32]) {
            for (i, &xi) in x.iter().enumerate() {
                out[i] = xi;
                out[self.d + i] = 2.0 * xi;
            }
        }
    }

    fn sample_batch(rows: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = crate::rng::Rng::seed_from(seed);
        let data: Vec<f32> = (0..rows * d).map(|_| rng.f32() - 0.5).collect();
        Matrix::from_vec(rows, d, data).unwrap()
    }

    #[test]
    fn default_batch_matches_single() {
        let map = DoubleMap { d: 3 };
        let x = sample_batch(5, 3, 1);
        let zb = map.transform_batch(&x);
        for i in 0..5 {
            assert_eq!(zb.row(i), &map.transform(x.row(i))[..]);
        }
    }

    #[test]
    fn batch_is_bit_identical_across_thread_counts() {
        let map = DoubleMap { d: 4 };
        for rows in [0usize, 1, 2, 7, 33] {
            let x = sample_batch(rows, 4, 2);
            let serial = map.transform_batch_threads(&x, 1);
            for threads in [2usize, 3, 8, 64] {
                // Includes threads > rows.
                assert_eq!(map.transform_batch_threads(&x, threads), serial);
            }
        }
    }

    #[test]
    fn feature_gram_symmetric_and_thread_invariant() {
        let map = DoubleMap { d: 3 };
        let x = sample_batch(9, 3, 3);
        let serial = feature_gram_threads(&map, &x, 1);
        for i in 0..9 {
            assert!(serial.get(i, i) >= 0.0);
            for j in 0..9 {
                assert_eq!(serial.get(i, j), serial.get(j, i));
            }
        }
        for threads in [2usize, 4, 16] {
            assert_eq!(feature_gram_threads(&map, &x, threads), serial);
        }
    }

    #[test]
    fn default_sparse_paths_match_dense() {
        // The trait defaults densify per row, so sparse output must be
        // exactly the dense output — for any thread count.
        let map = DoubleMap { d: 6 };
        let mut x = sample_batch(9, 6, 4);
        // Punch holes so the CSR form is genuinely sparse.
        for i in 0..9 {
            for j in 0..6 {
                if (i + j) % 3 != 0 {
                    x.set(i, j, 0.0);
                }
            }
        }
        let sx = crate::linalg::SparseMatrix::from_dense(&x);
        let dense = map.transform_batch(&x);
        assert_eq!(map.transform_batch_sparse(&sx), dense);
        for threads in [1usize, 2, 8] {
            assert_eq!(map.transform_batch_sparse_threads(&sx, threads), dense);
        }
        let mut row_out = vec![0.0f32; map.output_dim()];
        map.transform_sparse_into(sx.row(3), &mut row_out);
        assert_eq!(&row_out[..], dense.row(3));
        assert_eq!(
            feature_gram_sparse(&map, &sx),
            feature_gram(&map, &x),
            "gram must be storage-invariant"
        );
    }

    #[test]
    fn transform_dataset_dispatches_on_storage() {
        let map = DoubleMap { d: 3 };
        let x = sample_batch(5, 3, 6);
        let dense =
            crate::data::Dataset::new("d", x.clone(), vec![1.0, -1.0, 1.0, -1.0, 1.0]).unwrap();
        let sparse = dense.clone().into_sparse();
        let zd = transform_dataset(&map, &dense);
        let zs = transform_dataset(&map, &sparse);
        assert_eq!(zd, zs);
        assert_eq!(zd, map.transform_batch(&x));
    }

    #[test]
    fn scratch_slices_are_disjoint_and_grow_only() {
        let mut s = Scratch::new();
        assert_eq!(s.capacity(), 0);
        {
            let (a, b) = s.two(3, 5);
            assert_eq!((a.len(), b.len()), (3, 5));
            a.fill(1.0);
            b.fill(2.0);
            assert!(a.iter().all(|&v| v == 1.0), "slices must not alias");
        }
        let grown = s.capacity();
        assert!(grown >= 8);
        // Smaller requests reuse the backing buffer.
        let _ = s.one(4);
        assert_eq!(s.capacity(), grown);
        let (w, x, y, z) = s.four(1, 2, 3, 4);
        assert_eq!((w.len(), x.len(), y.len(), z.len()), (1, 2, 3, 4));
        assert!(s.capacity() >= 10);
    }

    #[test]
    fn scratch_transform_matches_plain_transform() {
        // The default scratch entry points must be the plain ones.
        let map = DoubleMap { d: 4 };
        let x = [0.25f32, -1.0, 0.5, 3.0];
        let mut scratch = Scratch::new();
        let mut out = vec![0.0f32; map.output_dim()];
        map.transform_into_scratch(&x, &mut out, &mut scratch);
        assert_eq!(out, map.transform(&x));
        // Sparse default too.
        let m = Matrix::from_rows(&[x.to_vec()]).unwrap();
        let sm = crate::linalg::SparseMatrix::from_dense(&m);
        let mut out2 = vec![0.0f32; map.output_dim()];
        map.transform_sparse_into_scratch(sm.row(0), &mut out2, &mut scratch);
        assert_eq!(out2, out);
    }

    #[test]
    fn maclaurin_reexport_is_the_same_trait() {
        // The deprecation re-export must stay usable as the same item.
        fn takes_new(m: &dyn FeatureMap) -> usize {
            m.output_dim()
        }
        fn takes_old(m: &dyn crate::maclaurin::FeatureMap) -> usize {
            m.output_dim()
        }
        let map = DoubleMap { d: 2 };
        assert_eq!(takes_new(&map), takes_old(&map));
    }
}
