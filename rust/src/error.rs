//! Crate-wide error type.
//!
//! A single flat enum keeps `?` ergonomic across the substrates without
//! pulling in `thiserror` (not vendored in this build environment).

use std::fmt;

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes surfaced by the library.
#[derive(Debug)]
pub enum Error {
    /// A configuration value is out of range or inconsistent.
    Config(String),
    /// Kernel definition problem (negative Maclaurin coefficient,
    /// evaluation outside the radius of convergence, ...).
    Kernel(String),
    /// Dataset parsing / generation problem.
    Data(String),
    /// Shape mismatch between tensors, models and maps.
    Shape { expected: String, got: String },
    /// Training failed to make progress / converge.
    Solver(String),
    /// PJRT runtime failure (artifact missing, compile error, ...).
    Runtime(String),
    /// Coordinator failure (queue closed, worker died, overload).
    Coordinator(String),
    /// Benchmark gate failure (`rfdot bench-diff` found a regression).
    Bench(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Kernel(m) => write!(f, "kernel error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Shape { expected, got } => {
                write!(f, "shape mismatch: expected {expected}, got {got}")
            }
            Error::Solver(m) => write!(f, "solver error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Bench(m) => write!(f, "bench error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand for shape errors.
    pub fn shape(expected: impl Into<String>, got: impl Into<String>) -> Self {
        Error::Shape { expected: expected.into(), got: got.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_prefixed() {
        assert!(Error::Config("bad".into()).to_string().starts_with("config"));
        assert!(Error::shape("[2,2]", "[3]").to_string().contains("expected [2,2]"));
    }

    #[test]
    fn io_source_is_preserved() {
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "x"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
