//! In-tree data-parallel execution subsystem (no external crates are
//! reachable in this environment, so this is `std::thread` only).
//!
//! The paper's whole pitch is speed — explicit feature maps make
//! training and serving *linear* in the data — and the CPU hot paths
//! that realize that promise ([`crate::linalg::Matrix::matmul`],
//! [`crate::features::FeatureMap::transform_batch`],
//! [`crate::kernels::gram`] / [`crate::features::feature_gram`], the SVM
//! scoring loops) are embarrassingly row-parallel. This module provides
//! the one primitive they all share:
//!
//! * [`par_chunks`] — partition a row-major buffer into contiguous
//!   row blocks and run the same per-block routine on a scoped worker
//!   pool ([`std::thread::scope`]: workers borrow the caller's data,
//!   are joined before the call returns, and propagate panics).
//! * [`par_map`] / [`par_sum_usize`] — fill-a-vector and
//!   integer-reduction conveniences built on the same partitioning.
//! * [`max_threads`] / [`set_max_threads`] — the process-wide
//!   parallelism knob, surfaced through `config` (`threads`), the CLI
//!   (`--threads`), the bench harness and
//!   [`crate::coordinator::CoordinatorConfig::intra_op_threads`]. The
//!   `RFDOT_THREADS` environment variable seeds the default.
//!
//! **Determinism contract:** every helper here partitions work into
//! *whole rows* (or whole indices) and each row is computed by the same
//! serial routine regardless of the thread count — there is no
//! cross-row floating-point reduction whose order could change. Running
//! with 1 thread, 8 threads, or more threads than rows therefore
//! produces **bit-identical** results; `rust/tests/parallel_identity.rs`
//! holds every hot path to that by exact equality.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide thread budget; 0 = not yet resolved.
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Threads the hardware advertises (1 if unknown).
pub fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The process-wide worker budget used when a call site passes
/// `threads = 0`. Resolved on first use from `RFDOT_THREADS` (if set to
/// a positive integer) or the hardware parallelism; overridable at any
/// time with [`set_max_threads`].
pub fn max_threads() -> usize {
    match MAX_THREADS.load(Ordering::Relaxed) {
        0 => {
            let n = std::env::var("RFDOT_THREADS")
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(hardware_threads);
            // Benign race: every initializer computes the same value.
            MAX_THREADS.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

/// Set the process-wide worker budget (clamped to ≥ 1). This is the
/// single knob behind `--threads`, the `threads` config field and the
/// coordinator's `intra_op_threads = 0` ("inherit") setting.
pub fn set_max_threads(threads: usize) {
    MAX_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// Resolve a per-call thread request against the global knob and the
/// number of work units: `0` means "use [`max_threads`]", and no more
/// workers than units are ever spawned.
pub fn resolve_threads(threads: usize, units: usize) -> usize {
    let t = if threads == 0 { max_threads() } else { threads };
    t.max(1).min(units.max(1))
}

/// Work (in primitive mul-add/eval units) below which the *auto* paths
/// (`threads == 0`) run inline: scoped spawn/join costs tens of
/// microseconds, which dwarfs the compute for small operands (a 16×16
/// Gram, PCA's per-iteration matvec). Scheduling only — results are
/// bit-identical either way; an explicit thread count always fans out
/// as requested so the identity tests exercise real parallel code.
pub const MIN_PAR_WORK: usize = 1 << 17;

/// [`resolve_threads`] with the [`MIN_PAR_WORK`] heuristic: an auto
/// request (`threads == 0`) whose estimated `work` is below the cutoff
/// resolves to 1 thread.
pub fn resolve_threads_for_work(threads: usize, units: usize, work: usize) -> usize {
    if threads == 0 && work < MIN_PAR_WORK {
        1
    } else {
        resolve_threads(threads, units)
    }
}

/// Balanced contiguous partition of `0..n` into at most `parts` ranges
/// (the first `n % parts` ranges get one extra unit; no empty ranges).
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Balanced contiguous partition of `0..n` for *triangular* workloads
/// where unit `i` costs `i + 1` (lower-triangle Gram rows): boundaries
/// sit at `n·√(p/parts)` so every range carries roughly equal total
/// work. Scheduling only — results never depend on the partition.
pub fn partition_triangular(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0usize);
    for p in 1..parts {
        let b = ((n as f64) * ((p as f64) / (parts as f64)).sqrt()).round() as usize;
        let prev = *bounds.last().expect("non-empty");
        // Strictly increasing, leaving ≥ 1 unit for each later range.
        bounds.push(b.max(prev + 1).min(n - (parts - p)));
    }
    bounds.push(n);
    bounds.windows(2).map(|w| w[0]..w[1]).collect()
}

/// [`par_chunks`] with caller-supplied contiguous row ranges (e.g.
/// from [`partition_triangular`]) instead of equal-row blocks. The
/// ranges must cover `0..data.len()/stride` in order without gaps.
pub fn par_chunks_ranges<T, F>(stride: usize, data: &mut [T], ranges: &[Range<usize>], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if ranges.len() <= 1 {
        f(0, data);
        return;
    }
    std::thread::scope(|s| {
        let mut rest = data;
        let mut inline: Option<(usize, &mut [T])> = None;
        for (idx, r) in ranges.iter().enumerate() {
            let (block, tail) = rest.split_at_mut(r.len() * stride);
            rest = tail;
            if idx == 0 {
                // The calling thread takes the first block itself
                // instead of idling at the scope barrier.
                inline = Some((r.start, block));
            } else {
                let f = &f;
                let start = r.start;
                s.spawn(move || f(start, block));
            }
        }
        if let Some((start, block)) = inline {
            f(start, block);
        }
    });
}

/// Row-chunked parallel-for over a mutable row-major buffer.
///
/// `data` is treated as `data.len() / stride` logical rows of `stride`
/// elements each. The buffer is split into contiguous row blocks, one
/// per scoped worker, and `f(first_row, block)` runs once per block
/// (`block` covers rows `first_row .. first_row + block.len() / stride`).
/// With `threads <= 1` (after resolving `0` via [`max_threads`]) the
/// closure runs inline on the whole buffer — the serial path and the
/// parallel path execute the same per-row code, which is what makes the
/// results bit-identical.
///
/// `stride` must evenly divide `data.len()`; a `stride` of 0 is only
/// meaningful for an empty buffer (the closure then runs once on it).
pub fn par_chunks<T, F>(threads: usize, stride: usize, data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert!(stride == 0 || data.len() % stride == 0, "stride must divide data.len()");
    let units = if stride == 0 { 0 } else { data.len() / stride };
    let t = resolve_threads(threads, units);
    if t <= 1 || units <= 1 {
        f(0, data);
        return;
    }
    // Ceil division keeps every block whole-row and the count ≤ t.
    let chunk_units = (units + t - 1) / t;
    std::thread::scope(|s| {
        let mut blocks = data.chunks_mut(chunk_units * stride).enumerate();
        // The calling thread takes the first block itself instead of
        // idling at the scope barrier (t-way parallelism, t-1 spawns).
        let inline = blocks.next();
        for (ci, block) in blocks {
            let f = &f;
            s.spawn(move || f(ci * chunk_units, block));
        }
        if let Some((ci, block)) = inline {
            f(ci * chunk_units, block);
        }
    });
}

/// Parallel `(0..n).map(f).collect()` over the scoped worker pool.
/// Index `i` always lands in slot `i`, so the output is identical to the
/// serial collect for any thread count.
pub fn par_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send + Clone + Default,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    par_chunks(threads, 1, &mut out, |i0, block| {
        for (k, slot) in block.iter_mut().enumerate() {
            *slot = f(i0 + k);
        }
    });
    out
}

/// Parallel integer reduction: partition `0..n`, run `f` per range on
/// the scoped pool, and sum the counts. Integer addition is associative,
/// so this is exactly the serial count for any thread count.
pub fn par_sum_usize<F>(threads: usize, n: usize, f: F) -> usize
where
    F: Fn(Range<usize>) -> usize + Sync,
{
    let t = resolve_threads(threads, n);
    if t <= 1 || n <= 1 {
        return f(0..n);
    }
    std::thread::scope(|s| {
        let mut ranges = partition(n, t).into_iter();
        let inline = ranges.next();
        let handles: Vec<_> = ranges
            .map(|r| {
                let f = &f;
                s.spawn(move || f(r))
            })
            .collect();
        let mut total = inline.map(|r| f(r)).unwrap_or(0);
        for h in handles {
            total += h.join().expect("parallel worker panicked");
        }
        total
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_balanced_and_covers() {
        for n in [0usize, 1, 2, 7, 64, 65] {
            for parts in [1usize, 2, 3, 8, 100] {
                let ranges = partition(n, parts);
                // Coverage in order, no gaps.
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next);
                    assert!(!r.is_empty());
                    next = r.end;
                }
                assert_eq!(next, n);
                if n > 0 {
                    assert!(ranges.len() <= parts.min(n));
                    let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                    let (min, max) =
                        (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(max - min <= 1, "unbalanced: {lens:?}");
                }
            }
        }
    }

    #[test]
    fn par_chunks_visits_every_row_once() {
        for threads in [1usize, 2, 3, 9, 64] {
            let rows = 17;
            let cols = 5;
            let mut data = vec![0u32; rows * cols];
            par_chunks(threads, cols, &mut data, |row0, block| {
                for (i, row) in block.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += 1 + (row0 + i) as u32;
                    }
                }
            });
            for (idx, v) in data.iter().enumerate() {
                assert_eq!(*v, 1 + (idx / cols) as u32, "row {} touched wrong", idx / cols);
            }
        }
    }

    #[test]
    fn par_chunks_handles_empty_and_single() {
        let mut empty: Vec<f32> = Vec::new();
        par_chunks(4, 0, &mut empty, |_, block| assert!(block.is_empty()));
        par_chunks(4, 3, &mut empty, |_, block| assert!(block.is_empty()));
        let mut one = vec![0.0f32; 3];
        par_chunks(8, 3, &mut one, |row0, block| {
            assert_eq!(row0, 0);
            block.fill(2.0);
        });
        assert_eq!(one, vec![2.0; 3]);
    }

    #[test]
    fn partition_triangular_covers_and_balances() {
        for n in [0usize, 1, 4, 7, 100] {
            for parts in [1usize, 2, 4, 9, 200] {
                let ranges = partition_triangular(n, parts);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next);
                    assert!(!r.is_empty());
                    next = r.end;
                }
                assert_eq!(next, n);
            }
        }
        // Triangular work (row i costs i+1) is near-equal across ranges.
        let n = 1000;
        let ranges = partition_triangular(n, 4);
        let total = n * (n + 1) / 2;
        for r in &ranges {
            let work: usize = r.clone().map(|i| i + 1).sum();
            assert!(
                work * 4 < total * 3 / 2 && work * 4 > total / 2,
                "unbalanced triangular range {r:?}: {work} of {total}"
            );
        }
    }

    #[test]
    fn par_chunks_ranges_visits_every_row_once() {
        let rows = 23;
        let cols = 3;
        let mut data = vec![0u32; rows * cols];
        let ranges = partition_triangular(rows, 5);
        par_chunks_ranges(cols, &mut data, &ranges, |row0, block| {
            for (i, row) in block.chunks_mut(cols).enumerate() {
                for v in row.iter_mut() {
                    *v += 1 + (row0 + i) as u32;
                }
            }
        });
        for (idx, v) in data.iter().enumerate() {
            assert_eq!(*v, 1 + (idx / cols) as u32);
        }
    }

    #[test]
    fn par_map_matches_serial() {
        for threads in [1usize, 2, 5, 33] {
            let got = par_map(threads, 100, |i| (i * i) as u64);
            let want: Vec<u64> = (0..100).map(|i| (i * i) as u64).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn par_sum_matches_serial() {
        for threads in [1usize, 2, 7, 100] {
            let got = par_sum_usize(threads, 1000, |r| r.filter(|i| i % 3 == 0).count());
            assert_eq!(got, (0..1000).filter(|i| i % 3 == 0).count());
        }
        assert_eq!(par_sum_usize(4, 0, |r| r.count()), 0);
    }

    #[test]
    fn knob_round_trips() {
        // The knob is process-global and tests run concurrently, so
        // this must stay the only test in the binary that *mutates* it
        // (set-path CLI coverage passes `--threads 0`, a no-op).
        set_max_threads(3);
        assert_eq!(max_threads(), 3);
        set_max_threads(0); // clamped to 1
        assert_eq!(max_threads(), 1);
        set_max_threads(hardware_threads());
    }

    #[test]
    #[should_panic]
    fn worker_panics_propagate() {
        let mut data = vec![0u8; 64];
        par_chunks(8, 1, &mut data, |row0, _| {
            if row0 > 0 {
                panic!("injected");
            }
        });
    }
}
