//! Deterministic pseudo-random number generation.
//!
//! The build environment has no access to crates.io, so the library ships
//! its own small, well-tested RNG stack instead of `rand`:
//!
//! * [`Rng`] — xoshiro256++ core seeded through SplitMix64. Fast,
//!   high-quality, and — critically for this reproduction — *stable
//!   across platforms and processes*, which is what lets the Rust native
//!   engine, the PJRT artifact path and the Python oracle all derive the
//!   same Rademacher vectors from the same seed (see
//!   `maclaurin::serialize`).
//! * [`Geometric`] — the external measure `P[N = n] ∝ p^{-(n+1)}` the
//!   paper imposes on Maclaurin orders (§4).
//! * [`rademacher`] — bit-packed `{±1}^d` vector sampling and sign-flip
//!   dot products.

pub mod rademacher;

pub use rademacher::RademacherMatrix;

/// SplitMix64 step; used for seeding and as a simple stream splitter.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator (Blackman & Vigna).
///
/// Deterministic, seedable, `Clone`-able; cloning forks the exact stream,
/// [`Rng::split`] forks a decorrelated stream.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Seed from a single `u64` via SplitMix64 (never yields the all-zero
    /// state xoshiro must avoid).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Fork an independent generator: the child is seeded from the
    /// parent's next output mixed through SplitMix64, so parent and child
    /// streams are decorrelated.
    pub fn split(&mut self) -> Rng {
        let mut sm = self.next_u64() ^ 0xA076_1D64_78BD_642F;
        Rng::seed_from(splitmix64(&mut sm))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits (upper half of a 64-bit draw).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection
    /// (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is undefined");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform in `[lo, hi)` as `f64`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (second value cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        // Avoid log(0): draw u from (0, 1].
        let u = 1.0 - self.f64();
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_cache = Some(r * s);
        r * c
    }

    /// A fair ±1 draw.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// `true` with probability `prob`.
    pub fn bernoulli(&mut self, prob: f64) -> bool {
        self.f64() < prob
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// The external measure on Maclaurin orders, `P[N = n] = (1 - q) q^n`
/// with `q = 1/p` — i.e. `P[N = n] = (p - 1) / p^(n+1)`.
///
/// For the paper's recommended `p = 2` this is *exactly* the measure of
/// §4 (`P[N = n] = 2^-(n+1)`), which is normalized as written. For
/// `p ≠ 2` the paper's raw `p^-(n+1)` does not sum to one, so we use the
/// normalized geometric law and carry the exact inverse probability in
/// the estimator weight (`maclaurin` divides by `P[N]` rather than
/// hard-coding `p^(N+1)`), keeping the estimator unbiased for every
/// `p > 1`.
#[derive(Clone, Copy, Debug)]
pub struct Geometric {
    /// The paper's `p > 1`.
    pub p: f64,
}

impl Geometric {
    /// Create the order distribution; panics unless `p > 1`.
    pub fn new(p: f64) -> Self {
        assert!(p > 1.0, "external measure requires p > 1, got {p}");
        Geometric { p }
    }

    /// Probability mass at order `n`.
    #[inline]
    pub fn pmf(&self, n: u32) -> f64 {
        (self.p - 1.0) / self.p.powi(n as i32 + 1)
    }

    /// Inverse mass `1 / P[N = n]` — the importance weight in the
    /// Random Maclaurin estimator.
    #[inline]
    pub fn inv_pmf(&self, n: u32) -> f64 {
        self.p.powi(n as i32 + 1) / (self.p - 1.0)
    }

    /// Draw an order by CDF inversion: `N = floor(log_q(1 - U))` where
    /// `q = 1/p`.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let u = rng.f64(); // in [0, 1)
        if u == 0.0 {
            return 0;
        }
        let q = 1.0 / self.p;
        // P[N >= n] = q^n; invert the survival function.
        let n = ((1.0 - u).ln() / q.ln()).floor();
        if n < 0.0 {
            0
        } else {
            n as u32
        }
    }

    /// Survival function `P[N ≥ n] = p^{-n}`.
    #[inline]
    pub fn survival(&self, n: u32) -> f64 {
        (1.0 / self.p).powi(n as i32)
    }

    /// Draw an order but clamped at `max_order` (all tail mass lands on
    /// `max_order`).
    pub fn sample_capped(&self, max_order: u32, rng: &mut Rng) -> u32 {
        self.sample(rng).min(max_order)
    }

    /// Probability that [`Self::sample_capped`] emits `n`: the plain pmf
    /// below the cap, the whole survival mass at it. Using *this* (not
    /// the raw pmf) as the importance weight makes the capped Random
    /// Maclaurin estimator exactly unbiased for the order-`cap`
    /// truncation of the kernel (§4.2), instead of carrying an
    /// uncontrolled bias at the cap.
    #[inline]
    pub fn pmf_capped(&self, n: u32, cap: u32) -> f64 {
        if n < cap {
            self.pmf(n)
        } else {
            self.survival(cap)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_decorrelates() {
        let mut a = Rng::seed_from(7);
        let mut c = a.split();
        let x: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::seed_from(1);
        for _ in 0..10_000 {
            let u = rng.f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::seed_from(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn geometric_p2_matches_paper_measure() {
        let g = Geometric::new(2.0);
        // P[N=n] = 2^-(n+1): normalized exactly as in the paper.
        assert!((g.pmf(0) - 0.5).abs() < 1e-15);
        assert!((g.pmf(3) - 1.0 / 16.0).abs() < 1e-15);
        let total: f64 = (0..64).map(|n| g.pmf(n)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geometric_sampler_matches_pmf() {
        let g = Geometric::new(2.0);
        let mut rng = Rng::seed_from(5);
        let n = 200_000;
        let mut counts = [0usize; 8];
        for _ in 0..n {
            let k = g.sample(&mut rng) as usize;
            if k < counts.len() {
                counts[k] += 1;
            }
        }
        for (k, &c) in counts.iter().enumerate() {
            let expected = g.pmf(k as u32);
            let got = c as f64 / n as f64;
            assert!(
                (got - expected).abs() < 0.005,
                "order {k}: got {got}, expected {expected}"
            );
        }
    }

    #[test]
    fn geometric_general_p_normalized() {
        for &p in &[1.5, 3.0, 10.0] {
            let g = Geometric::new(p);
            let total: f64 = (0..500).map(|n| g.pmf(n)).sum();
            assert!((total - 1.0).abs() < 1e-9, "p={p} total={total}");
            assert!((g.pmf(2) * g.inv_pmf(2) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn pmf_capped_sums_to_one() {
        for &p in &[1.5, 2.0, 4.0] {
            let g = Geometric::new(p);
            for cap in [0u32, 1, 5, 12] {
                let total: f64 = (0..=cap).map(|n| g.pmf_capped(n, cap)).sum();
                assert!((total - 1.0).abs() < 1e-12, "p={p} cap={cap} total={total}");
            }
        }
    }

    #[test]
    fn pmf_capped_matches_capped_sampler() {
        let g = Geometric::new(2.0);
        let mut rng = Rng::seed_from(21);
        let cap = 3u32;
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[g.sample_capped(cap, &mut rng) as usize] += 1;
        }
        for (k, &c) in counts.iter().enumerate() {
            let expected = g.pmf_capped(k as u32, cap);
            let got = c as f64 / n as f64;
            assert!((got - expected).abs() < 0.005, "order {k}: {got} vs {expected}");
        }
    }

    #[test]
    fn geometric_capped_never_exceeds() {
        let g = Geometric::new(1.2); // heavy tail
        let mut rng = Rng::seed_from(9);
        for _ in 0..10_000 {
            assert!(g.sample_capped(6, &mut rng) <= 6);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(2);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from(4);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
