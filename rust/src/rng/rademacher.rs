//! Bit-packed Rademacher (`{±1}^d`) vectors.
//!
//! The Random Maclaurin map multiplies together `N` projections
//! `ω_j^T x` per output feature. Storing each ω as one bit per coordinate
//! (0 ↦ +1, 1 ↦ −1) cuts the map's memory footprint 32× relative to an
//! f32 matrix — the dominant cost at large `D` — and the projection
//! becomes a sign-flipped sum which the hot path unrolls word-by-word.
//!
//! The packed form is also the *canonical serialization*: the Python
//! oracle and the PJRT artifact path expand the very same words to ±1
//! floats, so all three engines agree bit-for-bit on the sampled map.
//!
//! The words live behind a [`WeightStore`] (ISSUE 8): sampling yields
//! an owned store, while loading an `RFDM0003` artifact yields a
//! zero-copy view into the shared region — the projection hot path is
//! identical (and bit-identical) either way.

use crate::artifact::WeightStore;
use crate::rng::Rng;

/// A stack of `rows` bit-packed Rademacher vectors of dimension `dim`.
#[derive(Clone, Debug, PartialEq)]
pub struct RademacherMatrix {
    dim: usize,
    rows: usize,
    words_per_row: usize,
    /// Row-major packed bits; bit `k` of word `w` in a row encodes
    /// coordinate `w * 64 + k`: 0 ↦ +1.0, 1 ↦ −1.0. Owned when
    /// sampled, artifact-backed when loaded.
    words: WeightStore<u64>,
}

impl RademacherMatrix {
    /// Sample `rows` independent Rademacher vectors in `{±1}^dim` using
    /// fair coin tosses (one `u64` draw per 64 coordinates).
    pub fn sample(rows: usize, dim: usize, rng: &mut Rng) -> Self {
        let words_per_row = dim.div_ceil(64);
        let mut words = Vec::with_capacity(rows * words_per_row);
        for _ in 0..rows {
            for w in 0..words_per_row {
                let mut bits = rng.next_u64();
                // Mask tail bits beyond `dim` so equality/serialization is
                // canonical.
                let used = (dim - w * 64).min(64);
                if used < 64 {
                    bits &= (1u64 << used) - 1;
                }
                words.push(bits);
            }
        }
        RademacherMatrix { dim, rows, words_per_row, words: WeightStore::from_vec(words) }
    }

    /// Number of vectors.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Vector dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Raw packed words (row-major), for serialization.
    pub fn words(&self) -> &[u64] {
        self.words.as_slice()
    }

    /// Rebuild from packed words (inverse of [`Self::words`]).
    pub fn from_words(rows: usize, dim: usize, words: Vec<u64>) -> Self {
        Self::from_store(rows, dim, WeightStore::from_vec(words))
    }

    /// Rebuild over any store — owned or a zero-copy artifact view.
    pub fn from_store(rows: usize, dim: usize, words: WeightStore<u64>) -> Self {
        let words_per_row = dim.div_ceil(64);
        assert_eq!(words.len(), rows * words_per_row, "packed length mismatch");
        RademacherMatrix { dim, rows, words_per_row, words }
    }

    /// Sign of coordinate `j` of row `i` as ±1.0.
    #[inline]
    pub fn sign(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.dim);
        let w = self.words.as_slice()[i * self.words_per_row + j / 64];
        if (w >> (j % 64)) & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// `ω_i^T x`: sign-flipped sum of `x` under row `i`.
    ///
    /// Word-unrolled: each 64-coordinate chunk tests bits of a local copy
    /// of the word, which the compiler turns into branch-free selects.
    pub fn project(&self, i: usize, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.dim);
        let row = &self.words.as_slice()[i * self.words_per_row..(i + 1) * self.words_per_row];
        let mut acc = 0.0f32;
        for (w, chunk) in row.iter().zip(x.chunks(64)) {
            let mut bits = *w;
            for &v in chunk {
                // bit set ⇒ −v, clear ⇒ +v.
                acc += if bits & 1 == 0 { v } else { -v };
                bits >>= 1;
            }
        }
        acc
    }

    /// Project every row at once: `out[i] = ω_i^T x`.
    pub fn project_all(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(out.len(), self.rows);
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.project(i, x);
        }
    }

    /// Expand row `i` into a dense ±1.0 f32 vector (PJRT/oracle path).
    pub fn dense_row(&self, i: usize) -> Vec<f32> {
        (0..self.dim).map(|j| self.sign(i, j)).collect()
    }

    /// Expand the whole matrix row-major into ±1.0 f32s.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.dim);
        for i in 0..self.rows {
            for j in 0..self.dim {
                out.push(self.sign(i, j));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_project(m: &RademacherMatrix, i: usize, x: &[f32]) -> f32 {
        (0..x.len()).map(|j| m.sign(i, j) * x[j]).sum()
    }

    #[test]
    fn signs_are_pm_one() {
        let mut rng = Rng::seed_from(1);
        let m = RademacherMatrix::sample(4, 37, &mut rng);
        for i in 0..4 {
            for j in 0..37 {
                let s = m.sign(i, j);
                assert!(s == 1.0 || s == -1.0);
            }
        }
    }

    #[test]
    fn project_matches_naive_all_widths() {
        let mut rng = Rng::seed_from(2);
        for dim in [1, 3, 63, 64, 65, 100, 128, 200] {
            let m = RademacherMatrix::sample(3, dim, &mut rng);
            let x: Vec<f32> = (0..dim).map(|k| (k as f32 * 0.37).sin()).collect();
            for i in 0..3 {
                let fast = m.project(i, &x);
                let slow = naive_project(&m, i, &x);
                assert!(
                    (fast - slow).abs() < 1e-4,
                    "dim={dim} row={i}: {fast} vs {slow}"
                );
            }
        }
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::seed_from(3);
        let m = RademacherMatrix::sample(5, 70, &mut rng);
        let d = m.to_dense();
        assert_eq!(d.len(), 5 * 70);
        for i in 0..5 {
            for j in 0..70 {
                assert_eq!(d[i * 70 + j], m.sign(i, j));
            }
        }
    }

    #[test]
    fn words_roundtrip() {
        let mut rng = Rng::seed_from(4);
        let m = RademacherMatrix::sample(7, 90, &mut rng);
        let m2 = RademacherMatrix::from_words(7, 90, m.words().to_vec());
        assert_eq!(m, m2);
    }

    #[test]
    fn balanced_signs() {
        let mut rng = Rng::seed_from(5);
        let m = RademacherMatrix::sample(1000, 64, &mut rng);
        let total: f64 = (0..1000)
            .flat_map(|i| (0..64).map(move |j| (i, j)))
            .map(|(i, j)| m.sign(i, j) as f64)
            .sum();
        let frac = total / (1000.0 * 64.0);
        assert!(frac.abs() < 0.01, "sign bias {frac}");
    }

    #[test]
    fn expectation_preserves_dot_product() {
        // Lemma 6 of the paper: E[ω^T x · ω^T y] = <x, y>.
        let mut rng = Rng::seed_from(6);
        let d = 16;
        let x: Vec<f32> = (0..d).map(|k| (k as f32 * 0.3).cos()).collect();
        let y: Vec<f32> = (0..d).map(|k| (k as f32 * 0.7).sin()).collect();
        let exact: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let trials = 200_000;
        let m = RademacherMatrix::sample(trials, d, &mut rng);
        let mean: f64 = (0..trials)
            .map(|i| (m.project(i, &x) * m.project(i, &y)) as f64)
            .sum::<f64>()
            / trials as f64;
        assert!(
            (mean - exact as f64).abs() < 0.05,
            "mean {mean} vs exact {exact}"
        );
    }

    #[test]
    fn project_all_matches_project() {
        let mut rng = Rng::seed_from(7);
        let m = RademacherMatrix::sample(9, 33, &mut rng);
        let x: Vec<f32> = (0..33).map(|k| k as f32 * 0.01 - 0.2).collect();
        let mut out = vec![0.0; 9];
        m.project_all(&x, &mut out);
        for i in 0..9 {
            assert_eq!(out[i], m.project(i, &x));
        }
    }
}
