//! Host-side f32 tensors marshalled to/from PJRT literals.

use crate::{Error, Result};

/// A dense row-major f32 tensor of arbitrary rank (rank 0 = scalar).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Wrap a buffer with a shape.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(
                format!("{shape:?} ({n} elems)"),
                format!("{} elems", data.len()),
            ));
        }
        Ok(Tensor { shape, data })
    }

    /// Scalar tensor.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Zero-filled tensor.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// From a 2-D matrix.
    pub fn from_matrix(m: &crate::linalg::Matrix) -> Tensor {
        Tensor { shape: vec![m.rows(), m.cols()], data: m.as_slice().to_vec() }
    }

    /// Into a 2-D matrix (errors unless rank 2).
    pub fn into_matrix(self) -> Result<crate::linalg::Matrix> {
        if self.shape.len() != 2 {
            return Err(Error::shape("rank 2", format!("rank {}", self.shape.len())));
        }
        crate::linalg::Matrix::from_vec(self.shape[0], self.shape[1], self.data)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Convert to an `xla::Literal` (flat vec + reshape).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // PJRT scalars: reshape to rank 0.
            return lit
                .reshape(&[])
                .map_err(|e| Error::Runtime(format!("reshape scalar: {e}")));
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims)
            .map_err(|e| Error::Runtime(format!("reshape {:?}: {e}", self.shape)))
    }

    /// Read back from an `xla::Literal`, validating the element count
    /// against `shape`.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("literal to_vec: {e}")))?;
        Tensor::new(shape.to_vec(), data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::scalar(4.0).shape(), &[] as &[usize]);
        assert_eq!(Tensor::zeros(vec![2, 2]).data(), &[0.0; 4]);
    }

    #[test]
    fn matrix_roundtrip() {
        let m = crate::linalg::Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let t = Tensor::from_matrix(&m);
        assert_eq!(t.shape(), &[2, 2]);
        let m2 = t.into_matrix().unwrap();
        assert_eq!(m, m2);
        assert!(Tensor::scalar(1.0).into_matrix().is_err());
    }

    #[test]
    fn literal_roundtrip() {
        // Requires the xla extension to be loadable; the literal API is
        // host-only (no PJRT client needed).
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit, &[2, 3]).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let t = Tensor::scalar(2.5);
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit, &[]).unwrap();
        assert_eq!(t2.data(), &[2.5]);
    }
}
