//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! `make artifacts` runs `python/compile/aot.py` once, producing
//! `artifacts/<name>.hlo.txt` (HLO **text** — the only interchange format
//! xla_extension 0.5.1 accepts from jax ≥ 0.5 lowering, see DESIGN.md)
//! plus `<name>.json` manifests. This module wraps the `xla` crate:
//!
//! ```text
//! PjRtClient::cpu() → HloModuleProto::from_text_file → XlaComputation
//!   → client.compile → PjRtLoadedExecutable.execute(literals)
//! ```
//!
//! Python is never touched at runtime — a compiled [`LoadedArtifact`] is
//! a self-contained executable behind a `Send + Sync` handle, shared by
//! the coordinator's worker threads.

pub mod tensor;

pub use tensor::Tensor;

use crate::config::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Shape + dtype of one artifact argument or result.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let name = v.req("name")?.as_str().unwrap_or_default().to_string();
        let shape = v
            .req("shape")?
            .as_arr()
            .ok_or_else(|| Error::Config("shape must be an array".into()))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Config("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v.req("dtype")?.as_str().unwrap_or("f32").to_string();
        if dtype != "f32" {
            return Err(Error::Runtime(format!("unsupported dtype {dtype}")));
        }
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// Parsed `<name>.json` manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    /// Parse a manifest JSON document.
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let v = Json::parse(text)?;
        let name = v.req("name")?.as_str().unwrap_or_default().to_string();
        let kind = v
            .req("config")?
            .req("kind")?
            .as_str()
            .unwrap_or_default()
            .to_string();
        let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| Error::Config(format!("{key} must be an array")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ArtifactMeta {
            name,
            kind,
            inputs: parse_specs("inputs")?,
            outputs: parse_specs("outputs")?,
        })
    }

    /// Batch dimension of the first input (transform/score artifacts).
    pub fn batch(&self) -> usize {
        self.inputs.first().and_then(|s| s.shape.first().copied()).unwrap_or(0)
    }
}

/// A PJRT client bound to an artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

impl Engine {
    /// Connect to the CPU PJRT plugin.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("pjrt client: {e}")))?;
        Ok(Engine { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Platform string (e.g. "cpu") — for logs and `rfdot info`.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Directory artifacts are loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load `<name>.hlo.txt` + `<name>.json` and compile the module.
    pub fn load(&self, name: &str) -> Result<LoadedArtifact> {
        let hlo_path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let meta_path = self.artifact_dir.join(format!("{name}.json"));
        if !hlo_path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts`",
                hlo_path.display()
            )));
        }
        let meta = ArtifactMeta::parse(&std::fs::read_to_string(&meta_path)?)?;
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", hlo_path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
        Ok(LoadedArtifact { meta, exe: Arc::new(exe) })
    }
}

/// A compiled artifact ready to execute. Clone-able and `Send + Sync`;
/// clones share the underlying executable.
#[derive(Clone)]
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    exe: Arc<xla::PjRtLoadedExecutable>,
}

impl LoadedArtifact {
    /// Pre-marshal a host tensor into an `xla::Literal` once (for
    /// loop-invariant arguments like the feature map's Omega/mask/coeff:
    /// rebuilding those literals per call dominated the serving hot
    /// path — see EXPERIMENTS.md section Perf). Note: `execute_b` with
    /// device-resident buffers would also skip the host->device copy,
    /// but this xla_extension build aborts on buffer-literal size
    /// bookkeeping in that path, so cached literals are the safe fast
    /// route.
    pub fn marshal(&self, t: &Tensor) -> Result<xla::Literal> {
        t.to_literal()
    }

    /// Execute with pre-marshalled literals (borrowed; no per-call
    /// literal construction). Shape validation against the manifest is
    /// the caller's duty; PJRT still validates internally.
    pub fn execute_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.meta.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        if parts.len() != self.meta.outputs.len() {
            return Err(Error::shape(
                format!("{} outputs", self.meta.outputs.len()),
                format!("{}", parts.len()),
            ));
        }
        parts
            .into_iter()
            .zip(&self.meta.outputs)
            .map(|(lit, spec)| Tensor::from_literal(&lit, &spec.shape))
            .collect()
    }

    /// Execute with host tensors; validates shapes against the manifest
    /// and unpacks the return tuple into host tensors.
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(Error::shape(
                format!("{} inputs", self.meta.inputs.len()),
                format!("{}", inputs.len()),
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.meta.inputs) {
            if t.shape() != spec.shape {
                return Err(Error::shape(
                    format!("{} {:?}", spec.name, spec.shape),
                    format!("{:?}", t.shape()),
                ));
            }
            literals.push(t.to_literal()?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.meta.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
        // aot.py lowers with return_tuple=True: unpack n outputs.
        let parts = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        if parts.len() != self.meta.outputs.len() {
            return Err(Error::shape(
                format!("{} outputs", self.meta.outputs.len()),
                format!("{}", parts.len()),
            ));
        }
        parts
            .into_iter()
            .zip(&self.meta.outputs)
            .map(|(lit, spec)| Tensor::from_literal(&lit, &spec.shape))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
          "name": "t", "config": {"kind": "transform", "batch": 4},
          "inputs": [{"name": "x", "shape": [4, 2], "dtype": "f32"}],
          "outputs": [{"name": "z", "shape": [4, 8], "dtype": "f32"}],
          "format": "hlo-text/return-tuple"
        }"#;
        let m = ArtifactMeta::parse(text).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.kind, "transform");
        assert_eq!(m.batch(), 4);
        assert_eq!(m.inputs[0].element_count(), 8);
    }

    #[test]
    fn manifest_rejects_bad_dtype() {
        let text = r#"{
          "name": "t", "config": {"kind": "transform"},
          "inputs": [{"name": "x", "shape": [4], "dtype": "f64"}],
          "outputs": []
        }"#;
        assert!(ArtifactMeta::parse(text).is_err());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let eng = match Engine::cpu(std::env::temp_dir()) {
            Ok(e) => e,
            Err(_) => return, // PJRT unavailable: skip
        };
        let err = match eng.load("definitely_missing") {
            Err(e) => e,
            Ok(_) => panic!("load of a missing artifact must fail"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
