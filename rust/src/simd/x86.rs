//! x86_64 AVX2 + FMA kernels.
//!
//! Every function here carries `#[target_feature(enable = "avx2,fma")]`
//! and is reached only through [`super::SimdPath::Avx2`], which the
//! dispatcher hands out only after `is_x86_feature_detected!` confirms
//! both features — that runtime check is the safety argument for every
//! call site in `super`.
//!
//! Lane discipline (the contract the sparse mirrors in `super`
//! replicate): `dot` accumulates 32 elements per iteration into four
//! 8-lane FMA accumulators (element `k` lands in accumulator `⌊(k mod
//! 32) / 8⌋`, lane `k mod 8`), reduces with the vector adds
//! `(acc0+acc1) + (acc2+acc3)`, spills to a stack array and folds the
//! 8 lanes ascending, then finishes the remainder `k ≥ 32·(n/32)`
//! ascending with scalar [`f32::mul_add`] — which is correctly rounded
//! and therefore bitwise identical to a 1-lane `vfmadd`. `axpy` fuses
//! every element the same way (vector body and scalar tail alike), so
//! a sparse update can mirror it with one `mul_add` per stored entry.
//! Butterflies and scaling use only IEEE add/sub/mul and are bitwise
//! identical to the scalar path.

use core::arch::x86_64::*;

/// Dense dot, 4×8-lane FMA.
///
/// # Safety
/// Requires AVX2 and FMA (guaranteed by the dispatcher's runtime
/// detection).
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let cut = 32 * (n / 32);
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut k = 0usize;
    while k < cut {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(k)), _mm256_loadu_ps(bp.add(k)), acc0);
        acc1 =
            _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(k + 8)), _mm256_loadu_ps(bp.add(k + 8)), acc1);
        acc2 =
            _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(k + 16)), _mm256_loadu_ps(bp.add(k + 16)), acc2);
        acc3 =
            _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(k + 24)), _mm256_loadu_ps(bp.add(k + 24)), acc3);
        k += 32;
    }
    let sum = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    let mut t = [0.0f32; 8];
    _mm256_storeu_ps(t.as_mut_ptr(), sum);
    let mut s = 0.0f32;
    for v in t {
        s += v;
    }
    for k in cut..n {
        s = a[k].mul_add(b[k], s);
    }
    s
}

/// `y += alpha * x`, fused at every position.
///
/// # Safety
/// Requires AVX2 and FMA (guaranteed by the dispatcher's runtime
/// detection).
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let cut = 8 * (n / 8);
    let av = _mm256_set1_ps(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut k = 0usize;
    while k < cut {
        let v = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(k)), _mm256_loadu_ps(yp.add(k)));
        _mm256_storeu_ps(yp.add(k), v);
        k += 8;
    }
    for k in cut..n {
        y[k] = alpha.mul_add(x[k], y[k]);
    }
}

/// `x *= alpha` (pure IEEE multiplies — bitwise equal to scalar).
///
/// # Safety
/// Requires AVX2 and FMA (guaranteed by the dispatcher's runtime
/// detection).
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn scale_avx2(alpha: f32, x: &mut [f32]) {
    let n = x.len();
    let cut = 8 * (n / 8);
    let av = _mm256_set1_ps(alpha);
    let xp = x.as_mut_ptr();
    let mut k = 0usize;
    while k < cut {
        _mm256_storeu_ps(xp.add(k), _mm256_mul_ps(av, _mm256_loadu_ps(xp.add(k))));
        k += 8;
    }
    for v in &mut x[cut..] {
        *v *= alpha;
    }
}

/// One butterfly layer (pure IEEE add/sub — bitwise equal to scalar).
///
/// # Safety
/// Requires AVX2 and FMA (guaranteed by the dispatcher's runtime
/// detection).
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn fwht_butterfly_avx2(a: &mut [f32], b: &mut [f32]) {
    let n = a.len();
    let cut = 8 * (n / 8);
    let ap = a.as_mut_ptr();
    let bp = b.as_mut_ptr();
    let mut k = 0usize;
    while k < cut {
        let x = _mm256_loadu_ps(ap.add(k));
        let y = _mm256_loadu_ps(bp.add(k));
        _mm256_storeu_ps(ap.add(k), _mm256_add_ps(x, y));
        _mm256_storeu_ps(bp.add(k), _mm256_sub_ps(x, y));
        k += 8;
    }
    for k in cut..n {
        let (x, y) = (a[k], b[k]);
        a[k] = x + y;
        b[k] = x - y;
    }
}

/// `out[i] = scale * cos(out[i] + b[i])` via the shared Cody-Waite +
/// polynomial evaluation ([`super::cos_poly`] is the scalar replica
/// used for the remainder tail).
///
/// # Safety
/// Requires AVX2 and FMA (guaranteed by the dispatcher's runtime
/// detection).
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn cos_activate_avx2(out: &mut [f32], b: &[f32], scale: f32) {
    let n = out.len();
    let cut = 8 * (n / 8);
    let op = out.as_mut_ptr();
    let bp = b.as_ptr();
    let sv = _mm256_set1_ps(scale);
    let inv = _mm256_set1_ps(super::FRAC_1_2PI);
    let c1 = _mm256_set1_ps(-super::TWO_PI_A);
    let c2 = _mm256_set1_ps(-super::TWO_PI_B);
    let c3 = _mm256_set1_ps(-super::TWO_PI_C);
    let one = _mm256_set1_ps(1.0);
    let mut k = 0usize;
    while k < cut {
        let x = _mm256_add_ps(_mm256_loadu_ps(op.add(k)), _mm256_loadu_ps(bp.add(k)));
        // Nearest whole number of turns (round-to-nearest-even; the
        // scalar tail's `round` differs only at exact half-turns,
        // where either reduction target is valid).
        let turns = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(
            _mm256_mul_ps(x, inv),
        );
        let mut r = _mm256_fmadd_ps(turns, c1, x);
        r = _mm256_fmadd_ps(turns, c2, r);
        r = _mm256_fmadd_ps(turns, c3, r);
        let z = _mm256_mul_ps(r, r);
        let mut p = _mm256_set1_ps(super::COS_POLY[0]);
        for c in &super::COS_POLY[1..] {
            p = _mm256_fmadd_ps(p, z, _mm256_set1_ps(*c));
        }
        let cosv = _mm256_fmadd_ps(p, z, one);
        _mm256_storeu_ps(op.add(k), _mm256_mul_ps(sv, cosv));
        k += 8;
    }
    for k in cut..n {
        out[k] = scale * super::cos_poly(out[k] + b[k]);
    }
}
