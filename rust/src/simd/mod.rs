//! Feature-detected SIMD kernel layer for the transform hot paths.
//!
//! Every per-element loop the paper's cost model is made of — dense
//! dot products, `axpy`, the GEMM microkernel, FWHT butterflies, the
//! RFF cosine pass and the CSR gather reductions — runs through one of
//! three **kernel paths**, selected once per process:
//!
//! * [`SimdPath::Scalar`] — the original hand-unrolled scalar code,
//!   kept verbatim as the portable fallback *and* the test oracle;
//! * [`SimdPath::Avx2`] — x86_64 AVX2 + FMA intrinsics, used only when
//!   the CPU reports both features at runtime;
//! * [`SimdPath::Neon`] — aarch64 NEON (always available on aarch64).
//!
//! Selection is `--simd scalar|auto` on the CLI, the `RFDOT_SIMD`
//! environment variable, or the `"simd"` config field; the resolved
//! path is process-global ([`selected`]) the same way the
//! [`crate::parallel`] thread knob is. Every kernel also has a
//! path-explicit `*_with` variant so tests can compare paths without
//! touching the global.
//!
//! ## Lane discipline and the parity contracts
//!
//! The crate promises two bit-level invariants that SIMD must not
//! break *within a fixed path*:
//!
//! * **sparse = dense**: each path's dense `dot` has a fixed lane
//!   structure (scalar: 4 accumulators, lane `k mod 4`; AVX2: 32
//!   lanes, `k mod 32`; NEON: 16 lanes, `k mod 16`) and a fixed
//!   reduction order. The sparse mirrors ([`sparse_dot_dense_with`],
//!   [`sparse_self_dot_with`]) accumulate each stored entry into the
//!   lane its *column position* dictates and reduce in the identical
//!   order, so skipping zero entries changes nothing: a skipped zero
//!   contributes an exact `+0.0` to its lane on the dense side.
//! * **parallel = serial**: all kernels here are per-row routines;
//!   the [`crate::parallel`] helpers only partition rows, so thread
//!   count still never changes results.
//!
//! On the FMA paths every multiply-accumulate is *fused* (one
//! rounding), including remainder tails and sparse mirrors, which use
//! [`f32::mul_add`] — correctly rounded by spec and therefore bitwise
//! equal to the hardware `vfmadd`. Butterflies and scaling use only
//! IEEE add/sub/mul, so those kernels are bitwise identical across all
//! paths; `dot`/`axpy`/GEMM differ across paths only by summation
//! order and FMA rounding, bounded by [`dot_ulp_bound`] (the shared
//! tolerance of the parity property tests in
//! `rust/tests/properties.rs`). The vector cosine uses Cody-Waite
//! range reduction plus a degree-16 even polynomial (max error ~1e-6
//! absolute vs libm); within one path all four RFF activation sites
//! share it, so sparse/dense/batch parity still holds bitwise.

use crate::{Error, Result};
use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

// ------------------------------------------------------------ dispatch

/// A concrete kernel implementation the dispatcher can select.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPath {
    /// The portable scalar kernels (also the test oracle).
    Scalar,
    /// x86_64 AVX2 + FMA (runtime-detected).
    Avx2,
    /// aarch64 NEON (baseline on aarch64).
    Neon,
}

impl SimdPath {
    /// Stable name used in bench samples, serve output and logs.
    pub fn as_str(&self) -> &'static str {
        match self {
            SimdPath::Scalar => "scalar",
            SimdPath::Avx2 => "avx2",
            SimdPath::Neon => "neon",
        }
    }
}

/// The dispatch policy (what the user can ask for; [`SimdPath`] is
/// what the machine resolves it to).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Use the best runtime-detected path ([`detected`]).
    Auto,
    /// Force the portable scalar kernels.
    Scalar,
}

impl SimdMode {
    /// Parse a CLI/config/env spelling (`auto` or `scalar`).
    pub fn parse(s: &str) -> Result<SimdMode> {
        match s {
            "auto" => Ok(SimdMode::Auto),
            "scalar" => Ok(SimdMode::Scalar),
            other => Err(Error::Config(format!("unknown simd mode {other:?} (auto|scalar)"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Scalar => "scalar",
        }
    }
}

/// Process-wide dispatch mode; 0 = not yet resolved (the same lazy
/// idiom as `parallel::MAX_THREADS`).
static MODE: AtomicU8 = AtomicU8::new(0);

fn mode_code(m: SimdMode) -> u8 {
    match m {
        SimdMode::Auto => 1,
        SimdMode::Scalar => 2,
    }
}

/// The process-wide dispatch mode. Resolved on first use from
/// `RFDOT_SIMD` (if set to a valid spelling) or `auto`; overridable at
/// any time with [`set_mode`] (the single knob behind `--simd` and the
/// `"simd"` config field).
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        1 => SimdMode::Auto,
        2 => SimdMode::Scalar,
        _ => {
            let m = std::env::var("RFDOT_SIMD")
                .ok()
                .and_then(|s| SimdMode::parse(s.trim()).ok())
                .unwrap_or(SimdMode::Auto);
            // Benign race: every initializer computes the same value.
            MODE.store(mode_code(m), Ordering::Relaxed);
            note_dispatch(m);
            m
        }
    }
}

/// Set the process-wide dispatch mode.
pub fn set_mode(m: SimdMode) {
    MODE.store(mode_code(m), Ordering::Relaxed);
    note_dispatch(m);
}

/// Record the dispatch decision in the observability layer: a zero-
/// length `simd.dispatch` trace mark plus gauges exposing the mode
/// knob and the kernel path it resolves to (by [`mode_code`] /
/// [`SimdPath`] discriminant), so a metrics snapshot or trace always
/// says which kernels the process ran.
fn note_dispatch(m: SimdMode) {
    crate::obs::trace::mark("simd.dispatch");
    crate::obs::gauge("simd.mode").set(mode_code(m) as i64);
    let path = match m {
        SimdMode::Scalar => SimdPath::Scalar,
        SimdMode::Auto => detected(),
    };
    let code = match path {
        SimdPath::Scalar => 0,
        SimdPath::Avx2 => 1,
        SimdPath::Neon => 2,
    };
    crate::obs::gauge("simd.path").set(code);
}

/// The best kernel path this machine supports, independent of the
/// mode knob.
pub fn detected() -> SimdPath {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdPath::Avx2;
        }
        SimdPath::Scalar
    }
    #[cfg(target_arch = "aarch64")]
    {
        SimdPath::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        SimdPath::Scalar
    }
}

/// The path the global mode currently resolves to — what every
/// dispatched wrapper below executes.
pub fn selected() -> SimdPath {
    match mode() {
        SimdMode::Scalar => SimdPath::Scalar,
        SimdMode::Auto => detected(),
    }
}

/// The paths runnable on this machine (scalar first — the oracle the
/// parity property tests compare everything else against).
pub fn available_paths() -> Vec<SimdPath> {
    let mut paths = vec![SimdPath::Scalar];
    if detected() != SimdPath::Scalar {
        paths.push(detected());
    }
    paths
}

/// True when `path` can execute on this machine (a non-native `*_with`
/// call falls back to scalar, so asking first keeps tests honest).
pub fn path_available(path: SimdPath) -> bool {
    path == SimdPath::Scalar || path == detected()
}

// ---------------------------------------------------------- tolerances

/// Length-scaled error bound for comparing two dot products of the
/// same data computed with different (but fixed) summation orders /
/// FMA contraction — the shared tolerance of `dot_matches_naive` and
/// the SIMD parity property tests. With unit roundoff `u = eps/2`,
/// any summation order's forward error is at most `(n-1)·u·Σ|aᵢ·bᵢ|`
/// to first order; the two sides' summation depths plus the fused-vs-
/// separate product roundings total under `(2n+16)·u`, i.e.
/// `eps · (n + 8) · Σ|aᵢ·bᵢ|`.
pub fn dot_ulp_bound(a: &[f32], b: &[f32]) -> f32 {
    let mag: f32 = a.iter().zip(b).map(|(x, y)| (x * y).abs()).sum();
    f32::EPSILON * (a.len() as f32 + 8.0) * mag
}

// ------------------------------------------------------------- kernels
//
// Each kernel: a dispatched wrapper (global mode) plus a path-explicit
// `*_with` variant. The scalar bodies are the pre-SIMD hot-path code,
// moved here verbatim so `linalg` can delegate without behavior drift.

/// Dense dot product on the selected path.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with(selected(), a, b)
}

/// Dense dot product on an explicit path.
pub fn dot_with(path: SimdPath, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match path {
        SimdPath::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected after runtime detection.
        SimdPath::Avx2 => unsafe { x86::dot_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdPath::Neon => unsafe { neon::dot_neon(a, b) },
        #[allow(unreachable_patterns)]
        _ => dot_scalar(a, b),
    }
}

/// The original 4-lane scalar dot — the oracle every other path's
/// sparse mirror and parity test is defined against.
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let chunks = a.len() / 4;
    let mut acc = [0.0f32; 4];
    for c in 0..chunks {
        let k = c * 4;
        acc[0] += a[k] * b[k];
        acc[1] += a[k + 1] * b[k + 1];
        acc[2] += a[k + 2] * b[k + 2];
        acc[3] += a[k + 3] * b[k + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for k in chunks * 4..a.len() {
        s += a[k] * b[k];
    }
    s
}

/// `y += alpha * x` on the selected path.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_with(selected(), alpha, x, y);
}

/// `y += alpha * x` on an explicit path. On the FMA paths every
/// element is `y[k] = fma(alpha, x[k], y[k])` (vector body and scalar
/// tail alike), so the sparse mirror is a plain `mul_add` per stored
/// entry.
pub fn axpy_with(path: SimdPath, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match path {
        SimdPath::Scalar => {
            for (yi, xi) in y.iter_mut().zip(x) {
                *yi += alpha * xi;
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected after runtime detection.
        SimdPath::Avx2 => unsafe { x86::axpy_avx2(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdPath::Neon => unsafe { neon::axpy_neon(alpha, x, y) },
        #[allow(unreachable_patterns)]
        _ => {
            for (yi, xi) in y.iter_mut().zip(x) {
                *yi += alpha * xi;
            }
        }
    }
}

/// `x *= alpha` on the selected path. Pure IEEE multiplies — bitwise
/// identical across paths.
pub fn scale(alpha: f32, x: &mut [f32]) {
    scale_with(selected(), alpha, x);
}

/// `x *= alpha` on an explicit path.
pub fn scale_with(path: SimdPath, alpha: f32, x: &mut [f32]) {
    match path {
        SimdPath::Scalar => {
            for v in x.iter_mut() {
                *v *= alpha;
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected after runtime detection.
        SimdPath::Avx2 => unsafe { x86::scale_avx2(alpha, x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdPath::Neon => unsafe { neon::scale_neon(alpha, x) },
        #[allow(unreachable_patterns)]
        _ => {
            for v in x.iter_mut() {
                *v *= alpha;
            }
        }
    }
}

/// One FWHT butterfly layer: `(a[i], b[i]) = (a[i]+b[i], a[i]-b[i])`
/// for every `i`. Pure IEEE add/sub — bitwise identical across paths.
pub fn fwht_butterfly(a: &mut [f32], b: &mut [f32]) {
    fwht_butterfly_with(selected(), a, b);
}

/// One FWHT butterfly layer on an explicit path.
pub fn fwht_butterfly_with(path: SimdPath, a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    match path {
        SimdPath::Scalar => fwht_butterfly_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected after runtime detection.
        SimdPath::Avx2 => unsafe { x86::fwht_butterfly_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdPath::Neon => unsafe { neon::fwht_butterfly_neon(a, b) },
        #[allow(unreachable_patterns)]
        _ => fwht_butterfly_scalar(a, b),
    }
}

fn fwht_butterfly_scalar(a: &mut [f32], b: &mut [f32]) {
    for (ai, bi) in a.iter_mut().zip(b.iter_mut()) {
        let (x, y) = (*ai, *bi);
        *ai = x + y;
        *bi = x - y;
    }
}

/// The RFF cosine activation: `out[i] = scale * cos(out[i] + b[i])`,
/// on the selected path.
pub fn cos_activate(out: &mut [f32], b: &[f32], scale: f32) {
    cos_activate_with(selected(), out, b, scale);
}

/// The RFF cosine activation on an explicit path. Scalar uses libm
/// `cos`; the vector paths use [`cos_poly`] (Cody-Waite reduction +
/// even polynomial, ~1e-6 absolute error). All four RFF call sites
/// (dense/sparse × single/batch) share this kernel, so transforms stay
/// bitwise identical across storages and thread counts within one
/// path.
pub fn cos_activate_with(path: SimdPath, out: &mut [f32], b: &[f32], scale: f32) {
    debug_assert_eq!(out.len(), b.len());
    match path {
        SimdPath::Scalar => {
            for (o, bi) in out.iter_mut().zip(b) {
                *o = scale * (*o + bi).cos();
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected after runtime detection.
        SimdPath::Avx2 => unsafe { x86::cos_activate_avx2(out, b, scale) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdPath::Neon => unsafe { neon::cos_activate_neon(out, b, scale) },
        #[allow(unreachable_patterns)]
        _ => {
            for (o, bi) in out.iter_mut().zip(b) {
                *o = scale * (*o + bi).cos();
            }
        }
    }
}

// ---------------------------------------------------- vector cosine

/// Cody-Waite constants: `2π = C1 + C2 + C3` split so `k·C1` and
/// `k·C2` are exact for the `k` magnitudes the reduction sees (the
/// cephes `DP1..DP3` constants scaled by 8, each a dyadic rational).
const TWO_PI_A: f32 = 6.281_25; // 8 * 0.78515625
const TWO_PI_B: f32 = 1.935_005_2e-3; // 8 * 2.4187564849853515625e-4
const TWO_PI_C: f32 = 3.019_916e-7; // 2π - TWO_PI_A - TWO_PI_B
const FRAC_1_2PI: f32 = 0.159_154_94;

/// Even Maclaurin coefficients of `cos` in `z = r²`, through `r¹⁶`
/// (truncation ≤ π¹⁸/18! ≈ 1.4e-7 on the reduced range `|r| ≤ π`).
const COS_POLY: [f32; 8] = [
    4.779_477_3e-14,  // +1/16!
    -1.147_074_5e-11, // -1/14!
    2.087_675_7e-9,   // +1/12!
    -2.755_731_9e-7,  // -1/10!
    2.480_158_7e-5,   // +1/8!
    -1.388_888_9e-3,  // -1/6!
    4.166_666_8e-2,   // +1/4!
    -0.5,             // -1/2!
];

/// Scalar replica of the vector cosine (same constants, same FMA
/// structure via `mul_add`) — the remainder-tail routine of the vector
/// paths, and directly testable against libm. `round` ties differ
/// from the vector round-to-nearest-even only at exact half-turns,
/// where both reductions remain valid.
pub fn cos_poly(x: f32) -> f32 {
    let k = (x * FRAC_1_2PI).round();
    let r = (-k).mul_add(TWO_PI_A, x);
    let r = (-k).mul_add(TWO_PI_B, r);
    let r = (-k).mul_add(TWO_PI_C, r);
    let z = r * r;
    let mut p = COS_POLY[0];
    for c in &COS_POLY[1..] {
        p = p.mul_add(z, *c);
    }
    p.mul_add(z, 1.0)
}

// ------------------------------------------------------ sparse mirrors

/// Sparse·dense dot (`Σ values[e] * w[indices[e]]`) replicating the
/// selected path's dense lane discipline by *column position*, so the
/// result is bitwise equal to `dot(x_dense, w)` on the same path.
pub fn sparse_dot_dense(indices: &[u32], values: &[f32], w: &[f32]) -> f32 {
    sparse_dot_dense_with(selected(), indices, values, w)
}

/// [`sparse_dot_dense`] on an explicit path.
pub fn sparse_dot_dense_with(path: SimdPath, indices: &[u32], values: &[f32], w: &[f32]) -> f32 {
    match path {
        SimdPath::Scalar => sparse_dot_scalar(indices, values, w.len(), |k| w[k]),
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => sparse_dot_fma32(indices, values, w.len(), |k| w[k]),
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => sparse_dot_fma16(indices, values, w.len(), |k| w[k]),
        #[allow(unreachable_patterns)]
        _ => sparse_dot_scalar(indices, values, w.len(), |k| w[k]),
    }
}

/// Sparse self dot (`Σ values[e]²`) replicating the selected path's
/// dense `dot(x, x)` lane discipline over a row of width `dim`.
pub fn sparse_self_dot(indices: &[u32], values: &[f32], dim: usize) -> f32 {
    sparse_self_dot_with(selected(), indices, values, dim)
}

/// [`sparse_self_dot`] on an explicit path.
pub fn sparse_self_dot_with(path: SimdPath, indices: &[u32], values: &[f32], dim: usize) -> f32 {
    match path {
        SimdPath::Scalar => sparse_self_dot_scalar(indices, values, dim),
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => {
            let mut e = 0usize;
            sparse_dot_fma32(indices, values, dim, move |_| {
                let v = values[e];
                e += 1;
                v
            })
        }
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => {
            let mut e = 0usize;
            sparse_dot_fma16(indices, values, dim, move |_| {
                let v = values[e];
                e += 1;
                v
            })
        }
        #[allow(unreachable_patterns)]
        _ => sparse_self_dot_scalar(indices, values, dim),
    }
}

/// Sparse `w[indices[e]] += alpha * values[e]` matching the selected
/// path's dense [`axpy`] at the stored positions (skipped zeros leave
/// `w` untouched on both sides).
pub fn sparse_axpy(alpha: f32, indices: &[u32], values: &[f32], w: &mut [f32]) {
    sparse_axpy_with(selected(), alpha, indices, values, w);
}

/// [`sparse_axpy`] on an explicit path: the FMA paths fuse each
/// update exactly like their dense vector bodies do.
pub fn sparse_axpy_with(
    path: SimdPath,
    alpha: f32,
    indices: &[u32],
    values: &[f32],
    w: &mut [f32],
) {
    match path {
        SimdPath::Scalar => {
            for (&k, &v) in indices.iter().zip(values) {
                w[k as usize] += alpha * v;
            }
        }
        _ => {
            for (&k, &v) in indices.iter().zip(values) {
                w[k as usize] = alpha.mul_add(v, w[k as usize]);
            }
        }
    }
}

/// The scalar 4-lane sparse mirror (pre-SIMD `SparseRow::dot_dense`,
/// moved here verbatim): entries at columns below `cut = 4·(dim/4)`
/// land in lane `k mod 4`, the lanes reduce in the dense order, and
/// the tail accumulates ascending.
fn sparse_dot_scalar(
    indices: &[u32],
    values: &[f32],
    dim: usize,
    mut other: impl FnMut(usize) -> f32,
) -> f32 {
    let cut = 4 * (dim / 4);
    let split = indices.partition_point(|&k| (k as usize) < cut);
    let mut acc = [0.0f32; 4];
    for (&k, &v) in indices[..split].iter().zip(&values[..split]) {
        acc[(k as usize) & 3] += v * other(k as usize);
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for (&k, &v) in indices[split..].iter().zip(&values[split..]) {
        s += v * other(k as usize);
    }
    s
}

fn sparse_self_dot_scalar(indices: &[u32], values: &[f32], dim: usize) -> f32 {
    let mut e = 0usize;
    sparse_dot_scalar(indices, values, dim, move |_| {
        let v = values[e];
        e += 1;
        v
    })
}

/// The 32-lane FMA sparse mirror of the AVX2 dense `dot`: entries at
/// columns below `cut = 32·(dim/32)` land in lane `k mod 32` via
/// `mul_add` (correctly rounded, so bitwise equal to the vector
/// `vfmadd` on that lane), lanes reduce as
/// `t[j] = (m[j]+m[j+8]) + (m[j+16]+m[j+24])` for `j in 0..8` — the
/// AVX2 `(acc0+acc1)+(acc2+acc3)` vector adds — followed by the same
/// ascending fold over `t`, and the tail accumulates ascending with
/// `mul_add` exactly like the dense remainder loop.
#[cfg(target_arch = "x86_64")]
fn sparse_dot_fma32(
    indices: &[u32],
    values: &[f32],
    dim: usize,
    mut other: impl FnMut(usize) -> f32,
) -> f32 {
    let cut = 32 * (dim / 32);
    let split = indices.partition_point(|&k| (k as usize) < cut);
    let mut m = [0.0f32; 32];
    for (&k, &v) in indices[..split].iter().zip(&values[..split]) {
        let lane = (k as usize) & 31;
        m[lane] = v.mul_add(other(k as usize), m[lane]);
    }
    let mut s = 0.0f32;
    for j in 0..8 {
        s += (m[j] + m[j + 8]) + (m[j + 16] + m[j + 24]);
    }
    for (&k, &v) in indices[split..].iter().zip(&values[split..]) {
        s = v.mul_add(other(k as usize), s);
    }
    s
}

/// The 16-lane FMA sparse mirror of the NEON dense `dot`: entries
/// below `cut = 16·(dim/16)` land in lane `k mod 16` via `mul_add`
/// (a single `fmadd` on aarch64), lanes reduce as
/// `t[j] = (m[j]+m[j+4]) + (m[j+8]+m[j+12])` for `j in 0..4` — the
/// NEON `(acc0+acc1)+(acc2+acc3)` vector adds — then the ascending
/// fold and a `mul_add` tail, exactly the dense structure.
#[cfg(target_arch = "aarch64")]
fn sparse_dot_fma16(
    indices: &[u32],
    values: &[f32],
    dim: usize,
    mut other: impl FnMut(usize) -> f32,
) -> f32 {
    let cut = 16 * (dim / 16);
    let split = indices.partition_point(|&k| (k as usize) < cut);
    let mut m = [0.0f32; 16];
    for (&k, &v) in indices[..split].iter().zip(&values[..split]) {
        let lane = (k as usize) & 15;
        m[lane] = v.mul_add(other(k as usize), m[lane]);
    }
    let mut s = 0.0f32;
    for j in 0..4 {
        s += (m[j] + m[j + 4]) + (m[j + 8] + m[j + 12]);
    }
    for (&k, &v) in indices[split..].iter().zip(&values[split..]) {
        s = v.mul_add(other(k as usize), s);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::rng::Rng::seed_from(seed);
        let a: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        (a, b)
    }

    #[test]
    fn mode_parses_and_round_trips() {
        assert_eq!(SimdMode::parse("auto").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse("scalar").unwrap(), SimdMode::Scalar);
        assert!(SimdMode::parse("avx512").is_err());
        for m in [SimdMode::Auto, SimdMode::Scalar] {
            assert_eq!(SimdMode::parse(m.as_str()).unwrap(), m);
        }
        for p in [SimdPath::Scalar, SimdPath::Avx2, SimdPath::Neon] {
            assert!(!p.as_str().is_empty());
        }
    }

    #[test]
    fn available_paths_start_with_the_oracle() {
        let paths = available_paths();
        assert_eq!(paths[0], SimdPath::Scalar);
        assert!(paths.len() <= 2);
        for p in paths {
            assert!(path_available(p));
        }
        // `selected()` resolves to something runnable regardless of
        // the (possibly env-seeded) mode.
        assert!(path_available(selected()));
    }

    #[test]
    fn every_path_matches_the_scalar_dot_within_bound() {
        for n in [0usize, 1, 3, 7, 31, 32, 33, 64, 67, 131] {
            let (a, b) = vecs(n, 1000 + n as u64);
            let want = dot_with(SimdPath::Scalar, &a, &b);
            for path in available_paths() {
                let got = dot_with(path, &a, &b);
                let bound = dot_ulp_bound(&a, &b);
                assert!(
                    (got - want).abs() <= bound,
                    "dot n={n} {path:?}: {got} vs {want} (bound {bound})"
                );
            }
        }
    }

    #[test]
    fn butterfly_and_scale_are_bitwise_across_paths() {
        for n in [0usize, 1, 4, 8, 13, 64] {
            let (a0, b0) = vecs(n, 2000 + n as u64);
            for path in available_paths() {
                let (mut a, mut b) = (a0.clone(), b0.clone());
                fwht_butterfly_with(path, &mut a, &mut b);
                let (mut ar, mut br) = (a0.clone(), b0.clone());
                fwht_butterfly_scalar(&mut ar, &mut br);
                assert_eq!((a, b), (ar, br), "butterfly n={n} {path:?}");

                let mut x = a0.clone();
                scale_with(path, 0.25, &mut x);
                let want: Vec<f32> = a0.iter().map(|v| v * 0.25).collect();
                assert_eq!(x, want, "scale n={n} {path:?}");
            }
        }
    }

    #[test]
    fn cos_poly_tracks_libm() {
        for i in -2000..2000 {
            let x = i as f32 * 0.037;
            let got = cos_poly(x);
            let want = x.cos();
            assert!((got - want).abs() < 5e-6, "cos({x}): {got} vs {want}");
        }
    }

    #[test]
    fn cos_activate_paths_agree_with_libm_within_poly_error() {
        for n in [0usize, 1, 7, 8, 9, 33] {
            let (o0, b) = vecs(n, 3000 + n as u64);
            for path in available_paths() {
                let mut out = o0.clone();
                cos_activate_with(path, &mut out, &b, 0.5);
                for k in 0..n {
                    let want = 0.5 * (o0[k] + b[k]).cos();
                    assert!(
                        (out[k] - want).abs() < 5e-6,
                        "cos_activate n={n} k={k} {path:?}: {} vs {want}",
                        out[k]
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_mirrors_are_bitwise_on_every_path() {
        let mut rng = crate::rng::Rng::seed_from(99);
        for dim in [1usize, 3, 4, 15, 16, 17, 31, 32, 33, 64, 131] {
            // ~40% dense pattern exercising lanes and tails.
            let dense: Vec<f32> =
                (0..dim).map(|_| if rng.f64() < 0.4 { rng.f32() - 0.5 } else { 0.0 }).collect();
            let w: Vec<f32> = (0..dim).map(|_| rng.f32() - 0.5).collect();
            let indices: Vec<u32> = (0..dim as u32).filter(|&k| dense[k as usize] != 0.0).collect();
            let values: Vec<f32> = indices.iter().map(|&k| dense[k as usize]).collect();
            for path in available_paths() {
                let sd = sparse_dot_dense_with(path, &indices, &values, &w);
                assert_eq!(sd, dot_with(path, &dense, &w), "dot_dense dim={dim} {path:?}");
                let ss = sparse_self_dot_with(path, &indices, &values, dim);
                assert_eq!(ss, dot_with(path, &dense, &dense), "self_dot dim={dim} {path:?}");
                let mut wd = w.clone();
                axpy_with(path, 0.75, &dense, &mut wd);
                let mut ws = w.clone();
                sparse_axpy_with(path, 0.75, &indices, &values, &mut ws);
                assert_eq!(wd, ws, "axpy dim={dim} {path:?}");
            }
        }
    }
}
