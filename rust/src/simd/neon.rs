//! aarch64 NEON kernels.
//!
//! NEON is part of the aarch64 baseline, so [`super::detected`] always
//! returns [`super::SimdPath::Neon`] on this architecture; the
//! intrinsics are still `unsafe fn`s, and the explicit
//! `#[target_feature(enable = "neon")]` documents the requirement.
//!
//! Lane discipline (mirrored by the sparse helpers in `super`): `dot`
//! accumulates 16 elements per iteration into four 4-lane FMA
//! accumulators, reduces with the vector adds `(acc0+acc1) +
//! (acc2+acc3)`, spills to a stack array and folds the 4 lanes
//! ascending, then finishes the remainder `k ≥ 16·(n/16)` ascending
//! with scalar [`f32::mul_add`] (correctly rounded = a 1-lane `fmla`).
//! `axpy` fuses every element; butterflies and scaling are pure IEEE
//! add/sub/mul and bitwise equal to the scalar path.

use core::arch::aarch64::*;

/// Dense dot, 4×4-lane FMA.
///
/// # Safety
/// Requires NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub(super) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let cut = 16 * (n / 16);
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut k = 0usize;
    while k < cut {
        acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(k)), vld1q_f32(bp.add(k)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(k + 4)), vld1q_f32(bp.add(k + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(ap.add(k + 8)), vld1q_f32(bp.add(k + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(ap.add(k + 12)), vld1q_f32(bp.add(k + 12)));
        k += 16;
    }
    let sum = vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3));
    let mut t = [0.0f32; 4];
    vst1q_f32(t.as_mut_ptr(), sum);
    let mut s = 0.0f32;
    for v in t {
        s += v;
    }
    for k in cut..n {
        s = a[k].mul_add(b[k], s);
    }
    s
}

/// `y += alpha * x`, fused at every position.
///
/// # Safety
/// Requires NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub(super) unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let cut = 4 * (n / 4);
    let av = vdupq_n_f32(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut k = 0usize;
    while k < cut {
        let v = vfmaq_f32(vld1q_f32(yp.add(k)), av, vld1q_f32(xp.add(k)));
        vst1q_f32(yp.add(k), v);
        k += 4;
    }
    for k in cut..n {
        y[k] = alpha.mul_add(x[k], y[k]);
    }
}

/// `x *= alpha` (pure IEEE multiplies — bitwise equal to scalar).
///
/// # Safety
/// Requires NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub(super) unsafe fn scale_neon(alpha: f32, x: &mut [f32]) {
    let n = x.len();
    let cut = 4 * (n / 4);
    let av = vdupq_n_f32(alpha);
    let xp = x.as_mut_ptr();
    let mut k = 0usize;
    while k < cut {
        vst1q_f32(xp.add(k), vmulq_f32(av, vld1q_f32(xp.add(k))));
        k += 4;
    }
    for v in &mut x[cut..] {
        *v *= alpha;
    }
}

/// One butterfly layer (pure IEEE add/sub — bitwise equal to scalar).
///
/// # Safety
/// Requires NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub(super) unsafe fn fwht_butterfly_neon(a: &mut [f32], b: &mut [f32]) {
    let n = a.len();
    let cut = 4 * (n / 4);
    let ap = a.as_mut_ptr();
    let bp = b.as_mut_ptr();
    let mut k = 0usize;
    while k < cut {
        let x = vld1q_f32(ap.add(k));
        let y = vld1q_f32(bp.add(k));
        vst1q_f32(ap.add(k), vaddq_f32(x, y));
        vst1q_f32(bp.add(k), vsubq_f32(x, y));
        k += 4;
    }
    for k in cut..n {
        let (x, y) = (a[k], b[k]);
        a[k] = x + y;
        b[k] = x - y;
    }
}

/// `out[i] = scale * cos(out[i] + b[i])` via the shared Cody-Waite +
/// polynomial evaluation ([`super::cos_poly`] is the scalar replica
/// used for the remainder tail).
///
/// # Safety
/// Requires NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub(super) unsafe fn cos_activate_neon(out: &mut [f32], b: &[f32], scale: f32) {
    let n = out.len();
    let cut = 4 * (n / 4);
    let op = out.as_mut_ptr();
    let bp = b.as_ptr();
    let sv = vdupq_n_f32(scale);
    let inv = vdupq_n_f32(super::FRAC_1_2PI);
    let c1 = vdupq_n_f32(-super::TWO_PI_A);
    let c2 = vdupq_n_f32(-super::TWO_PI_B);
    let c3 = vdupq_n_f32(-super::TWO_PI_C);
    let one = vdupq_n_f32(1.0);
    let mut k = 0usize;
    while k < cut {
        let x = vaddq_f32(vld1q_f32(op.add(k)), vld1q_f32(bp.add(k)));
        // Nearest whole number of turns (frintn = round-to-nearest-
        // even; the scalar tail's `round` differs only at exact
        // half-turns, where either reduction target is valid).
        let turns = vrndnq_f32(vmulq_f32(x, inv));
        let mut r = vfmaq_f32(x, turns, c1);
        r = vfmaq_f32(r, turns, c2);
        r = vfmaq_f32(r, turns, c3);
        let z = vmulq_f32(r, r);
        let mut p = vdupq_n_f32(super::COS_POLY[0]);
        for c in &super::COS_POLY[1..] {
            p = vfmaq_f32(vdupq_n_f32(*c), p, z);
        }
        let cosv = vfmaq_f32(one, p, z);
        vst1q_f32(op.add(k), vmulq_f32(sv, cosv));
        k += 4;
    }
    for k in cut..n {
        out[k] = scale * super::cos_poly(out[k] + b[k]);
    }
}
