//! rfdot binary entrypoint — see `cli` for commands.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = rfdot::cli::run(argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
