//! A minimal blocking client for the `RFNP` wire protocol — the
//! reference implementation the README documents, used by the
//! `rfdot net-client` CLI, the integration tests and the
//! `net-roundtrip` bench. One synchronous request/reply per call,
//! plus a split send/receive surface for pipelining.

use crate::error::{Error, Result};
use crate::net::protocol::{
    decode_header, decode_payload, encode_frame, Frame, ModelEntry, Request, SparseRequest,
    HEADER_LEN,
};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A blocking RFNP connection.
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
}

impl NetClient {
    /// Connect with a read timeout (a server that stops answering
    /// surfaces as an error instead of a hang).
    pub fn connect(addr: impl ToSocketAddrs, read_timeout: Duration) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Runtime(format!("connect: {e}")))?;
        stream
            .set_read_timeout(Some(read_timeout))
            .map_err(|e| Error::Runtime(format!("set_read_timeout: {e}")))?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient { stream, next_id: 1 })
    }

    /// Send a raw frame (tests also write crafted bytes directly).
    pub fn send_frame(&mut self, frame: &Frame) -> Result<()> {
        self.stream
            .write_all(&encode_frame(frame))
            .map_err(|e| Error::Runtime(format!("send frame: {e}")))
    }

    /// Read one complete frame off the stream.
    pub fn read_frame(&mut self) -> Result<Frame> {
        let mut header = [0u8; HEADER_LEN];
        self.stream
            .read_exact(&mut header)
            .map_err(|e| Error::Runtime(format!("read frame header: {e}")))?;
        let (ty, len) = decode_header(&header).map_err(|e| e.to_error())?;
        let mut payload = vec![0u8; len as usize];
        self.stream
            .read_exact(&mut payload)
            .map_err(|e| Error::Runtime(format!("read frame payload: {e}")))?;
        decode_payload(ty, &payload).map_err(|e| e.to_error())
    }

    /// Round-trip a ping with an opaque token.
    pub fn ping(&mut self) -> Result<()> {
        let token = self.next_id.to_le_bytes().to_vec();
        self.next_id += 1;
        self.send_frame(&Frame::Ping { token: token.clone() })?;
        match self.read_frame()? {
            Frame::Pong { token: echoed } if echoed == token => Ok(()),
            Frame::Pong { .. } => Err(Error::Runtime("pong token mismatch".into())),
            f => Err(Error::Runtime(format!("expected pong, got {:?}", f.frame_type()))),
        }
    }

    /// Fire-and-forget liveness signal.
    pub fn heartbeat(&mut self) -> Result<()> {
        self.send_frame(&Frame::Heartbeat)
    }

    /// The server's model directory.
    pub fn list_models(&mut self) -> Result<Vec<ModelEntry>> {
        self.send_frame(&Frame::ListModels)?;
        match self.read_frame()? {
            Frame::Models(models) => Ok(models),
            f => Err(Error::Runtime(format!("expected models, got {:?}", f.frame_type()))),
        }
    }

    /// Send a dense request without waiting (pipelining); returns the
    /// request id to match against [`NetClient::recv_reply`].
    pub fn send_dense(&mut self, model: &str, values: Vec<f32>) -> Result<u64> {
        let req_id = self.next_id;
        self.next_id += 1;
        self.send_frame(&Frame::Dense(Request { req_id, model: to_name(model)?, values }))?;
        Ok(req_id)
    }

    /// Send a sparse (CSR) request without waiting.
    pub fn send_sparse(&mut self, model: &str, indices: Vec<u32>, values: Vec<f32>) -> Result<u64> {
        let req_id = self.next_id;
        self.next_id += 1;
        self.send_frame(&Frame::Sparse(SparseRequest {
            req_id,
            model: to_name(model)?,
            indices,
            values,
        }))?;
        Ok(req_id)
    }

    /// Receive the next reply; a server error frame comes back as the
    /// reconstructed [`Error`] tagged with its request id.
    pub fn recv_reply(&mut self) -> Result<(u64, Vec<f32>)> {
        match self.read_frame()? {
            Frame::Reply { req_id, values } => Ok((req_id, values)),
            Frame::Error(e) => Err(Error::Runtime(format!(
                "server error for request {}: {}",
                e.req_id,
                e.to_error()
            ))),
            f => Err(Error::Runtime(format!("expected reply, got {:?}", f.frame_type()))),
        }
    }

    /// Synchronous dense transform.
    pub fn transform(&mut self, model: &str, x: &[f32]) -> Result<Vec<f32>> {
        let req_id = self.send_dense(model, x.to_vec())?;
        let (got, values) = self.recv_reply()?;
        if got != req_id {
            return Err(Error::Runtime(format!(
                "reply id {got} does not match request id {req_id}"
            )));
        }
        Ok(values)
    }

    /// Synchronous sparse transform.
    pub fn transform_sparse(
        &mut self,
        model: &str,
        indices: &[u32],
        values: &[f32],
    ) -> Result<Vec<f32>> {
        let req_id = self.send_sparse(model, indices.to_vec(), values.to_vec())?;
        let (got, out) = self.recv_reply()?;
        if got != req_id {
            return Err(Error::Runtime(format!(
                "reply id {got} does not match request id {req_id}"
            )));
        }
        Ok(out)
    }
}

fn to_name(model: &str) -> Result<String> {
    if model.is_empty() || model.len() > crate::net::protocol::MAX_NAME {
        return Err(Error::Config(format!(
            "model name must be 1..={} bytes",
            crate::net::protocol::MAX_NAME
        )));
    }
    Ok(model.to_string())
}
