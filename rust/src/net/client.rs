//! A minimal blocking client for the `RFNP` wire protocol — the
//! reference implementation the README documents, used by the
//! `rfdot net-client` CLI, the integration tests and the
//! `net-roundtrip` bench. One synchronous request/reply per call,
//! plus a split send/receive surface for pipelining.
//!
//! # Survival semantics
//!
//! Every socket operation is bounded: connect, read *and* write
//! deadlines are set unconditionally ([`ClientConfig`]), so a server
//! that accepts and then goes silent surfaces as an error instead of a
//! hang (`rust/tests/chaos.rs` pins this with a never-replying
//! server). When [`ClientConfig::retries`] is non-zero, the
//! synchronous transform calls retry — with bounded exponential
//! backoff and decorrelated jitter — *only* requests the server
//! answered with a `retryable` error frame (backpressure, load shed,
//! deadline exceeded). Transport failures are never retried here: the
//! connection state is unknown, so reconnecting is the caller's
//! decision (the `net-client` CLI loop does exactly that).

use crate::error::{Error, Result};
use crate::net::protocol::{
    decode_header, decode_payload, encode_frame, ErrorFrame, Frame, ModelEntry, Request,
    SparseRequest, HEADER_LEN,
};
use crate::rng::splitmix64;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Socket deadlines and retry policy for a [`NetClient`].
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// TCP connect deadline.
    pub connect_timeout: Duration,
    /// Per-read socket deadline.
    pub read_timeout: Duration,
    /// Per-write socket deadline.
    pub write_timeout: Duration,
    /// How many times a retryable server error is retried (0 = the
    /// first answer is final, which is the library default).
    pub retries: u32,
    /// First backoff sleep; later sleeps jitter in
    /// `[backoff_base, 3 × previous]`, capped at `backoff_max`.
    pub backoff_base: Duration,
    pub backoff_max: Duration,
    /// Seed for the jitter stream (deterministic backoff in tests).
    pub retry_seed: u64,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_secs(10),
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            retries: 0,
            backoff_base: Duration::from_millis(2),
            backoff_max: Duration::from_millis(250),
            retry_seed: 0x5EED,
        }
    }
}

impl ClientConfig {
    /// One deadline for connect, read and write.
    pub fn with_timeout(mut self, d: Duration) -> ClientConfig {
        self.connect_timeout = d;
        self.read_timeout = d;
        self.write_timeout = d;
        self
    }

    pub fn with_retries(mut self, n: u32) -> ClientConfig {
        self.retries = n;
        self
    }
}

/// A blocking RFNP connection.
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
    config: ClientConfig,
    /// Decorrelated-jitter state: the previous sleep in micros plus the
    /// seeded RNG word.
    backoff_prev_us: u64,
    backoff_rng: u64,
}

impl NetClient {
    /// Connect with one deadline for everything (a server that stops
    /// answering — or never starts — surfaces as an error, not a hang).
    pub fn connect(addr: impl ToSocketAddrs, timeout: Duration) -> Result<NetClient> {
        Self::connect_with(addr, ClientConfig::default().with_timeout(timeout))
    }

    /// Connect with explicit deadlines and retry policy. All three
    /// socket timeouts are set unconditionally.
    pub fn connect_with(addr: impl ToSocketAddrs, config: ClientConfig) -> Result<NetClient> {
        let addr = addr
            .to_socket_addrs()
            .map_err(|e| Error::Runtime(format!("resolve: {e}")))?
            .next()
            .ok_or_else(|| Error::Runtime("resolve: no addresses".into()))?;
        let stream = TcpStream::connect_timeout(&addr, config.connect_timeout)
            .map_err(|e| Error::Runtime(format!("connect: {e}")))?;
        stream
            .set_read_timeout(Some(config.read_timeout))
            .map_err(|e| Error::Runtime(format!("set_read_timeout: {e}")))?;
        stream
            .set_write_timeout(Some(config.write_timeout))
            .map_err(|e| Error::Runtime(format!("set_write_timeout: {e}")))?;
        let _ = stream.set_nodelay(true);
        let backoff_rng = config.retry_seed;
        Ok(NetClient { stream, next_id: 1, config, backoff_prev_us: 0, backoff_rng })
    }

    /// Send a raw frame (tests also write crafted bytes directly).
    pub fn send_frame(&mut self, frame: &Frame) -> Result<()> {
        self.stream
            .write_all(&encode_frame(frame))
            .map_err(|e| Error::Runtime(format!("send frame: {e}")))
    }

    /// Read one complete frame off the stream.
    pub fn read_frame(&mut self) -> Result<Frame> {
        let mut header = [0u8; HEADER_LEN];
        self.stream
            .read_exact(&mut header)
            .map_err(|e| Error::Runtime(format!("read frame header: {e}")))?;
        let (ty, len) = decode_header(&header).map_err(|e| e.to_error())?;
        let mut payload = vec![0u8; len as usize];
        self.stream
            .read_exact(&mut payload)
            .map_err(|e| Error::Runtime(format!("read frame payload: {e}")))?;
        decode_payload(ty, &payload).map_err(|e| e.to_error())
    }

    /// Round-trip a ping with an opaque token.
    pub fn ping(&mut self) -> Result<()> {
        let token = self.next_id.to_le_bytes().to_vec();
        self.next_id += 1;
        self.send_frame(&Frame::Ping { token: token.clone() })?;
        match self.read_frame()? {
            Frame::Pong { token: echoed } if echoed == token => Ok(()),
            Frame::Pong { .. } => Err(Error::Runtime("pong token mismatch".into())),
            f => Err(Error::Runtime(format!("expected pong, got {:?}", f.frame_type()))),
        }
    }

    /// Fire-and-forget liveness signal.
    pub fn heartbeat(&mut self) -> Result<()> {
        self.send_frame(&Frame::Heartbeat)
    }

    /// The server's model directory.
    pub fn list_models(&mut self) -> Result<Vec<ModelEntry>> {
        self.send_frame(&Frame::ListModels)?;
        match self.read_frame()? {
            Frame::Models(models) => Ok(models),
            f => Err(Error::Runtime(format!("expected models, got {:?}", f.frame_type()))),
        }
    }

    /// Send a dense request without waiting (pipelining); returns the
    /// request id to match against [`NetClient::recv_reply`].
    pub fn send_dense(&mut self, model: &str, values: Vec<f32>) -> Result<u64> {
        let req_id = self.next_id;
        self.next_id += 1;
        self.send_frame(&Frame::Dense(Request { req_id, model: to_name(model)?, values }))?;
        Ok(req_id)
    }

    /// Send a sparse (CSR) request without waiting.
    pub fn send_sparse(&mut self, model: &str, indices: Vec<u32>, values: Vec<f32>) -> Result<u64> {
        let req_id = self.next_id;
        self.next_id += 1;
        self.send_frame(&Frame::Sparse(SparseRequest {
            req_id,
            model: to_name(model)?,
            indices,
            values,
        }))?;
        Ok(req_id)
    }

    /// Receive the next answer with the server's error taxonomy kept
    /// intact: `Ok(Ok(..))` is a reply, `Ok(Err(frame))` is a server
    /// error frame (the `retryable` flag drives the retry loop), and
    /// the outer `Err` is a transport/protocol failure.
    pub fn recv_outcome(
        &mut self,
    ) -> Result<std::result::Result<(u64, Vec<f32>), ErrorFrame>> {
        match self.read_frame()? {
            Frame::Reply { req_id, values } => Ok(Ok((req_id, values))),
            Frame::Error(e) => Ok(Err(e)),
            f => Err(Error::Runtime(format!("expected reply, got {:?}", f.frame_type()))),
        }
    }

    /// Receive the next reply; a server error frame comes back as the
    /// reconstructed [`Error`] tagged with its request id.
    pub fn recv_reply(&mut self) -> Result<(u64, Vec<f32>)> {
        match self.recv_outcome()? {
            Ok(reply) => Ok(reply),
            Err(e) => Err(Error::Runtime(format!(
                "server error for request {}: {}",
                e.req_id,
                e.to_error()
            ))),
        }
    }

    /// Decorrelated jitter: sleep uniformly in
    /// `[base, 3 × previous sleep]`, capped, seeded — the classic
    /// backoff that avoids thundering-herd resubmission.
    fn backoff(&mut self) {
        let base = self.config.backoff_base.as_micros() as u64;
        let cap = self.config.backoff_max.as_micros() as u64;
        let hi = (self.backoff_prev_us.max(base)).saturating_mul(3).min(cap);
        let span = hi.saturating_sub(base).max(1);
        let sleep_us = base + splitmix64(&mut self.backoff_rng) % span;
        self.backoff_prev_us = sleep_us;
        std::thread::sleep(Duration::from_micros(sleep_us));
    }

    /// One request with the configured retry policy: resend (with a
    /// fresh request id) only when the server marked the answer
    /// retryable and attempts remain.
    fn request_with_retry(
        &mut self,
        model: &str,
        send: impl Fn(&mut NetClient, &str) -> Result<u64>,
    ) -> Result<Vec<f32>> {
        self.backoff_prev_us = 0;
        let mut attempt = 0u32;
        loop {
            let req_id = send(self, model)?;
            match self.recv_outcome()? {
                Ok((got, values)) => {
                    if got != req_id {
                        return Err(Error::Runtime(format!(
                            "reply id {got} does not match request id {req_id}"
                        )));
                    }
                    return Ok(values);
                }
                Err(e) if e.retryable && attempt < self.config.retries => {
                    attempt += 1;
                    self.backoff();
                }
                Err(e) => {
                    return Err(Error::Runtime(format!(
                        "server error for request {}: {}",
                        e.req_id,
                        e.to_error()
                    )))
                }
            }
        }
    }

    /// Synchronous dense transform (retries retryable rejections when
    /// the config allows).
    pub fn transform(&mut self, model: &str, x: &[f32]) -> Result<Vec<f32>> {
        let values = x.to_vec();
        self.request_with_retry(model, move |c, m| c.send_dense(m, values.clone()))
    }

    /// Synchronous sparse transform.
    pub fn transform_sparse(
        &mut self,
        model: &str,
        indices: &[u32],
        values: &[f32],
    ) -> Result<Vec<f32>> {
        let (indices, values) = (indices.to_vec(), values.to_vec());
        self.request_with_retry(model, move |c, m| {
            c.send_sparse(m, indices.clone(), values.clone())
        })
    }
}

fn to_name(model: &str) -> Result<String> {
    if model.is_empty() || model.len() > crate::net::protocol::MAX_NAME {
        return Err(Error::Config(format!(
            "model name must be 1..={} bytes",
            crate::net::protocol::MAX_NAME
        )));
    }
    Ok(model.to_string())
}
