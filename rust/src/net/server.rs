//! The threaded TCP front-end: accept loop, per-connection reader /
//! writer pair, bounded write-back queues and liveness reaping.
//!
//! # Connection state machine
//!
//! Each accepted connection runs two threads. The **reader** frames
//! the byte stream (header → payload), decodes and dispatches; the
//! **writer** drains a bounded queue of outbound frames. States:
//!
//! ```text
//!            ┌────────────── valid frame ──────────────┐
//!            ▼                                          │
//! OPEN ── read frame ── payload malformed ──▶ error frame, stay OPEN
//!   │                └── header unframeable ─▶ error frame, CLOSED
//!   ├── peer closes / io error ─────────────▶ CLOSED
//!   └── missed > max heartbeat intervals ───▶ reap frame, CLOSED
//! ```
//!
//! * **Recoverable** payload errors (bad field, ragged sparse row,
//!   unknown model) answer with a named error frame and keep the
//!   connection open — the frame boundary was known from the header.
//! * **Fatal** framing errors (bad magic/version, non-zero reserved
//!   bytes, oversized length) poison the stream: one error frame,
//!   then close.
//!
//! # Backpressure
//!
//! The write-back queue is bounded by construction, never by luck:
//! a request is admitted only after claiming one of `write_queue`
//! reply permits, released by the writer once the reply frame is on
//! the wire. A slow reader therefore stalls its *own* permit supply —
//! further requests get a retryable reject frame (`net.reject`) while
//! every other connection keeps its own budget. Control frames
//! (pong, model lists, rejects, reap notices) ride a separate small
//! budget and are dropped (`net.dropped_control`) rather than ever
//! letting a worker callback block on a dead client.
//!
//! # Liveness
//!
//! The reader's socket read timeout is one heartbeat interval; an
//! interval with no bytes is a miss, any byte resets the count, and
//! more than `max_missed` consecutive misses reaps the connection
//! (`net.reaped`) with a final protocol error frame. A client only
//! has to send *something* per interval — `Heartbeat` is the no-op
//! frame for exactly that.

use crate::error::{Error, Result};
use crate::net::protocol::{
    self, decode_header, decode_payload, encode_frame, error_frame, protocol_error_frame, Frame,
    FrameType, HEADER_LEN,
};
use crate::net::registry::{ModelSlot, Registry};
use crate::obs;
use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Outbound frames the writer may drop when its budget is exhausted
/// (pongs, model lists, rejects) vs. replies that own a permit.
const CONTROL_HEADROOM: usize = 64;

/// Front-end tuning knobs (the coordinator behind each model has its
/// own [`crate::coordinator::CoordinatorConfig`]).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:0` (port 0 = ephemeral).
    pub listen: String,
    /// Heartbeat interval: the reader's read timeout, and the unit the
    /// liveness reaper counts in.
    pub heartbeat: Duration,
    /// Consecutive heartbeat intervals without a byte before the
    /// connection is reaped.
    pub max_missed: u32,
    /// Reply permits per connection — the bound on the write-back
    /// queue (backpressure beyond it is a retryable reject frame).
    pub write_queue: usize,
    /// Socket write timeout; a writer blocked this long marks the
    /// connection dead.
    pub write_timeout: Duration,
    /// Accept at most this many connections, then exit once they all
    /// close (0 = unlimited). CI smokes use this for determinism.
    pub max_conns: usize,
    /// Per-request deadline, measured from admission to the moment the
    /// worker answers: a reply that took longer is downgraded to a
    /// *retryable* deadline-exceeded error frame (`Duration::ZERO`
    /// disables; exactly one frame per request either way).
    pub request_deadline: Duration,
    /// Load shedding: when a model's coordinator has this many
    /// requests in flight (submitted − completed), further admissions
    /// get an immediate retryable load-shed frame instead of queueing
    /// behind a saturated pool (0 disables).
    pub shed_inflight: usize,
    /// Shutdown drain budget: how long [`NetServer::shutdown`] lets
    /// in-flight replies flush (read halves closed, writers draining)
    /// before force-closing the stragglers' sockets.
    pub drain: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            listen: "127.0.0.1:0".to_string(),
            heartbeat: Duration::from_secs(2),
            max_missed: 3,
            write_queue: 256,
            write_timeout: Duration::from_secs(10),
            max_conns: 0,
            request_deadline: Duration::ZERO,
            shed_inflight: 0,
            drain: Duration::from_secs(5),
        }
    }
}

/// A running TCP front-end over a shared [`Registry`].
pub struct NetServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<BTreeMap<u64, TcpStream>>>,
    drain: Duration,
}

impl NetServer {
    /// Bind and start accepting. The registry stays owned by the
    /// caller (shut the server down *before* the registry so no
    /// connection still holds a serving).
    pub fn start(registry: Arc<Registry>, config: NetConfig) -> Result<NetServer> {
        if config.write_queue == 0 {
            return Err(Error::Config("write_queue must be at least 1".into()));
        }
        if config.max_missed == 0 {
            return Err(Error::Config("max_missed must be at least 1".into()));
        }
        let listener = TcpListener::bind(&config.listen)
            .map_err(|e| Error::Runtime(format!("bind {}: {e}", config.listen)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Runtime(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Runtime(format!("set_nonblocking: {e}")))?;

        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<BTreeMap<u64, TcpStream>>> = Arc::new(Mutex::new(BTreeMap::new()));
        let accept = {
            let shutdown = shutdown.clone();
            let conns = conns.clone();
            let config = config.clone();
            thread::Builder::new()
                .name("rfdot-net-accept".into())
                .spawn(move || accept_loop(listener, registry, config, shutdown, conns))
                .map_err(|e| Error::Runtime(format!("spawn accept thread: {e}")))?
        };
        Ok(NetServer { addr, shutdown, accept: Some(accept), conns, drain: config.drain })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits — only returns on
    /// [`NetServer::shutdown`] or once a `max_conns` budget is spent
    /// and every connection has closed.
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting and drain: phase 1 closes every connection's
    /// *read* half (no new requests; writers keep flushing in-flight
    /// replies), then waits up to the configured drain budget for the
    /// connection threads to wind down; phase 2 force-closes whatever
    /// is left (`net.drain_forced` counts those sockets). In-flight
    /// replies therefore reach the wire before their sockets close,
    /// unless the peer stalls past the budget.
    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        {
            let conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
            for stream in conns.values() {
                let _ = stream.shutdown(Shutdown::Read);
            }
        }
        let deadline = Instant::now() + self.drain;
        let drained = loop {
            let done = match &self.accept {
                Some(h) => h.is_finished(),
                None => true,
            };
            if done {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            thread::sleep(Duration::from_millis(2));
        };
        if !drained {
            let conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
            for stream in conns.values() {
                obs::counter("net.drain_forced").add(1);
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        self.wait();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<Registry>,
    config: NetConfig,
    shutdown: Arc<AtomicBool>,
    conns: Arc<Mutex<BTreeMap<u64, TcpStream>>>,
) {
    let gauge_conns = obs::gauge("net.connections");
    let total = obs::counter("net.connections_total");
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut accepted = 0usize;
    loop {
        if shutdown.load(Ordering::Acquire) {
            break;
        }
        if config.max_conns > 0 && accepted >= config.max_conns {
            if handles.iter().all(|h| h.is_finished()) {
                break;
            }
            thread::sleep(Duration::from_millis(5));
            continue;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Chaos site: an injected error drops the fresh socket
                // on the floor (the peer sees a refused/reset connect),
                // before any accounting — the server itself keeps
                // accepting.
                if crate::faults::failpoint("net.accept").is_err() {
                    drop(stream);
                    continue;
                }
                accepted += 1;
                let conn_id = accepted as u64;
                total.add(1);
                gauge_conns.add(1);
                if let Ok(clone) = stream.try_clone() {
                    conns
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .insert(conn_id, clone);
                }
                let registry = registry.clone();
                let config = config.clone();
                let conns = conns.clone();
                let handle = thread::Builder::new()
                    .name(format!("rfdot-net-conn-{conn_id}"))
                    .spawn(move || {
                        conn_loop(stream, conn_id, registry, &config);
                        conns.lock().unwrap_or_else(|e| e.into_inner()).remove(&conn_id);
                        obs::gauge("net.connections").add(-1);
                    });
                match handle {
                    Ok(h) => handles.push(h),
                    Err(_) => gauge_conns.add(-1),
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => thread::sleep(Duration::from_millis(2)),
        }
    }
    for h in handles {
        let _ = h.join();
    }
}

/// Outbound queue items: replies own a reply permit, control frames
/// own a control slot; the writer returns the budget after the bytes
/// hit the socket (or the connection dies).
enum Out {
    Reply(Vec<u8>),
    Control(Vec<u8>),
}

/// Claim one unit from a budget without blocking.
fn claim(budget: &AtomicUsize) -> bool {
    budget
        .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| v.checked_sub(1))
        .is_ok()
}

fn writer_loop(
    stream: TcpStream,
    rx: Receiver<Out>,
    permits: Arc<AtomicUsize>,
    control: Arc<AtomicUsize>,
) {
    let frames_sent = obs::counter("net.frames_sent");
    let mut stream = stream;
    let mut dead = false;
    for out in rx {
        let (mut bytes, budget) = match out {
            Out::Reply(b) => (b, &permits),
            Out::Control(b) => (b, &control),
        };
        if !dead {
            let _span = obs::span("net.write_frame");
            // Chaos site: `error` is a socket write failure (connection
            // dies), `corrupt` flips a byte of the outbound frame (the
            // client's framing layer must catch it), `delay` is a slow
            // wire.
            let fault = crate::faults::mangle("net.write", &mut bytes);
            if fault.is_err() || stream.write_all(&bytes).is_err() {
                dead = true;
                let _ = stream.shutdown(Shutdown::Both);
            } else {
                frames_sent.add(1);
            }
        }
        // Budgets recover even on a dead connection so the reader
        // never deadlocks on permits while winding down.
        budget.fetch_add(1, Ordering::AcqRel);
    }
}

/// What one blocking read attempt of an exact-size buffer concluded.
enum ReadStatus {
    /// Buffer filled.
    Full,
    /// Clean EOF on a frame boundary.
    Closed,
    /// Too many heartbeat intervals without a byte.
    Reaped,
    /// Mid-frame EOF or an unrecoverable socket error.
    Dead,
}

/// Fill `buf` exactly, counting heartbeat-interval timeouts into
/// `missed` (any received byte resets it). Works under a socket read
/// timeout, so partial reads across timeout boundaries keep their
/// already-received prefix — framing never desynchronizes.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    missed: &mut u32,
    max_missed: u32,
) -> ReadStatus {
    let mut got = 0usize;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                return if got == 0 { ReadStatus::Closed } else { ReadStatus::Dead };
            }
            Ok(n) => {
                got += n;
                *missed = 0;
            }
            Err(e) => match e.kind() {
                ErrorKind::WouldBlock | ErrorKind::TimedOut => {
                    *missed += 1;
                    if *missed > max_missed {
                        return ReadStatus::Reaped;
                    }
                }
                ErrorKind::Interrupted => {}
                _ => return ReadStatus::Dead,
            },
        }
    }
    ReadStatus::Full
}

fn conn_loop(mut stream: TcpStream, _conn_id: u64, registry: Arc<Registry>, config: &NetConfig) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(config.heartbeat));
    let wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = wstream.set_write_timeout(Some(config.write_timeout));

    let frames = obs::counter("net.frames");
    let bad_frames = obs::counter("net.bad_frames");
    let rejects = obs::counter("net.reject");
    let reaped = obs::counter("net.reaped");
    let dropped_control = obs::counter("net.dropped_control");

    let (tx, rx) = mpsc::sync_channel::<Out>(config.write_queue + CONTROL_HEADROOM);
    let permits = Arc::new(AtomicUsize::new(config.write_queue));
    let control = Arc::new(AtomicUsize::new(CONTROL_HEADROOM));
    let writer = {
        let permits = permits.clone();
        let control = control.clone();
        thread::Builder::new()
            .name("rfdot-net-writer".into())
            .spawn(move || writer_loop(wstream, rx, permits, control))
    };
    let writer = match writer {
        Ok(h) => h,
        Err(_) => return,
    };

    // Control-frame send: claims a control slot, drops the frame (and
    // counts it) when the budget is gone — never blocks the reader.
    let send_control = |frame: &Frame| {
        if claim(&control) {
            if tx.send(Out::Control(encode_frame(frame))).is_err() {
                control.fetch_add(1, Ordering::AcqRel);
            }
        } else {
            dropped_control.add(1);
        }
    };

    let mut missed = 0u32;
    loop {
        // Chaos site: an injected error is a failed socket read — the
        // connection winds down exactly like a peer reset (in-flight
        // replies still flush through the writer drain below).
        if crate::faults::failpoint("net.read").is_err() {
            break;
        }
        let mut header = [0u8; HEADER_LEN];
        match read_full(&mut stream, &mut header, &mut missed, config.max_missed) {
            ReadStatus::Full => {}
            ReadStatus::Closed | ReadStatus::Dead => break,
            ReadStatus::Reaped => {
                reaped.add(1);
                send_control(&protocol_error_frame(
                    0,
                    format!(
                        "liveness: no frame in {} heartbeat intervals, reaping connection",
                        config.max_missed + 1
                    ),
                ));
                break;
            }
        }
        let _span = obs::span("net.frame");
        let (ty, len) = match decode_header(&header) {
            Ok(x) => x,
            Err(e) => {
                // Fatal: the stream can no longer be framed.
                bad_frames.add(1);
                send_control(&protocol_error_frame(0, e.message.clone()));
                break;
            }
        };
        let mut payload = vec![0u8; len as usize];
        match read_full(&mut stream, &mut payload, &mut missed, config.max_missed) {
            ReadStatus::Full => {}
            ReadStatus::Closed | ReadStatus::Dead => break,
            ReadStatus::Reaped => {
                reaped.add(1);
                send_control(&protocol_error_frame(0, "liveness: stalled mid-frame"));
                break;
            }
        }
        frames.add(1);
        let frame = match decode_payload(ty, &payload) {
            Ok(f) => f,
            Err(e) => {
                // Recoverable: the boundary was known; reject the frame
                // by name, echo the request id when the prefix has one,
                // and keep the connection open.
                bad_frames.add(1);
                let rid = match ty {
                    FrameType::Dense | FrameType::Sparse if payload.len() >= 8 => {
                        u64::from_le_bytes(payload[..8].try_into().unwrap())
                    }
                    _ => 0,
                };
                send_control(&protocol_error_frame(rid, e.message.clone()));
                continue;
            }
        };
        match frame {
            Frame::Heartbeat => {}
            Frame::Ping { token } => send_control(&Frame::Pong { token }),
            Frame::ListModels => send_control(&Frame::Models(registry.list())),
            Frame::Dense(req) => {
                let Some(slot) = registry.get(&req.model) else {
                    send_control(&unknown_model(req.req_id, &req.model));
                    continue;
                };
                if !admit(&slot, &permits, &rejects, req.req_id, config.shed_inflight, &send_control)
                {
                    continue;
                }
                let cb = reply_callback(req.req_id, &slot, &tx, config.request_deadline);
                let serving = slot.serving();
                let res = serving.coordinator().submit_callback(req.values, cb);
                drop(serving);
                if let Err(e) = res {
                    settle_admission_error(&tx, &rejects, req.req_id, e);
                }
            }
            Frame::Sparse(req) => {
                let Some(slot) = registry.get(&req.model) else {
                    send_control(&unknown_model(req.req_id, &req.model));
                    continue;
                };
                if !admit(&slot, &permits, &rejects, req.req_id, config.shed_inflight, &send_control)
                {
                    continue;
                }
                let cb = reply_callback(req.req_id, &slot, &tx, config.request_deadline);
                let serving = slot.serving();
                let res =
                    serving.coordinator().submit_sparse_callback(req.indices, req.values, cb);
                drop(serving);
                if let Err(e) = res {
                    settle_admission_error(&tx, &rejects, req.req_id, e);
                }
            }
            // Server-to-client frames arriving at the server are a
            // protocol violation, but a harmless, framed one.
            Frame::Pong { .. } | Frame::Models(_) | Frame::Reply { .. } | Frame::Error(_) => {
                bad_frames.add(1);
                send_control(&protocol_error_frame(
                    0,
                    format!("unexpected server frame type 0x{:02x}", ty.as_u8()),
                ));
            }
        }
    }
    // Drain: dropping our sender leaves only in-flight callbacks; the
    // writer exits after the last of their replies is on the wire.
    drop(tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

fn unknown_model(req_id: u64, name: &str) -> Frame {
    Frame::Error(protocol::ErrorFrame {
        req_id,
        code: protocol::ErrorCode::UnknownModel,
        retryable: false,
        message: format!("unknown model {name:?}"),
    })
}

/// Claim a reply permit for a request; on exhaustion send the
/// retryable write-queue reject and refuse admission. When load
/// shedding is configured and the model's coordinator is saturated
/// (in-flight ≥ the threshold), the request is shed *before* touching
/// the permit budget — an immediate retryable frame (`net.shed`)
/// instead of queueing behind a pool that cannot keep up.
fn admit(
    slot: &Arc<ModelSlot>,
    permits: &AtomicUsize,
    rejects: &obs::Counter,
    req_id: u64,
    shed_inflight: usize,
    send_control: &impl Fn(&Frame),
) -> bool {
    if shed_inflight > 0 {
        let serving = slot.serving();
        let stats = serving.coordinator().stats();
        let submitted = stats.submitted.load(Ordering::Relaxed);
        let completed = stats.completed.load(Ordering::Relaxed);
        if submitted.saturating_sub(completed) >= shed_inflight as u64 {
            obs::counter("net.shed").add(1);
            send_control(&error_frame(
                req_id,
                &Error::Coordinator(format!(
                    "load shed: {} requests in flight (limit {shed_inflight})",
                    submitted.saturating_sub(completed)
                )),
            ));
            return false;
        }
    }
    if !claim(permits) {
        rejects.add(1);
        send_control(&error_frame(
            req_id,
            &Error::Coordinator("write queue full (backpressure)".into()),
        ));
        return false;
    }
    slot.requests().add(1);
    true
}

/// The exactly-once reply path: runs on whichever worker answers the
/// job, records per-model latency, and hands the encoded frame to the
/// bounded writer queue (never blocks: the send rides the permit
/// claimed at admission). With a request deadline configured, an
/// answer that arrives late is downgraded to a *retryable*
/// deadline-exceeded error frame (`net.deadline_exceeded`) — still
/// exactly one frame for the request, so the client can resubmit
/// without ever double-counting.
fn reply_callback(
    req_id: u64,
    slot: &Arc<ModelSlot>,
    tx: &SyncSender<Out>,
    deadline: Duration,
) -> impl FnOnce(Result<Vec<f32>>) + Send + 'static {
    let latency = slot.latency_us().clone();
    let tx = tx.clone();
    let start = Instant::now();
    move |r: Result<Vec<f32>>| {
        let elapsed = start.elapsed();
        latency.record_f64(elapsed.as_secs_f64() * 1e6);
        let frame = if deadline > Duration::ZERO && elapsed > deadline {
            obs::counter("net.deadline_exceeded").add(1);
            error_frame(
                req_id,
                &Error::Coordinator(format!(
                    "deadline exceeded: answered in {:.1}ms (limit {:.1}ms)",
                    elapsed.as_secs_f64() * 1e3,
                    deadline.as_secs_f64() * 1e3
                )),
            )
        } else {
            match r {
                Ok(values) => Frame::Reply { req_id, values },
                Err(e) => error_frame(req_id, &e),
            }
        };
        let _ = tx.send(Out::Reply(encode_frame(&frame)));
    }
}

/// A submission the coordinator refused at admission (lane
/// backpressure, shape error): the callback never armed, so answer on
/// the already-claimed reply permit.
fn settle_admission_error(tx: &SyncSender<Out>, rejects: &obs::Counter, req_id: u64, e: Error) {
    if matches!(&e, Error::Coordinator(m) if m.contains("backpressure")) {
        rejects.add(1);
    }
    let _ = tx.send(Out::Reply(encode_frame(&error_frame(req_id, &e))));
}
