//! The model registry behind the TCP front-end: several named maps
//! served concurrently, each one a [`Serving`] — an [`RFDM0003`
//! artifact](crate::artifact::MapArtifact) instantiated once through
//! [`MapArtifactFactory`] (every worker shares the one read-only
//! weight region) plus a dedicated [`Coordinator`].
//!
//! # Hot-swap protocol
//!
//! [`Registry::insert`] on an existing name is a zero-downtime swap:
//!
//! 1. **load new** — the incoming artifact is instantiated and its
//!    coordinator started *before* any shared state is touched; a bad
//!    artifact fails the swap without disturbing the live version.
//! 2. **atomically switch** — the slot's `Arc<Serving>` is replaced
//!    under a write lock; every subsequent [`ModelSlot::serving`]
//!    lookup routes to the new version. Lookups hold the read lock
//!    only long enough to clone the `Arc`.
//! 3. **drain in-flight** — requests already admitted to the old
//!    coordinator keep their exactly-once reply guarantee: clean
//!    shutdown closes the ingress lanes and the workers answer every
//!    queued job with its real reply.
//! 4. **retire old when refcount drains** — a background retirer waits
//!    for transient `Arc<Serving>` clones (readers mid-submit) to
//!    drop, then tears the old serving down. Dropping it shuts the
//!    coordinator down (drain above) and releases the artifact's
//!    weight region, so the `artifact.bytes` gauge returns to
//!    baseline — `rust/tests/net_registry.rs` pins all four steps.

use crate::artifact::MapArtifact;
use crate::coordinator::{Coordinator, CoordinatorConfig, MapArtifactFactory};
use crate::error::{Error, Result};
use crate::features::FeatureMap;
use crate::maclaurin::RandomMaclaurin;
use crate::metrics::Summary;
use crate::net::protocol::ModelEntry;
use crate::obs;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

/// How long a retirer waits for transient `Arc<Serving>` clones to
/// drop before declaring the retire stuck (see
/// [`Registry::with_retire_deadline`]).
pub const DEFAULT_RETIRE_DEADLINE: Duration = Duration::from_secs(5);

/// One live model version: the shared artifact, its instantiated map
/// (for dims and offline reference transforms) and a dedicated
/// coordinator built over [`MapArtifactFactory`], so every worker
/// thread reads the same weight region.
pub struct Serving {
    name: String,
    version: u64,
    artifact: Arc<MapArtifact>,
    map: Arc<RandomMaclaurin>,
    coord: Coordinator,
}

impl Serving {
    fn start(
        name: &str,
        version: u64,
        artifact: Arc<MapArtifact>,
        config: CoordinatorConfig,
    ) -> Result<Serving> {
        let factory = MapArtifactFactory::new(artifact.clone())?;
        let map = Arc::new(artifact.instantiate()?);
        let coord = Coordinator::start(Arc::new(factory), config);
        Ok(Serving { name: name.to_string(), version, artifact, map, coord })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn input_dim(&self) -> usize {
        self.map.input_dim()
    }

    pub fn output_dim(&self) -> usize {
        self.map.output_dim()
    }

    pub fn artifact(&self) -> &Arc<MapArtifact> {
        &self.artifact
    }

    /// The instantiated map (offline reference transforms in tests).
    pub fn map(&self) -> &Arc<RandomMaclaurin> {
        &self.map
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }
}

/// A named registry slot. The slot outlives individual versions, so
/// its per-model metric handles (`net.model.<name>.requests`,
/// `net.model.<name>.latency_us`) accumulate across hot-swaps.
pub struct ModelSlot {
    name: String,
    current: RwLock<Arc<Serving>>,
    next_version: AtomicU64,
    requests: Arc<obs::Counter>,
    latency_us: Arc<obs::Histogram>,
    swaps: Arc<obs::Counter>,
}

impl ModelSlot {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Clone the current version's handle (the atomic-switch read
    /// side: lookups never block behind a swap for more than the
    /// `Arc` clone).
    pub fn serving(&self) -> Arc<Serving> {
        self.current.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Per-model request counter (admission-side).
    pub fn requests(&self) -> &Arc<obs::Counter> {
        &self.requests
    }

    /// Per-model reply latency histogram in microseconds.
    pub fn latency_us(&self) -> &Arc<obs::Histogram> {
        &self.latency_us
    }
}

/// Per-model stats for the consolidated serve stats line.
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub name: String,
    pub version: u64,
    pub requests: u64,
    pub swaps: u64,
    pub latency_us: Summary,
}

/// The multi-tenant model registry: named slots, hot-swap, retirement.
pub struct Registry {
    models: RwLock<BTreeMap<String, Arc<ModelSlot>>>,
    /// Serializes administrative writes (insert/swap/remove) so the
    /// slow part of a swap — instantiating the incoming artifact —
    /// never runs under the `models` lock that lookups take.
    admin: Mutex<()>,
    coord_config: CoordinatorConfig,
    retirers: Mutex<Vec<thread::JoinHandle<()>>>,
    retire_deadline: Duration,
}

impl Registry {
    /// A registry whose servings run coordinators with this config.
    pub fn new(coord_config: CoordinatorConfig) -> Registry {
        Registry {
            models: RwLock::new(BTreeMap::new()),
            admin: Mutex::new(()),
            coord_config,
            retirers: Mutex::new(Vec::new()),
            retire_deadline: DEFAULT_RETIRE_DEADLINE,
        }
    }

    /// Bound how long a retirer waits for a replaced version's
    /// refcount to drain. Past the deadline the retire degrades to a
    /// logged + metered *stuck retire* (`net.registry.stuck_retires`):
    /// the retirer drops its handle and exits, and the old serving
    /// tears down whenever the leaked holder finally lets go — a
    /// bounded background thread instead of an unbounded hang.
    pub fn with_retire_deadline(mut self, deadline: Duration) -> Registry {
        self.retire_deadline = deadline;
        self
    }

    /// Insert a model or hot-swap an existing one (see the module docs
    /// for the swap protocol). Returns the new version number.
    pub fn insert(&self, name: &str, artifact: Arc<MapArtifact>) -> Result<u64> {
        let _span = obs::span("net.swap");
        if name.is_empty() || name.len() > crate::net::protocol::MAX_NAME {
            return Err(Error::Config(format!(
                "model name must be 1..={} bytes, got {}",
                crate::net::protocol::MAX_NAME,
                name.len()
            )));
        }
        // Chaos site: an injected error fails the swap before any
        // shared state is touched — the live version must stay intact
        // (same contract as a bad artifact).
        crate::faults::failpoint("registry.swap")?;
        // The admin lock serializes writers; lookups stay on the
        // `models` read lock and never wait on artifact instantiation.
        let _admin = self.admin.lock().unwrap_or_else(|e| e.into_inner());
        let slot = {
            let models = self.models.read().unwrap_or_else(|e| e.into_inner());
            models.get(name).cloned()
        };
        match slot {
            Some(slot) => {
                let version = slot.next_version.fetch_add(1, Ordering::Relaxed);
                // Step 1 (load new) before touching shared state: a bad
                // artifact must not disturb the live version.
                let fresh = Arc::new(Serving::start(
                    name,
                    version,
                    artifact,
                    self.coord_config.clone(),
                )?);
                // Step 2: atomic switch.
                let old = {
                    let mut cur = slot.current.write().unwrap_or_else(|e| e.into_inner());
                    std::mem::replace(&mut *cur, fresh)
                };
                slot.swaps.add(1);
                // Steps 3–4: drain + retire off the request path.
                self.spawn_retirer(old);
                Ok(version)
            }
            None => {
                let fresh = Arc::new(Serving::start(
                    name,
                    1,
                    artifact,
                    self.coord_config.clone(),
                )?);
                let slot = Arc::new(ModelSlot {
                    name: name.to_string(),
                    current: RwLock::new(fresh),
                    next_version: AtomicU64::new(2),
                    requests: obs::counter(&format!("net.model.{name}.requests")),
                    latency_us: obs::histogram(&format!("net.model.{name}.latency_us")),
                    swaps: obs::counter(&format!("net.model.{name}.swaps")),
                });
                let mut models = self.models.write().unwrap_or_else(|e| e.into_inner());
                models.insert(name.to_string(), slot);
                Ok(1)
            }
        }
    }

    /// Look up a slot by name.
    pub fn get(&self, name: &str) -> Option<Arc<ModelSlot>> {
        self.models.read().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Remove a model entirely (retires its current serving).
    pub fn remove(&self, name: &str) -> bool {
        let _admin = self.admin.lock().unwrap_or_else(|e| e.into_inner());
        let slot = {
            let mut models = self.models.write().unwrap_or_else(|e| e.into_inner());
            models.remove(name)
        };
        match slot {
            Some(slot) => {
                // The retirer waits out both this clone and the slot's
                // own reference (dropped with the slot below).
                self.spawn_retirer(slot.serving());
                true
            }
            None => false,
        }
    }

    /// Wire-protocol directory listing (sorted by name).
    pub fn list(&self) -> Vec<ModelEntry> {
        let models = self.models.read().unwrap_or_else(|e| e.into_inner());
        models
            .values()
            .map(|slot| {
                let s = slot.serving();
                ModelEntry {
                    name: slot.name.clone(),
                    version: s.version(),
                    input_dim: s.input_dim() as u32,
                    output_dim: s.output_dim() as u32,
                }
            })
            .collect()
    }

    /// Per-model stats for the consolidated serve stats line and tests.
    pub fn model_stats(&self) -> Vec<ModelStats> {
        let models = self.models.read().unwrap_or_else(|e| e.into_inner());
        models
            .values()
            .map(|slot| ModelStats {
                name: slot.name.clone(),
                version: slot.serving().version(),
                requests: slot.requests.get(),
                swaps: slot.swaps.get(),
                latency_us: slot.latency_us.summary(),
            })
            .collect()
    }

    /// Step 4: wait (off-thread) for transient `Arc<Serving>` clones to
    /// drop, then tear the old version down. `Serving::drop` shuts its
    /// coordinator down cleanly — already-admitted jobs are answered
    /// with real replies — and releases the artifact weight region.
    /// The wait is bounded by the registry's retire deadline: a leaked
    /// `Arc<Serving>` (a connection that never lets go) degrades to a
    /// logged + metered stuck retire instead of an unbounded hang, and
    /// the serving still tears down whenever the holder finally drops.
    fn spawn_retirer(&self, old: Arc<Serving>) {
        let deadline = self.retire_deadline;
        // Gauge guard: pending accounting must survive injected panics
        // inside the retirer thread.
        struct Pending;
        impl Drop for Pending {
            fn drop(&mut self) {
                obs::gauge("net.registry.pending_retires").add(-1);
            }
        }
        obs::gauge("net.registry.pending_retires").add(1);
        let handle = thread::Builder::new()
            .name("rfdot-net-retire".into())
            .spawn(move || {
                let _pending = Pending;
                let name = old.name().to_string();
                let version = old.version();
                // Chaos site: an injected error degrades this retire to
                // the stuck path immediately (the deterministic way to
                // exercise it); an injected panic unwinds — the gauge
                // guard and the `Arc` drop still run.
                let drain_ok = crate::faults::failpoint("registry.drain").is_ok();
                let give_up = Instant::now() + deadline;
                let mut old = old;
                while drain_ok {
                    match Arc::try_unwrap(old) {
                        Ok(serving) => {
                            // Chaos site: retire must complete even when
                            // it fires — an error is logged, a panic
                            // unwinds; either way `serving` drops and
                            // the weight region is released.
                            if let Err(e) = crate::faults::failpoint("registry.retire") {
                                eprintln!("rfdot: retire fault for {name} v{version}: {e}");
                            }
                            drop(serving); // Coordinator::drop drains + joins.
                            obs::counter("net.retired").add(1);
                            obs::counter("net.registry.retired").add(1);
                            return;
                        }
                        Err(still_shared) => {
                            if Instant::now() >= give_up {
                                old = still_shared;
                                break;
                            }
                            old = still_shared;
                            thread::sleep(Duration::from_micros(200));
                        }
                    }
                }
                // Stuck: someone still holds the old version past the
                // deadline. Log + meter, drop our handle, exit — the
                // teardown runs from the leaked holder's final drop.
                obs::counter("net.registry.stuck_retires").add(1);
                eprintln!(
                    "rfdot: stuck retire: {name} v{version} still referenced after {:?}",
                    deadline
                );
                drop(old);
            })
            .expect("spawn retirer thread");
        self.retirers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(handle);
    }

    /// Retire every model and join all retirer threads. Call after the
    /// front-end has stopped (no connection still holds a `Serving`).
    pub fn shutdown(&self) {
        let names: Vec<String> = {
            let models = self.models.read().unwrap_or_else(|e| e.into_inner());
            models.keys().cloned().collect()
        };
        for name in names {
            self.remove(&name);
        }
        self.drain_retirers();
    }

    /// Join every spawned retirer (tests use this to assert the
    /// `artifact.bytes` gauge returned to baseline).
    pub fn drain_retirers(&self) {
        let handles: Vec<_> = {
            let mut g = self.retirers.lock().unwrap_or_else(|e| e.into_inner());
            g.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Registry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Exponential;
    use crate::maclaurin::RmConfig;
    use crate::rng::Rng;

    fn artifact(seed: u64, d: usize, n: usize) -> Arc<MapArtifact> {
        let mut rng = Rng::seed_from(seed);
        let map = RandomMaclaurin::sample(
            &Exponential::new(1.0),
            d,
            n,
            RmConfig::default().with_max_order(6),
            &mut rng,
        );
        Arc::new(MapArtifact::from_map(&map).expect("encode artifact"))
    }

    fn config() -> CoordinatorConfig {
        CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn insert_swap_and_retire_release_the_artifact() {
        let baseline = crate::artifact::resident_bytes();
        let reg = Registry::new(config());
        assert_eq!(reg.insert("reg-test", artifact(1, 6, 16)).unwrap(), 1);
        let v1 = reg.get("reg-test").unwrap().serving();
        assert_eq!(v1.version(), 1);
        let x = vec![0.25; 6];
        let y1 = v1.coordinator().submit(x.clone()).unwrap().wait().unwrap();
        assert_eq!(y1, v1.map().transform(&x), "reply must match the offline map");
        drop(v1);

        assert_eq!(reg.insert("reg-test", artifact(2, 6, 16)).unwrap(), 2);
        let v2 = reg.get("reg-test").unwrap().serving();
        assert_eq!(v2.version(), 2);
        let y2 = v2.coordinator().submit(x.clone()).unwrap().wait().unwrap();
        assert_eq!(y2, v2.map().transform(&x));
        assert_ne!(y1, y2, "independently sampled maps must differ");
        drop(v2);

        let entries = reg.list();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].version, 2);
        assert_eq!(entries[0].input_dim, 6);

        reg.shutdown();
        assert_eq!(
            crate::artifact::resident_bytes(),
            baseline,
            "retirement must release every artifact weight region"
        );
    }

    #[test]
    fn retirement_is_metered() {
        let retired_before = obs::counter("net.registry.retired").get();
        let stuck_before = obs::counter("net.registry.stuck_retires").get();
        let reg = Registry::new(config());
        reg.insert("reg-meter", artifact(7, 5, 8)).unwrap();
        reg.insert("reg-meter", artifact(8, 5, 8)).unwrap(); // one swap-retire
        reg.shutdown(); // plus the final remove-retire
        assert!(
            obs::counter("net.registry.retired").get() >= retired_before + 2,
            "swap + shutdown must both count into net.registry.retired"
        );
        assert_eq!(
            obs::counter("net.registry.stuck_retires").get(),
            stuck_before,
            "clean retires must not count as stuck"
        );
    }

    #[test]
    fn stuck_retire_degrades_to_a_metered_bounded_exit() {
        let baseline = crate::artifact::resident_bytes();
        let stuck_before = obs::counter("net.registry.stuck_retires").get();
        let reg = Registry::new(config()).with_retire_deadline(Duration::from_millis(20));
        reg.insert("reg-stuck", artifact(9, 5, 8)).unwrap();
        // A leaked holder: this clone outlives the swap's drain window.
        let leaked = reg.get("reg-stuck").unwrap().serving();
        reg.insert("reg-stuck", artifact(10, 5, 8)).unwrap();
        // The bounded deadline means this join completes instead of
        // hanging behind the leaked Arc.
        reg.drain_retirers();
        assert!(
            obs::counter("net.registry.stuck_retires").get() > stuck_before,
            "a held Arc past the deadline must count as a stuck retire"
        );
        // The old version still works while leaked, and tears down when
        // the holder finally lets go.
        let x = vec![0.5; 5];
        assert_eq!(
            leaked.coordinator().submit(x.clone()).unwrap().wait().unwrap(),
            leaked.map().transform(&x)
        );
        drop(leaked);
        reg.shutdown();
        assert_eq!(
            crate::artifact::resident_bytes(),
            baseline,
            "stuck retires must still release the weights once the holder drops"
        );
    }

    #[test]
    fn bad_artifact_swap_leaves_live_version_untouched() {
        let reg = Registry::new(config());
        reg.insert("reg-bad", artifact(3, 5, 8)).unwrap();
        let bytes = artifact(3, 5, 8).as_bytes().to_vec();
        let broken = MapArtifact::from_bytes(&bytes[..]).unwrap();
        // An empty-named insert is the cheap invalid-swap stand-in.
        assert!(reg.insert("", Arc::new(broken)).is_err());
        let live = reg.get("reg-bad").unwrap().serving();
        assert_eq!(live.version(), 1, "failed swap must not advance the version");
    }
}
