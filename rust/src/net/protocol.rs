//! The `RFNP` wire protocol: a small length-prefixed binary framing for
//! the network serving tier.
//!
//! # Frame layout
//!
//! Every frame is a fixed 12-byte header followed by `payload_len`
//! payload bytes, all integers little-endian:
//!
//! ```text
//! offset  size  field
//! 0       4     magic       b"RFNP"
//! 4       1     version     1
//! 5       1     frame type  (see below)
//! 6       2     reserved    must be 0
//! 8       4     payload_len u32, <= MAX_PAYLOAD (16 MiB)
//! ```
//!
//! Client → server frames: `Ping` (0x01, opaque token echoed back),
//! `Heartbeat` (0x02, empty payload, liveness only), `ListModels`
//! (0x03, empty), `Dense` (0x04), `Sparse` (0x05, CSR). Server →
//! client: `Pong` (0x81), `Models` (0x83), `Reply` (0x84), `Error`
//! (0xEE, carrying the [`crate::Error`] taxonomy as a numeric code
//! plus a retryable flag).
//!
//! # Error discipline
//!
//! [`decode_header`] failures are **fatal** ([`FrameError::fatal`]):
//! bad magic/version, non-zero reserved bytes, or an oversized length
//! mean the stream can no longer be framed, so the server sends one
//! error frame and closes. [`decode_payload`] failures are
//! **recoverable**: the header gave an exact payload length, so the
//! frame boundary is known, the malformed frame is skipped with a
//! named error frame, and the connection stays open in a defined
//! state. Every length field is proven against the bytes actually
//! present *before* any allocation, so a crafted count can never force
//! a multi-gigabyte `Vec::with_capacity` (the allocation-bomb guard
//! the torture suite in `rust/tests/net_protocol.rs` pins).

use crate::error::{Error, Result};

/// Frame magic: RFdot Network Protocol.
pub const MAGIC: [u8; 4] = *b"RFNP";
/// Current wire protocol version.
pub const VERSION: u8 = 1;
/// Fixed frame header size in bytes.
pub const HEADER_LEN: usize = 12;
/// Maximum payload size (16 MiB) — the allocation-bomb guard: a header
/// claiming more is rejected before any payload byte is read.
pub const MAX_PAYLOAD: u32 = 1 << 24;
/// Maximum model name length in bytes.
pub const MAX_NAME: usize = 255;

/// Wire frame type tags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameType {
    Ping = 0x01,
    Heartbeat = 0x02,
    ListModels = 0x03,
    Dense = 0x04,
    Sparse = 0x05,
    Pong = 0x81,
    Models = 0x83,
    Reply = 0x84,
    Error = 0xEE,
}

impl FrameType {
    pub fn from_u8(b: u8) -> Option<FrameType> {
        match b {
            0x01 => Some(FrameType::Ping),
            0x02 => Some(FrameType::Heartbeat),
            0x03 => Some(FrameType::ListModels),
            0x04 => Some(FrameType::Dense),
            0x05 => Some(FrameType::Sparse),
            0x81 => Some(FrameType::Pong),
            0x83 => Some(FrameType::Models),
            0x84 => Some(FrameType::Reply),
            0xEE => Some(FrameType::Error),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }
}

/// Numeric error codes carried by the error frame. Codes 1–9 map the
/// [`crate::Error`] variants in declaration order; 10 and 11 are
/// protocol-level conditions with no library counterpart.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    Config = 1,
    Kernel = 2,
    Data = 3,
    Shape = 4,
    Solver = 5,
    Runtime = 6,
    Coordinator = 7,
    Bench = 8,
    Io = 9,
    /// Malformed frame or framing-level violation.
    Protocol = 10,
    /// Request named a model the registry does not serve.
    UnknownModel = 11,
}

impl ErrorCode {
    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        match b {
            1 => Some(ErrorCode::Config),
            2 => Some(ErrorCode::Kernel),
            3 => Some(ErrorCode::Data),
            4 => Some(ErrorCode::Shape),
            5 => Some(ErrorCode::Solver),
            6 => Some(ErrorCode::Runtime),
            7 => Some(ErrorCode::Coordinator),
            8 => Some(ErrorCode::Bench),
            9 => Some(ErrorCode::Io),
            10 => Some(ErrorCode::Protocol),
            11 => Some(ErrorCode::UnknownModel),
            _ => None,
        }
    }

    /// Map a library error to its wire code plus the retryable flag.
    /// The retryable family is transient server state the client may
    /// simply wait out and resend: backpressure rejections
    /// (coordinator lane full, bounded write queue full), load
    /// shedding, and per-request deadline overruns — in every case the
    /// request either was never accepted or already got its one
    /// (error) answer, so a resend can never double-execute.
    pub fn from_error(e: &Error) -> (ErrorCode, bool) {
        let code = match e {
            Error::Config(_) => ErrorCode::Config,
            Error::Kernel(_) => ErrorCode::Kernel,
            Error::Data(_) => ErrorCode::Data,
            Error::Shape { .. } => ErrorCode::Shape,
            Error::Solver(_) => ErrorCode::Solver,
            Error::Runtime(_) => ErrorCode::Runtime,
            Error::Coordinator(_) => ErrorCode::Coordinator,
            Error::Bench(_) => ErrorCode::Bench,
            Error::Io(_) => ErrorCode::Io,
        };
        let retryable = matches!(e, Error::Coordinator(m) if m.contains("backpressure")
            || m.contains("load shed")
            || m.contains("deadline exceeded"));
        (code, retryable)
    }
}

/// A dense transform request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub req_id: u64,
    pub model: String,
    pub values: Vec<f32>,
}

/// A sparse (CSR row) transform request. Indices must be strictly
/// ascending; the counts for indices and values are carried separately
/// on the wire so a ragged frame is a named protocol error, not a
/// silent truncation.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseRequest {
    pub req_id: u64,
    pub model: String,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

/// One entry of a `Models` response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelEntry {
    pub name: String,
    pub version: u64,
    pub input_dim: u32,
    pub output_dim: u32,
}

/// The error frame body. `req_id` 0 marks a connection-level error
/// (no specific request); otherwise it echoes the failing request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ErrorFrame {
    pub req_id: u64,
    pub code: ErrorCode,
    pub retryable: bool,
    pub message: String,
}

impl ErrorFrame {
    /// Reconstruct a library error from the wire form (client side).
    pub fn to_error(&self) -> Error {
        let m = self.message.clone();
        match self.code {
            ErrorCode::Config => Error::Config(m),
            ErrorCode::Kernel => Error::Kernel(m),
            ErrorCode::Data => Error::Data(m),
            ErrorCode::Shape => Error::Runtime(format!("shape error: {m}")),
            ErrorCode::Solver => Error::Solver(m),
            ErrorCode::Runtime => Error::Runtime(m),
            ErrorCode::Coordinator => Error::Coordinator(m),
            ErrorCode::Bench => Error::Bench(m),
            ErrorCode::Io => Error::Runtime(format!("io error: {m}")),
            ErrorCode::Protocol => Error::Runtime(format!("protocol error: {m}")),
            ErrorCode::UnknownModel => Error::Runtime(format!("unknown model: {m}")),
        }
    }
}

/// A decoded wire frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Ping { token: Vec<u8> },
    Heartbeat,
    ListModels,
    Dense(Request),
    Sparse(SparseRequest),
    Pong { token: Vec<u8> },
    Models(Vec<ModelEntry>),
    Reply { req_id: u64, values: Vec<f32> },
    Error(ErrorFrame),
}

impl Frame {
    pub fn frame_type(&self) -> FrameType {
        match self {
            Frame::Ping { .. } => FrameType::Ping,
            Frame::Heartbeat => FrameType::Heartbeat,
            Frame::ListModels => FrameType::ListModels,
            Frame::Dense(_) => FrameType::Dense,
            Frame::Sparse(_) => FrameType::Sparse,
            Frame::Pong { .. } => FrameType::Pong,
            Frame::Models(_) => FrameType::Models,
            Frame::Reply { .. } => FrameType::Reply,
            Frame::Error(_) => FrameType::Error,
        }
    }
}

/// A codec failure. `fatal` distinguishes framing-level corruption
/// (bad magic/version/reserved/oversized length — the stream can no
/// longer be framed, close after one error frame) from payload-shape
/// errors (frame boundary known, connection stays open).
#[derive(Clone, Debug)]
pub struct FrameError {
    pub fatal: bool,
    pub message: String,
}

impl FrameError {
    fn fatal(msg: impl Into<String>) -> FrameError {
        FrameError { fatal: true, message: msg.into() }
    }

    fn soft(msg: impl Into<String>) -> FrameError {
        FrameError { fatal: false, message: msg.into() }
    }

    /// The library-error form (always the protocol taxonomy slot).
    pub fn to_error(&self) -> Error {
        Error::Runtime(format!("protocol error: {}", self.message))
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

/// Encode a frame header. `payload_len` must already be `<=`
/// [`MAX_PAYLOAD`] (all in-tree encoders guarantee it).
pub fn encode_header(ty: FrameType, payload_len: usize) -> [u8; HEADER_LEN] {
    debug_assert!(payload_len as u64 <= MAX_PAYLOAD as u64);
    let mut h = [0u8; HEADER_LEN];
    h[..4].copy_from_slice(&MAGIC);
    h[4] = VERSION;
    h[5] = ty.as_u8();
    // h[6..8] reserved, zero.
    h[8..12].copy_from_slice(&(payload_len as u32).to_le_bytes());
    h
}

/// Decode and validate a frame header; returns the frame type and the
/// payload length. All failures are fatal (see [`FrameError`]).
pub fn decode_header(h: &[u8; HEADER_LEN]) -> std::result::Result<(FrameType, u32), FrameError> {
    if h[..4] != MAGIC {
        return Err(FrameError::fatal(format!(
            "bad magic {:02x?} (want {:02x?} = \"RFNP\")",
            &h[..4],
            MAGIC
        )));
    }
    if h[4] != VERSION {
        return Err(FrameError::fatal(format!(
            "unsupported protocol version {} (want {VERSION})",
            h[4]
        )));
    }
    let ty = FrameType::from_u8(h[5]).ok_or_else(|| {
        FrameError::fatal(format!("unknown frame type 0x{:02x}", h[5]))
    })?;
    if h[6] != 0 || h[7] != 0 {
        return Err(FrameError::fatal("non-zero reserved header bytes"));
    }
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    if len > MAX_PAYLOAD {
        return Err(FrameError::fatal(format!(
            "frame length {len} exceeds MAX_PAYLOAD {MAX_PAYLOAD}"
        )));
    }
    Ok((ty, len))
}

/// Little-endian payload cursor with named-field error messages.
struct R<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> R<'a> {
    fn new(b: &'a [u8]) -> R<'a> {
        R { b, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.off
    }

    fn take(&mut self, n: usize, field: &str) -> std::result::Result<&'a [u8], FrameError> {
        if self.remaining() < n {
            return Err(FrameError::soft(format!(
                "{field} truncated: need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self, field: &str) -> std::result::Result<u8, FrameError> {
        Ok(self.take(1, field)?[0])
    }

    fn u16(&mut self, field: &str) -> std::result::Result<u16, FrameError> {
        let s = self.take(2, field)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self, field: &str) -> std::result::Result<u32, FrameError> {
        let s = self.take(4, field)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, field: &str) -> std::result::Result<u64, FrameError> {
        let s = self.take(8, field)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    /// `count` little-endian f32 words. The byte count is proven
    /// present before the Vec is reserved (allocation-bomb guard).
    fn f32s(&mut self, count: usize, field: &str) -> std::result::Result<Vec<f32>, FrameError> {
        let bytes = count.checked_mul(4).ok_or_else(|| {
            FrameError::soft(format!("{field} count overflows"))
        })?;
        let s = self.take(bytes, field)?;
        let mut v = Vec::with_capacity(count);
        for c in s.chunks_exact(4) {
            v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(v)
    }

    fn u32s(&mut self, count: usize, field: &str) -> std::result::Result<Vec<u32>, FrameError> {
        let bytes = count.checked_mul(4).ok_or_else(|| {
            FrameError::soft(format!("{field} count overflows"))
        })?;
        let s = self.take(bytes, field)?;
        let mut v = Vec::with_capacity(count);
        for c in s.chunks_exact(4) {
            v.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(v)
    }

    fn name(&mut self) -> std::result::Result<String, FrameError> {
        let len = self.u16("model name length")? as usize;
        if len > MAX_NAME {
            return Err(FrameError::soft(format!(
                "model name length {len} exceeds {MAX_NAME}"
            )));
        }
        let bytes = self.take(len, "model name")?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| FrameError::soft("model name is not valid UTF-8"))
    }

    fn finish(self, what: &str) -> std::result::Result<(), FrameError> {
        if self.remaining() != 0 {
            return Err(FrameError::soft(format!(
                "{what}: {} trailing payload bytes",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// Decode a payload for a known frame type. Failures are recoverable
/// (`fatal == false`): the frame boundary came from the header, so the
/// connection can keep framing after rejecting this frame.
pub fn decode_payload(ty: FrameType, payload: &[u8]) -> std::result::Result<Frame, FrameError> {
    let mut r = R::new(payload);
    match ty {
        FrameType::Ping => Ok(Frame::Ping { token: payload.to_vec() }),
        FrameType::Pong => Ok(Frame::Pong { token: payload.to_vec() }),
        FrameType::Heartbeat => {
            r.finish("heartbeat frame")?;
            Ok(Frame::Heartbeat)
        }
        FrameType::ListModels => {
            r.finish("list-models frame")?;
            Ok(Frame::ListModels)
        }
        FrameType::Dense => {
            let req_id = r.u64("dense request id")?;
            let model = r.name()?;
            let dim = r.u32("dense dim")? as usize;
            let values = r.f32s(dim, "dense values")?;
            r.finish("dense frame")?;
            Ok(Frame::Dense(Request { req_id, model, values }))
        }
        FrameType::Sparse => {
            let req_id = r.u64("sparse request id")?;
            let model = r.name()?;
            let nidx = r.u32("sparse index count")? as usize;
            let nval = r.u32("sparse value count")? as usize;
            if nidx != nval {
                return Err(FrameError::soft(format!(
                    "sparse indices/values length mismatch: {nidx} indices vs {nval} values"
                )));
            }
            let indices = r.u32s(nidx, "sparse indices")?;
            let values = r.f32s(nval, "sparse values")?;
            if let Some(w) = indices.windows(2).find(|w| w[0] >= w[1]) {
                return Err(FrameError::soft(format!(
                    "sparse indices not strictly ascending ({} then {})",
                    w[0], w[1]
                )));
            }
            r.finish("sparse frame")?;
            Ok(Frame::Sparse(SparseRequest { req_id, model, indices, values }))
        }
        FrameType::Models => {
            let count = r.u32("model count")? as usize;
            // Each entry is at least 2 (name len) + 8 + 4 + 4 bytes, so
            // a crafted count is proven against the payload before the
            // Vec is reserved.
            if count.saturating_mul(18) > payload.len() {
                return Err(FrameError::soft(format!(
                    "model count {count} exceeds payload ({} bytes)",
                    payload.len()
                )));
            }
            let mut models = Vec::with_capacity(count);
            for _ in 0..count {
                let name = r.name()?;
                let version = r.u64("model version")?;
                let input_dim = r.u32("model input dim")?;
                let output_dim = r.u32("model output dim")?;
                models.push(ModelEntry { name, version, input_dim, output_dim });
            }
            r.finish("models frame")?;
            Ok(Frame::Models(models))
        }
        FrameType::Reply => {
            let req_id = r.u64("reply request id")?;
            let dim = r.u32("reply dim")? as usize;
            let values = r.f32s(dim, "reply values")?;
            r.finish("reply frame")?;
            Ok(Frame::Reply { req_id, values })
        }
        FrameType::Error => {
            let req_id = r.u64("error request id")?;
            let code_byte = r.u8("error code")?;
            let code = ErrorCode::from_u8(code_byte).ok_or_else(|| {
                FrameError::soft(format!("unknown error code {code_byte}"))
            })?;
            let retryable = match r.u8("error retryable flag")? {
                0 => false,
                1 => true,
                b => {
                    return Err(FrameError::soft(format!(
                        "error retryable flag must be 0 or 1, got {b}"
                    )))
                }
            };
            let msg_len = r.u16("error message length")? as usize;
            let bytes = r.take(msg_len, "error message")?;
            let message = String::from_utf8(bytes.to_vec())
                .map_err(|_| FrameError::soft("error message is not valid UTF-8"))?;
            r.finish("error frame")?;
            Ok(Frame::Error(ErrorFrame { req_id, code, retryable, message }))
        }
    }
}

/// Encode one frame (header + payload).
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut p = Vec::new();
    match f {
        Frame::Ping { token } | Frame::Pong { token } => p.extend_from_slice(token),
        Frame::Heartbeat | Frame::ListModels => {}
        Frame::Dense(req) => {
            p.extend_from_slice(&req.req_id.to_le_bytes());
            put_name(&mut p, &req.model);
            p.extend_from_slice(&(req.values.len() as u32).to_le_bytes());
            for v in &req.values {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::Sparse(req) => {
            p.extend_from_slice(&req.req_id.to_le_bytes());
            put_name(&mut p, &req.model);
            p.extend_from_slice(&(req.indices.len() as u32).to_le_bytes());
            p.extend_from_slice(&(req.values.len() as u32).to_le_bytes());
            for i in &req.indices {
                p.extend_from_slice(&i.to_le_bytes());
            }
            for v in &req.values {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::Models(models) => {
            p.extend_from_slice(&(models.len() as u32).to_le_bytes());
            for m in models {
                put_name(&mut p, &m.name);
                p.extend_from_slice(&m.version.to_le_bytes());
                p.extend_from_slice(&m.input_dim.to_le_bytes());
                p.extend_from_slice(&m.output_dim.to_le_bytes());
            }
        }
        Frame::Reply { req_id, values } => {
            p.extend_from_slice(&req_id.to_le_bytes());
            p.extend_from_slice(&(values.len() as u32).to_le_bytes());
            for v in values {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::Error(e) => {
            p.extend_from_slice(&e.req_id.to_le_bytes());
            p.push(e.code as u8);
            p.push(e.retryable as u8);
            let msg = e.message.as_bytes();
            let len = msg.len().min(u16::MAX as usize);
            p.extend_from_slice(&(len as u16).to_le_bytes());
            p.extend_from_slice(&msg[..len]);
        }
    }
    let mut out = Vec::with_capacity(HEADER_LEN + p.len());
    out.extend_from_slice(&encode_header(f.frame_type(), p.len()));
    out.extend_from_slice(&p);
    out
}

fn put_name(p: &mut Vec<u8>, name: &str) {
    let b = name.as_bytes();
    debug_assert!(b.len() <= MAX_NAME);
    p.extend_from_slice(&(b.len() as u16).to_le_bytes());
    p.extend_from_slice(b);
}

/// Build the error frame for a library error (server reply path).
pub fn error_frame(req_id: u64, e: &Error) -> Frame {
    let (code, retryable) = ErrorCode::from_error(e);
    Frame::Error(ErrorFrame { req_id, code, retryable, message: e.to_string() })
}

/// Build a protocol-level error frame (malformed frame, liveness reap).
pub fn protocol_error_frame(req_id: u64, message: impl Into<String>) -> Frame {
    Frame::Error(ErrorFrame {
        req_id,
        code: ErrorCode::Protocol,
        retryable: false,
        message: message.into(),
    })
}

/// Decode exactly one frame from the front of `buf`; returns the frame
/// and the number of bytes consumed. A buffer shorter than the header
/// plus the declared payload is a truncation error (fatal — there is
/// no more stream to wait on at this call level). This is the
/// byte-slice entry point the torture suite sweeps; the server uses
/// the streaming split ([`decode_header`] / [`decode_payload`]).
pub fn decode_frame(buf: &[u8]) -> std::result::Result<(Frame, usize), FrameError> {
    if buf.len() < HEADER_LEN {
        return Err(FrameError::fatal(format!(
            "header truncated: need {HEADER_LEN} bytes, have {}",
            buf.len()
        )));
    }
    let header: [u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().unwrap();
    let (ty, len) = decode_header(&header)?;
    let total = HEADER_LEN + len as usize;
    if buf.len() < total {
        return Err(FrameError::fatal(format!(
            "payload truncated: need {} bytes, have {}",
            total,
            buf.len()
        )));
    }
    let frame = decode_payload(ty, &buf[HEADER_LEN..total])?;
    Ok((frame, total))
}

/// Convenience round-trip check used by the client: decode a whole
/// buffer as exactly one frame.
pub fn decode_single(buf: &[u8]) -> Result<Frame> {
    let (frame, used) = decode_frame(buf).map_err(|e| e.to_error())?;
    if used != buf.len() {
        return Err(Error::Runtime(format!(
            "protocol error: {} trailing bytes after frame",
            buf.len() - used
        )));
    }
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Ping { token: b"tok".to_vec() },
            Frame::Heartbeat,
            Frame::ListModels,
            Frame::Dense(Request {
                req_id: 7,
                model: "m".into(),
                values: vec![1.0, -2.5, 3.25],
            }),
            Frame::Sparse(SparseRequest {
                req_id: 8,
                model: "m".into(),
                indices: vec![0, 3, 9],
                values: vec![0.5, -1.0, 2.0],
            }),
            Frame::Pong { token: b"tok".to_vec() },
            Frame::Models(vec![ModelEntry {
                name: "m".into(),
                version: 3,
                input_dim: 10,
                output_dim: 64,
            }]),
            Frame::Reply { req_id: 7, values: vec![9.0, 8.0] },
            Frame::Error(ErrorFrame {
                req_id: 7,
                code: ErrorCode::Coordinator,
                retryable: true,
                message: "queue full (backpressure)".into(),
            }),
        ]
    }

    #[test]
    fn frames_round_trip() {
        for f in sample_frames() {
            let bytes = encode_frame(&f);
            let (decoded, used) = decode_frame(&bytes).expect("valid frame must decode");
            assert_eq!(used, bytes.len());
            assert_eq!(decoded, f);
        }
    }

    #[test]
    fn error_code_maps_every_variant_and_round_trips() {
        use crate::error::Error as E;
        let cases: Vec<Error> = vec![
            E::Config("c".into()),
            E::Kernel("k".into()),
            E::Data("d".into()),
            E::shape(1, 2),
            E::Solver("s".into()),
            E::Runtime("r".into()),
            E::Coordinator("queue full (backpressure)".into()),
            E::Bench("b".into()),
            E::Io(std::io::ErrorKind::UnexpectedEof.into()),
        ];
        let mut codes = std::collections::BTreeSet::new();
        for e in &cases {
            let (code, _) = ErrorCode::from_error(e);
            assert_eq!(ErrorCode::from_u8(code as u8), Some(code));
            codes.insert(code as u8);
        }
        assert_eq!(codes.len(), cases.len(), "each variant must map to a distinct code");
        let (_, retryable) =
            ErrorCode::from_error(&E::Coordinator("queue full (backpressure)".into()));
        assert!(retryable, "backpressure must be retryable");
        let (_, retryable) = ErrorCode::from_error(&E::Coordinator("shut down".into()));
        assert!(!retryable);
    }
}
