//! The network serving tier: a multi-tenant TCP front-end over the
//! sharded [`crate::coordinator`], following the request-handling /
//! coordinator-state split (ROADMAP item 1, after xaynet's service
//! layering).
//!
//! Three layers, each its own module:
//!
//! 1. [`protocol`] — the `RFNP` length-prefixed binary codec: magic +
//!    version + frame-type header, dense and sparse CSR request forms,
//!    `ping`/`heartbeat`/`list-models`, and an error frame carrying
//!    the [`crate::Error`] taxonomy. Hardened like the RFDM readers:
//!    every length proven before allocation, named per-field errors
//!    (`rust/tests/net_protocol.rs` sweeps every truncation).
//! 2. [`registry`] — named models, each a [`registry::Serving`]
//!    instantiated from an RFDM0003 artifact through
//!    [`crate::coordinator::MapArtifactFactory`] (tenants share one
//!    read-only weight region), with zero-downtime hot-swap: load new
//!    → atomic switch → drain in-flight → retire when the refcount
//!    drains (`rust/tests/net_registry.rs`).
//! 3. [`server`] — the threaded front-end: accept loop, reader/writer
//!    thread pair per connection, bounded write-back queues with
//!    permit-accounted backpressure, heartbeat liveness reaping
//!    (`rust/tests/net_server.rs`), plus [`client::NetClient`], the
//!    reference client.
//!
//! Observability: `net.connections`, `net.frames`, `net.frames_sent`,
//! `net.reject`, `net.reaped`, `net.bad_frames`, `net.dropped_control`,
//! `net.retired`, `net.shed`, `net.deadline_exceeded`,
//! `net.drain_forced`, the registry retirement family
//! (`net.registry.retired`, `net.registry.pending_retires` gauge,
//! `net.registry.stuck_retires`), and per-model
//! `net.model.<name>.requests` / `.latency_us` / `.swaps` — all
//! through [`crate::obs`] and visible in
//! [`crate::obs::MetricsSnapshot`]; `--trace` spans cover frame
//! handling (`net.frame`, `net.write_frame`) and swaps (`net.swap`).
//! Fault injection for the whole tier (accept/read/write plus the
//! coordinator and registry sites) lives in [`crate::faults`].

pub mod client;
pub mod protocol;
pub mod registry;
pub mod server;

pub use client::{ClientConfig, NetClient};
pub use registry::{ModelSlot, ModelStats, Registry, Serving};
pub use server::{NetConfig, NetServer};
