//! LIBSVM/SVMlight sparse text format parser.
//!
//! Lines look like `+1 3:0.5 7:1.25 # comment`. Indices are 1-based.
//! This lets the benchmark harness run on the *real* UCI datasets when a
//! copy is available, instead of the synthetic surrogates.

use super::Dataset;
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::path::Path;

/// Parse LIBSVM-format text. Labels are binarized: values > 0 map to +1,
/// the rest to −1 (the paper binarizes non-binary problems randomly; a
/// deterministic threshold keeps runs reproducible). If `dim` is `None`
/// the dimensionality is the largest index seen.
pub fn parse_str(name: &str, text: &str, dim: Option<usize>) -> Result<Dataset> {
    struct Row {
        label: f32,
        feats: Vec<(usize, f32)>,
    }
    let mut rows: Vec<Row> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            Some(p) => &raw[..p],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let label_tok = it.next().expect("non-empty line has a first token");
        let label_val: f32 = label_tok
            .parse()
            .map_err(|_| Error::Data(format!("line {}: bad label {label_tok:?}", lineno + 1)))?;
        let label = if label_val > 0.0 { 1.0 } else { -1.0 };
        let mut feats = Vec::new();
        for tok in it {
            let (idx_s, val_s) = tok
                .split_once(':')
                .ok_or_else(|| Error::Data(format!("line {}: bad pair {tok:?}", lineno + 1)))?;
            let idx: usize = idx_s
                .parse()
                .map_err(|_| Error::Data(format!("line {}: bad index {idx_s:?}", lineno + 1)))?;
            if idx == 0 {
                return Err(Error::Data(format!("line {}: indices are 1-based", lineno + 1)));
            }
            let val: f32 = val_s
                .parse()
                .map_err(|_| Error::Data(format!("line {}: bad value {val_s:?}", lineno + 1)))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push(Row { label, feats });
    }

    let d = match dim {
        Some(d) => {
            if max_idx > d {
                return Err(Error::Data(format!("feature index {max_idx} exceeds dim {d}")));
            }
            d
        }
        None => max_idx,
    };

    let mut x = Matrix::zeros(rows.len(), d);
    let mut y = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        for &(j, v) in &row.feats {
            x.set(i, j, v);
        }
        y.push(row.label);
    }
    Dataset::new(name, x, y)
}

/// Parse a LIBSVM-format file from disk.
pub fn parse_file(path: impl AsRef<Path>, dim: Option<usize>) -> Result<Dataset> {
    let path = path.as_ref();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".to_string());
    let text = std::fs::read_to_string(path)?;
    parse_str(&name, &text, dim)
}

/// Serialize a dataset back to LIBSVM format (round-trip support for
/// exporting the synthetic surrogates).
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for i in 0..ds.len() {
        out.push_str(if ds.y[i] > 0.0 { "+1" } else { "-1" });
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                out.push_str(&format!(" {}:{}", j + 1, v));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_lines() {
        let ds = parse_str("t", "+1 1:0.5 3:2\n-1 2:1 # tail comment\n\n", None).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.x.row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(ds.x.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn binarizes_multiclass_labels() {
        let ds = parse_str("t", "3 1:1\n0 1:1\n-2 1:1\n", None).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0, -1.0]);
    }

    #[test]
    fn respects_explicit_dim() {
        let ds = parse_str("t", "+1 2:1\n", Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(parse_str("t", "+1 9:1\n", Some(5)).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_str("t", "abc 1:1\n", None).is_err());
        assert!(parse_str("t", "+1 0:1\n", None).is_err());
        assert!(parse_str("t", "+1 1=5\n", None).is_err());
        assert!(parse_str("t", "+1 x:5\n", None).is_err());
        assert!(parse_str("t", "+1 1:zz\n", None).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = "+1 1:0.25 3:-1\n-1 2:4\n";
        let ds = parse_str("t", src, None).unwrap();
        let back = to_string(&ds);
        let ds2 = parse_str("t", &back, None).unwrap();
        assert_eq!(ds.x, ds2.x);
        assert_eq!(ds.y, ds2.y);
    }
}
