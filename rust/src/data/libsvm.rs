//! LIBSVM/SVMlight sparse text format parser.
//!
//! Lines look like `+1 3:0.5 7:1.25 # comment`. Indices are 1-based.
//! This lets the benchmark harness run on the *real* UCI datasets when a
//! copy is available, instead of the synthetic surrogates.
//!
//! The format is sparse and so is the result: parsing goes **straight
//! into CSR** ([`crate::linalg::SparseMatrix`], via
//! [`crate::data::Dataset::new_sparse`]) with no densify step, so the
//! downstream feature maps and the linear SVM run their `O(nnz)` fast
//! paths. Duplicate feature indices on a line (`3:1 3:2`) are a parse
//! error — LIBSVM requires unique ascending indices, and silently
//! keeping the last occurrence (what the old dense `Matrix::set` path
//! did) corrupts data without a trace. Out-of-order indices are
//! accepted and sorted (several published dumps are unsorted), but
//! duplicates never are.

use super::Dataset;
use crate::linalg::SparseMatrix;
use crate::{Error, Result};
use std::path::Path;

/// Parse LIBSVM-format text. Labels are binarized: values > 0 map to +1,
/// the rest to −1 (the paper binarizes non-binary problems randomly; a
/// deterministic threshold keeps runs reproducible). If `dim` is `None`
/// the dimensionality is the largest index seen.
pub fn parse_str(name: &str, text: &str, dim: Option<usize>) -> Result<Dataset> {
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut y: Vec<f32> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            Some(p) => &raw[..p],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let label_tok = it.next().expect("non-empty line has a first token");
        let label_val: f32 = label_tok
            .parse()
            .map_err(|_| Error::Data(format!("line {}: bad label {label_tok:?}", lineno + 1)))?;
        let label = if label_val > 0.0 { 1.0 } else { -1.0 };
        let mut feats: Vec<(u32, f32)> = Vec::new();
        for tok in it {
            let (idx_s, val_s) = tok
                .split_once(':')
                .ok_or_else(|| Error::Data(format!("line {}: bad pair {tok:?}", lineno + 1)))?;
            let idx: usize = idx_s
                .parse()
                .map_err(|_| Error::Data(format!("line {}: bad index {idx_s:?}", lineno + 1)))?;
            if idx == 0 {
                return Err(Error::Data(format!("line {}: indices are 1-based", lineno + 1)));
            }
            let val: f32 = val_s
                .parse()
                .map_err(|_| Error::Data(format!("line {}: bad value {val_s:?}", lineno + 1)))?;
            let col = u32::try_from(idx - 1).map_err(|_| {
                Error::Data(format!("line {}: feature index {idx} too large", lineno + 1))
            })?;
            max_idx = max_idx.max(idx);
            feats.push((col, val));
        }
        feats.sort_by_key(|&(j, _)| j);
        for w in feats.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(Error::Data(format!(
                    "line {}: duplicate feature index {} (LIBSVM requires unique indices)",
                    lineno + 1,
                    w[0].0 + 1
                )));
            }
        }
        rows.push(feats);
        y.push(label);
    }

    let d = match dim {
        Some(d) => {
            if max_idx > d {
                return Err(Error::Data(format!("feature index {max_idx} exceeds dim {d}")));
            }
            d
        }
        None => max_idx,
    };

    let x = SparseMatrix::from_rows(d, &rows)?;
    Dataset::new_sparse(name, x, y)
}

/// Parse a LIBSVM-format file from disk.
pub fn parse_file(path: impl AsRef<Path>, dim: Option<usize>) -> Result<Dataset> {
    let path = path.as_ref();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".to_string());
    let text = std::fs::read_to_string(path)?;
    parse_str(&name, &text, dim)
}

/// Serialize a dataset back to LIBSVM format (round-trip support for
/// exporting the synthetic surrogates). Sparse storage streams its
/// stored entries directly; dense storage scans for nonzeros.
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for i in 0..ds.len() {
        out.push_str(if ds.y[i] > 0.0 { "+1" } else { "-1" });
        match ds.storage() {
            crate::data::Storage::Sparse(s) => {
                let row = s.row(i);
                for (&j, &v) in row.indices.iter().zip(row.values) {
                    if v != 0.0 {
                        out.push_str(&format!(" {}:{}", j + 1, v));
                    }
                }
            }
            crate::data::Storage::Dense(m) => {
                for (j, &v) in m.row(i).iter().enumerate() {
                    if v != 0.0 {
                        out.push_str(&format!(" {}:{}", j + 1, v));
                    }
                }
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_lines() {
        let ds = parse_str("t", "+1 1:0.5 3:2\n-1 2:1 # tail comment\n\n", None).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.x().row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(ds.x().row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn parses_straight_into_csr() {
        // The tentpole contract: no densify step, nnz is the stored count.
        let ds = parse_str("t", "+1 2:1 9:0.5\n-1 1:-3\n", None).unwrap();
        assert!(ds.is_sparse());
        let s = ds.sparse().unwrap();
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.cols(), 9);
        assert_eq!(s.row(0).indices, &[1, 8]);
    }

    #[test]
    fn binarizes_multiclass_labels() {
        let ds = parse_str("t", "3 1:1\n0 1:1\n-2 1:1\n", None).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0, -1.0]);
    }

    #[test]
    fn respects_explicit_dim() {
        let ds = parse_str("t", "+1 2:1\n", Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(parse_str("t", "+1 9:1\n", Some(5)).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_str("t", "abc 1:1\n", None).is_err());
        assert!(parse_str("t", "+1 0:1\n", None).is_err());
        assert!(parse_str("t", "+1 1=5\n", None).is_err());
        assert!(parse_str("t", "+1 x:5\n", None).is_err());
        assert!(parse_str("t", "+1 1:zz\n", None).is_err());
        // Indices beyond the u32 column space must error, not wrap
        // (4294967297 - 1 would silently truncate to column 0).
        let err = parse_str("t", "+1 4294967297:1\n", None).unwrap_err();
        assert!(err.to_string().contains("too large"), "{err}");
    }

    #[test]
    fn rejects_duplicate_indices() {
        // Regression: `3:1 3:2` used to silently keep the last value via
        // Matrix::set; LIBSVM requires unique indices, so it is a parse
        // error now.
        let err = parse_str("t", "+1 3:1 3:2\n", None).unwrap_err();
        assert!(err.to_string().contains("duplicate feature index 3"), "{err}");
        // Even duplicates that agree on the value are rejected.
        assert!(parse_str("t", "+1 1:1 2:5 2:5\n", None).is_err());
        // Out-of-order (but unique) indices are sorted, not rejected.
        let ds = parse_str("t", "+1 3:3 1:1\n", None).unwrap();
        assert_eq!(ds.x().row(0), &[1.0, 0.0, 3.0]);
    }

    #[test]
    fn roundtrip() {
        let src = "+1 1:0.25 3:-1\n-1 2:4\n";
        let ds = parse_str("t", src, None).unwrap();
        let back = to_string(&ds);
        let ds2 = parse_str("t", &back, None).unwrap();
        assert_eq!(ds.x(), ds2.x());
        assert_eq!(ds.y, ds2.y);
        // Dense storage serializes identically.
        assert_eq!(to_string(&ds.clone().into_dense()), back);
    }
}
