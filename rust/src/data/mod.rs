//! Dataset substrate.
//!
//! The paper evaluates on six UCI datasets (Nursery, Spambase, Cod-RNA,
//! Adult, IJCNN, Covertype). This environment has no network access, so
//! [`synthetic`] provides surrogates with the same sample counts,
//! dimensionalities and marginal structure, labeled by a genuinely
//! nonlinear teacher (see DESIGN.md §5 for the substitution argument);
//! [`libsvm`] parses the standard LIBSVM text format so the real datasets
//! drop in unchanged when available.
//!
//! Matching the paper's protocol (§6.3): vectors are L2-normalized with
//! constants learnt on the training split, 60% of the data (capped at
//! 20 000) is used for training, and non-binary problems are binarized.
//!
//! # Dense and sparse storage
//!
//! A [`Dataset`] owns its features through the [`Storage`] enum: either
//! a dense row-major [`Matrix`] (the synthetic surrogates) or a CSR
//! [`SparseMatrix`] (what [`libsvm`] now parses *directly*, with no
//! densify step — the real UCI encodings are mostly zeros). Consumers
//! that understand sparsity dispatch on [`Dataset::storage`] (the
//! feature maps' `transform_batch_sparse`, the sparse dual coordinate
//! descent in [`crate::svm::linear`]); everything else calls
//! [`Dataset::x`], which returns the dense matrix directly or lazily
//! materializes (and caches) a dense view of the CSR storage. The two
//! storages are interchangeable by contract: every sparse fast path in
//! the crate produces outputs equal to the dense path on the densified
//! data (`rust/tests/sparse_parity.rs`), so [`Dataset::into_sparse`] /
//! [`Dataset::into_dense`] change cost, never results.

pub mod libsvm;
pub mod synthetic;

pub use synthetic::{SyntheticSpec, Teacher, UciSurrogate};

use crate::linalg::{Matrix, SparseMatrix};
use crate::rng::Rng;
use crate::{Error, Result};
use std::sync::OnceLock;

/// Feature storage: dense row-major or CSR.
#[derive(Clone, Debug)]
pub enum Storage {
    /// `n × d` dense matrix (row per example).
    Dense(Matrix),
    /// CSR matrix with the same logical shape.
    Sparse(SparseMatrix),
}

impl Storage {
    /// Number of examples.
    pub fn rows(&self) -> usize {
        match self {
            Storage::Dense(m) => m.rows(),
            Storage::Sparse(s) => s.rows(),
        }
    }

    /// Feature dimensionality.
    pub fn cols(&self) -> usize {
        match self {
            Storage::Dense(m) => m.cols(),
            Storage::Sparse(s) => s.cols(),
        }
    }
}

/// A labeled binary classification dataset (labels ±1).
#[derive(Debug)]
pub struct Dataset {
    pub name: String,
    storage: Storage,
    /// Lazily materialized dense view of sparse storage (never used for
    /// dense storage). Reset by every mutating method.
    dense_view: OnceLock<Matrix>,
    /// Labels in `{-1.0, +1.0}`.
    pub y: Vec<f32>,
}

impl Clone for Dataset {
    fn clone(&self) -> Self {
        // The dense-view cache is cheap to rebuild; don't copy it.
        Dataset {
            name: self.name.clone(),
            storage: self.storage.clone(),
            dense_view: OnceLock::new(),
            y: self.y.clone(),
        }
    }
}

impl Dataset {
    /// Construct with validation (dense storage).
    pub fn new(name: impl Into<String>, x: Matrix, y: Vec<f32>) -> Result<Self> {
        Self::with_storage(name, Storage::Dense(x), y)
    }

    /// Construct with validation (CSR storage).
    pub fn new_sparse(name: impl Into<String>, x: SparseMatrix, y: Vec<f32>) -> Result<Self> {
        Self::with_storage(name, Storage::Sparse(x), y)
    }

    /// Construct from any [`Storage`], validating labels.
    pub fn with_storage(name: impl Into<String>, storage: Storage, y: Vec<f32>) -> Result<Self> {
        if storage.rows() != y.len() {
            return Err(Error::shape(
                format!("{} labels", storage.rows()),
                format!("{}", y.len()),
            ));
        }
        if let Some(bad) = y.iter().find(|&&v| v != 1.0 && v != -1.0) {
            return Err(Error::Data(format!("label {bad} not in {{-1, +1}}")));
        }
        Ok(Dataset { name: name.into(), storage, dense_view: OnceLock::new(), y })
    }

    /// The feature storage (dispatch point for sparse-aware consumers).
    pub fn storage(&self) -> &Storage {
        &self.storage
    }

    /// The CSR storage, if this dataset is sparse.
    pub fn sparse(&self) -> Option<&SparseMatrix> {
        match &self.storage {
            Storage::Sparse(s) => Some(s),
            Storage::Dense(_) => None,
        }
    }

    /// True when the storage is CSR.
    pub fn is_sparse(&self) -> bool {
        matches!(self.storage, Storage::Sparse(_))
    }

    /// Dense feature matrix: the storage itself for dense datasets, a
    /// lazily materialized (cached) view for sparse ones. Sparse-aware
    /// hot paths should dispatch on [`Dataset::storage`] instead.
    pub fn x(&self) -> &Matrix {
        match &self.storage {
            Storage::Dense(m) => m,
            Storage::Sparse(s) => self.dense_view.get_or_init(|| s.to_dense()),
        }
    }

    /// Stored nonzero entries (counted for dense storage).
    pub fn nnz(&self) -> usize {
        match &self.storage {
            Storage::Dense(m) => m.as_slice().iter().filter(|&&v| v != 0.0).count(),
            Storage::Sparse(s) => s.nnz(),
        }
    }

    /// Convert to CSR storage (no-op if already sparse). Results are
    /// unchanged by contract; only the cost model moves to `O(nnz)`.
    pub fn into_sparse(self) -> Dataset {
        let storage = match self.storage {
            Storage::Dense(m) => Storage::Sparse(SparseMatrix::from_dense(&m)),
            s @ Storage::Sparse(_) => s,
        };
        Dataset { name: self.name, storage, dense_view: OnceLock::new(), y: self.y }
    }

    /// Convert to dense storage (no-op if already dense).
    pub fn into_dense(self) -> Dataset {
        let storage = match self.storage {
            Storage::Sparse(s) => Storage::Dense(s.to_dense()),
            d @ Storage::Dense(_) => d,
        };
        Dataset { name: self.name, storage, dense_view: OnceLock::new(), y: self.y }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.storage.cols()
    }

    /// Fraction of positive labels.
    pub fn positive_fraction(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.y.len() as f64
    }

    /// L2-normalize every row in place (the paper's protocol for
    /// unbounded kernels; puts the data on the unit sphere so `R = 1`).
    /// Sparse rows scale their stored values by the same `1/‖row‖`
    /// factor the dense path uses (the norm is computed with the dense
    /// path's lane structure via [`crate::linalg::SparseRow::norm2`]),
    /// so both storages normalize to equal values.
    pub fn normalize_rows(&mut self) {
        match &mut self.storage {
            Storage::Dense(m) => {
                for i in 0..m.rows() {
                    crate::linalg::normalize(m.row_mut(i));
                }
            }
            Storage::Sparse(s) => {
                for i in 0..s.rows() {
                    let n = s.row(i).norm2();
                    if n > 0.0 {
                        crate::linalg::scale(1.0 / n, s.row_values_mut(i));
                    }
                }
            }
        }
        self.dense_view = OnceLock::new();
    }

    /// Random shuffled train/test split: `train_frac` of the data, with
    /// the train side capped at `max_train` examples (paper: 60%, cap
    /// 20 000). The shuffle consumes the RNG identically for both
    /// storages, and the split preserves the storage kind.
    pub fn split(&self, train_frac: f64, max_train: usize, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.len();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_train = ((n as f64 * train_frac) as usize).min(max_train).min(n);
        let take = |ids: &[usize]| {
            let storage = match &self.storage {
                Storage::Dense(m) => {
                    let rows: Vec<Vec<f32>> = ids.iter().map(|&i| m.row(i).to_vec()).collect();
                    Storage::Dense(Matrix::from_rows(&rows).expect("rows are uniform"))
                }
                Storage::Sparse(s) => Storage::Sparse(s.select_rows(ids)),
            };
            let y: Vec<f32> = ids.iter().map(|&i| self.y[i]).collect();
            Dataset { name: self.name.clone(), storage, dense_view: OnceLock::new(), y }
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }

    /// Keep only the first `n` examples (used by `--scale` to shrink the
    /// large surrogates for CI-sized runs).
    pub fn truncate(&mut self, n: usize) {
        if n >= self.len() {
            return;
        }
        self.storage = match &self.storage {
            Storage::Dense(m) => Storage::Dense(m.slice_rows(0, n)),
            Storage::Sparse(s) => Storage::Sparse(s.slice_rows(0, n)),
        };
        self.dense_view = OnceLock::new();
        self.y.truncate(n);
    }

    /// The paper's σ heuristic: mean pairwise Euclidean distance over the
    /// (training) data, estimated from `pairs` random pairs. Uses the
    /// dense view (an estimation helper, not a hot path).
    pub fn mean_pairwise_distance(&self, pairs: usize, rng: &mut Rng) -> f64 {
        if self.len() < 2 {
            return 1.0;
        }
        let x = self.x();
        let mut acc = 0.0;
        for _ in 0..pairs {
            let i = rng.below(self.len() as u64) as usize;
            let mut j = rng.below(self.len() as u64) as usize;
            while j == i {
                j = rng.below(self.len() as u64) as usize;
            }
            let (a, b) = (x.row(i), x.row(j));
            let d2: f32 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
            acc += (d2 as f64).sqrt();
        }
        acc / pairs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_rows(&[
            vec![3.0, 4.0],
            vec![1.0, 0.0],
            vec![0.0, 2.0],
            vec![1.0, 1.0],
        ])
        .unwrap();
        Dataset::new("toy", x, vec![1.0, -1.0, 1.0, -1.0]).unwrap()
    }

    #[test]
    fn validation() {
        let x = Matrix::zeros(2, 2);
        assert!(Dataset::new("a", x.clone(), vec![1.0]).is_err());
        assert!(Dataset::new("b", x.clone(), vec![1.0, 0.5]).is_err());
        assert!(Dataset::new("c", x, vec![1.0, -1.0]).is_ok());
        // Sparse constructor validates the same invariants.
        let s = SparseMatrix::from_rows(2, &[vec![(0, 1.0)], vec![(1, -2.0)]]).unwrap();
        assert!(Dataset::new_sparse("d", s.clone(), vec![1.0]).is_err());
        assert!(Dataset::new_sparse("e", s, vec![1.0, -1.0]).is_ok());
    }

    #[test]
    fn normalize_rows_unit() {
        let mut d = toy();
        d.normalize_rows();
        for i in 0..d.len() {
            let n = crate::linalg::norm2(d.x().row(i));
            assert!((n - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn sparse_normalize_matches_dense() {
        let mut dense = toy();
        let mut sparse = toy().into_sparse();
        assert!(sparse.is_sparse());
        dense.normalize_rows();
        sparse.normalize_rows();
        assert_eq!(dense.x(), sparse.x());
    }

    #[test]
    fn storage_round_trip_preserves_values() {
        let d = toy();
        let s = d.clone().into_sparse();
        assert_eq!(s.nnz(), 6);
        assert_eq!(s.dim(), 2);
        assert_eq!(d.x(), s.x());
        let back = s.clone().into_dense();
        assert!(!back.is_sparse());
        assert_eq!(back.x(), d.x());
        assert_eq!(s.sparse().unwrap().to_dense(), *d.x());
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let mut rng = Rng::seed_from(1);
        let (tr, te) = d.split(0.5, 100, &mut rng);
        assert_eq!(tr.len() + te.len(), d.len());
        assert_eq!(tr.len(), 2);
        // Cap applies.
        let (tr2, te2) = d.split(1.0, 1, &mut rng);
        assert_eq!(tr2.len(), 1);
        assert_eq!(te2.len(), 3);
    }

    #[test]
    fn sparse_split_matches_dense_split() {
        // Same RNG seed ⇒ same shuffle ⇒ same rows, whatever the storage.
        let d = toy();
        let s = d.clone().into_sparse();
        let mut rng_d = Rng::seed_from(9);
        let mut rng_s = Rng::seed_from(9);
        let (tr_d, te_d) = d.split(0.5, 100, &mut rng_d);
        let (tr_s, te_s) = s.split(0.5, 100, &mut rng_s);
        assert!(tr_s.is_sparse() && te_s.is_sparse());
        assert_eq!(tr_d.x(), tr_s.x());
        assert_eq!(te_d.x(), te_s.x());
        assert_eq!(tr_d.y, tr_s.y);
    }

    #[test]
    fn truncate_keeps_prefix() {
        let mut d = toy();
        d.truncate(2);
        assert_eq!(d.len(), 2);
        assert_eq!(d.x().rows(), 2);
        d.truncate(100); // no-op
        assert_eq!(d.len(), 2);
        let mut s = toy().into_sparse();
        s.truncate(2);
        assert_eq!(s.len(), 2);
        assert_eq!(s.x(), d.x());
    }

    #[test]
    fn pairwise_distance_positive() {
        let d = toy();
        let mut rng = Rng::seed_from(2);
        let m = d.mean_pairwise_distance(200, &mut rng);
        assert!(m > 0.0 && m < 10.0);
    }

    #[test]
    fn positive_fraction() {
        assert!((toy().positive_fraction() - 0.5).abs() < 1e-12);
    }
}
