//! Dataset substrate.
//!
//! The paper evaluates on six UCI datasets (Nursery, Spambase, Cod-RNA,
//! Adult, IJCNN, Covertype). This environment has no network access, so
//! [`synthetic`] provides surrogates with the same sample counts,
//! dimensionalities and marginal structure, labeled by a genuinely
//! nonlinear teacher (see DESIGN.md §5 for the substitution argument);
//! [`libsvm`] parses the standard LIBSVM text format so the real datasets
//! drop in unchanged when available.
//!
//! Matching the paper's protocol (§6.3): vectors are L2-normalized with
//! constants learnt on the training split, 60% of the data (capped at
//! 20 000) is used for training, and non-binary problems are binarized.

pub mod libsvm;
pub mod synthetic;

pub use synthetic::{SyntheticSpec, Teacher, UciSurrogate};

use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::{Error, Result};

/// A labeled binary classification dataset (labels ±1).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// `n × d` feature matrix (row per example).
    pub x: Matrix,
    /// Labels in `{-1.0, +1.0}`.
    pub y: Vec<f32>,
}

impl Dataset {
    /// Construct with validation.
    pub fn new(name: impl Into<String>, x: Matrix, y: Vec<f32>) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(Error::shape(format!("{} labels", x.rows()), format!("{}", y.len())));
        }
        if let Some(bad) = y.iter().find(|&&v| v != 1.0 && v != -1.0) {
            return Err(Error::Data(format!("label {bad} not in {{-1, +1}}")));
        }
        Ok(Dataset { name: name.into(), x, y })
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Fraction of positive labels.
    pub fn positive_fraction(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.y.len() as f64
    }

    /// L2-normalize every row in place (the paper's protocol for
    /// unbounded kernels; puts the data on the unit sphere so `R = 1`).
    pub fn normalize_rows(&mut self) {
        for i in 0..self.x.rows() {
            crate::linalg::normalize(self.x.row_mut(i));
        }
    }

    /// Random shuffled train/test split: `train_frac` of the data, with
    /// the train side capped at `max_train` examples (paper: 60%, cap
    /// 20 000).
    pub fn split(&self, train_frac: f64, max_train: usize, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.len();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_train = ((n as f64 * train_frac) as usize).min(max_train).min(n);
        let take = |ids: &[usize]| {
            let rows: Vec<Vec<f32>> = ids.iter().map(|&i| self.x.row(i).to_vec()).collect();
            let y: Vec<f32> = ids.iter().map(|&i| self.y[i]).collect();
            Dataset {
                name: self.name.clone(),
                x: Matrix::from_rows(&rows).expect("rows are uniform"),
                y,
            }
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }

    /// Keep only the first `n` examples (used by `--scale` to shrink the
    /// large surrogates for CI-sized runs).
    pub fn truncate(&mut self, n: usize) {
        if n >= self.len() {
            return;
        }
        self.x = self.x.slice_rows(0, n);
        self.y.truncate(n);
    }

    /// The paper's σ heuristic: mean pairwise Euclidean distance over the
    /// (training) data, estimated from `pairs` random pairs.
    pub fn mean_pairwise_distance(&self, pairs: usize, rng: &mut Rng) -> f64 {
        if self.len() < 2 {
            return 1.0;
        }
        let mut acc = 0.0;
        for _ in 0..pairs {
            let i = rng.below(self.len() as u64) as usize;
            let mut j = rng.below(self.len() as u64) as usize;
            while j == i {
                j = rng.below(self.len() as u64) as usize;
            }
            let (a, b) = (self.x.row(i), self.x.row(j));
            let d2: f32 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
            acc += (d2 as f64).sqrt();
        }
        acc / pairs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_rows(&[
            vec![3.0, 4.0],
            vec![1.0, 0.0],
            vec![0.0, 2.0],
            vec![1.0, 1.0],
        ])
        .unwrap();
        Dataset::new("toy", x, vec![1.0, -1.0, 1.0, -1.0]).unwrap()
    }

    #[test]
    fn validation() {
        let x = Matrix::zeros(2, 2);
        assert!(Dataset::new("a", x.clone(), vec![1.0]).is_err());
        assert!(Dataset::new("b", x.clone(), vec![1.0, 0.5]).is_err());
        assert!(Dataset::new("c", x, vec![1.0, -1.0]).is_ok());
    }

    #[test]
    fn normalize_rows_unit() {
        let mut d = toy();
        d.normalize_rows();
        for i in 0..d.len() {
            let n = crate::linalg::norm2(d.x.row(i));
            assert!((n - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let mut rng = Rng::seed_from(1);
        let (tr, te) = d.split(0.5, 100, &mut rng);
        assert_eq!(tr.len() + te.len(), d.len());
        assert_eq!(tr.len(), 2);
        // Cap applies.
        let (tr2, te2) = d.split(1.0, 1, &mut rng);
        assert_eq!(tr2.len(), 1);
        assert_eq!(te2.len(), 3);
    }

    #[test]
    fn truncate_keeps_prefix() {
        let mut d = toy();
        d.truncate(2);
        assert_eq!(d.len(), 2);
        assert_eq!(d.x.rows(), 2);
        d.truncate(100); // no-op
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn pairwise_distance_positive() {
        let d = toy();
        let mut rng = Rng::seed_from(2);
        let m = d.mean_pairwise_distance(200, &mut rng);
        assert!(m > 0.0 && m < 10.0);
    }

    #[test]
    fn positive_fraction() {
        assert!((toy().positive_fraction() - 0.5).abs() < 1e-12);
    }
}
