//! Synthetic surrogates for the paper's six UCI datasets.
//!
//! No network access is available in the build environment, so each UCI
//! dataset is replaced by a generator that matches its sample count,
//! dimensionality and marginal structure, and draws labels from a
//! *nonlinear teacher* — a small random kernel machine — plus label
//! noise calibrated so the achievable accuracy lands in the paper's
//! band. What the paper's Table 1/Figure 2 measure is the *relative*
//! behaviour of exact-kernel SVM vs. linear SVM over random features,
//! which only requires that the Bayes separator be genuinely nonlinear
//! at the given `n` and `d`; see DESIGN.md §3/§5.

use super::Dataset;
use crate::linalg::{normalize, Matrix};
use crate::rng::Rng;

/// Feature marginal families (mirroring the UCI originals' structure).
#[derive(Clone, Debug)]
pub enum Marginal {
    /// iid standard Gaussian (dense continuous features).
    Gaussian,
    /// iid uniform on [-1, 1].
    Uniform,
    /// Positive heavy-tailed (`exp(N(0,1)) / e`) — Spambase-like
    /// frequency features.
    LogNormal,
    /// Integer-coded categorical attributes, one per column, value
    /// uniform in `[0, card)` scaled to `[0, 1]` (Nursery-like).
    Categorical { cards: Vec<usize> },
    /// One-hot encoded categorical blocks; `cards` are the block sizes
    /// and must sum to `d` (Adult-like binary indicators).
    OneHotBlocks { cards: Vec<usize> },
    /// `continuous` Gaussian columns followed by one-hot `blocks`
    /// (Covertype-like).
    Mixed { continuous: usize, blocks: Vec<usize> },
}

impl Marginal {
    /// Sample one example into `row`.
    fn fill(&self, row: &mut [f32], rng: &mut Rng) {
        match self {
            Marginal::Gaussian => {
                for v in row.iter_mut() {
                    *v = rng.normal() as f32;
                }
            }
            Marginal::Uniform => {
                for v in row.iter_mut() {
                    *v = (rng.f64() * 2.0 - 1.0) as f32;
                }
            }
            Marginal::LogNormal => {
                for v in row.iter_mut() {
                    *v = ((rng.normal()).exp() / std::f64::consts::E) as f32;
                }
            }
            Marginal::Categorical { cards } => {
                assert_eq!(cards.len(), row.len());
                for (v, &card) in row.iter_mut().zip(cards) {
                    let k = rng.below(card.max(1) as u64) as f32;
                    *v = if card > 1 { k / (card - 1) as f32 } else { 0.0 };
                }
            }
            Marginal::OneHotBlocks { cards } => {
                assert_eq!(cards.iter().sum::<usize>(), row.len());
                row.fill(0.0);
                let mut off = 0;
                for &card in cards {
                    let k = rng.below(card as u64) as usize;
                    row[off + k] = 1.0;
                    off += card;
                }
            }
            Marginal::Mixed { continuous, blocks } => {
                assert_eq!(continuous + blocks.iter().sum::<usize>(), row.len());
                for v in row[..*continuous].iter_mut() {
                    *v = rng.normal() as f32;
                }
                let tail = &mut row[*continuous..];
                tail.fill(0.0);
                let mut off = 0;
                for &card in blocks {
                    let k = rng.below(card as u64) as usize;
                    tail[off + k] = 1.0;
                    off += card;
                }
            }
        }
    }
}

/// The nonlinear ground-truth concept: a small random kernel machine
/// `sign(Σ_m α_m K_t(s_m, x) − b)` with `b` set to the median score so
/// classes are balanced.
#[derive(Clone, Debug)]
pub enum Teacher {
    /// `K_t(s, x) = (⟨s, x⟩ + 1)^degree`.
    Polynomial { degree: u32, centers: usize },
    /// `K_t(s, x) = exp(−gamma ‖s − x‖²)`.
    Rbf { gamma: f64, centers: usize },
}

impl Teacher {
    fn centers(&self) -> usize {
        match self {
            Teacher::Polynomial { centers, .. } | Teacher::Rbf { centers, .. } => *centers,
        }
    }

    fn eval(&self, s: &[f32], x: &[f32]) -> f64 {
        match self {
            Teacher::Polynomial { degree, .. } => {
                let t = crate::linalg::dot(s, x) as f64;
                (t + 1.0).powi(*degree as i32)
            }
            Teacher::Rbf { gamma, .. } => {
                let d2: f32 = s.iter().zip(x).map(|(a, b)| (a - b) * (a - b)).sum();
                (-gamma * d2 as f64).exp()
            }
        }
    }
}

/// Full description of a synthetic dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    pub n: usize,
    pub d: usize,
    pub marginal: Marginal,
    pub teacher: Teacher,
    /// Label flip probability — the accuracy ceiling is ≈ 1 − noise.
    pub noise: f64,
}

impl SyntheticSpec {
    /// Generate the dataset. Rows are L2-normalized (the paper's
    /// protocol, making `R = 1` in all bounds).
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from(seed);
        let mut x = Matrix::zeros(self.n, self.d);
        for i in 0..self.n {
            self.marginal.fill(x.row_mut(i), &mut rng);
            normalize(x.row_mut(i));
        }

        // Teacher support set: drawn from the same marginal, normalized.
        let m = self.teacher.centers();
        let mut centers = Matrix::zeros(m, self.d);
        let mut alphas = Vec::with_capacity(m);
        for c in 0..m {
            self.marginal.fill(centers.row_mut(c), &mut rng);
            normalize(centers.row_mut(c));
            alphas.push(rng.normal());
        }

        let mut scores: Vec<f64> = (0..self.n)
            .map(|i| {
                (0..m)
                    .map(|c| alphas[c] * self.teacher.eval(centers.row(c), x.row(i)))
                    .sum()
            })
            .collect();

        // Balance classes with the median score as threshold.
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("scores are finite"));
        let thresh = sorted[self.n / 2];

        let y: Vec<f32> = scores
            .iter_mut()
            .map(|s| {
                let mut label = if *s > thresh { 1.0 } else { -1.0 };
                if rng.bernoulli(self.noise) {
                    label = -label;
                }
                label
            })
            .collect();

        Dataset::new(self.name.clone(), x, y).expect("synthetic labels are valid")
    }
}

/// The six surrogates, named after the UCI datasets they stand in for,
/// with the paper's Table 1 sample counts and dimensionalities.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UciSurrogate {
    Nursery,
    Spambase,
    CodRna,
    Adult,
    Ijcnn,
    Covertype,
}

impl UciSurrogate {
    /// All six, in the paper's Table 1 order.
    pub const ALL: [UciSurrogate; 6] = [
        UciSurrogate::Nursery,
        UciSurrogate::Spambase,
        UciSurrogate::CodRna,
        UciSurrogate::Adult,
        UciSurrogate::Ijcnn,
        UciSurrogate::Covertype,
    ];

    /// Parse from a lowercase name.
    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "nursery" => UciSurrogate::Nursery,
            "spambase" => UciSurrogate::Spambase,
            "cod-rna" | "codrna" => UciSurrogate::CodRna,
            "adult" => UciSurrogate::Adult,
            "ijcnn" => UciSurrogate::Ijcnn,
            "covertype" => UciSurrogate::Covertype,
            _ => return None,
        })
    }

    /// The surrogate's generator spec at a given size scale
    /// (`scale = 1.0` reproduces the paper's N; benches default lower).
    pub fn spec(self, scale: f64) -> SyntheticSpec {
        let s = |n: usize| ((n as f64 * scale) as usize).max(200);
        match self {
            UciSurrogate::Nursery => SyntheticSpec {
                name: "nursery".into(),
                n: s(13_000),
                d: 8,
                marginal: Marginal::Categorical { cards: vec![3, 5, 4, 4, 3, 2, 3, 3] },
                teacher: Teacher::Polynomial { degree: 3, centers: 24 },
                noise: 0.002,
            },
            UciSurrogate::Spambase => SyntheticSpec {
                name: "spambase".into(),
                n: s(4_600),
                d: 57,
                marginal: Marginal::LogNormal,
                teacher: Teacher::Polynomial { degree: 3, centers: 32 },
                noise: 0.06,
            },
            UciSurrogate::CodRna => SyntheticSpec {
                name: "cod-rna".into(),
                n: s(60_000),
                d: 8,
                marginal: Marginal::Gaussian,
                teacher: Teacher::Rbf { gamma: 2.0, centers: 32 },
                noise: 0.045,
            },
            UciSurrogate::Adult => SyntheticSpec {
                name: "adult".into(),
                n: s(49_000),
                d: 123,
                marginal: Marginal::OneHotBlocks {
                    // 14 categorical attributes one-hot encoded; block
                    // sizes sum to 123 like the a9a encoding.
                    cards: vec![8, 7, 16, 7, 14, 6, 5, 2, 41, 2, 3, 4, 4, 4],
                },
                teacher: Teacher::Polynomial { degree: 3, centers: 40 },
                noise: 0.155,
            },
            UciSurrogate::Ijcnn => SyntheticSpec {
                name: "ijcnn".into(),
                n: s(141_000),
                d: 22,
                marginal: Marginal::Gaussian,
                teacher: Teacher::Rbf { gamma: 1.5, centers: 40 },
                noise: 0.015,
            },
            UciSurrogate::Covertype => SyntheticSpec {
                name: "covertype".into(),
                n: s(581_000),
                d: 54,
                marginal: Marginal::Mixed { continuous: 10, blocks: vec![4, 40] },
                teacher: Teacher::Rbf { gamma: 1.0, centers: 48 },
                noise: 0.21,
            },
        }
    }

    /// Generate the surrogate dataset.
    pub fn load(self, scale: f64, seed: u64) -> Dataset {
        self.spec(scale).generate(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn onehot_blocks_sum_to_dim() {
        for u in UciSurrogate::ALL {
            let spec = u.spec(0.02);
            match &spec.marginal {
                Marginal::OneHotBlocks { cards } => {
                    assert_eq!(cards.iter().sum::<usize>(), spec.d)
                }
                Marginal::Mixed { continuous, blocks } => {
                    assert_eq!(continuous + blocks.iter().sum::<usize>(), spec.d)
                }
                Marginal::Categorical { cards } => assert_eq!(cards.len(), spec.d),
                _ => {}
            }
        }
    }

    #[test]
    fn generated_shapes_and_normalization() {
        let ds = UciSurrogate::Spambase.load(0.05, 7);
        assert_eq!(ds.dim(), 57);
        assert!(ds.len() >= 200);
        for i in 0..ds.len() {
            let n = crate::linalg::norm2(ds.x().row(i));
            assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
        }
    }

    #[test]
    fn classes_roughly_balanced() {
        for u in [UciSurrogate::Nursery, UciSurrogate::CodRna, UciSurrogate::Adult] {
            let ds = u.load(0.02, 3);
            let frac = ds.positive_fraction();
            assert!((0.35..0.65).contains(&frac), "{}: frac {frac}", ds.name);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = UciSurrogate::Nursery.load(0.02, 11);
        let b = UciSurrogate::Nursery.load(0.02, 11);
        assert_eq!(a.x(), b.x());
        assert_eq!(a.y, b.y);
        let c = UciSurrogate::Nursery.load(0.02, 12);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn from_name_roundtrip() {
        for u in UciSurrogate::ALL {
            let name = u.spec(0.01).name;
            assert_eq!(UciSurrogate::from_name(&name), Some(u));
        }
        assert_eq!(UciSurrogate::from_name("nope"), None);
    }

    #[test]
    fn labels_are_not_linearly_trivial() {
        // Sanity: a linear threshold on a single coordinate should not
        // explain the labels (the teacher is nonlinear).
        let ds = UciSurrogate::CodRna.load(0.01, 5);
        let mut best = 0.0f64;
        for j in 0..ds.dim() {
            for sign in [1.0f32, -1.0] {
                let acc = (0..ds.len())
                    .filter(|&i| {
                        let pred = if sign * ds.x().get(i, j) > 0.0 { 1.0 } else { -1.0 };
                        pred == ds.y[i]
                    })
                    .count() as f64
                    / ds.len() as f64;
                best = best.max(acc);
            }
        }
        assert!(best < 0.8, "single-coordinate rule reaches {best}");
    }
}
