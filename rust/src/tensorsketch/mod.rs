//! TensorSketch (Pham & Pagh, KDD 2013) — the post-paper standard for
//! *polynomial* kernel features, included as the natural modern baseline
//! for the benches.
//!
//! For `K(x, y) = (⟨x, y⟩ + r)^p`: sketch the degree-`p` tensor product
//! with `p` independent Count Sketches composed by FFT-domain
//! multiplication (circular convolution). The offset `r` is handled the
//! usual way, by appending a `√r` coordinate to the input. Unbiased,
//! and typically lower-variance than Random Maclaurin at equal `D` for
//! pure polynomial kernels — but, unlike Random Maclaurin, it does not
//! extend to arbitrary dot product kernels.

use crate::features::{FeatureMap, Scratch};
use crate::linalg::fft::{complex_mul_inplace, fft};
use crate::rng::Rng;

/// A sampled TensorSketch map for `(⟨x, y⟩ + r)^p`.
pub struct TensorSketch {
    degree: u32,
    offset: f64,
    d_in: usize,
    /// Sketch width (output dimension; power of two for the FFT).
    width: usize,
    /// Per-factor hash bucket `h_j[i] ∈ [0, width)`.
    hashes: Vec<Vec<u32>>,
    /// Per-factor sign `s_j[i] ∈ {±1}`.
    signs: Vec<Vec<f32>>,
}

impl TensorSketch {
    /// Sample a sketch. `width` is rounded up to a power of two (the
    /// shared [`crate::linalg::next_pow2`] padding rule of the radix-2
    /// transform family).
    pub fn sample(degree: u32, offset: f64, d: usize, width: usize, rng: &mut Rng) -> Self {
        assert!(degree >= 1 && d > 0 && width > 0);
        let width = crate::linalg::next_pow2(width);
        // The appended sqrt(r) coordinate implements the offset.
        let d_ext = d + usize::from(offset > 0.0);
        let mut hashes = Vec::with_capacity(degree as usize);
        let mut signs = Vec::with_capacity(degree as usize);
        for _ in 0..degree {
            hashes.push((0..d_ext).map(|_| rng.below(width as u64) as u32).collect());
            signs.push((0..d_ext).map(|_| rng.sign() as f32).collect());
        }
        TensorSketch { degree, offset, d_in: d, width, hashes, signs }
    }

    /// Count-sketch one (extended) input under factor `j`.
    fn count_sketch(&self, j: usize, x: &[f32], out_re: &mut [f32]) {
        out_re.fill(0.0);
        let h = &self.hashes[j];
        let s = &self.signs[j];
        for (i, &xi) in x.iter().enumerate() {
            if xi != 0.0 {
                out_re[h[i] as usize] += s[i] * xi;
            }
        }
        self.sketch_offset(j, x.len(), out_re);
    }

    /// Count-sketch one CSR row under factor `j` — the `O(nnz)` loop
    /// the algorithm is famous for: only the stored entries scatter,
    /// visited in the same ascending-index order the dense loop keeps
    /// after its `x[i] != 0` skip, so the sketch is bit-identical.
    fn count_sketch_sparse(&self, j: usize, x: crate::linalg::SparseRow<'_>, out_re: &mut [f32]) {
        out_re.fill(0.0);
        let h = &self.hashes[j];
        let s = &self.signs[j];
        for (&i, &xi) in x.indices.iter().zip(x.values) {
            if xi != 0.0 {
                out_re[h[i as usize] as usize] += s[i as usize] * xi;
            }
        }
        self.sketch_offset(j, x.dim, out_re);
    }

    /// Fold the appended `√r` offset coordinate into a sketch.
    fn sketch_offset(&self, j: usize, d: usize, out_re: &mut [f32]) {
        if self.offset > 0.0 {
            let h = &self.hashes[j];
            let s = &self.signs[j];
            out_re[h[d] as usize] += s[d] * (self.offset as f32).sqrt();
        }
    }

    /// FFT-domain product of the `degree` per-factor sketches, written
    /// into `out`. `sketch(j, buf)` fills `buf` with factor `j`'s count
    /// sketch — the only step that differs between dense and CSR
    /// inputs. The four accumulator buffers (the count-sketch
    /// accumulators and their FFT imaginary halves) live in the
    /// caller's reusable [`Scratch`], so a warm scratch makes the whole
    /// combine allocation-free.
    fn combine_sketches<F: FnMut(usize, &mut [f32])>(
        &self,
        out: &mut [f32],
        scratch: &mut Scratch,
        mut sketch: F,
    ) {
        let n = self.width;
        let (acc_re, acc_im, cur_re, cur_im) = scratch.four(n, n, n, n);
        for j in 0..self.degree as usize {
            sketch(j, cur_re);
            cur_im.fill(0.0);
            fft(cur_re, cur_im, false);
            if j == 0 {
                acc_re.copy_from_slice(cur_re);
                acc_im.copy_from_slice(cur_im);
            } else {
                complex_mul_inplace(acc_re, acc_im, cur_re, cur_im);
            }
        }
        fft(acc_re, acc_im, true);
        out.copy_from_slice(acc_re);
    }
}

impl FeatureMap for TensorSketch {
    fn input_dim(&self) -> usize {
        self.d_in
    }

    fn output_dim(&self) -> usize {
        self.width
    }

    fn transform_into(&self, x: &[f32], out: &mut [f32]) {
        self.transform_into_scratch(x, out, &mut Scratch::new());
    }

    /// Allocation-free hot path: the count-sketch accumulators come
    /// from the caller's reusable [`Scratch`]. Bit-identical to
    /// [`FeatureMap::transform_into`].
    fn transform_into_scratch(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        let _span = crate::obs::span("transform.tensorsketch");
        assert_eq!(x.len(), self.d_in);
        assert_eq!(out.len(), self.width);
        self.combine_sketches(out, scratch, |j, buf| self.count_sketch(j, x, buf));
    }

    /// Sparse fast path: the count sketches scatter only the `nnz`
    /// stored entries (the dense loop's `O(d)` zero scan disappears),
    /// then the identical FFT combine — bit-equal to the dense path.
    fn transform_sparse_into(&self, x: crate::linalg::SparseRow<'_>, out: &mut [f32]) {
        self.transform_sparse_into_scratch(x, out, &mut Scratch::new());
    }

    /// CSR twin of [`FeatureMap::transform_into_scratch`].
    fn transform_sparse_into_scratch(
        &self,
        x: crate::linalg::SparseRow<'_>,
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let _span = crate::obs::span("transform.tensorsketch");
        assert_eq!(x.dim, self.d_in, "input dim mismatch");
        assert_eq!(out.len(), self.width, "output dim mismatch");
        self.combine_sketches(out, scratch, |j, buf| self.count_sketch_sparse(j, x, buf));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::feature_gram;
    use crate::kernels::{gram, mean_abs_gram_error, Polynomial};
    use crate::linalg::{dot, Matrix};

    fn sphere_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        let rows: Vec<Vec<f32>> =
            (0..n).map(|_| crate::prop::gens::unit_vec(&mut rng, d)).collect();
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn unbiased_for_homogeneous_quadratic() {
        let mut rng = Rng::seed_from(1);
        let d = 6;
        let x = crate::prop::gens::unit_vec(&mut Rng::seed_from(2), d);
        let y = crate::prop::gens::unit_vec(&mut Rng::seed_from(3), d);
        let exact = (dot(&x, &y) as f64).powi(2);
        let trials = 300;
        let mut acc = 0.0;
        for _ in 0..trials {
            let ts = TensorSketch::sample(2, 0.0, d, 64, &mut rng);
            acc += dot(&ts.transform(&x), &ts.transform(&y)) as f64;
        }
        let mean = acc / trials as f64;
        assert!((mean - exact).abs() < 0.05, "mean {mean} vs exact {exact}");
    }

    #[test]
    fn approximates_poly_kernel_gram() {
        let mut rng = Rng::seed_from(4);
        let x = sphere_points(40, 8, 5);
        let kernel = Polynomial::new(3, 1.0);
        let ts = TensorSketch::sample(3, 1.0, 8, 1024, &mut rng);
        let exact = gram(&kernel, &x);
        let approx = feature_gram(&ts, &x);
        let err = mean_abs_gram_error(&exact, &approx);
        // (1 + t)^3 <= 8 on the sphere; 1024-wide sketch should be tight.
        assert!(err < 0.35, "tensorsketch gram err {err}");
    }

    #[test]
    fn width_rounds_to_power_of_two() {
        let mut rng = Rng::seed_from(6);
        let ts = TensorSketch::sample(2, 0.0, 4, 100, &mut rng);
        assert_eq!(ts.output_dim(), 128);
    }

    #[test]
    fn sparse_sketch_matches_dense_bitwise() {
        let mut rng = Rng::seed_from(11);
        let d = 17;
        let ts = TensorSketch::sample(3, 1.0, d, 64, &mut rng);
        let mut data_rng = Rng::seed_from(12);
        let mut x = Matrix::zeros(5, d);
        for i in 0..5 {
            for j in 0..d {
                if data_rng.f64() < 0.3 {
                    x.set(i, j, data_rng.f32() - 0.5);
                }
            }
        }
        let sx = crate::linalg::SparseMatrix::from_dense(&x);
        let dense = ts.transform_batch(&x);
        for i in 0..5 {
            let mut got = vec![0.0f32; ts.output_dim()];
            ts.transform_sparse_into(sx.row(i), &mut got);
            assert_eq!(&got[..], dense.row(i), "row {i}");
        }
        for threads in [1usize, 2, 8] {
            assert_eq!(ts.transform_batch_sparse_threads(&sx, threads), dense);
        }
    }

    #[test]
    fn sketch_is_deterministic_given_seed() {
        let x = vec![0.3f32, -0.1, 0.5, 0.2];
        let a = TensorSketch::sample(3, 1.0, 4, 64, &mut Rng::seed_from(9)).transform(&x);
        let b = TensorSketch::sample(3, 1.0, 4, 64, &mut Rng::seed_from(9)).transform(&x);
        assert_eq!(a, b);
    }
}
