//! CLI subcommand implementations.

use super::Args;
use crate::bench::{self, Table};
use crate::config::json::Json;
use crate::config::{ExperimentConfig, KernelSpec};
use crate::coordinator::{
    BackendFactory, Coordinator, CoordinatorConfig, MapArtifactFactory, PjrtTransformFactory,
};
use crate::data::libsvm;
use crate::kernels::{gram, mean_abs_gram_error, DotProductKernel};
use crate::linalg::Matrix;
use crate::features::{feature_gram, FeatureMap};
use crate::maclaurin::{RandomMaclaurin, RmConfig};
use crate::metrics::Stopwatch;
use crate::rng::Rng;
use crate::runtime::Engine;
use crate::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn warn_unknown(args: &Args) {
    for f in args.unknown_flags() {
        eprintln!("warning: unknown flag --{f} ignored");
    }
}

/// Consume `--threads N` and, when given, pin the global data-parallel
/// worker budget (0 / absent keeps auto-detect or `RFDOT_THREADS`).
fn apply_threads(args: &mut Args) -> Result<()> {
    let threads = args.usize_flag("threads", 0)?;
    if threads > 0 {
        crate::parallel::set_max_threads(threads);
    }
    Ok(())
}

/// Consume `--simd scalar|auto` and, when given, pin the global kernel
/// dispatch mode (absent keeps auto-detect or `RFDOT_SIMD`).
fn apply_simd(args: &mut Args) -> Result<()> {
    let simd = args.str_flag("simd", "");
    if !simd.is_empty() {
        crate::simd::set_mode(crate::simd::SimdMode::parse(&simd)?);
    }
    Ok(())
}

/// Consume `--projection dense|structured` (default dense).
fn parse_projection(args: &mut Args) -> Result<crate::structured::ProjectionKind> {
    crate::structured::ProjectionKind::parse(&args.str_flag("projection", "dense"))
}

/// Consume `--trace` and `--trace-out PATH`. Either turns the
/// process-global [`crate::obs`] span flag on (an export path without
/// spans would always be empty); absent, the flag keeps its
/// `RFDOT_TRACE` / config resolution. Returns the export path
/// (empty = no export).
fn apply_trace(args: &mut Args) -> String {
    let trace = args.switch("trace");
    let out = args.str_flag("trace-out", "");
    if trace || !out.is_empty() {
        crate::obs::set_enabled(true);
    }
    out
}

/// Consume `--faults SPEC` and, when given, parse + install the fault
/// plan (absent keeps the lazy `RFDOT_FAULTS` / config resolution). An
/// invalid spec is a config error — a typo'd site must fail loudly,
/// not silently inject nothing.
fn apply_faults(args: &mut Args) -> Result<()> {
    let spec = args.str_flag("faults", "");
    if !spec.is_empty() {
        crate::faults::install_spec(&spec)?;
    }
    Ok(())
}

/// `rfdot info` — engine and artifact inventory.
pub fn info(args: &mut Args) -> Result<()> {
    let dir = args.str_flag("artifact-dir", "artifacts");
    warn_unknown(args);
    println!("rfdot {}", crate::VERSION);
    match Engine::cpu(&dir) {
        Ok(engine) => println!("pjrt platform: {}", engine.platform()),
        Err(e) => println!("pjrt unavailable: {e}"),
    }
    let mut found = false;
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                let size = e.metadata().map(|m| m.len()).unwrap_or(0);
                println!("artifact: {stem} ({size} bytes)");
                found = true;
            }
        }
    }
    if !found {
        println!("no artifacts in {dir}/ — run `make artifacts`");
    }
    Ok(())
}

/// `rfdot quickstart` — map a toy dataset, check gram error (dense and
/// structured projections side by side), fit LIN.
pub fn quickstart(args: &mut Args) -> Result<()> {
    apply_threads(args)?;
    apply_simd(args)?;
    warn_unknown(args);
    println!("== Random Maclaurin quickstart ==");
    let kernel = crate::kernels::Polynomial::new(10, 1.0);
    let (d, n_feat, n_pts) = (16usize, 512usize, 60usize);
    let mut rng = Rng::seed_from(42);
    let mut rows = Vec::new();
    for _ in 0..n_pts {
        rows.push(crate::prop::gens::unit_vec(&mut rng, d));
    }
    let x = Matrix::from_rows(&rows)?;
    let exact = gram(&kernel, &x);
    println!("kernel {} on {n_pts} unit vectors, D = {n_feat}", kernel.name());
    for kind in
        [crate::structured::ProjectionKind::Dense, crate::structured::ProjectionKind::Structured]
    {
        let map = RandomMaclaurin::sample(
            &kernel,
            d,
            n_feat,
            RmConfig::default().with_projection(kind),
            &mut rng,
        );
        let approx = feature_gram(&map, &x);
        let err = mean_abs_gram_error(&exact, &approx);
        println!(
            "{:>10} projection: mean |<Z(x),Z(y)> - K(x,y)| = {err:.4}  (K up to {:.0})",
            kind.as_str(),
            kernel.f(1.0)
        );
    }
    println!("(paper Fig 1b: error decays ~ 1/sqrt(D); try --features via gram-error)");
    Ok(())
}

/// `rfdot gram-error` — one Figure-1 measurement. `--sparse` routes the
/// feature transforms through the CSR fast paths (identical numbers by
/// the sparse parity contract; the knob exercises the pipeline and lets
/// `--sparse`/dense timings be compared on one command).
pub fn gram_error(args: &mut Args) -> Result<()> {
    let kernel_spec = KernelSpec::parse(&args.str_flag("kernel", "poly:10:1"))?;
    let d = args.usize_flag("d", 16)?;
    let n_feat = args.usize_flag("features", 512)?;
    let n_pts = args.usize_flag("points", 100)?;
    let runs = args.usize_flag("runs", 5)?;
    let h01 = args.switch("h01");
    let seed = args.num_flag("seed", 7.0)? as u64;
    let projection = parse_projection(args)?;
    let sparse = args.switch("sparse");
    apply_threads(args)?;
    apply_simd(args)?;
    warn_unknown(args);

    let kernel = kernel_spec.build(1.0);
    let mut rng = Rng::seed_from(seed);
    let mut rows = Vec::new();
    for _ in 0..n_pts {
        rows.push(crate::prop::gens::unit_vec(&mut rng, d));
    }
    let x = Matrix::from_rows(&rows)?;
    let sx = sparse.then(|| crate::linalg::SparseMatrix::from_dense(&x));
    let exact = gram(kernel.as_ref(), &x);
    let mut errs = Vec::new();
    for _ in 0..runs {
        let map = RandomMaclaurin::sample(
            kernel.as_ref(),
            d,
            n_feat,
            RmConfig::default().with_h01(h01).with_projection(projection),
            &mut rng,
        );
        let approx = match &sx {
            Some(sx) => crate::features::feature_gram_sparse(&map, sx),
            None => feature_gram(&map, &x),
        };
        errs.push(mean_abs_gram_error(&exact, &approx));
    }
    println!(
        "kernel={} d={d} D={n_feat} h01={h01} projection={} storage={} runs={runs}: err = {:.5} ± {:.5}",
        kernel.name(),
        projection.as_str(),
        if sparse { "sparse" } else { "dense" },
        crate::linalg::mean(&errs),
        crate::linalg::stddev(&errs),
    );
    Ok(())
}

/// `rfdot table1-row` — one row of Table 1.
pub fn table1_row(args: &mut Args) -> Result<()> {
    let mut config = ExperimentConfig {
        dataset: args.str_flag("dataset", "nursery"),
        kernel: KernelSpec::parse(&args.str_flag("kernel", "poly:10:1"))?,
        scale: args.num_flag("scale", 0.1)?,
        c: args.num_flag("c", 1.0)?,
        seed: args.num_flag("seed", 42.0)? as u64,
        threads: args.usize_flag("threads", 0)?,
        projection: parse_projection(args)?,
        sparse: args.switch("sparse"),
        ..Default::default()
    };
    let d_rf = args.usize_flag("features", 500)?;
    let d_h01 = args.usize_flag("h01-features", 100)?;
    config.n_features = d_rf;
    config.validate()?;
    apply_simd(args)?;
    warn_unknown(args);

    let row = bench::run_row(&config, d_rf, d_h01)?;
    print_rows(&[row]);
    Ok(())
}

/// Render RowResults in the paper's Table 1 shape.
pub fn print_rows(rows: &[bench::RowResult]) {
    let mut t = Table::new(&[
        "dataset", "N(train/test)", "d", "variant", "acc", "trn", "tst", "speedup(trn/tst)",
        "size",
    ]);
    for row in rows {
        for cell in [&row.exact, &row.rf, &row.h01] {
            let (strn, stst) = row.speedup(cell);
            t.row(&[
                row.dataset.clone(),
                format!("{}/{}", row.n_train, row.n_test),
                format!("{}", row.d),
                cell.label.clone(),
                format!("{:.2}%", cell.accuracy * 100.0),
                bench::fmt_duration(cell.train_s),
                bench::fmt_duration(cell.test_s),
                if cell.label == "K+SMO" {
                    "-".into()
                } else {
                    format!("{strn:.1}x/{stst:.1}x")
                },
                format!("{}", cell.size),
            ]);
        }
    }
    t.print();
}

/// `rfdot report` — run the reproduction grid and regenerate
/// `REPORT.md` / `REPORT.json` / `report/*.svg` (see [`crate::report`]).
/// `--quick` runs the CI-sized slice; interrupted runs resume from the
/// JSON run-log unless `--fresh`; `--config FILE` loads a `"report"`
/// section with grid overrides.
pub fn report(args: &mut Args) -> Result<()> {
    let config_path = args.str_flag("config", "");
    let quick = args.switch("quick");
    let mut config = if !config_path.is_empty() {
        // The file's "quick" field picks the baseline its overrides sit
        // on; a --quick flag on top cannot be honored faithfully (we
        // cannot tell which axes the file meant to pin), so reject the
        // combination instead of silently running the wrong grid.
        if quick {
            return Err(crate::Error::Config(
                "--quick conflicts with --config; set \"quick\": true inside the config file"
                    .into(),
            ));
        }
        crate::config::ReportConfig::load(&config_path)?
    } else if quick {
        crate::config::ReportConfig::quick()
    } else {
        crate::config::ReportConfig::full()
    };
    config.seed = args.num_flag("seed", config.seed as f64)? as u64;
    config.out_dir = args.str_flag("out-dir", &config.out_dir);
    if args.switch("fresh") {
        config.resume = false;
    }
    apply_threads(args)?;
    apply_simd(args)?;
    warn_unknown(args);

    let sw = Stopwatch::start();
    let report = crate::report::run(&config)?;
    let ok = report
        .cells
        .iter()
        .filter(|c| matches!(c.status, crate::report::CellStatus::Ok(_)))
        .count();
    println!(
        "report: {} cells ({} ok, {} skipped), {} accuracy rows, {} thread points, \
         {} serving points in {}",
        report.cells.len(),
        ok,
        report.cells.len() - ok,
        report.accuracy.len(),
        report.threads.len(),
        report.serving.len(),
        bench::fmt_duration(sw.elapsed_secs()),
    );
    println!(
        "wrote {dir}/REPORT.md, {dir}/REPORT.json and {dir}/report/*.svg",
        dir = config.out_dir
    );
    Ok(())
}

/// `rfdot transform` — featurize a LIBSVM file.
pub fn transform(args: &mut Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.str_flag("output", "-");
    let kernel_spec = KernelSpec::parse(&args.str_flag("kernel", "poly:10:1"))?;
    let n_feat = args.usize_flag("features", 256)?;
    let h01 = args.switch("h01");
    let seed = args.num_flag("seed", 7.0)? as u64;
    let projection = parse_projection(args)?;
    // --recycle: structured blocks draw from one shared randomness pool
    // (smaller serialized state; default off keeps numerics bit-identical).
    let recycle = args.switch("recycle");
    apply_threads(args)?;
    apply_simd(args)?;
    warn_unknown(args);

    // parse_file yields CSR storage, so the batch transform below runs
    // the O(D·nnz) sparse fast path automatically.
    let mut ds = libsvm::parse_file(&input, None)?;
    ds.normalize_rows();
    let kernel = kernel_spec.build(1.0);
    let mut rng = Rng::seed_from(seed);
    let map = RandomMaclaurin::sample(
        kernel.as_ref(),
        ds.dim(),
        n_feat,
        RmConfig::default().with_h01(h01).with_projection(projection).with_recycle(recycle),
        &mut rng,
    );
    let sw = Stopwatch::start();
    let z = crate::features::transform_dataset(&map, &ds);
    let dt = sw.elapsed_secs();
    let out_ds = crate::data::Dataset::new(ds.name.clone(), z, ds.y.clone())?;
    let text = libsvm::to_string(&out_ds);
    if output == "-" {
        print!("{text}");
    } else {
        std::fs::write(&output, text)?;
    }
    eprintln!(
        "transformed {} x {} -> {} features in {} ({:.0} vec/s)",
        ds.len(),
        ds.dim(),
        map.output_dim(),
        bench::fmt_duration(dt),
        ds.len() as f64 / dt.max(1e-9),
    );
    Ok(())
}

/// `rfdot serve` — run the coordinator under a synthetic client load and
/// report throughput/latency (the serving demo).
pub fn serve(args: &mut Args) -> Result<()> {
    let artifact = args.str_flag("artifact", "transform_serve");
    let dir = args.str_flag("artifact-dir", "artifacts");
    let requests = args.usize_flag("requests", 2000)?;
    let clients = args.usize_flag("clients", 4)?.max(1);
    let native = args.switch("native");
    let workers = args.usize_flag("workers", 2)?;
    // Batch-queue shards (0 = one per worker; 1 = the shared-queue
    // baseline topology). Workers steal across shards when theirs runs
    // dry; the per-shard summary below shows the steal counts.
    let shards = args.usize_flag("shards", 0)?;
    let max_batch = args.usize_flag("max-batch", 256)?;
    let max_wait_ms = args.num_flag("max-wait-ms", 2.0)?;
    let seed = args.num_flag("seed", 7.0)? as u64;
    let projection = parse_projection(args)?;
    // Clients send CSR (index, value) pairs via `submit_sparse` — the
    // LIBSVM-shaped wire format — instead of dense vectors.
    let sparse = args.switch("sparse");
    // --recycle: structured blocks share one randomness pool (native
    // engine only; affects map sampling, not serving semantics).
    let recycle = args.switch("recycle");
    // For serving, --threads means intra-op threads per worker batch
    // (the native backend's data-parallel fan-out).
    let intra_op_threads = args.usize_flag("threads", 1)?;
    // Network front-end knobs (active only with --listen).
    let listen = args.str_flag("listen", "");
    let models_spec = args.str_flag("models", "");
    let heartbeat_ms = args.num_flag("heartbeat-ms", 2000.0)? as u64;
    let max_missed = args.usize_flag("max-missed", 3)? as u32;
    let write_queue = args.usize_flag("write-queue", 256)?;
    let conns = args.usize_flag("conns", 0)?;
    // Robustness knobs: per-request deadline (0 = off), load-shed
    // in-flight threshold (0 = off), and a fault-injection spec.
    let deadline_ms = args.num_flag("deadline-ms", 0.0)? as u64;
    let shed = args.usize_flag("shed", 0)?;
    apply_faults(args)?;
    apply_simd(args)?;
    let trace_out = apply_trace(args);
    warn_unknown(args);

    if !listen.is_empty() {
        return serve_listen(ListenParams {
            listen,
            models_spec,
            heartbeat_ms,
            max_missed,
            write_queue,
            conns,
            deadline_ms,
            shed,
            workers,
            shards,
            max_batch,
            max_wait_ms,
            intra_op_threads,
            seed,
            projection,
            recycle,
            trace_out,
        });
    }

    if projection == crate::structured::ProjectionKind::Structured && !native {
        return Err(crate::Error::Config(
            "--projection structured is served natively (PJRT transform artifacts consume \
             dense Ω tensors); add --native"
                .into(),
        ));
    }

    // Kernel + map for the serving workload (d is fixed by the artifact).
    let kernel = crate::kernels::Exponential::new(1.0);
    let mut rng = Rng::seed_from(seed);

    let (factory, d): (Arc<dyn BackendFactory>, usize) = if native {
        let d = 22;
        let map = RandomMaclaurin::sample(
            &kernel,
            d,
            512,
            RmConfig::default()
                .with_max_order(8)
                .with_projection(projection)
                .with_recycle(recycle),
            &mut rng,
        );
        // Serve through the zero-copy artifact: every worker borrows
        // one shared read-only weight region instead of re-owning the
        // map (bit-identical replies — see `rust/tests/artifact_shared.rs`).
        let artifact = Arc::new(crate::artifact::MapArtifact::from_map(&map)?);
        (Arc::new(MapArtifactFactory::new(artifact)?), d)
    } else {
        // Probe the manifest (no PJRT) for the shapes, then hand the
        // factory to the coordinator: each worker compiles its own
        // executable.
        let meta = crate::runtime::ArtifactMeta::parse(&std::fs::read_to_string(
            std::path::Path::new(&dir).join(format!("{artifact}.json")),
        )?)?;
        let d = meta.inputs[0].shape[1];
        let n_max = meta.inputs[1].shape[0] as u32;
        let features = meta.inputs[1].shape[2];
        let map = RandomMaclaurin::sample(
            &kernel,
            d,
            features,
            RmConfig::default().with_max_order(n_max),
            &mut rng,
        );
        (Arc::new(PjrtTransformFactory::new(&dir, &artifact, Arc::new(map))?), d)
    };

    let coord = Arc::new(Coordinator::start(
        factory,
        CoordinatorConfig {
            max_batch,
            max_wait: Duration::from_micros((max_wait_ms * 1000.0) as u64),
            queue_depth: 8192,
            workers,
            intra_op_threads,
            shards,
        },
    ));

    println!(
        "{}",
        serve_config_line(
            if native { "native" } else { "pjrt" },
            workers,
            shards,
            max_batch,
            intra_op_threads,
            sparse,
            !trace_out.is_empty() || crate::obs::enabled(),
        )
    );
    println!("serving {requests} requests from {clients} clients");

    // Periodic progress: a monitor thread prints one interval-gated
    // stats line per second while the clients run (sub-second runs stay
    // quiet; the final summary below prints regardless).
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let monitor = {
        let coord = coord.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut last = std::time::Instant::now();
            while !stop.load(Ordering::Relaxed) {
                std::thread::park_timeout(Duration::from_millis(100));
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                if last.elapsed() >= Duration::from_secs(1) {
                    println!("stats: {}", coord.stats().summary());
                    last = std::time::Instant::now();
                }
            }
        })
    };

    let sw = Stopwatch::start();
    let per_client = requests / clients;
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from(1000 + c as u64);
            let mut ok = 0usize;
            let mut rejected = 0usize;
            for _ in 0..per_client {
                let submitted = if sparse {
                    // ~1/8 density synthetic payload: ascending indices,
                    // the LIBSVM-shaped wire format.
                    let indices: Vec<u32> = (0..d as u32).step_by(8).collect();
                    let values: Vec<f32> =
                        indices.iter().map(|_| rng.f32() - 0.5).collect();
                    coord.submit_sparse(indices, values)
                } else {
                    coord.submit((0..d).map(|_| rng.f32() - 0.5).collect())
                };
                match submitted {
                    Ok(t) => {
                        if t.wait().is_ok() {
                            ok += 1;
                        }
                    }
                    Err(_) => rejected += 1,
                }
            }
            (ok, rejected)
        }));
    }
    let mut total_ok = 0;
    let mut total_rej = 0;
    for h in handles {
        let (ok, rej) = h.join().expect("client thread");
        total_ok += ok;
        total_rej += rej;
    }
    let dt = sw.elapsed_secs();
    stop.store(true, Ordering::Relaxed);
    monitor.thread().unpark();
    monitor.join().expect("monitor thread");
    let stats = coord.stats();
    println!("completed {total_ok} ok, {total_rej} rejected in {}", bench::fmt_duration(dt));
    println!("throughput: {:.0} req/s", total_ok as f64 / dt.max(1e-9));
    println!("stats: {}", stats.summary());
    // Per-shard view: where batches landed, who stole what, and true
    // nearest-rank latency percentiles per shard.
    for s in coord.shard_snapshots() {
        println!(
            "shard {}: batches={} items={} steals={} lat p50={:.0}us p90={:.0}us max={:.0}us (n={})",
            s.shard,
            s.batches,
            s.items,
            s.steals,
            s.latency_us.p50,
            s.latency_us.p90,
            s.latency_us.max,
            s.latency_us.n,
        );
    }
    assert_eq!(total_ok as u64, stats.completed.load(Ordering::Relaxed));
    // Merged latency histogram across shards: the estimated tail
    // quantiles the per-shard lines cannot show (each shard only sees
    // its own jobs).
    let merged = coord.merged_latency();
    if !merged.is_empty() {
        let s = merged.summary();
        println!(
            "latency (all shards): p50={:.0}us p90={:.0}us max={:.0}us (n={})",
            s.p50, s.p90, s.max, s.n
        );
    }
    if !trace_out.is_empty() {
        let doc = crate::obs::trace::chrome_trace(&crate::obs::trace::drain());
        std::fs::write(&trace_out, doc.pretty())?;
        let check = crate::obs::trace::check_balanced(&doc)?;
        println!(
            "wrote {trace_out}: {} trace events ({} spans, {} threads)",
            check.events, check.spans, check.threads
        );
    }
    Ok(())
}

/// The consolidated `rfdot serve` startup line: every knob shaping the
/// run in one stable `key=value` row (split out so the format is
/// testable). `shards == 0` prints the resolved work-stealing default
/// (one shard per worker).
fn serve_config_line(
    backend: &str,
    workers: usize,
    shards: usize,
    max_batch: usize,
    intra_op_threads: usize,
    sparse: bool,
    trace: bool,
) -> String {
    let eff_shards = if shards == 0 { workers.max(1) } else { shards };
    format!(
        "serve config: backend={backend} workers={workers} shards={eff_shards} \
         max_batch={max_batch} threads={intra_op_threads} payload={} simd={} trace={}",
        if sparse { "sparse" } else { "dense" },
        crate::simd::selected().as_str(),
        if trace { "on" } else { "off" },
    )
}

/// Everything `rfdot serve --listen` needs, carved off the flag soup.
struct ListenParams {
    listen: String,
    models_spec: String,
    heartbeat_ms: u64,
    max_missed: u32,
    write_queue: usize,
    conns: usize,
    deadline_ms: u64,
    shed: usize,
    workers: usize,
    shards: usize,
    max_batch: usize,
    max_wait_ms: f64,
    intra_op_threads: usize,
    seed: u64,
    projection: crate::structured::ProjectionKind,
    recycle: bool,
    trace_out: String,
}

/// `rfdot serve --listen ADDR` — the multi-tenant TCP front-end: a
/// model registry (one coordinator per named model, hot-swappable)
/// behind the RFNP wire protocol. Prints a parseable
/// `listening on <addr>` line, then blocks until shutdown (or until
/// `--conns N` connections have come and gone), and exits with the
/// consolidated front-end + per-model stats lines.
fn serve_listen(p: ListenParams) -> Result<()> {
    let coord_config = CoordinatorConfig {
        max_batch: p.max_batch,
        max_wait: Duration::from_micros((p.max_wait_ms * 1000.0) as u64),
        queue_depth: 8192,
        workers: p.workers,
        intra_op_threads: p.intra_op_threads,
        shards: p.shards,
    };
    let registry = Arc::new(crate::net::Registry::new(coord_config));
    if p.models_spec.is_empty() {
        // Default tenant: the same synthetic model as the native demo
        // path, served through its zero-copy artifact.
        let kernel = crate::kernels::Exponential::new(1.0);
        let mut rng = Rng::seed_from(p.seed);
        let map = RandomMaclaurin::sample(
            &kernel,
            22,
            512,
            RmConfig::default()
                .with_max_order(8)
                .with_projection(p.projection)
                .with_recycle(p.recycle),
            &mut rng,
        );
        let artifact = Arc::new(crate::artifact::MapArtifact::from_map(&map)?);
        registry.insert("default", artifact)?;
    } else {
        for part in p.models_spec.split(',') {
            let (name, path) = part.split_once('=').ok_or_else(|| {
                crate::Error::Config(format!(
                    "--models entries are name=path.rfdm, got {part:?}"
                ))
            })?;
            let artifact =
                Arc::new(crate::artifact::MapArtifact::load(std::path::Path::new(path.trim()))?);
            registry.insert(name.trim(), artifact)?;
        }
    }

    let net_config = crate::net::NetConfig {
        listen: p.listen.clone(),
        heartbeat: Duration::from_millis(p.heartbeat_ms.max(1)),
        max_missed: p.max_missed.max(1),
        write_queue: p.write_queue.max(1),
        write_timeout: Duration::from_secs(10),
        max_conns: p.conns,
        request_deadline: Duration::from_millis(p.deadline_ms),
        shed_inflight: p.shed,
        ..crate::net::NetConfig::default()
    };
    let mut server = crate::net::NetServer::start(registry.clone(), net_config)?;
    let names: Vec<String> = registry.list().into_iter().map(|m| m.name).collect();
    println!(
        "listening on {} ({} models: {})",
        server.local_addr(),
        names.len(),
        names.join(",")
    );
    if p.conns > 0 {
        println!("exiting after {} connections", p.conns);
    }
    server.wait();

    // Consolidated stats: front-end counters, then the per-model
    // request/latency breakdown (same numbers as `MetricsSnapshot`).
    println!(
        "net: connections_total={} frames={} frames_sent={} rejects={} reaped={} bad_frames={} \
         shed={} deadline_exceeded={} retired={} pending_retires={} stuck_retires={} faults={}",
        crate::obs::counter("net.connections_total").get(),
        crate::obs::counter("net.frames").get(),
        crate::obs::counter("net.frames_sent").get(),
        crate::obs::counter("net.reject").get(),
        crate::obs::counter("net.reaped").get(),
        crate::obs::counter("net.bad_frames").get(),
        crate::obs::counter("net.shed").get(),
        crate::obs::counter("net.deadline_exceeded").get(),
        crate::obs::counter("net.registry.retired").get(),
        crate::obs::gauge("net.registry.pending_retires").get(),
        crate::obs::counter("net.registry.stuck_retires").get(),
        crate::obs::counter("faults.injected").get(),
    );
    for m in registry.model_stats() {
        println!("{}", model_stats_line(&m));
    }
    server.shutdown();
    drop(server);
    registry.shutdown();

    if !p.trace_out.is_empty() {
        let doc = crate::obs::trace::chrome_trace(&crate::obs::trace::drain());
        std::fs::write(&p.trace_out, doc.pretty())?;
        let check = crate::obs::trace::check_balanced(&doc)?;
        println!(
            "wrote {}: {} trace events ({} spans, {} threads)",
            p.trace_out, check.events, check.spans, check.threads
        );
    }
    Ok(())
}

/// One per-model line of the consolidated serve stats: request count,
/// swap count and the latency summary of `net.model.<name>.latency_us`
/// (split out so the format is testable).
fn model_stats_line(m: &crate::net::ModelStats) -> String {
    format!(
        "model {}: v{} requests={} swaps={} lat p50={:.0}us p90={:.0}us max={:.0}us (n={})",
        m.name,
        m.version,
        m.requests,
        m.swaps,
        m.latency_us.p50,
        m.latency_us.p90,
        m.latency_us.max,
        m.latency_us.n,
    )
}

/// `rfdot net-client` — exercise a running RFNP server: ping, model
/// discovery, interleaved dense + sparse requests with a client-side
/// bitwise dense/sparse parity check, and (with `--malformed`) crafted
/// bad frames that must come back as named error frames.
pub fn net_client(args: &mut Args) -> Result<()> {
    let connect = args.require("connect")?;
    let requests = args.usize_flag("requests", 8)?.max(1);
    let model_flag = args.str_flag("model", "");
    let malformed = args.switch("malformed");
    let seed = args.num_flag("seed", 42.0)? as u64;
    // Survival knobs: one deadline for connect/read/write, and how
    // many times a retryable server rejection (backpressure, shed,
    // deadline) is retried with backoff before giving up.
    let timeout_ms = args.num_flag("timeout-ms", 10_000.0)? as u64;
    let retries = args.usize_flag("retries", 0)? as u32;
    warn_unknown(args);

    let client_config = crate::net::ClientConfig::default()
        .with_timeout(Duration::from_millis(timeout_ms.max(1)))
        .with_retries(retries);
    let mut client =
        crate::net::NetClient::connect_with(connect.as_str(), client_config)?;
    client.ping()?;
    let models = client.list_models()?;
    if models.is_empty() {
        return Err(crate::Error::Runtime("server lists no models".into()));
    }
    let entry = if model_flag.is_empty() {
        models[0].clone()
    } else {
        models
            .iter()
            .find(|m| m.name == model_flag)
            .cloned()
            .ok_or_else(|| {
                crate::Error::Config(format!(
                    "model {model_flag:?} not served; available: {}",
                    models.iter().map(|m| m.name.as_str()).collect::<Vec<_>>().join(",")
                ))
            })?
    };
    let d = entry.input_dim as usize;
    let mut rng = Rng::seed_from(seed);
    for _ in 0..requests {
        // A sparse row and its densified twin must produce bitwise
        // identical replies (the coordinator's CSR parity contract,
        // observed end to end over the wire).
        let indices: Vec<u32> = (0..d as u32).step_by(2).collect();
        let values: Vec<f32> = indices.iter().map(|_| rng.f32() - 0.5).collect();
        let mut dense_x = vec![0.0f32; d];
        for (&i, &v) in indices.iter().zip(values.iter()) {
            dense_x[i as usize] = v;
        }
        let dense = client.transform(&entry.name, &dense_x)?;
        if dense.len() != entry.output_dim as usize {
            return Err(crate::Error::Runtime(format!(
                "reply dim {} does not match advertised output dim {}",
                dense.len(),
                entry.output_dim
            )));
        }
        let sparse = client.transform_sparse(&entry.name, &indices, &values)?;
        if sparse.len() != dense.len()
            || sparse.iter().zip(dense.iter()).any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(crate::Error::Runtime(
                "sparse reply differs bitwise from the dense reply".into(),
            ));
        }
    }
    client.heartbeat()?;
    if malformed {
        probe_malformed(&connect)?;
    }
    println!(
        "net-client: ping ok, {} models, {requests} dense/sparse pairs bitwise-equal{}",
        models.len(),
        if malformed { ", malformed frames rejected" } else { "" }
    );
    Ok(())
}

/// Two deliberately broken connections (bad magic; oversized length
/// claim): each must be answered with a named protocol error frame and
/// a close — never a hang or an allocation. Uses exactly two extra
/// connections (CI's `--conns` budget counts on it).
fn probe_malformed(addr: &str) -> Result<()> {
    use crate::net::protocol::{encode_header, FrameType, HEADER_LEN, MAGIC, VERSION};
    // Bad magic: fatal framing error.
    let mut bad_magic = [0u8; HEADER_LEN];
    bad_magic[..4].copy_from_slice(b"XXXX");
    bad_magic[4] = VERSION;
    bad_magic[5] = FrameType::Ping.as_u8();
    expect_error_then_close(addr, &bad_magic, "magic")?;
    // Oversized length: the allocation-bomb guard.
    let mut bomb = encode_header(FrameType::Dense, 0);
    debug_assert_eq!(bomb[..4], MAGIC);
    bomb[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    expect_error_then_close(addr, &bomb, "length")?;
    Ok(())
}

/// Open a fresh connection, send `bytes`, and require a protocol error
/// frame whose message contains `needle`, followed by EOF.
fn expect_error_then_close(addr: &str, bytes: &[u8], needle: &str) -> Result<()> {
    use crate::net::protocol::Frame;
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)
        .map_err(|e| crate::Error::Runtime(format!("connect: {e}")))?;
    let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
    s.write_all(bytes)
        .map_err(|e| crate::Error::Runtime(format!("send malformed frame: {e}")))?;
    let mut header = [0u8; crate::net::protocol::HEADER_LEN];
    s.read_exact(&mut header)
        .map_err(|e| crate::Error::Runtime(format!("read error-frame header: {e}")))?;
    let (ty, len) = crate::net::protocol::decode_header(&header)
        .map_err(|e| crate::Error::Runtime(format!("server sent unframeable bytes: {e}")))?;
    let mut payload = vec![0u8; len as usize];
    s.read_exact(&mut payload)
        .map_err(|e| crate::Error::Runtime(format!("read error-frame payload: {e}")))?;
    match crate::net::protocol::decode_payload(ty, &payload).map_err(|e| e.to_error())? {
        Frame::Error(e) if e.message.contains(needle) => {}
        Frame::Error(e) => {
            return Err(crate::Error::Runtime(format!(
                "error frame does not name {needle:?}: {}",
                e.message
            )))
        }
        f => {
            return Err(crate::Error::Runtime(format!(
                "expected error frame, got {:?}",
                f.frame_type()
            )))
        }
    }
    // The connection must be closed after a fatal framing error.
    let mut probe = [0u8; 1];
    match s.read(&mut probe) {
        Ok(0) => Ok(()),
        Ok(_) => Err(crate::Error::Runtime(
            "connection still open after fatal framing error".into(),
        )),
        Err(e) => Err(crate::Error::Runtime(format!(
            "connection not cleanly closed after fatal framing error: {e}"
        ))),
    }
}

/// A human label for an array element in a bench JSON file, derived
/// from its identity fields (`{"map": "fourier", "threads": 4, ...}`),
/// so a regression report reads `samples[map=fourier,threads=4]`
/// instead of `samples[7]`.
fn bench_elem_label(v: &Json) -> Option<String> {
    let mut parts = Vec::new();
    for k in
        ["map", "kernel", "simd", "n", "threads", "workers", "shards", "batch", "sparsity", "clients"]
    {
        match v.get(k) {
            Some(Json::Str(s)) => parts.push(format!("{k}={s}")),
            Some(Json::Num(n)) => parts.push(format!("{k}={n}")),
            _ => {}
        }
    }
    if parts.is_empty() {
        None
    } else {
        Some(parts.join(","))
    }
}

/// Count the *measured* timing leaves of one bench document: numeric
/// `*secs*` keys with a positive value (nulls are pending). The gate
/// uses this to refuse to pass when the old baseline had measurements
/// but none survived the structural pairing (a renamed section would
/// otherwise fail open).
fn count_measured_secs(v: &Json) -> usize {
    match v {
        Json::Obj(m) => m
            .iter()
            .map(|(k, v)| {
                if k.contains("secs") {
                    usize::from(matches!(v.as_f64(), Some(x) if x > 0.0))
                } else {
                    count_measured_secs(v)
                }
            })
            .sum(),
        Json::Arr(a) => a.iter().map(count_measured_secs).sum(),
        _ => 0,
    }
}

/// Walk two bench JSON documents in parallel and collect every numeric
/// timing leaf present in both — keys containing `secs` (the
/// seconds-per-op convention of every `BENCH_*.json` schema), where
/// larger means slower. Null leaves (pending baselines not yet measured
/// in this environment) and leaves without a counterpart are never
/// compared; their paths land in `skipped` so the report can list
/// exactly what the gate did not cover.
fn collect_bench_timings(
    path: &str,
    old: &Json,
    new: &Json,
    out: &mut Vec<(String, f64, f64)>,
    skipped: &mut Vec<String>,
) {
    match (old, new) {
        (Json::Obj(a), Json::Obj(b)) => {
            for (k, va) in a {
                let p = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                let vb = match b.get(k) {
                    Some(vb) => vb,
                    None => {
                        // A timing key that vanished from the new file
                        // must at least be visible as skipped — silence
                        // here would let a renamed/dropped metric fail
                        // the gate open.
                        if k.contains("secs") {
                            skipped.push(p);
                        }
                        continue;
                    }
                };
                if k.contains("secs") {
                    match (va.as_f64(), vb.as_f64()) {
                        (Some(x), Some(y)) if x > 0.0 => out.push((p, x, y)),
                        _ => skipped.push(p),
                    }
                } else {
                    collect_bench_timings(&p, va, vb, out, skipped);
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            // Pair elements by their identity fields, not position:
            // inserting or reordering sweep rows must not cross-wire
            // the comparison. When labels do not uniquely key the rows
            // (a collision in either file), identity pairing would
            // silently collapse rows, so fall back to index pairing for
            // the whole array. Rows without a counterpart count as
            // skipped.
            let labels_unique_within = |xs: &[Json]| {
                let mut seen = std::collections::BTreeSet::new();
                xs.iter().filter_map(bench_elem_label).all(|l| seen.insert(l))
            };
            let unique = labels_unique_within(a) && labels_unique_within(b);
            let by_label: std::collections::BTreeMap<String, &Json> =
                b.iter().filter_map(|v| bench_elem_label(v).map(|l| (l, v))).collect();
            for (i, va) in a.iter().enumerate() {
                match bench_elem_label(va).filter(|_| unique) {
                    Some(label) => match by_label.get(&label) {
                        Some(vb) => {
                            collect_bench_timings(&format!("{path}[{label}]"), va, vb, out, skipped)
                        }
                        None => skipped.push(format!("{path}[{label}]")),
                    },
                    None => match b.get(i) {
                        Some(vb) => {
                            collect_bench_timings(&format!("{path}[{i}]"), va, vb, out, skipped)
                        }
                        None => skipped.push(format!("{path}[{i}]")),
                    },
                }
            }
        }
        _ => {}
    }
}

/// `rfdot bench-diff <old.json> <new.json> [--max-regress PCT]` — the
/// CI regression gate over any two bench baseline files
/// (`BENCH_parallel/structured/sparse/serve.json`): compares every
/// timing metric the two files share and exits nonzero when any slowed
/// down by more than `--max-regress` percent (default 5). Unmeasured
/// (`null`) leaves — committed pending baselines — compare clean, so
/// the gate can be wired up before the first measured run. When the
/// two files record different top-level `simd` axes, the diff is
/// reported but never gates — the delta measures the kernel-path
/// change, not a regression.
pub fn bench_diff(args: &mut Args) -> Result<()> {
    let usage = "rfdot bench-diff <old.json> <new.json> [--max-regress PCT]";
    let old_path = args.require_positional(0, usage)?;
    let new_path = args.require_positional(1, usage)?;
    let max_regress = args.num_flag("max-regress", 5.0)?;
    warn_unknown(args);
    if max_regress < 0.0 {
        return Err(crate::Error::Config("--max-regress must be >= 0".into()));
    }
    let old = Json::parse(&std::fs::read_to_string(&old_path)?)?;
    let new = Json::parse(&std::fs::read_to_string(&new_path)?)?;
    // Two runs recorded on different kernel-dispatch paths (the
    // top-level "simd" axis) measure the path change, not a code
    // regression — the diff is still printed for inspection, but the
    // gate reports instead of failing.
    let simd_axis = |v: &Json| v.get("simd").and_then(Json::as_str).map(str::to_string);
    let cross_simd = match (simd_axis(&old), simd_axis(&new)) {
        (Some(a), Some(b)) if a != b => Some((a, b)),
        _ => None,
    };
    let mut pairs = Vec::new();
    let mut skipped = Vec::new();
    collect_bench_timings("", &old, &new, &mut pairs, &mut skipped);
    // Metrics the old baseline measured but the walk never reached
    // (renamed/moved containers): surface them instead of comparing a
    // smaller universe in silence. Best-effort — `skipped` also counts
    // null leaves, so this only catches net losses.
    let measured_old = count_measured_secs(&old);
    let unaccounted = measured_old.saturating_sub(pairs.len() + skipped.len());
    if unaccounted > 0 {
        println!(
            "warning: {unaccounted} measured timing metric(s) in {old_path} have no \
             counterpart in {new_path} (renamed or moved section?)"
        );
    }

    let allowed = 1.0 + max_regress / 100.0;
    let mut regressions = Vec::new();
    let mut t = Table::new(&["metric", "old", "new", "delta"]);
    for (path, o, n) in &pairs {
        let delta = (n / o - 1.0) * 100.0;
        t.row(&[
            path.clone(),
            bench::fmt_duration(*o),
            bench::fmt_duration(*n),
            format!("{delta:+.1}%"),
        ]);
        if n / o > allowed {
            regressions.push(format!("{path}: {delta:+.1}% (allowed +{max_regress}%)"));
        }
    }
    t.print();
    let skipped_total = skipped.len() + unaccounted;
    if skipped_total > 0 {
        println!(
            "({skipped_total} metric(s) skipped — unmeasured/pending or without a counterpart)"
        );
        for p in &skipped {
            println!("  skipped: {p}");
        }
    }
    if pairs.is_empty() {
        // A pending baseline (all nulls) legitimately compares clean;
        // an old file with real measurements that all vanished is
        // schema drift and must not pass the gate.
        if measured_old > 0 {
            return Err(crate::Error::Bench(format!(
                "{old_path} has {measured_old} measured timing metric(s) but none were \
                 comparable against {new_path} — schema drift?"
            )));
        }
        println!("no comparable timing metrics found (both baselines pending?)");
    }
    if let Some((a, b)) = cross_simd {
        println!(
            "bench-diff: simd axis differs (old: {a}, new: {b}) — {} slower metric(s) \
             reflect the kernel-path change, not gated",
            regressions.len()
        );
        return Ok(());
    }
    if regressions.is_empty() {
        println!(
            "bench-diff: ok — no regression beyond {max_regress}% across {} metric(s)",
            pairs.len()
        );
        Ok(())
    } else {
        for r in &regressions {
            eprintln!("REGRESSION {r}");
        }
        Err(crate::Error::Bench(format!(
            "{} metric(s) regressed beyond {max_regress}% ({old_path} -> {new_path})",
            regressions.len()
        )))
    }
}

/// `rfdot trace-check <trace.json>` — validate a Chrome `trace_event`
/// export: the document must parse, carry a `traceEvents` array, and
/// every `"B"` must be closed by a same-name `"E"` on its thread with
/// nothing left open (the shape `rfdot serve --trace-out` guarantees).
/// Prints a one-line summary; any violation exits nonzero — the CI
/// validator for the serve tracing smoke.
pub fn trace_check(args: &mut Args) -> Result<()> {
    let usage = "rfdot trace-check <trace.json>";
    let path = args.require_positional(0, usage)?;
    warn_unknown(args);
    let doc = Json::parse(&std::fs::read_to_string(&path)?)?;
    let check = crate::obs::trace::check_balanced(&doc)?;
    println!(
        "trace-check: ok — {} events, {} spans, {} threads ({path})",
        check.events, check.spans, check.threads
    );
    Ok(())
}

/// `rfdot map-info <map.rfdm>` — header, section table and byte
/// economics of a serialized feature-map record. Any RFDM version is
/// accepted; legacy `RFDM0001`/`0002` records are up-converted to the
/// `RFDM0003` artifact layout on read, exactly like the load paths, so
/// what this prints is what a loader would hold in memory.
/// `--selftest` skips the file and exercises the up-conversion end to
/// end on freshly sampled maps instead: each record kind must
/// round-trip with bit-identical transforms, and a recycled map must
/// serialize measurably smaller — the CI smoke for the artifact layer.
pub fn map_info(args: &mut Args) -> Result<()> {
    let selftest = args.switch("selftest");
    warn_unknown(args);
    if selftest {
        return map_info_selftest();
    }
    let usage = "rfdot map-info <map.rfdm>  (or: rfdot map-info --selftest)";
    let path = args.require_positional(0, usage)?;
    let art = crate::artifact::MapArtifact::load(&path)?;
    println!("{path}:");
    print_artifact_info(&art.info());
    Ok(())
}

fn print_artifact_info(info: &crate::artifact::ArtifactInfo) {
    println!(
        "  {} map{}  kernel={}  d={}  D={}  rows={}  max_order={}  p={}  h01={}  seed={}",
        info.kind,
        if info.recycled { " (recycled)" } else { "" },
        info.kernel,
        info.d,
        info.n_random,
        info.rows,
        info.max_order,
        info.p,
        info.h01,
        info.proj_seed,
    );
    println!("  container: {} bytes", info.total_bytes);
    for s in &info.sections {
        println!(
            "    {:<8} {:>10} bytes  ({:>8} elems @ byte {})",
            s.name, s.bytes, s.elems, s.byte_off
        );
    }
    let stored = info.stored_weight_bytes;
    let expanded = info.expanded_weight_bytes;
    println!(
        "  weights: {stored} bytes stored; an owned per-tenant copy would pay \
         {expanded} bytes ({:.2}x)",
        expanded as f64 / (stored as f64).max(1.0),
    );
}

/// The `map-info --selftest` body: every record kind must up-convert
/// to the artifact layout with bit-identical transforms, and recycling
/// must shrink serialized structured state.
fn map_info_selftest() -> Result<()> {
    use crate::artifact::MapArtifact;
    use crate::maclaurin::serialize;
    use crate::structured::ProjectionKind;

    let kernel = crate::kernels::Polynomial::new(4, 0.5);
    let d = 17;
    let probe: Vec<f32> =
        (0..d).map(|i| ((i * 37 + 11) % 23) as f32 / 23.0 - 0.5).collect();

    // Materialized RFDM0003 container size per variant (the seed-only
    // RFDM0002 record is tiny by construction, so the honest "recycling
    // shrinks state" comparison is between the up-converted containers
    // every loader actually holds in memory).
    let mut container = [0usize; 3];
    for (slot, (label, projection, recycle)) in [
        ("dense (RFDM0001)", ProjectionKind::Dense, false),
        ("structured (RFDM0002)", ProjectionKind::Structured, false),
        ("structured+recycle (RFDM0003)", ProjectionKind::Structured, true),
    ]
    .into_iter()
    .enumerate()
    {
        let mut rng = Rng::seed_from(29);
        let map = RandomMaclaurin::sample(
            &kernel,
            d,
            48,
            RmConfig::default().with_projection(projection).with_recycle(recycle),
            &mut rng,
        );
        let record = serialize::to_bytes(&map);
        // Up-convert (v3 records parse directly) and check the
        // borrowed, artifact-backed map transforms bit-identically.
        let art = MapArtifact::from_bytes(&record)?;
        container[slot] = art.total_bytes();
        let reloaded = art.instantiate()?;
        if reloaded.transform(&probe) != map.transform(&probe) {
            return Err(crate::Error::Data(format!(
                "map-info selftest: {label} up-conversion changed transform output"
            )));
        }
        println!("map-info selftest: {label} — {} record bytes, up-converted ok", record.len());
        print_artifact_info(&art.info());
    }
    if container[2] >= container[1] {
        return Err(crate::Error::Data(format!(
            "map-info selftest: recycling must shrink the materialized structured \
             container ({} -> {} bytes)",
            container[1], container[2]
        )));
    }
    println!(
        "map-info selftest: ok — recycling saves {} of {} container bytes",
        container[1] - container[2],
        container[1]
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn quickstart_runs() {
        quickstart(&mut argv(&["quickstart"])).unwrap();
    }

    #[test]
    fn gram_error_runs_small() {
        gram_error(&mut argv(&[
            "gram-error", "--kernel", "poly:3:1", "--d", "6", "--features", "64", "--points",
            "20", "--runs", "2",
        ]))
        .unwrap();
    }

    #[test]
    fn threads_flag_accepted() {
        // `--threads 0` exercises parsing/consumption without mutating
        // the process-global knob (tests share it; see
        // parallel::tests::knob_round_trips).
        gram_error(&mut argv(&[
            "gram-error", "--kernel", "poly:2:1", "--d", "4", "--features", "16", "--points",
            "10", "--runs", "1", "--threads", "0",
        ]))
        .unwrap();
    }

    #[test]
    fn gram_error_structured_runs_small() {
        gram_error(&mut argv(&[
            "gram-error", "--kernel", "poly:3:1", "--d", "6", "--features", "64", "--points",
            "20", "--runs", "2", "--projection", "structured",
        ]))
        .unwrap();
    }

    #[test]
    fn rejects_unknown_projection() {
        assert!(gram_error(&mut argv(&["gram-error", "--projection", "sparse"])).is_err());
    }

    #[test]
    fn rejects_unknown_simd_mode() {
        // Parse fails before set_mode runs, so the process-global
        // dispatch knob is never mutated (tests share it; forcing a
        // mode end to end lives in tests/structured_parity.rs, which
        // owns a dispatch lock).
        let err =
            gram_error(&mut argv(&["gram-error", "--simd", "avx512"])).unwrap_err();
        assert!(err.to_string().contains("simd"), "{err}");
    }

    #[test]
    fn gram_error_sparse_runs_small() {
        gram_error(&mut argv(&[
            "gram-error", "--kernel", "poly:3:1", "--d", "6", "--features", "64", "--points",
            "20", "--runs", "2", "--sparse",
        ]))
        .unwrap();
    }

    #[test]
    fn map_info_selftest_passes() {
        map_info(&mut argv(&["map-info", "--selftest"])).unwrap();
    }

    #[test]
    fn map_info_reads_a_saved_record() {
        let dir = std::env::temp_dir().join(format!("rfdot-mapinfo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.rfdm");
        let mut rng = Rng::seed_from(3);
        let map = RandomMaclaurin::sample(
            &crate::kernels::Polynomial::new(3, 1.0),
            9,
            32,
            RmConfig::default(),
            &mut rng,
        );
        crate::maclaurin::serialize::save(&map, &path).unwrap();
        map_info(&mut argv(&["map-info", path.to_str().unwrap()])).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_native_sparse_smoke() {
        serve(&mut argv(&[
            "serve", "--native", "--sparse", "--requests", "40", "--clients", "2", "--workers",
            "1",
        ]))
        .unwrap();
    }

    #[test]
    fn table1_row_sparse_smoke() {
        table1_row(&mut argv(&[
            "table1-row",
            "--dataset",
            "nursery",
            "--kernel",
            "poly:3:1",
            "--scale",
            "0.02",
            "--features",
            "64",
            "--h01-features",
            "32",
            "--sparse",
        ]))
        .unwrap();
    }

    #[test]
    fn serve_structured_requires_native() {
        let err = serve(&mut argv(&["serve", "--projection", "structured"])).unwrap_err();
        assert!(err.to_string().contains("--native"), "{err}");
    }

    #[test]
    fn serve_native_structured_smoke() {
        serve(&mut argv(&[
            "serve", "--native", "--projection", "structured", "--requests", "40", "--clients",
            "2", "--workers", "1",
        ]))
        .unwrap();
    }

    #[test]
    fn transform_requires_input() {
        assert!(transform(&mut argv(&["transform"])).is_err());
    }

    #[test]
    fn report_requires_readable_config() {
        assert!(report(&mut argv(&["report", "--config", "/nonexistent/report.json"])).is_err());
    }

    #[test]
    fn report_rejects_quick_alongside_config() {
        let err = report(&mut argv(&["report", "--config", "x.json", "--quick"])).unwrap_err();
        assert!(err.to_string().contains("conflicts with --config"), "{err}");
    }

    #[test]
    fn report_runs_a_minimal_config_grid() {
        // End-to-end through the CLI with a deliberately tiny custom
        // grid (one kernel, one D, one map per cell) so the smoke stays
        // cheap; the full quick grid is covered by tests/report_schema.rs.
        let dir = std::env::temp_dir().join("rfdot_cli_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("cfg.json");
        std::fs::write(
            &cfg,
            r#"{"report": {"quick": true, "points": 8, "runs": 1, "d_sweep": [8],
                "kernels": ["poly:2:1"], "threads_sweep": [1],
                "accuracy_features": 32}}"#,
        )
        .unwrap();
        report(&mut argv(&[
            "report",
            "--config",
            cfg.to_str().unwrap(),
            "--out-dir",
            dir.to_str().unwrap(),
            "--seed",
            "11",
        ]))
        .unwrap();
        assert!(dir.join("REPORT.md").exists());
        assert!(dir.join("REPORT.json").exists());
        assert!(dir.join("report_runlog.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn info_runs_without_artifacts() {
        info(&mut argv(&["info", "--artifact-dir", "/nonexistent-dir"])).unwrap();
    }

    #[test]
    fn table1_row_smoke() {
        table1_row(&mut argv(&[
            "table1-row",
            "--dataset",
            "nursery",
            "--kernel",
            "poly:3:1",
            "--scale",
            "0.02",
            "--features",
            "64",
            "--h01-features",
            "32",
        ]))
        .unwrap();
    }

    #[test]
    fn table1_row_rejects_bad_kernel() {
        assert!(table1_row(&mut argv(&["table1-row", "--kernel", "bogus"])).is_err());
    }

    #[test]
    fn serve_native_smoke() {
        serve(&mut argv(&[
            "serve", "--native", "--requests", "40", "--clients", "2", "--workers", "1",
        ]))
        .unwrap();
    }

    #[test]
    fn serve_native_sharded_smoke() {
        // The --shards knob end to end: shared (1) and explicit 2-shard
        // topologies both serve the same load.
        for shards in ["1", "2"] {
            serve(&mut argv(&[
                "serve", "--native", "--requests", "40", "--clients", "2", "--workers", "2",
                "--shards", shards,
            ]))
            .unwrap();
        }
    }

    fn write_bench_json(name: &str, secs: f64, with_null: bool) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("rfdot_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let null_row = if with_null {
            r#", {"map": "fourier", "sparsity": 0.9, "dense_secs_per_vec": null}"#
        } else {
            ""
        };
        std::fs::write(
            &path,
            format!(
                r#"{{"bench": "x", "sweep": {{"samples": [
                     {{"map": "maclaurin", "threads": 2, "dense_secs_per_vec": {secs}}}{null_row}
                   ]}}}}"#
            ),
        )
        .unwrap();
        path
    }

    #[test]
    fn bench_diff_passes_on_equal_and_fails_on_regression() {
        let old = write_bench_json("old.json", 1.0e-6, true);
        let same = write_bench_json("same.json", 1.0e-6, true);
        let slow = write_bench_json("slow.json", 2.0e-6, true);
        let fast = write_bench_json("fast.json", 0.5e-6, true);
        let ok = |a: &std::path::Path, b: &std::path::Path| {
            bench_diff(&mut argv(&[
                "bench-diff",
                a.to_str().unwrap(),
                b.to_str().unwrap(),
                "--max-regress",
                "10",
            ]))
        };
        ok(&old, &same).unwrap();
        // Speedups never fail the gate.
        ok(&old, &fast).unwrap();
        // A 2x slowdown beyond the 10% allowance does, with the Bench
        // error variant (nonzero exit through main).
        let err = ok(&old, &slow).unwrap_err();
        assert!(matches!(err, crate::Error::Bench(_)), "{err}");
        assert!(err.to_string().contains("regressed"), "{err}");
    }

    #[test]
    fn bench_diff_fails_when_measured_metrics_all_vanish() {
        // A renamed container (schema drift) must not fail open: the
        // old file has real measurements, the new file shares no
        // comparable leaves, so the gate errors instead of printing ok.
        let dir = std::env::temp_dir().join("rfdot_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let old = dir.join("drift_old.json");
        let new = dir.join("drift_new.json");
        std::fs::write(&old, r#"{"serve": {"samples": [{"workers": 1, "secs": 1.0e-6}]}}"#)
            .unwrap();
        std::fs::write(&new, r#"{"serving": {"rows": [{"workers": 1, "secs": 1.0e-6}]}}"#)
            .unwrap();
        let err = bench_diff(&mut argv(&[
            "bench-diff",
            old.to_str().unwrap(),
            new.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("schema drift"), "{err}");
    }

    #[test]
    fn bench_diff_requires_two_operands_and_readable_files() {
        let err = bench_diff(&mut argv(&["bench-diff"])).unwrap_err();
        assert!(err.to_string().contains("usage"), "{err}");
        assert!(bench_diff(&mut argv(&["bench-diff", "/nonexistent/a.json", "/nonexistent/b.json"]))
            .is_err());
    }

    #[test]
    fn bench_diff_pairs_samples_by_identity_not_position() {
        // Reordered / inserted sweep rows must compare against the row
        // with the same identity fields, not whatever sits at the same
        // index — otherwise the gate fails open (or falsely fails).
        let dir = std::env::temp_dir().join("rfdot_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let old = dir.join("ident_old.json");
        let new = dir.join("ident_new.json");
        std::fs::write(
            &old,
            r#"{"sweep": {"samples": [
                 {"map": "a", "secs": 1.0e-6},
                 {"map": "b", "secs": 9.0e-6}
               ]}}"#,
        )
        .unwrap();
        // Same numbers, reversed order, plus a brand-new row: no
        // regression despite index misalignment.
        std::fs::write(
            &new,
            r#"{"sweep": {"samples": [
                 {"map": "c", "secs": 5.0e-6},
                 {"map": "b", "secs": 9.0e-6},
                 {"map": "a", "secs": 1.0e-6}
               ]}}"#,
        )
        .unwrap();
        bench_diff(&mut argv(&[
            "bench-diff",
            old.to_str().unwrap(),
            new.to_str().unwrap(),
            "--max-regress",
            "5",
        ]))
        .unwrap();
        // And a genuine slowdown on one identity is still caught
        // through the reordering.
        std::fs::write(
            &new,
            r#"{"sweep": {"samples": [
                 {"map": "b", "secs": 9.0e-6},
                 {"map": "a", "secs": 3.0e-6}
               ]}}"#,
        )
        .unwrap();
        assert!(bench_diff(&mut argv(&[
            "bench-diff",
            old.to_str().unwrap(),
            new.to_str().unwrap(),
        ]))
        .is_err());
    }

    #[test]
    fn bench_diff_reports_but_never_gates_across_simd_axes() {
        // A scalar-forced run compared against an auto-dispatch run
        // measures the kernel-path change; the gate must say so and
        // pass even on a large slowdown. Same axis still gates.
        let dir = std::env::temp_dir().join("rfdot_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let fast = dir.join("simd_fast.json");
        let slow = dir.join("simd_slow.json");
        std::fs::write(
            &fast,
            r#"{"simd": "avx2", "sweep": {"samples": [
                 {"kernel": "dot", "n": 1024, "secs_per_call": 1.0e-7}
               ]}}"#,
        )
        .unwrap();
        std::fs::write(
            &slow,
            r#"{"simd": "scalar", "sweep": {"samples": [
                 {"kernel": "dot", "n": 1024, "secs_per_call": 8.0e-7}
               ]}}"#,
        )
        .unwrap();
        bench_diff(&mut argv(&["bench-diff", fast.to_str().unwrap(), slow.to_str().unwrap()]))
            .unwrap();
        // Identical axes: the same slowdown fails as usual.
        let slow_same = dir.join("simd_slow_same_axis.json");
        std::fs::write(
            &slow_same,
            r#"{"simd": "avx2", "sweep": {"samples": [
                 {"kernel": "dot", "n": 1024, "secs_per_call": 8.0e-7}
               ]}}"#,
        )
        .unwrap();
        assert!(bench_diff(&mut argv(&[
            "bench-diff",
            fast.to_str().unwrap(),
            slow_same.to_str().unwrap(),
        ]))
        .is_err());
    }

    #[test]
    fn bench_diff_skips_pending_null_baselines() {
        // A committed pending baseline (all nulls) self-compares clean —
        // the shape the CI smoke runs before the first measured sweep.
        let dir = std::env::temp_dir().join("rfdot_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let pending = dir.join("pending.json");
        std::fs::write(
            &pending,
            r#"{"bench": "serve_sweep", "serve": {"samples": [
                 {"workers": 1, "shards": 1, "secs_per_req": null}
               ]}}"#,
        )
        .unwrap();
        bench_diff(&mut argv(&[
            "bench-diff",
            pending.to_str().unwrap(),
            pending.to_str().unwrap(),
        ]))
        .unwrap();
    }

    #[test]
    fn serve_config_line_names_every_knob() {
        // shards=0 resolves to the work-stealing default (one per
        // worker); every knob appears as a stable key=value token.
        let line = serve_config_line("native", 2, 0, 256, 1, true, false);
        for needle in [
            "backend=native",
            "workers=2",
            "shards=2",
            "max_batch=256",
            "threads=1",
            "payload=sparse",
            "simd=",
            "trace=off",
        ] {
            assert!(line.contains(needle), "missing {needle:?} in {line:?}");
        }
        let explicit = serve_config_line("pjrt", 4, 3, 128, 2, false, true);
        assert!(explicit.contains("shards=3"), "{explicit}");
        assert!(explicit.contains("payload=dense"), "{explicit}");
        assert!(explicit.contains("trace=on"), "{explicit}");
    }

    #[test]
    fn model_stats_line_names_every_field() {
        let line = model_stats_line(&crate::net::ModelStats {
            name: "default".into(),
            version: 3,
            requests: 42,
            swaps: 2,
            latency_us: crate::metrics::Summary {
                n: 42,
                mean: 120.0,
                min: 80.0,
                p50: 110.0,
                p90: 200.0,
                max: 250.0,
            },
        });
        for needle in [
            "model default:",
            "v3",
            "requests=42",
            "swaps=2",
            "p50=110us",
            "p90=200us",
            "max=250us",
            "(n=42)",
        ] {
            assert!(line.contains(needle), "missing {needle:?} in {line:?}");
        }
    }

    #[test]
    fn bench_diff_lists_skipped_leaf_paths() {
        // Null (pending) leaves and rows without a counterpart must
        // surface by path, not just as an opaque count.
        let old = Json::parse(
            r#"{"other_secs": 2.0e-6, "sweep": {"samples": [
                 {"map": "a", "secs": 1.0e-6},
                 {"map": "b", "secs": null}
               ]}}"#,
        )
        .unwrap();
        let new = Json::parse(
            r#"{"sweep": {"samples": [
                 {"map": "a", "secs": 1.5e-6}
               ]}}"#,
        )
        .unwrap();
        let mut pairs = Vec::new();
        let mut skipped = Vec::new();
        collect_bench_timings("", &old, &new, &mut pairs, &mut skipped);
        assert_eq!(pairs.len(), 1, "{pairs:?}");
        assert!(skipped.contains(&"other_secs".to_string()), "{skipped:?}");
        assert!(skipped.iter().any(|p| p.contains("map=b")), "{skipped:?}");
        assert_eq!(skipped.len(), 2, "{skipped:?}");
    }

    #[test]
    fn trace_check_validates_files() {
        let dir = std::env::temp_dir().join("rfdot_trace_check_test");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.json");
        std::fs::write(
            &good,
            r#"{"displayTimeUnit": "ms", "traceEvents": [
                 {"cat": "rfdot", "name": "a", "ph": "B", "pid": 1, "tid": 1, "ts": 0.0},
                 {"cat": "rfdot", "name": "a", "ph": "E", "pid": 1, "tid": 1, "ts": 2.5}
               ]}"#,
        )
        .unwrap();
        trace_check(&mut argv(&["trace-check", good.to_str().unwrap()])).unwrap();
        // An unclosed begin fails through the same path CI uses.
        let bad = dir.join("bad.json");
        std::fs::write(
            &bad,
            r#"{"traceEvents": [
                 {"name": "a", "ph": "B", "pid": 1, "tid": 1, "ts": 0.0}
               ]}"#,
        )
        .unwrap();
        assert!(trace_check(&mut argv(&["trace-check", bad.to_str().unwrap()])).is_err());
        // Operand and readability errors are loud too.
        assert!(trace_check(&mut argv(&["trace-check"])).is_err());
        assert!(
            trace_check(&mut argv(&["trace-check", "/nonexistent/trace.json"])).is_err()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn transform_roundtrip_via_tempfile() {
        let dir = std::env::temp_dir().join("rfdot_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let inp = dir.join("in.libsvm");
        let out = dir.join("out.libsvm");
        std::fs::write(&inp, "+1 1:0.5 2:1\n-1 1:1 3:0.25\n").unwrap();
        transform(&mut argv(&[
            "transform",
            "--input",
            inp.to_str().unwrap(),
            "--output",
            out.to_str().unwrap(),
            "--kernel",
            "poly:2:1",
            "--features",
            "16",
        ]))
        .unwrap();
        let z = crate::data::libsvm::parse_file(&out, None).unwrap();
        assert_eq!(z.len(), 2);
        assert_eq!(z.y, vec![1.0, -1.0]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
