//! Tiny flag parser: `--key value` pairs plus boolean `--switch`es and
//! bare positional operands after a positional command word.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed argv.
#[derive(Clone, Debug, Default)]
pub struct Args {
    command: String,
    flags: BTreeMap<String, String>,
    /// Bare tokens after the command that are not flag values
    /// (`bench-diff old.json new.json`).
    positionals: Vec<String>,
    /// Flags that were consumed by a getter (for unknown-flag warnings).
    seen: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse `argv` (without the binary name).
    pub fn parse(argv: Vec<String>) -> Args {
        let mut it = argv.into_iter().peekable();
        let command = it.peek().map(|s| !s.starts_with("--")).unwrap_or(false);
        let command = if command { it.next().unwrap_or_default() } else { String::new() };
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let is_value_next =
                    it.peek().map(|v| !v.starts_with("--")).unwrap_or(false);
                if is_value_next {
                    flags.insert(key.to_string(), it.next().expect("peeked"));
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                positionals.push(tok);
            }
        }
        Args { command, flags, positionals, seen: Default::default() }
    }

    pub fn command(&self) -> &str {
        &self.command
    }

    /// String flag with default.
    pub fn str_flag(&mut self, key: &str, default: &str) -> String {
        self.seen.insert(key.to_string());
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required string flag.
    pub fn require(&mut self, key: &str) -> Result<String> {
        self.seen.insert(key.to_string());
        self.flags
            .get(key)
            .cloned()
            .ok_or_else(|| Error::Config(format!("missing required flag --{key}")))
    }

    /// Numeric flag with default.
    pub fn num_flag(&mut self, key: &str, default: f64) -> Result<f64> {
        self.seen.insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("flag --{key} expects a number, got {v:?}"))),
        }
    }

    /// Usize flag with default.
    pub fn usize_flag(&mut self, key: &str, default: usize) -> Result<usize> {
        Ok(self.num_flag(key, default as f64)? as usize)
    }

    /// Boolean switch.
    pub fn switch(&mut self, key: &str) -> bool {
        self.seen.insert(key.to_string());
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    /// Bare positional operand `i` (0-based, after the command word).
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }

    /// Required positional operand with a usage hint in the error.
    pub fn require_positional(&self, i: usize, usage: &str) -> Result<String> {
        self.positional(i)
            .map(str::to_string)
            .ok_or_else(|| Error::Config(format!("missing operand {} (usage: {usage})", i + 1)))
    }

    /// Flags that were provided but never consumed — surfaced as a
    /// warning so typos do not pass silently.
    pub fn unknown_flags(&self) -> Vec<String> {
        self.flags.keys().filter(|k| !self.seen.contains(*k)).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn parses_command_and_flags() {
        let mut a = parse(&["serve", "--requests", "100", "--native"]);
        assert_eq!(a.command(), "serve");
        assert_eq!(a.usize_flag("requests", 0).unwrap(), 100);
        assert!(a.switch("native"));
        assert!(!a.switch("missing"));
    }

    #[test]
    fn defaults_and_errors() {
        let mut a = parse(&["x"]);
        assert_eq!(a.str_flag("kernel", "poly:10:1"), "poly:10:1");
        assert!(a.require("input").is_err());
        let mut b = parse(&["x", "--n", "abc"]);
        assert!(b.num_flag("n", 1.0).is_err());
    }

    #[test]
    fn no_command() {
        let a = parse(&["--flag", "v"]);
        assert_eq!(a.command(), "");
    }

    #[test]
    fn unknown_flags_reported() {
        let mut a = parse(&["cmd", "--used", "1", "--typo", "2"]);
        let _ = a.usize_flag("used", 0);
        assert_eq!(a.unknown_flags(), vec!["typo".to_string()]);
    }

    #[test]
    fn positionals_collected_in_order() {
        let mut a = parse(&["bench-diff", "old.json", "new.json", "--max-regress", "5"]);
        assert_eq!(a.command(), "bench-diff");
        assert_eq!(a.positional(0), Some("old.json"));
        assert_eq!(a.positional(1), Some("new.json"));
        assert_eq!(a.positional(2), None);
        assert_eq!(a.num_flag("max-regress", 0.0).unwrap(), 5.0);
        assert!(a.require_positional(2, "x <a> <b>").is_err());
        // Flag values are not positionals: "5" above was consumed by
        // --max-regress, and flags may interleave with operands.
        let b = parse(&["cmd", "--flag", "v", "pos"]);
        assert_eq!(b.positional(0), Some("pos"));
    }
}
