//! Command-line interface (hand-rolled; `clap` is not reachable offline).
//!
//! ```text
//! rfdot info                     # engine + artifact inventory
//! rfdot quickstart               # tiny end-to-end demo
//! rfdot gram-error [flags]       # Figure-1 style approximation error
//! rfdot table1-row [flags]       # one Table-1 row (exact vs RF vs H0/1)
//! rfdot report [flags]           # full grid -> REPORT.md + REPORT.json
//! rfdot transform [flags]        # featurize a LIBSVM file
//! rfdot serve [flags]            # serving demo over the coordinator
//! rfdot serve --listen ADDR      # multi-tenant TCP front-end (RFNP)
//! rfdot net-client [flags]       # exercise a running RFNP server
//! rfdot bench-diff A B [flags]   # regression gate over bench baselines
//! rfdot trace-check FILE         # validate a Chrome trace_event export
//! rfdot map-info FILE            # inspect a serialized map record
//! ```

pub mod args;
pub mod commands;

pub use args::Args;

use crate::Result;

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> Result<()> {
    let mut args = Args::parse(argv);
    match args.command() {
        "info" => commands::info(&mut args),
        "quickstart" => commands::quickstart(&mut args),
        "gram-error" => commands::gram_error(&mut args),
        "table1-row" => commands::table1_row(&mut args),
        "report" => commands::report(&mut args),
        "transform" => commands::transform(&mut args),
        "serve" => commands::serve(&mut args),
        "net-client" => commands::net_client(&mut args),
        "bench-diff" => commands::bench_diff(&mut args),
        "trace-check" => commands::trace_check(&mut args),
        "map-info" => commands::map_info(&mut args),
        "help" | "" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print!("{}", HELP);
            std::process::exit(2);
        }
    }
}

pub const HELP: &str = "\
rfdot — Random Feature Maps for Dot Product Kernels (Kar & Karnick, 2012)

USAGE: rfdot <command> [flags]

COMMANDS:
  info          PJRT engine info + artifact inventory
  quickstart    tiny end-to-end demo (map, gram error, linear SVM)
  gram-error    kernel approximation error vs D  (Figure 1 point)
                  --kernel poly:10:1 | hom:10 | exp[:sigma2]   --d 16
                  --features 512  --points 100  --runs 5  --h01
  table1-row    exact kernel SVM vs RF vs H0/1   (Table 1 row)
                  --dataset nursery --kernel poly:10:1 --scale 0.1
                  --features 500 --h01-features 100 --c 1.0 --seed 42
  report        run the full reproduction grid (every feature-map
                family x kernel x projection x storage x D) and
                regenerate REPORT.md + REPORT.json + report/*.svg
                  --quick (CI-sized slice)  --out-dir .  --seed 42
                  --fresh (ignore the resumable run-log)
                  --config FILE ("report" section overrides the grid)
  transform     featurize a LIBSVM file with a sampled map
                  --input FILE --output FILE --kernel ... --features N
  serve         coordinator serving demo (per-shard stats printed)
                  --artifact transform_serve --artifact-dir artifacts
                  --requests 2000 --clients 4 --native
                  --workers 2 --shards 0  (0 = one work-stealing shard
                  per worker; 1 = the shared-queue baseline)
                  --trace-out trace.json  (write a Chrome trace_event
                  file of the run; implies --trace)
                with --listen the demo becomes a multi-tenant TCP
                front-end speaking the RFNP wire protocol:
                  --listen 127.0.0.1:7474  (port 0 = ephemeral; the
                  bound address is printed as \"listening on <addr>\")
                  --models name=path.rfdm,name2=path2.rfdm  (RFDM
                  artifacts to serve; default: one sampled demo model
                  named \"default\")
                  --heartbeat-ms 2000 --max-missed 3  (liveness: reap
                  clients silent for more than N intervals)
                  --write-queue 256  (bounded per-client write-back
                  queue; overflow is a retryable reject frame)
                  --deadline-ms N  (per-request answer deadline: late
                  replies become retryable deadline-exceeded frames;
                  0 = off, the default)
                  --shed N  (load-shed threshold: reject new requests
                  with a retryable frame once N are in flight; 0 = off)
                  --conns N  (exit after N connections close; CI)
  net-client    exercise a running RFNP server: ping, list-models,
                interleaved dense + sparse requests with client-side
                dense/sparse parity checking, optional malformed-frame
                probes (expects named error frames back)
                  --connect 127.0.0.1:7474 --requests 8 --model default
                  --malformed  (also probe bad magic + oversized frame
                  on two extra connections)  --seed 42
                  --timeout-ms 10000  (connect/read/write socket
                  deadline — a silent server is an error, not a hang)
                  --retries N  (re-send a request up to N times when
                  the server answers with a retryable error frame,
                  with jittered exponential backoff; default 0)
  bench-diff    compare two bench baseline JSON files and exit nonzero
                on regression (the CI perf gate)
                  rfdot bench-diff old.json new.json --max-regress 5
  trace-check   validate a Chrome trace_event JSON file: parses, has
                traceEvents, and every begin pairs with its end
                  rfdot trace-check trace.json
  map-info      inspect a serialized feature-map record (any RFDM
                version; legacy records are shown up-converted to the
                zero-copy RFDM0003 artifact layout): header fields,
                section table, stored vs per-tenant weight bytes
                  rfdot map-info map.rfdm
                  rfdot map-info --selftest  (CI smoke: up-convert
                  every record kind, verify bit-identical transforms,
                  check recycling shrinks the container)
  help          this message

  --projection dense|structured
                how sampled maps realize their random projections:
                an explicit matrix (dense, the default) or FWHT-backed
                HD blocks (structured, O(D log d) per input; served
                natively — combine with --native for `serve`).
  --recycle     recycle randomness across structured HD/Fastfood
                blocks: blocks draw from one shared pool inside the
                map artifact instead of independent per-block samples
                (smaller serialized/shared state). Default off — the
                default numerics stay bit-identical.
  --threads N   data-parallel CPU workers for the hot paths (default:
                auto-detect, or the RFDOT_THREADS env var). For `serve`
                this is the intra-op thread count per worker batch and
                defaults to 1 (batches already fan out across workers).
  --simd scalar|auto
                kernel dispatch for the transform hot paths: auto (the
                default, or the RFDOT_SIMD env var) picks the best
                runtime-detected path (AVX2+FMA / NEON); scalar forces
                the portable oracle kernels everywhere.
  --trace       enable tracing spans (also the RFDOT_TRACE env var or
                the \"trace\" config field); near-zero cost when off.
                Spans cover submit -> batch -> transform -> reply plus
                every per-family transform/projection hot path.
  --faults SPEC deterministic fault injection (also the RFDOT_FAULTS
                env var or the \"faults\" config field); one relaxed
                atomic load when off. SPEC is comma-separated
                site=action[:prob][:after_n] rules plus an optional
                seed=N term, e.g.
                  seed=7,net.write=error:0.05,rfdm.decode=error::100
                Actions: error | panic | delay-<ms> | corrupt-byte.
                Same seed + same spec replays the identical fault
                schedule. Site catalogue: ARCHITECTURE.md (robustness).
";
