//! # rfdot — Random Feature Maps for Dot Product Kernels
//!
//! A full-stack reproduction of Kar & Karnick, *"Random Feature Maps for
//! Dot Product Kernels"* (AISTATS 2012): low-distortion randomized
//! embeddings `Z: R^d -> R^D` such that `⟨Z(x), Z(y)⟩ ≈ f(⟨x, y⟩)` for any
//! positive definite dot product kernel, together with everything needed
//! to reproduce the paper's evaluation:
//!
//! * [`kernels`] — dot product kernel definitions and Maclaurin-series
//!   machinery (Schoenberg characterization, Theorem 1).
//! * [`features`] — the crate-level embedding layer: the [`features::FeatureMap`]
//!   trait every map family implements, plus data-parallel batch
//!   transforms and [`features::feature_gram`].
//! * [`parallel`] — the in-tree data-parallel execution subsystem
//!   (scoped worker pool, row-chunked `par_chunks`, the process-wide
//!   `--threads` knob) that the linalg/feature/SVM hot paths run on;
//!   parallel results are bit-identical to serial ones.
//! * [`structured`] — the structured random projection subsystem:
//!   a [`structured::Projection`] trait with dense and FWHT-backed
//!   HD-block/SRHT implementations (`O(D log d)` instead of `O(D d)`
//!   per input), selected by the `--projection dense|structured` knob
//!   and sampled through by both the Maclaurin and Fourier families.
//! * [`maclaurin`] — the Random Maclaurin feature maps (Algorithm 1), the
//!   H0/1 heuristic (§6.1), the truncated deterministic variant (§4.2)
//!   and compositional kernels (Algorithm 2).
//! * [`rff`] — Random Fourier Features (Rahimi & Recht 2007), used both
//!   as the paper's main point of comparison and as the black-box inner
//!   map for compositional kernels.
//! * [`svm`] — the learning substrates the paper benchmarks with: a
//!   kernel SMO dual solver (LIBSVM stand-in) and a dual coordinate
//!   descent linear SVM (LIBLINEAR stand-in).
//! * [`data`] — dataset substrate: synthetic surrogates for the paper's
//!   six UCI datasets plus a LIBSVM-format parser that reads straight
//!   into CSR ([`linalg::SparseMatrix`]); datasets carry dense or
//!   sparse storage interchangeably (equal results, different cost).
//! * [`coordinator`] + [`runtime`] — the serving layer: a dynamic
//!   batcher/router in front of AOT-compiled JAX/Pallas artifacts
//!   executed through PJRT (the `xla` crate). Python is build-time only.
//! * [`net`] — the network serving tier: a multi-tenant TCP front-end
//!   (`rfdot serve --listen`) speaking the length-prefixed `RFNP` wire
//!   protocol, backed by a hot-swappable model registry where each
//!   named model is an RFDM0003 artifact instantiated through
//!   [`coordinator::MapArtifactFactory`], with bounded per-client
//!   write-back queues, heartbeat liveness and per-model metrics.
//! * [`report`] — the self-documenting reproduction-report subsystem:
//!   `rfdot report` runs the declared grid (feature-map family × kernel
//!   × projection × storage × D), resumable via a JSON run-log, and
//!   regenerates `REPORT.md` / `REPORT.json` with in-tree SVG plots so
//!   the repo's evidence is generated, never hand-written.
//! * [`simd`] — the feature-detected kernel-dispatch layer under the
//!   transform hot paths: runtime-selected AVX2+FMA / NEON / scalar
//!   implementations of `dot`, `axpy`, the GEMM/FWHT inner loops, the
//!   RFF cosine pass and the CSR reductions, overridable with the
//!   `--simd scalar|auto` knob; within a fixed path the sparse/dense
//!   and parallel/serial bit-parity contracts still hold.
//! * [`obs`] — the observability layer: always-on counters/gauges and
//!   mergeable log-bucketed histograms (`obs::Histogram`, the serving
//!   layer's steady-state latency store), tracing spans
//!   (`obs::span`, enabled by `--trace` / `RFDOT_TRACE` / config
//!   `"trace"`, near-zero cost when off) threaded through the
//!   coordinator and every transform/projection hot path, and
//!   deterministic JSON export including a Chrome `trace_event`
//!   emitter (`rfdot serve --trace-out`).
//! * [`faults`] — deterministic, seeded fault injection: named
//!   failpoints (`--faults SPEC` / `RFDOT_FAULTS` / config `"faults"`)
//!   threaded through the artifact/decode/coordinator/registry/socket
//!   paths, zero-cost when disarmed, replaying bit-identically from
//!   the seed (`rust/tests/chaos.rs` sweeps every site).
//! * [`bench`], [`prop`], [`metrics`], [`config`], [`rng`], [`linalg`] —
//!   infrastructure substrates (no external crates are reachable in the
//!   build environment, so benchmarking, property testing, config
//!   parsing and RNG are provided in-tree).
//!
//! ## Quickstart
//!
//! ```
//! use rfdot::features::FeatureMap;
//! use rfdot::kernels::Polynomial;
//! use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
//! use rfdot::rng::Rng;
//!
//! // K(x, y) = (1 + <x, y>)^10 approximated with 512 random features.
//! let kernel = Polynomial::new(10, 1.0);
//! let mut rng = Rng::seed_from(42);
//! let map = RandomMaclaurin::sample(&kernel, 8, 512, RmConfig::default(), &mut rng);
//! let x = vec![0.1f32; 8];
//! let z = map.transform(&x);
//! assert_eq!(z.len(), 512);
//! ```

pub mod artifact;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod faults;
pub mod features;
pub mod kernels;
pub mod linalg;
pub mod maclaurin;
pub mod metrics;
pub mod net;
pub mod nystrom;
pub mod obs;
pub mod parallel;
pub mod prop;
pub mod report;
pub mod rff;
pub mod rng;
pub mod runtime;
pub mod simd;
pub mod structured;
pub mod svm;
pub mod tensorsketch;
pub mod unsup;

mod error;
pub use error::{Error, Result};

/// Compile the README's quickstart snippet as a doctest, so the
/// documented API can never drift from the real one (`cargo test`
/// builds and runs it; the shell/text blocks are ignored by rustdoc).
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
pub struct ReadmeDoctests;

/// Library version (mirrors the crate version).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
