//! Execution backends for the coordinator.
//!
//! The `xla` crate's PJRT handles are deliberately `!Send` (raw C
//! pointers + `Rc` internals), so backends are **thread-local**: the
//! coordinator takes a [`BackendFactory`] (which *is* `Send + Sync`) and
//! each worker thread builds and owns its own backend instance — for
//! PJRT that means one compiled executable per worker, compiled from the
//! same artifact. The factory also reports a [`BackendSpec`] up front
//! (parsed from the artifact manifest, no PJRT needed) so the batcher
//! can size batches before any worker exists.
//!
//! Engines:
//! * [`NativeBackend`] — the pure-Rust bit-packed feature map (any
//!   batch size, no artifacts needed);
//! * [`PjrtTransformBackend`] / [`PjrtScoreBackend`] — the AOT-compiled
//!   JAX/Pallas artifacts executed through PJRT (fixed batch; the map's
//!   dense tensors are expanded once per worker).
//!
//! The cross-engine integration tests (rust/tests/pjrt_roundtrip.rs)
//! hold both engines to identical outputs for identical sampled maps.

use crate::artifact::MapArtifact;
use crate::linalg::Matrix;
use crate::features::FeatureMap;
use crate::maclaurin::RandomMaclaurin;
use crate::runtime::{ArtifactMeta, Engine, LoadedArtifact, Tensor};
use crate::{Error, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Shape contract of a backend, known before construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BackendSpec {
    pub input_dim: usize,
    pub output_dim: usize,
    /// Largest (or, when `fixed_batch`, exact) batch size.
    pub max_batch: usize,
    /// True if `run_batch` requires exactly `max_batch` rows.
    pub fixed_batch: bool,
}

/// Something that can transform a batch of row vectors.
/// Deliberately NOT `Send`: PJRT handles stay on the thread that built
/// them.
pub trait Backend {
    fn spec(&self) -> BackendSpec;

    /// Transform all rows of `x`.
    fn run_batch(&self, x: &Matrix) -> Result<Matrix>;

    /// Intra-op parallelism hint from
    /// [`crate::coordinator::CoordinatorConfig::intra_op_threads`]
    /// (`0` = the global [`crate::parallel`] knob). Default: ignored —
    /// PJRT executables manage their own threading; only the native
    /// engine honors it.
    fn set_intra_op_threads(&mut self, _threads: usize) {}
}

/// Builds per-worker backends; shared across threads.
pub trait BackendFactory: Send + Sync {
    /// Shape contract (must match what `build()` produces).
    fn spec(&self) -> BackendSpec;

    /// Construct a thread-local backend instance.
    fn build(&self) -> Result<Box<dyn Backend>>;
}

/// Factory from a closure + spec (used heavily in tests).
pub struct ClosureFactory<F> {
    pub spec: BackendSpec,
    pub f: F,
}

impl<F> BackendFactory for ClosureFactory<F>
where
    F: Fn() -> Result<Box<dyn Backend>> + Send + Sync,
{
    fn spec(&self) -> BackendSpec {
        self.spec
    }

    fn build(&self) -> Result<Box<dyn Backend>> {
        (self.f)()
    }
}

// ---------------------------------------------------------------- native

/// Pure-Rust feature map backend.
pub struct NativeBackend {
    map: Arc<dyn FeatureMap>,
    /// Worker threads per `run_batch` (`0` = the global knob; default 1
    /// because batches already fan out across coordinator workers).
    threads: usize,
}

impl NativeBackend {
    pub fn new(map: Arc<dyn FeatureMap>) -> Self {
        Self::with_threads(map, 1)
    }

    /// Native backend with an explicit intra-op worker count.
    pub fn with_threads(map: Arc<dyn FeatureMap>, threads: usize) -> Self {
        NativeBackend { map, threads }
    }
}

impl Backend for NativeBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec {
            input_dim: self.map.input_dim(),
            output_dim: self.map.output_dim(),
            max_batch: usize::MAX,
            fixed_batch: false,
        }
    }

    fn run_batch(&self, x: &Matrix) -> Result<Matrix> {
        Ok(self.map.transform_batch_threads(x, self.threads))
    }

    fn set_intra_op_threads(&mut self, threads: usize) {
        self.threads = threads;
    }
}

/// Factory for [`NativeBackend`] (the map is shared, not re-sampled).
pub struct NativeFactory {
    map: Arc<dyn FeatureMap>,
}

impl NativeFactory {
    pub fn new(map: Arc<dyn FeatureMap>) -> Self {
        NativeFactory { map }
    }
}

impl BackendFactory for NativeFactory {
    fn spec(&self) -> BackendSpec {
        BackendSpec {
            input_dim: self.map.input_dim(),
            output_dim: self.map.output_dim(),
            max_batch: usize::MAX,
            fixed_batch: false,
        }
    }

    fn build(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend::new(self.map.clone())))
    }
}

/// Factory over one shared [`MapArtifact`] (ISSUE 8): the map is
/// instantiated **once** — a thin view whose weight stores borrow the
/// artifact's read-only region — and every worker's backend clones the
/// same `Arc`. N workers therefore share one copy of the weights (and
/// one lazily-expanded dense projection, behind the map's `OnceLock`)
/// instead of re-materializing per-worker state, which is the
/// bytes-per-tenant win `rfdot map-info` reports.
pub struct MapArtifactFactory {
    artifact: Arc<MapArtifact>,
    map: Arc<RandomMaclaurin>,
}

impl MapArtifactFactory {
    pub fn new(artifact: Arc<MapArtifact>) -> Result<Self> {
        let map = Arc::new(artifact.instantiate()?);
        Ok(MapArtifactFactory { artifact, map })
    }

    /// The shared artifact region behind every worker.
    pub fn artifact(&self) -> &Arc<MapArtifact> {
        &self.artifact
    }

    /// The shared artifact-backed map the backends serve.
    pub fn map(&self) -> &Arc<RandomMaclaurin> {
        &self.map
    }
}

impl BackendFactory for MapArtifactFactory {
    fn spec(&self) -> BackendSpec {
        BackendSpec {
            input_dim: self.map.input_dim(),
            output_dim: self.map.output_dim(),
            max_batch: usize::MAX,
            fixed_batch: false,
        }
    }

    fn build(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend::new(self.map.clone())))
    }
}

// ----------------------------------------------------------------- pjrt

fn read_meta(dir: &std::path::Path, name: &str) -> Result<ArtifactMeta> {
    let meta_path = dir.join(format!("{name}.json"));
    ArtifactMeta::parse(&std::fs::read_to_string(&meta_path).map_err(|e| {
        Error::Runtime(format!("manifest {}: {e} — run `make artifacts`", meta_path.display()))
    })?)
}

fn check_transform_meta(meta: &ArtifactMeta, map: &RandomMaclaurin, kind: &str) -> Result<()> {
    if meta.kind != kind {
        return Err(Error::Runtime(format!(
            "artifact {} has kind {}, expected {kind}",
            meta.name, meta.kind
        )));
    }
    let d = meta.inputs[0].shape[1];
    let features = meta.inputs[1].shape[2];
    if map.input_dim() != d || map.n_random() != features {
        return Err(Error::shape(
            format!("artifact d={d} D={features}"),
            format!("map d={} D={}", map.input_dim(), map.n_random()),
        ));
    }
    if map.config().h01 {
        return Err(Error::Runtime(
            "transform artifacts serve the random block only; H0/1 maps are served natively"
                .into(),
        ));
    }
    if map.is_structured() {
        return Err(Error::Runtime(
            "transform artifacts consume dense Ω tensors; structured (FWHT) maps are served \
             natively"
                .into(),
        ));
    }
    Ok(())
}

/// PJRT backend for `transform` artifacts: inputs `(x, omega, mask,
/// coeff)`, output `z`. The map tensors are expanded once per instance.
pub struct PjrtTransformBackend {
    artifact: LoadedArtifact,
    /// Pre-marshalled map literals, built once at construction:
    /// rebuilding Omega's literal per call dominated the hot path
    /// (section Perf).
    omega_lit: xla::Literal,
    mask_lit: xla::Literal,
    coeff_lit: xla::Literal,
    batch: usize,
    d: usize,
    features: usize,
}

impl PjrtTransformBackend {
    /// Bind a sampled map to a loaded `transform` artifact. The map's
    /// dense tensors are expanded and uploaded to the device once.
    pub fn new(artifact: LoadedArtifact, map: &RandomMaclaurin) -> Result<Self> {
        check_transform_meta(&artifact.meta, map, "transform")?;
        let x_spec = &artifact.meta.inputs[0];
        let omega_spec = &artifact.meta.inputs[1];
        let (batch, d) = (x_spec.shape[0], x_spec.shape[1]);
        let (n_max, _, features) =
            (omega_spec.shape[0], omega_spec.shape[1], omega_spec.shape[2]);
        let (omega, mask, coeff) = map.to_padded_dense(n_max as u32);
        let omega_lit = artifact.marshal(&Tensor::new(vec![n_max, d, features], omega)?)?;
        let mask_lit = artifact.marshal(&Tensor::new(vec![n_max, features], mask)?)?;
        let coeff_lit = artifact.marshal(&Tensor::new(vec![features], coeff)?)?;
        Ok(PjrtTransformBackend {
            artifact,
            omega_lit,
            mask_lit,
            coeff_lit,
            batch,
            d,
            features,
        })
    }
}

impl Backend for PjrtTransformBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec {
            input_dim: self.d,
            output_dim: self.features,
            max_batch: self.batch,
            fixed_batch: true,
        }
    }

    fn run_batch(&self, x: &Matrix) -> Result<Matrix> {
        if x.rows() != self.batch || x.cols() != self.d {
            return Err(Error::shape(
                format!("[{}, {}]", self.batch, self.d),
                format!("[{}, {}]", x.rows(), x.cols()),
            ));
        }
        // Only the batch's literal is built per call.
        let x_lit = self.artifact.marshal(&Tensor::from_matrix(x))?;
        let mut out = self.artifact.execute_literals(&[
            &x_lit,
            &self.omega_lit,
            &self.mask_lit,
            &self.coeff_lit,
        ])?;
        out.remove(0).into_matrix()
    }
}

/// Factory for [`PjrtTransformBackend`]: parses the manifest eagerly
/// (shape contract, validation) and compiles one executable per worker.
pub struct PjrtTransformFactory {
    dir: PathBuf,
    artifact: String,
    map: Arc<RandomMaclaurin>,
    spec: BackendSpec,
}

impl PjrtTransformFactory {
    pub fn new(
        dir: impl Into<PathBuf>,
        artifact: impl Into<String>,
        map: Arc<RandomMaclaurin>,
    ) -> Result<Self> {
        let dir = dir.into();
        let artifact = artifact.into();
        let meta = read_meta(&dir, &artifact)?;
        check_transform_meta(&meta, &map, "transform")?;
        let spec = BackendSpec {
            input_dim: meta.inputs[0].shape[1],
            output_dim: meta.inputs[1].shape[2],
            max_batch: meta.batch(),
            fixed_batch: true,
        };
        Ok(PjrtTransformFactory { dir, artifact, map, spec })
    }
}

impl BackendFactory for PjrtTransformFactory {
    fn spec(&self) -> BackendSpec {
        self.spec
    }

    fn build(&self) -> Result<Box<dyn Backend>> {
        let engine = Engine::cpu(&self.dir)?;
        let loaded = engine.load(&self.artifact)?;
        Ok(Box::new(PjrtTransformBackend::new(loaded, &self.map)?))
    }
}

/// PJRT backend for fused `transform_score` artifacts: inputs
/// `(x, omega, mask, coeff, w, b)`, output `scores [B]` (returned as a
/// `[B, 1]` matrix so the reply plumbing stays uniform).
pub struct PjrtScoreBackend {
    artifact: LoadedArtifact,
    omega: Tensor,
    mask: Tensor,
    coeff: Tensor,
    w: Tensor,
    b: Tensor,
    batch: usize,
    d: usize,
}

impl PjrtScoreBackend {
    pub fn new(
        artifact: LoadedArtifact,
        map: &RandomMaclaurin,
        w: Vec<f32>,
        b: f32,
    ) -> Result<Self> {
        check_transform_meta(&artifact.meta, map, "transform_score")?;
        let x_spec = &artifact.meta.inputs[0];
        let omega_spec = &artifact.meta.inputs[1];
        let (batch, d) = (x_spec.shape[0], x_spec.shape[1]);
        let (n_max, _, features) =
            (omega_spec.shape[0], omega_spec.shape[1], omega_spec.shape[2]);
        if w.len() != features {
            return Err(Error::shape(format!("w len {features}"), format!("{}", w.len())));
        }
        let (omega, mask, coeff) = map.to_padded_dense(n_max as u32);
        Ok(PjrtScoreBackend {
            artifact,
            omega: Tensor::new(vec![n_max, d, features], omega)?,
            mask: Tensor::new(vec![n_max, features], mask)?,
            coeff: Tensor::new(vec![features], coeff)?,
            w: Tensor::new(vec![features], w)?,
            b: Tensor::scalar(b),
            batch,
            d,
        })
    }
}

impl Backend for PjrtScoreBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec {
            input_dim: self.d,
            output_dim: 1,
            max_batch: self.batch,
            fixed_batch: true,
        }
    }

    fn run_batch(&self, x: &Matrix) -> Result<Matrix> {
        if x.rows() != self.batch || x.cols() != self.d {
            return Err(Error::shape(
                format!("[{}, {}]", self.batch, self.d),
                format!("[{}, {}]", x.rows(), x.cols()),
            ));
        }
        let inputs = [
            Tensor::from_matrix(x),
            self.omega.clone(),
            self.mask.clone(),
            self.coeff.clone(),
            self.w.clone(),
            self.b.clone(),
        ];
        let out = self.artifact.execute(&inputs)?;
        let scores = out[0].data().to_vec();
        Matrix::from_vec(self.batch, 1, scores)
    }
}

/// A bucketed PJRT transform backend: several compiled variants of the
/// same computation at different batch sizes; each incoming batch is
/// padded only up to the *smallest bucket that fits* (and chunked by
/// the largest bucket when oversized). This is the §Perf fix for the
/// padding waste a single fixed-256 artifact pays at low occupancy.
pub struct PjrtBucketedBackend {
    /// Ascending by batch size.
    buckets: Vec<PjrtTransformBackend>,
}

impl PjrtBucketedBackend {
    pub fn new(mut buckets: Vec<PjrtTransformBackend>) -> Result<Self> {
        if buckets.is_empty() {
            return Err(Error::Runtime("bucketed backend needs >= 1 bucket".into()));
        }
        buckets.sort_by_key(|b| b.batch);
        let d = buckets[0].d;
        let f = buckets[0].features;
        if !buckets.iter().all(|b| b.d == d && b.features == f) {
            return Err(Error::shape(
                format!("uniform buckets d={d} D={f}"),
                "mismatched bucket shapes",
            ));
        }
        Ok(PjrtBucketedBackend { buckets })
    }

    fn bucket_for(&self, n: usize) -> &PjrtTransformBackend {
        self.buckets
            .iter()
            .find(|b| b.batch >= n)
            .unwrap_or_else(|| self.buckets.last().expect("non-empty"))
    }
}

impl Backend for PjrtBucketedBackend {
    fn spec(&self) -> BackendSpec {
        let largest = self.buckets.last().expect("non-empty");
        BackendSpec {
            input_dim: largest.d,
            output_dim: largest.features,
            max_batch: largest.batch,
            // The bucketed backend pads internally; the coordinator can
            // hand it ragged batches directly.
            fixed_batch: false,
        }
    }

    fn run_batch(&self, x: &Matrix) -> Result<Matrix> {
        let n = x.rows();
        let d = self.buckets[0].d;
        let features = self.buckets[0].features;
        let mut out = Matrix::zeros(n, features);
        let max_bucket = self.buckets.last().expect("non-empty").batch;
        let mut start = 0usize;
        while start < n {
            let take = (n - start).min(max_bucket);
            let backend = self.bucket_for(take);
            let mut padded = Matrix::zeros(backend.batch, d);
            for i in 0..take {
                padded.row_mut(i).copy_from_slice(x.row(start + i));
            }
            let z = backend.run_batch(&padded)?;
            for i in 0..take {
                out.row_mut(start + i).copy_from_slice(z.row(i));
            }
            start += take;
        }
        Ok(out)
    }
}

/// Factory for [`PjrtBucketedBackend`] over a list of artifact names
/// (e.g. `transform_serve_b16`, `transform_serve_b64`,
/// `transform_serve`).
pub struct PjrtBucketedFactory {
    dir: PathBuf,
    artifacts: Vec<String>,
    map: Arc<RandomMaclaurin>,
    spec: BackendSpec,
}

impl PjrtBucketedFactory {
    pub fn new(
        dir: impl Into<PathBuf>,
        artifacts: Vec<String>,
        map: Arc<RandomMaclaurin>,
    ) -> Result<Self> {
        let dir = dir.into();
        if artifacts.is_empty() {
            return Err(Error::Config("need at least one artifact name".into()));
        }
        let mut max_batch = 0;
        let mut input_dim = 0;
        let mut output_dim = 0;
        for name in &artifacts {
            let meta = read_meta(&dir, name)?;
            check_transform_meta(&meta, &map, "transform")?;
            max_batch = max_batch.max(meta.batch());
            input_dim = meta.inputs[0].shape[1];
            output_dim = meta.inputs[1].shape[2];
        }
        let spec = BackendSpec { input_dim, output_dim, max_batch, fixed_batch: false };
        Ok(PjrtBucketedFactory { dir, artifacts, map, spec })
    }
}

impl BackendFactory for PjrtBucketedFactory {
    fn spec(&self) -> BackendSpec {
        self.spec
    }

    fn build(&self) -> Result<Box<dyn Backend>> {
        let engine = Engine::cpu(&self.dir)?;
        let mut buckets = Vec::with_capacity(self.artifacts.len());
        for name in &self.artifacts {
            let loaded = engine.load(name)?;
            buckets.push(PjrtTransformBackend::new(loaded, &self.map)?);
        }
        Ok(Box::new(PjrtBucketedBackend::new(buckets)?))
    }
}

/// Factory for [`PjrtScoreBackend`].
pub struct PjrtScoreFactory {
    dir: PathBuf,
    artifact: String,
    map: Arc<RandomMaclaurin>,
    w: Vec<f32>,
    b: f32,
    spec: BackendSpec,
}

impl PjrtScoreFactory {
    pub fn new(
        dir: impl Into<PathBuf>,
        artifact: impl Into<String>,
        map: Arc<RandomMaclaurin>,
        w: Vec<f32>,
        b: f32,
    ) -> Result<Self> {
        let dir = dir.into();
        let artifact = artifact.into();
        let meta = read_meta(&dir, &artifact)?;
        check_transform_meta(&meta, &map, "transform_score")?;
        let spec = BackendSpec {
            input_dim: meta.inputs[0].shape[1],
            output_dim: 1,
            max_batch: meta.batch(),
            fixed_batch: true,
        };
        Ok(PjrtScoreFactory { dir, artifact, map, w, b, spec })
    }
}

impl BackendFactory for PjrtScoreFactory {
    fn spec(&self) -> BackendSpec {
        self.spec
    }

    fn build(&self) -> Result<Box<dyn Backend>> {
        let engine = Engine::cpu(&self.dir)?;
        let loaded = engine.load(&self.artifact)?;
        Ok(Box::new(PjrtScoreBackend::new(
            loaded,
            &self.map,
            self.w.clone(),
            self.b,
        )?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Exponential;
    use crate::maclaurin::RmConfig;
    use crate::rng::Rng;

    #[test]
    fn native_backend_matches_map() {
        let mut rng = Rng::seed_from(1);
        let map = Arc::new(RandomMaclaurin::sample(
            &Exponential::new(1.0),
            4,
            16,
            RmConfig::default(),
            &mut rng,
        ));
        let backend = NativeBackend::new(map.clone());
        let spec = backend.spec();
        assert_eq!(spec.input_dim, 4);
        assert_eq!(spec.output_dim, 16);
        assert!(!spec.fixed_batch);
        let x = Matrix::from_rows(&[vec![0.1, 0.2, 0.3, 0.4]]).unwrap();
        let out = backend.run_batch(&x).unwrap();
        assert_eq!(out.row(0), &map.transform(x.row(0))[..]);
    }

    #[test]
    fn native_factory_builds_consistent_spec() {
        let mut rng = Rng::seed_from(2);
        let map = Arc::new(RandomMaclaurin::sample(
            &Exponential::new(1.0),
            3,
            8,
            RmConfig::default(),
            &mut rng,
        ));
        let factory = NativeFactory::new(map);
        let b = factory.build().unwrap();
        assert_eq!(factory.spec(), b.spec());
    }

    #[test]
    fn native_backend_serves_structured_maps() {
        // The structured path must ride the coordinator's native
        // backend unchanged (that's where its speedup lands).
        let mut rng = Rng::seed_from(5);
        let config = RmConfig::default()
            .with_projection(crate::structured::ProjectionKind::Structured);
        let map = Arc::new(RandomMaclaurin::sample(&Exponential::new(1.0), 6, 32, config, &mut rng));
        let backend = NativeBackend::new(map.clone());
        let x = Matrix::from_rows(&[vec![0.1, -0.2, 0.3, 0.0, 0.05, 0.2]]).unwrap();
        let out = backend.run_batch(&x).unwrap();
        assert_eq!(out.row(0), &map.transform(x.row(0))[..]);
    }

    #[test]
    fn map_artifact_factory_backends_share_one_region() {
        let mut rng = Rng::seed_from(6);
        let map = RandomMaclaurin::sample(
            &Exponential::new(1.0),
            6,
            24,
            RmConfig::default(),
            &mut rng,
        );
        let artifact = Arc::new(MapArtifact::from_map(&map).unwrap());
        let factory = MapArtifactFactory::new(artifact.clone()).unwrap();
        assert_eq!(factory.spec().input_dim, 6);
        assert_eq!(factory.spec().output_dim, 24);
        // Two builds serve bit-identical outputs from the shared map.
        let (a, b) = (factory.build().unwrap(), factory.build().unwrap());
        let x = Matrix::from_rows(&[vec![0.1, -0.2, 0.3, 0.0, 0.05, 0.2]]).unwrap();
        let (za, zb) = (a.run_batch(&x).unwrap(), b.run_batch(&x).unwrap());
        assert_eq!(za, zb);
        assert_eq!(za.row(0), &map.transform(x.row(0))[..]);
        assert_eq!(factory.artifact().total_bytes(), artifact.total_bytes());
    }

    #[test]
    fn transform_meta_rejects_structured_maps() {
        let meta = crate::runtime::ArtifactMeta::parse(
            r#"{
              "name": "t", "config": {"kind": "transform"},
              "inputs": [
                {"name": "x", "shape": [4, 6], "dtype": "f32"},
                {"name": "omega", "shape": [8, 6, 32], "dtype": "f32"},
                {"name": "mask", "shape": [8, 32], "dtype": "f32"},
                {"name": "coeff", "shape": [32], "dtype": "f32"}
              ],
              "outputs": [{"name": "z", "shape": [4, 32], "dtype": "f32"}]
            }"#,
        )
        .unwrap();
        let mut rng = Rng::seed_from(6);
        let config = RmConfig::default()
            .with_projection(crate::structured::ProjectionKind::Structured);
        let map = RandomMaclaurin::sample(&Exponential::new(1.0), 6, 32, config, &mut rng);
        let err = match check_transform_meta(&meta, &map, "transform") {
            Err(e) => e,
            Ok(()) => panic!("structured map must be rejected by the artifact path"),
        };
        assert!(err.to_string().contains("natively"), "{err}");
    }

    #[test]
    fn pjrt_factory_rejects_missing_manifest() {
        let mut rng = Rng::seed_from(3);
        let map = Arc::new(RandomMaclaurin::sample(
            &Exponential::new(1.0),
            4,
            8,
            RmConfig::default(),
            &mut rng,
        ));
        let err = match PjrtTransformFactory::new(std::env::temp_dir(), "nope", map) {
            Err(e) => e,
            Ok(_) => panic!("missing manifest must fail"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
