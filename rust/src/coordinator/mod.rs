//! L3 serving coordinator: request router + dynamic batcher + sharded
//! work-stealing workers.
//!
//! The paper's feature maps turn kernel-machine serving into *linear*
//! serving: transform a vector, dot it with a weight vector. This module
//! is the production shell around that hot path:
//!
//! ```text
//! clients ──submit(x)────────▶ lane 0 ──▶ batcher 0 ──▶ shard 0 ─▶ worker 0
//!         ──submit_callback─▶ lane 1 ──▶ batcher 1 ──▶ shard 1 ─▶ worker 1
//!         ──submit_batch tail▶  ...        ...           ...        ...
//!            (round-robin lane; each batcher coalesces  (own shard first,
//!             ≤ max_batch within max_wait, feeds its     steal when dry)
//!             own home shard)
//!         ──submit_batch(xs)── full max_batch chunks ──▶ shards
//!                              (bypass lanes + batchers)   │
//!                                            thread-local Backend::run_batch
//!                                                          ▼
//!                               per-request replies (channel / batch
//!                               slot / completion callback)
//! ```
//!
//! * **Sharded batch queues** — each worker owns a shard and pops from
//!   it without touching the others; a worker whose shard runs dry
//!   *steals* from its neighbours, so stragglers never idle the pool
//!   and the pre-shard single shared `Mutex<Receiver>` contention point
//!   is gone. `shards = 1` reproduces the old shared-queue topology
//!   (kept as the bench baseline). Shard choice is scheduling, never
//!   semantics: replies are bit-identical for any shard count.
//! * **Sharded ingress** — the submit side is a set of bounded lanes,
//!   one per shard, each fronted by its own `sync_channel` and drained
//!   by its own batcher thread feeding its home shard — the same
//!   topology the worker-side deques use, so a single hot ingress
//!   channel never serializes a multi-shard pool. Submitters
//!   round-robin the lanes; lane choice is scheduling, never
//!   semantics (`shards = 1` reproduces the old single-queue path
//!   exactly).
//! * **Backpressure** — every submit lane is bounded (total depth
//!   `queue_depth` split across lanes); when all lanes are full,
//!   callers get [`Error::Coordinator`] instead of unbounded memory
//!   growth. The shard queues are bounded too (batchers block,
//!   clients do not).
//! * **Async submission** — [`Ticket::poll`] is the non-blocking
//!   counterpart of [`Ticket::wait`], and
//!   [`Coordinator::submit_callback`] invokes a completion callback on
//!   the worker thread — both without an external async runtime.
//! * **Batch submission** — [`Coordinator::submit_batch`] /
//!   [`Coordinator::submit_batch_sparse`] share one reply channel
//!   across a whole client batch, amortizing the per-request ticket
//!   and channel overhead. Pre-formed full `max_batch` chunks are
//!   pushed straight onto the shard queues (non-blocking), bypassing
//!   the submit channel and batcher thread entirely; only the ragged
//!   tail — and any chunk the pool had no room for — takes the
//!   per-job batcher path, which owns backpressure. The bypass is
//!   metered in [`crate::metrics::Stats::direct_batches`] and changes
//!   scheduling only: reply order, exactly-once and the stats
//!   invariants are identical either way.
//! * **Thread-local backends** — PJRT handles are `!Send`, so each
//!   worker builds its own executable from a shared [`BackendFactory`].
//! * **Fixed-shape backends** — the PJRT artifacts take a fixed batch;
//!   ragged tails are padded and the replies sliced (pad waste is
//!   metered in [`crate::metrics::Stats::pad_slots`]).
//! * **Exactly-once replies** — every accepted request receives exactly
//!   one reply, including on worker build failure, backend failure,
//!   work stealing or shutdown drain; the tests in this module and
//!   `rust/tests/serve_shard.rs` drive random schedules against that
//!   invariant. [`Coordinator::shutdown`] drains everything queued; if
//!   a worker died (panicking backend) and left jobs unservable, they
//!   are failed with an explicit shutdown error instead of leaving
//!   `Ticket::wait` to hang.
//! * **Sparse submissions** — [`Coordinator::submit_sparse`] accepts
//!   CSR (index, value) pairs; they scatter into the same zeroed batch
//!   rows dense submissions copy into, so batching, padding and the
//!   exactly-once contract are shared and the reply equals the dense
//!   submission of the densified vector.
//! * **Per-shard metrics** — every shard records batches, items, steal
//!   counts and latency into a log-bucketed, mergeable
//!   [`crate::obs::Histogram`] that never stops recording (steady-state
//!   latency, not just a warm-up window), surfaced by
//!   [`Coordinator::shard_snapshots`], [`Coordinator::merged_latency`],
//!   `rfdot serve` and the `rfdot report` serving panel.
//! * **Tracing** — when the process-wide [`crate::obs`] flag is on
//!   (`--trace` / `RFDOT_TRACE`), the submit, batch-formation,
//!   steal, backend-execution and reply-delivery stages each record
//!   spans (`serve.submit`, `serve.batch_form`, `serve.steal`,
//!   `serve.run_batch`, `serve.reply`), exportable as Chrome trace
//!   JSON via `rfdot serve --trace-out`. Disabled, each span site is
//!   one relaxed atomic load.

pub mod backend;

pub use backend::{
    Backend, BackendFactory, BackendSpec, ClosureFactory, MapArtifactFactory, NativeBackend,
    NativeFactory, PjrtBucketedBackend, PjrtBucketedFactory, PjrtScoreBackend, PjrtScoreFactory,
    PjrtTransformBackend, PjrtTransformFactory,
};

use crate::metrics::{Stats, Summary};
use crate::obs;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Tolerate mutex poisoning: the protected state (job deques, sample
/// vecs) is valid at every instruction boundary, and the shutdown path
/// must keep working after a worker panic — that is exactly when the
/// explicit-shutdown-error guarantee matters.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar twin of [`lock`]: one place owns the poison policy for the
/// waits too.
fn wait_on<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Batching/queueing policy.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Largest batch handed to the backend (clamped to the backend's
    /// own `max_batch`).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch after the first
    /// request arrives.
    pub max_wait: Duration,
    /// Bound on the submit queue (backpressure threshold).
    pub queue_depth: usize,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Data-parallel threads each worker's backend may use *within* a
    /// batch ([`Backend::set_intra_op_threads`]; honored by the native
    /// engine, ignored by PJRT). `0` = the global [`crate::parallel`]
    /// knob; the default of 1 keeps per-batch work serial because
    /// batches already fan out across `workers`.
    pub intra_op_threads: usize,
    /// Batch-queue shards. `0` (the default) means one shard per
    /// worker — the sharded topology; `1` is a single queue every
    /// worker pops from — the pre-shard topology, kept as the bench
    /// baseline. Workers own shard `w % shards` and steal from the
    /// others when their own runs dry; the choice only moves
    /// contention, never results.
    pub shards: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 128,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            workers: 2,
            intra_op_threads: 1,
            shards: 0,
        }
    }
}

/// One request's feature payload: a dense vector or CSR index/value
/// pairs. Both scatter into the same batch matrix row, so the backend
/// (and the reply) cannot tell them apart — sparse submission is a
/// wire-format optimization, not a semantic fork.
enum Payload {
    Dense(Vec<f32>),
    Sparse { indices: Vec<u32>, values: Vec<f32> },
}

impl Payload {
    /// Write the payload into a zeroed batch row.
    fn scatter_into(&self, row: &mut [f32]) {
        match self {
            Payload::Dense(x) => row.copy_from_slice(x),
            Payload::Sparse { indices, values } => {
                for (&k, &v) in indices.iter().zip(values) {
                    row[k as usize] = v;
                }
            }
        }
    }
}

/// Where one request's reply goes. Every accepted job carries exactly
/// one of these and every route delivers exactly once.
enum Reply {
    /// Dedicated one-shot channel ([`Coordinator::submit`] /
    /// [`Coordinator::submit_sparse`]).
    Channel(SyncSender<Result<Vec<f32>>>),
    /// Slot `i` of a batch submission's shared channel
    /// ([`Coordinator::submit_batch`]).
    Indexed(SyncSender<(u32, Result<Vec<f32>>)>, u32),
    /// Completion callback, invoked on the worker thread
    /// ([`Coordinator::submit_callback`]).
    Callback(Box<dyn FnOnce(Result<Vec<f32>>) + Send>),
}

impl Reply {
    fn send(self, r: Result<Vec<f32>>) {
        match self {
            // Receiver gone = caller stopped caring; not an error.
            Reply::Channel(tx) => {
                let _ = tx.send(r);
            }
            Reply::Indexed(tx, i) => {
                let _ = tx.send((i, r));
            }
            Reply::Callback(f) => f(r),
        }
    }
}

/// One accepted request in flight. The reply route is armed until
/// `respond` fires; dropping an unanswered job (worker panic unwinding
/// a batch, queue teardown) answers it with an error from the `Drop`
/// impl — that is what makes the exactly-once contract hold for
/// *every* reply route, callbacks included, on every failure path.
struct Job {
    x: Payload,
    submitted: Instant,
    reply: Option<Reply>,
}

impl Job {
    fn new(x: Payload, reply: Reply) -> Job {
        Job { x, submitted: Instant::now(), reply: Some(reply) }
    }

    /// Deliver the reply (exactly once; later calls are no-ops and the
    /// drop guard disarms).
    fn respond(&mut self, r: Result<Vec<f32>>) {
        if let Some(reply) = self.reply.take() {
            reply.send(r);
        }
    }

    /// Disarm and drop a job that was never accepted into the queue:
    /// the caller reports the failure through its own `Result`, so the
    /// reply route must not also fire from the drop guard (a stray
    /// duplicate would corrupt [`BatchTicket`] slot accounting).
    fn disarm(mut self) {
        let _ = self.reply.take();
    }
}

impl Drop for Job {
    fn drop(&mut self) {
        if let Some(reply) = self.reply.take() {
            reply.send(Err(Error::Coordinator("coordinator dropped the request".into())));
        }
    }
}

/// A handle to a reply; `wait` blocks until the coordinator answers,
/// `poll` checks without blocking.
pub struct Ticket {
    rx: Receiver<Result<Vec<f32>>>,
    taken: bool,
}

impl Ticket {
    /// Block for the result.
    pub fn wait(self) -> Result<Vec<f32>> {
        if self.taken {
            return Err(Error::Coordinator("reply was already taken via poll".into()));
        }
        self.rx
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))?
    }

    /// Block with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<Vec<f32>> {
        if self.taken {
            return Err(Error::Coordinator("reply was already taken via poll".into()));
        }
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => {
                Err(Error::Coordinator("timed out waiting for reply".into()))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Coordinator("coordinator dropped the request".into()))
            }
        }
    }

    /// Non-blocking check — the poll-based async surface (no external
    /// runtime). Returns `None` while the request is in flight and
    /// `Some(reply)` exactly once when it completes (or once the
    /// coordinator dropped it); after that the ticket is spent.
    pub fn poll(&mut self) -> Option<Result<Vec<f32>>> {
        if self.taken {
            return Some(Err(Error::Coordinator("reply was already taken via poll".into())));
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.taken = true;
                Some(r)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.taken = true;
                Some(Err(Error::Coordinator("coordinator dropped the request".into())))
            }
        }
    }
}

/// A handle to a whole batch submission's replies: one shared channel,
/// slots keyed by submission order ([`Coordinator::submit_batch`]).
pub struct BatchTicket {
    rx: Receiver<(u32, Result<Vec<f32>>)>,
    /// Slot `i` of the submitted batch; immediate rejections (queue
    /// full) are filled in at submission time.
    results: Vec<Option<Result<Vec<f32>>>>,
    /// Replies still in flight.
    pending: usize,
    /// Requests the queue actually accepted.
    accepted: usize,
}

impl BatchTicket {
    /// How many of the batch's requests were accepted into the queue
    /// (the rest were rejected immediately, e.g. by backpressure, and
    /// their slots already hold errors).
    pub fn accepted(&self) -> usize {
        self.accepted
    }

    /// Block until every in-flight reply arrives; returns one reply per
    /// submitted input, in submission order.
    pub fn wait(mut self) -> Vec<Result<Vec<f32>>> {
        while self.pending > 0 {
            match self.rx.recv() {
                Ok((i, r)) => {
                    self.results[i as usize] = Some(r);
                    self.pending -= 1;
                }
                // All senders gone with replies outstanding: a worker
                // died mid-batch. The missing slots become errors below.
                Err(_) => break,
            }
        }
        self.results
            .into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| {
                    Err(Error::Coordinator("coordinator dropped the request".into()))
                })
            })
            .collect()
    }
}

/// Per-shard serving metrics: batch/item/steal counters plus a
/// log-bucketed latency histogram ([`obs::Histogram`]: bounded memory,
/// records for the whole process lifetime, mergeable across shards —
/// unlike the freeze-after-cap `SampleBuffer` it replaced). Batches
/// are attributed to the shard they were *queued* on; `steals` counts
/// how many of them were executed by a worker whose home shard is
/// elsewhere.
struct ShardStats {
    batches: AtomicU64,
    items: AtomicU64,
    steals: AtomicU64,
    latency_us: obs::Histogram,
}

impl ShardStats {
    fn new() -> ShardStats {
        ShardStats {
            batches: AtomicU64::new(0),
            items: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            latency_us: obs::Histogram::new(),
        }
    }
}

/// A point-in-time copy of one shard's metrics
/// ([`Coordinator::shard_snapshots`]).
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    /// Batches queued to this shard.
    pub batches: u64,
    /// Requests inside those batches.
    pub items: u64,
    /// Batches of this shard executed by another shard's worker.
    pub steals: u64,
    /// Percentile summary of this shard's request latencies in
    /// microseconds — exact `n`/`mean`/`min`/`max`, log-bucket-estimated
    /// `p50`/`p90` (see [`obs::Histogram`] for the error bound).
    pub latency_us: Summary,
}

/// One batch shard: a bounded-by-the-pool deque plus its metrics.
struct Shard {
    queue: Mutex<VecDeque<Vec<Job>>>,
    stats: ShardStats,
}

/// Book-keeping shared by the batcher and every worker, guarded by one
/// small mutex (`central`). The shard deques have their own locks — the
/// hot pop path touches `central` only to claim a batch count, not to
/// move jobs, which is what kills the old single `Mutex<Receiver>`
/// convoy.
struct Central {
    /// Batches currently queued across all shards.
    queued: usize,
    /// False once the batcher is done (submit side closed and drained).
    open: bool,
    /// Workers that have not exited (panic included, via a drop guard).
    workers_alive: usize,
}

struct ShardQueues {
    shards: Vec<Shard>,
    central: Mutex<Central>,
    /// Signaled on push/close: work may be available.
    work_cv: Condvar,
    /// Signaled on pop/worker-exit: queue space may be available.
    space_cv: Condvar,
    /// Bound on `queued` (backpressure toward the batcher; client
    /// backpressure is the submit queue's bound).
    cap: usize,
}

impl ShardQueues {
    fn new(shards: usize, workers: usize, cap: usize) -> ShardQueues {
        ShardQueues {
            shards: (0..shards)
                .map(|_| Shard { queue: Mutex::new(VecDeque::new()), stats: ShardStats::new() })
                .collect(),
            central: Mutex::new(Central { queued: 0, open: true, workers_alive: workers }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            cap,
        }
    }

    /// Push a batch onto `shard`, blocking while the pool-wide bound is
    /// hit. Returns the batch back if no live worker remains to serve
    /// it (so the caller can answer instead of leaving waits to hang).
    fn push(&self, shard: usize, batch: Vec<Job>) -> std::result::Result<(), Vec<Job>> {
        let mut g = lock(&self.central);
        while g.queued >= self.cap {
            if g.workers_alive == 0 {
                return Err(batch);
            }
            g = wait_on(&self.space_cv, g);
        }
        if g.workers_alive == 0 {
            return Err(batch);
        }
        lock(&self.shards[shard].queue).push_back(batch);
        g.queued += 1;
        drop(g);
        self.work_cv.notify_one();
        Ok(())
    }

    /// Non-blocking twin of [`ShardQueues::push`] for pre-formed full
    /// batches submitted by clients (the batcher keeps the blocking
    /// variant — it owns a thread and may wait; clients must not).
    /// Returns the batch when the pool-wide bound is hit, the intake is
    /// closed, or no live worker remains, so the caller can fall back
    /// to the batcher path and inherit its backpressure semantics.
    fn try_push(&self, shard: usize, batch: Vec<Job>) -> std::result::Result<(), Vec<Job>> {
        let mut g = lock(&self.central);
        if g.queued >= self.cap || !g.open || g.workers_alive == 0 {
            return Err(batch);
        }
        lock(&self.shards[shard].queue).push_back(batch);
        g.queued += 1;
        drop(g);
        self.work_cv.notify_one();
        Ok(())
    }

    /// Blocking pop for the worker whose home shard is `home`: claim a
    /// queued batch under the central lock, then take it from the home
    /// shard if possible, stealing from neighbours otherwise. Returns
    /// `(shard the batch was queued on, batch)`, or `None` once the
    /// queue is closed and fully drained.
    fn pop(&self, home: usize) -> Option<(usize, Vec<Job>)> {
        let n = self.shards.len();
        let mut g = lock(&self.central);
        loop {
            if g.queued > 0 {
                g.queued -= 1;
                drop(g);
                self.space_cv.notify_one();
                // The decrement claimed exactly one batch. A concurrent
                // claimant may drain a shard we already scanned while a
                // fresh push lands behind us, so the scan retries until
                // the claimed batch is found — it exists by the counter
                // invariant (batches are deque-inserted before they are
                // counted and claimed before they are removed).
                loop {
                    for i in 0..n {
                        let s = (home + i) % n;
                        let batch = lock(&self.shards[s].queue).pop_front();
                        if let Some(b) = batch {
                            return Some((s, b));
                        }
                    }
                    std::thread::yield_now();
                }
            }
            if !g.open {
                return None;
            }
            g = wait_on(&self.work_cv, g);
        }
    }

    /// Close the intake: workers drain what is queued, then exit.
    fn close(&self) {
        lock(&self.central).open = false;
        self.work_cv.notify_all();
        self.space_cv.notify_all();
    }

    /// A worker is gone (normal exit or panic). When the *last* one
    /// goes, every still-queued job is drained and returned so the
    /// caller can fail it immediately — leaving jobs in the deques with
    /// no one to serve them would hang their `Ticket::wait`s until
    /// shutdown.
    fn worker_exited(&self) -> Vec<Job> {
        let mut g = lock(&self.central);
        g.workers_alive = g.workers_alive.saturating_sub(1);
        let residual =
            if g.workers_alive == 0 { self.drain_with(&mut g) } else { Vec::new() };
        drop(g);
        // A batcher waiting for space must re-check worker liveness.
        self.space_cv.notify_all();
        residual
    }

    /// Drain every queued job; the caller holds the central lock, so
    /// no push can interleave (pushes insert under the same lock).
    fn drain_with(&self, g: &mut Central) -> Vec<Job> {
        let mut left = Vec::new();
        for shard in &self.shards {
            let mut q = lock(&shard.queue);
            while let Some(batch) = q.pop_front() {
                g.queued = g.queued.saturating_sub(1);
                left.extend(batch);
            }
        }
        left
    }

    /// Post-join shutdown sweep: on a clean drain this is empty (live
    /// workers emptied the queues, and a dying last worker already
    /// drained via [`ShardQueues::worker_exited`]); anything left is a
    /// queued-but-unserved job the caller must fail.
    fn drain_residual(&self) -> Vec<Job> {
        let mut g = lock(&self.central);
        self.drain_with(&mut g)
    }
}

/// Decrements `workers_alive` however the worker exits — the unwind
/// path is what keeps a panicking backend from hanging the batcher,
/// queued tickets, and `shutdown`: when the last worker dies, the
/// guard fails everything still queued on the spot.
struct WorkerGuard {
    queues: Arc<ShardQueues>,
    stats: Arc<Stats>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let residual = self.queues.worker_exited();
        if !residual.is_empty() {
            answer_all_err(residual, "no live workers to serve the request", &self.stats, None);
        }
    }
}

/// The serving coordinator. Create with [`Coordinator::start`], submit
/// vectors with [`Coordinator::submit`] (or the batch/callback/sparse
/// variants), stop with [`Coordinator::shutdown`] (also runs on drop).
pub struct Coordinator {
    /// Per-shard submit lanes (one bounded channel per shard, each
    /// drained by its own batcher). `None` after shutdown.
    submit_tx: Option<Vec<SyncSender<Job>>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    queues: Arc<ShardQueues>,
    stats: Arc<Stats>,
    spec: BackendSpec,
    /// Effective batch cap (config clamped to the backend spec) — the
    /// chunk size for the pre-formed full-batch bypass.
    max_batch: usize,
    /// Round-robin shard cursor for directly pushed batches; the
    /// batchers keep their own home shards, and shard choice is
    /// scheduling, never semantics, so the cursors need no
    /// coordination.
    direct_shard: AtomicUsize,
    /// Round-robin cursor over the submit lanes — like `direct_shard`,
    /// purely scheduling.
    ingress_cursor: AtomicUsize,
}

impl Coordinator {
    /// Spin up the batcher + sharded workers over a backend factory.
    pub fn start(factory: Arc<dyn BackendFactory>, config: CoordinatorConfig) -> Coordinator {
        let stats = Arc::new(Stats::new());
        let spec = factory.spec();
        let max_batch = config.max_batch.min(spec.max_batch).max(1);
        let workers = config.workers.max(1);
        let shards = if config.shards == 0 { workers } else { config.shards };
        // Pool-wide batch bound: enough to keep workers busy without
        // hoarding requests away from latency accounting.
        let queues = Arc::new(ShardQueues::new(shards, workers, (workers * 2).max(shards)));

        let mut threads = Vec::new();

        // Per-shard ingress: one bounded lane + one batcher per shard,
        // mirroring the worker-side deque topology. The total submit
        // depth stays `queue_depth`, split across the lanes. The last
        // batcher to see its lane close closes the shard queues
        // (`ShardQueues::close` is idempotent, so the race is benign).
        let lane_depth = (config.queue_depth / shards).max(1);
        let batchers_alive = Arc::new(AtomicUsize::new(shards));
        let mut submit_tx = Vec::with_capacity(shards);
        for s in 0..shards {
            let (lane_tx, lane_rx) = sync_channel::<Job>(lane_depth);
            submit_tx.push(lane_tx);
            let stats = stats.clone();
            let queues = queues.clone();
            let max_wait = config.max_wait;
            let alive = batchers_alive.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rfdot-batcher-{s}"))
                    .spawn(move || {
                        batcher_loop(lane_rx, s, queues, max_batch, max_wait, stats, alive);
                    })
                    .expect("spawn batcher"),
            );
        }

        // Worker threads (each builds its own thread-local backend and
        // owns shard `w % shards`).
        for w in 0..workers {
            let queues = queues.clone();
            let factory = factory.clone();
            let stats = stats.clone();
            let intra_op_threads = config.intra_op_threads;
            let home = w % shards;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rfdot-worker-{w}"))
                    .spawn(move || worker_loop(home, queues, factory, stats, intra_op_threads))
                    .expect("spawn worker"),
            );
        }

        Coordinator {
            submit_tx: Some(submit_tx),
            threads,
            queues,
            stats,
            spec,
            max_batch,
            direct_shard: AtomicUsize::new(0),
            ingress_cursor: AtomicUsize::new(0),
        }
    }

    /// Submit one vector; returns a [`Ticket`] for the reply, or an
    /// immediate backpressure/shape error.
    pub fn submit(&self, x: Vec<f32>) -> Result<Ticket> {
        self.check_dense(&x)?;
        self.submit_payload(Payload::Dense(x))
    }

    /// Submit one CSR vector as (index, value) pairs — indices strictly
    /// ascending and `< input_dim` (validated, like LIBSVM rows). The
    /// request rides the same queue, batching, padding and exactly-once
    /// reply machinery as [`Coordinator::submit`]; the reply equals the
    /// dense submission of the densified vector.
    pub fn submit_sparse(&self, indices: Vec<u32>, values: Vec<f32>) -> Result<Ticket> {
        self.check_sparse(&indices, &values)?;
        self.submit_payload(Payload::Sparse { indices, values })
    }

    /// Submit one vector with a completion callback instead of a
    /// ticket — the push-based async surface (no external runtime).
    /// The callback runs exactly once iff this call returns `Ok`:
    /// normally on the worker thread that answers the request, or with
    /// an error on whichever coordinator thread tears the job down
    /// (worker panic unwind, queue drain). Keep it cheap and
    /// non-panicking (hand the reply to a channel or task queue) — it
    /// runs inside the serving hot loop and possibly during unwinding.
    pub fn submit_callback(
        &self,
        x: Vec<f32>,
        callback: impl FnOnce(Result<Vec<f32>>) + Send + 'static,
    ) -> Result<()> {
        self.check_dense(&x)?;
        let _span = obs::span("serve.submit");
        self.enqueue(Job::new(Payload::Dense(x), Reply::Callback(Box::new(callback))))
    }

    /// CSR twin of [`Coordinator::submit_callback`]: one sparse row,
    /// validated like [`Coordinator::submit_sparse`], answered through a
    /// completion callback with the same exactly-once contract (the
    /// network front-end's reply path rides this surface).
    pub fn submit_sparse_callback(
        &self,
        indices: Vec<u32>,
        values: Vec<f32>,
        callback: impl FnOnce(Result<Vec<f32>>) + Send + 'static,
    ) -> Result<()> {
        self.check_sparse(&indices, &values)?;
        let _span = obs::span("serve.submit");
        self.enqueue(Job::new(
            Payload::Sparse { indices, values },
            Reply::Callback(Box::new(callback)),
        ))
    }

    /// Submit a whole batch of vectors through one shared reply
    /// channel, amortizing the per-request ticket/channel overhead.
    /// Shape errors fail the whole call before anything is queued;
    /// per-request backpressure rejections land in the corresponding
    /// reply slots ([`BatchTicket::accepted`] tells how many got in).
    pub fn submit_batch(&self, xs: Vec<Vec<f32>>) -> Result<BatchTicket> {
        for x in &xs {
            self.check_dense(x)?;
        }
        Ok(self.submit_batch_payloads(xs.into_iter().map(Payload::Dense).collect()))
    }

    /// CSR twin of [`Coordinator::submit_batch`]: each row is (indices,
    /// values) pairs validated like [`Coordinator::submit_sparse`];
    /// replies equal the dense submissions of the densified rows.
    pub fn submit_batch_sparse(
        &self,
        rows: Vec<(Vec<u32>, Vec<f32>)>,
    ) -> Result<BatchTicket> {
        for (indices, values) in &rows {
            self.check_sparse(indices, values)?;
        }
        Ok(self.submit_batch_payloads(
            rows.into_iter()
                .map(|(indices, values)| Payload::Sparse { indices, values })
                .collect(),
        ))
    }

    fn submit_batch_payloads(&self, payloads: Vec<Payload>) -> BatchTicket {
        let _span = obs::span("serve.submit");
        let n = payloads.len();
        let (tx, rx) = sync_channel::<(u32, Result<Vec<f32>>)>(n.max(1));
        let mut results: Vec<Option<Result<Vec<f32>>>> = Vec::with_capacity(n);
        let mut pending = 0usize;
        let mut payloads = payloads.into_iter().enumerate();

        // Pre-formed full batches bypass the batcher: a client batch of
        // `k * max_batch + tail` rows already *is* `k` backend batches,
        // so funneling the rows one by one through the submit channel
        // just to have the batcher thread re-coalesce them buys nothing
        // and serializes on that channel. Carve full chunks off the
        // front and push each straight onto a shard (non-blocking; the
        // bypass mirrors the batcher's stats so the accounting
        // invariants — submitted == completed, Σ shard items ==
        // batched_items — are topology-blind). The first chunk the pool
        // has no room for ends the bypass; it and the remaining rows
        // take the per-job path below, which owns the backpressure
        // semantics (accept what fits, reject the rest into the reply
        // slots).
        if self.submit_tx.is_some() {
            while payloads.len() >= self.max_batch {
                let chunk: Vec<Job> = payloads
                    .by_ref()
                    .take(self.max_batch)
                    .map(|(i, p)| Job::new(p, Reply::Indexed(tx.clone(), i as u32)))
                    .collect();
                let len = chunk.len();
                let shard = self.direct_shard.fetch_add(1, Ordering::Relaxed)
                    % self.queues.shards.len();
                match self.queues.try_push(shard, chunk) {
                    Ok(()) => {
                        self.stats.submitted.fetch_add(len as u64, Ordering::Relaxed);
                        self.stats.batches.fetch_add(1, Ordering::Relaxed);
                        self.stats.direct_batches.fetch_add(1, Ordering::Relaxed);
                        self.stats.batched_items.fetch_add(len as u64, Ordering::Relaxed);
                        for _ in 0..len {
                            results.push(None);
                        }
                        pending += len;
                    }
                    Err(chunk) => {
                        for job in chunk {
                            match self.enqueue(job) {
                                Ok(()) => {
                                    results.push(None);
                                    pending += 1;
                                }
                                Err(e) => results.push(Some(Err(e))),
                            }
                        }
                        break;
                    }
                }
            }
        }
        for (i, payload) in payloads {
            let job = Job::new(payload, Reply::Indexed(tx.clone(), i as u32));
            match self.enqueue(job) {
                Ok(()) => {
                    results.push(None);
                    pending += 1;
                }
                Err(e) => results.push(Some(Err(e))),
            }
        }
        BatchTicket { rx, results, pending, accepted: pending }
    }

    fn check_dense(&self, x: &[f32]) -> Result<()> {
        if x.len() != self.spec.input_dim {
            return Err(Error::shape(
                format!("dim {}", self.spec.input_dim),
                format!("{}", x.len()),
            ));
        }
        Ok(())
    }

    fn check_sparse(&self, indices: &[u32], values: &[f32]) -> Result<()> {
        if indices.len() != values.len() {
            return Err(Error::shape(
                format!("{} indices", indices.len()),
                format!("{} values", values.len()),
            ));
        }
        for (p, &k) in indices.iter().enumerate() {
            if k as usize >= self.spec.input_dim {
                return Err(Error::Data(format!(
                    "sparse index {k} out of range (dim = {})",
                    self.spec.input_dim
                )));
            }
            if p > 0 && indices[p - 1] >= k {
                return Err(Error::Data(format!(
                    "sparse indices must be strictly ascending ({} then {k})",
                    indices[p - 1]
                )));
            }
        }
        Ok(())
    }

    fn submit_payload(&self, payload: Payload) -> Result<Ticket> {
        let _span = obs::span("serve.submit");
        let (reply_tx, reply_rx) = sync_channel(1);
        self.enqueue(Job::new(payload, Reply::Channel(reply_tx)))?;
        Ok(Ticket { rx: reply_rx, taken: false })
    }

    fn enqueue(&self, job: Job) -> Result<()> {
        if let Err(e) = crate::faults::failpoint("coord.submit") {
            job.disarm();
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let lanes = match self.submit_tx.as_ref() {
            Some(lanes) => lanes,
            None => {
                job.disarm();
                return Err(Error::Coordinator("coordinator is shut down".into()));
            }
        };
        // Round-robin the submit lanes; a full lane falls through to
        // the next one, so backpressure only fires when every lane is
        // full. Lane choice is scheduling, never semantics. A lane whose
        // batcher died (injected panic) reports `Disconnected` — skip it
        // like a full one and keep scanning: one dead batcher must not
        // fail submissions while other lanes are live (regression:
        // `submissions_survive_a_dead_batcher_lane` in serve_shard.rs).
        let start = self.ingress_cursor.fetch_add(1, Ordering::Relaxed);
        let mut job = job;
        let mut dead_lanes = 0;
        for k in 0..lanes.len() {
            let lane = (start + k) % lanes.len();
            match lanes[lane].try_send(job) {
                Ok(()) => {
                    self.stats.submitted.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                Err(TrySendError::Full(j)) => job = j,
                Err(TrySendError::Disconnected(j)) => {
                    job = j;
                    dead_lanes += 1;
                }
            }
        }
        job.disarm();
        if dead_lanes == lanes.len() {
            return Err(Error::Coordinator("coordinator is shut down".into()));
        }
        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
        Err(Error::Coordinator("queue full (backpressure)".into()))
    }

    /// Convenience: submit and wait.
    pub fn transform(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(x)?.wait()
    }

    /// Output dimensionality of replies.
    pub fn output_dim(&self) -> usize {
        self.spec.output_dim
    }

    /// Live metrics handle.
    pub fn stats(&self) -> &Stats {
        &self.stats
    }

    /// Number of batch shards.
    pub fn shards(&self) -> usize {
        self.queues.shards.len()
    }

    /// Point-in-time per-shard metrics (batches, items, steal counts,
    /// latency percentiles), in shard order.
    pub fn shard_snapshots(&self) -> Vec<ShardSnapshot> {
        self.queues
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| ShardSnapshot {
                shard: i,
                batches: s.stats.batches.load(Ordering::Relaxed),
                items: s.stats.items.load(Ordering::Relaxed),
                steals: s.stats.steals.load(Ordering::Relaxed),
                latency_us: s.stats.latency_us.summary(),
            })
            .collect()
    }

    /// Pool-wide latency histogram: every shard's histogram merged
    /// into one (bucket-count merging is exact and associative — see
    /// [`obs::Histogram::merge_from`]).
    pub fn merged_latency(&self) -> obs::Histogram {
        let merged = obs::Histogram::new();
        for s in &self.queues.shards {
            merged.merge_from(&s.stats.latency_us);
        }
        merged
    }

    /// Stop accepting requests, drain in-flight batches, join threads.
    /// Every request accepted before the call is still answered exactly
    /// once: drained batches get real replies; jobs orphaned by worker
    /// deaths were already failed when the last worker went down (the
    /// worker guard drains the queues), and the post-join sweep here
    /// backstops with an explicit shutdown error — never a hang (see
    /// `shutdown_fails_queued_unserved_tickets_explicitly` in
    /// `rust/tests/serve_shard.rs`).
    pub fn shutdown(&mut self) {
        self.submit_tx.take(); // closes every submit lane
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let leftover = self.queues.drain_residual();
        if !leftover.is_empty() {
            answer_all_err(
                leftover,
                "coordinator shut down before the request was served",
                &self.stats,
                None,
            );
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Decrements `batchers_alive` however the batcher exits. The unwind
/// path matters: a batcher that dies mid-batch (injected panic at
/// `coord.batch_form`) must still count itself out, or the last live
/// count never reaches zero, `ShardQueues::close` never fires, and
/// workers + `shutdown` hang forever waiting on `work_cv` (regression:
/// `batcher_panic_still_closes_the_shard_queues` in serve_shard.rs).
/// The in-flight batch itself is answered by `Job::drop` during the
/// unwind, so exactly-once holds on this path too.
struct BatcherGuard {
    queues: Arc<ShardQueues>,
    batchers_alive: Arc<AtomicUsize>,
}

impl Drop for BatcherGuard {
    fn drop(&mut self) {
        // The AcqRel decrement keeps the close after every lane's final
        // push; `close` is idempotent, so the last-out race is benign.
        if self.batchers_alive.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.queues.close();
        }
    }
}

fn batcher_loop(
    submit_rx: Receiver<Job>,
    home: usize,
    queues: Arc<ShardQueues>,
    max_batch: usize,
    max_wait: Duration,
    stats: Arc<Stats>,
    batchers_alive: Arc<AtomicUsize>,
) {
    let _guard = BatcherGuard { queues: queues.clone(), batchers_alive };
    loop {
        // Block for the first job of the batch.
        let first = match submit_rx.recv() {
            Ok(j) => j,
            // Lane closed and drained; the guard counts this batcher
            // out (and the last one out closes the shard queues).
            Err(_) => return,
        };
        let _span = obs::span("serve.batch_form");
        let mut batch = vec![first];
        let deadline = Instant::now() + max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match submit_rx.recv_timeout(deadline - now) {
                Ok(j) => batch.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Chaos site: a panic here unwinds through the guard (count
        // decremented, queues closed if last) and `Job::drop` answers
        // the formed batch; an error answers it explicitly.
        if let Err(e) = crate::faults::failpoint("coord.batch_form") {
            answer_all_err(batch, &e.to_string(), &stats, None);
            continue;
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_items.fetch_add(batch.len() as u64, Ordering::Relaxed);
        // Each batcher feeds its own home shard (lane s → shard s);
        // stealing rebalances stragglers.
        if let Err(batch) = queues.push(home, batch) {
            // Every worker is gone (they only die by panicking): answer
            // the accepted jobs instead of hanging their waits.
            answer_all_err(batch, "no live workers to serve the request", &stats, None);
        }
    }
}

fn worker_loop(
    home: usize,
    queues: Arc<ShardQueues>,
    factory: Arc<dyn BackendFactory>,
    stats: Arc<Stats>,
    intra_op_threads: usize,
) {
    // Liveness accounting survives panics (the guard's drop runs on
    // unwind, after the in-flight batch answered through `Job::drop`),
    // which is what keeps queued tickets and `shutdown` from hanging
    // after a worker dies.
    let _guard = WorkerGuard { queues: queues.clone(), stats: stats.clone() };
    // Build the thread-local backend; on failure, keep serving errors so
    // accepted requests are still answered exactly once.
    let mut backend = factory.build();
    if let Ok(b) = backend.as_mut() {
        b.set_intra_op_threads(intra_op_threads);
    }
    let spec = factory.spec();
    while let Some((shard, batch)) = queues.pop(home) {
        let shard_stats = &queues.shards[shard].stats;
        shard_stats.batches.fetch_add(1, Ordering::Relaxed);
        shard_stats.items.fetch_add(batch.len() as u64, Ordering::Relaxed);
        if shard != home {
            shard_stats.steals.fetch_add(1, Ordering::Relaxed);
            obs::trace::mark("serve.steal");
            // Chaos site: fires only on stolen batches. A panic unwinds
            // through `Job::drop` + `WorkerGuard`; an error answers the
            // batch here — either way every job is answered once.
            if let Err(e) = crate::faults::failpoint("coord.steal") {
                answer_all_err(batch, &e.to_string(), &stats, Some(shard_stats));
                continue;
            }
        }
        // Chaos site for the PR 5 worker-death path: `panic` kills this
        // worker mid-claim, exercising the guard's drain-and-fail of
        // everything still queued when the last worker dies.
        if let Err(e) = crate::faults::failpoint("coord.worker_panic") {
            answer_all_err(batch, &e.to_string(), &stats, Some(shard_stats));
            continue;
        }
        let backend = match &backend {
            Ok(b) => b,
            Err(e) => {
                stats.backend_errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("backend build failed: {e}");
                answer_all_err(batch, &msg, &stats, Some(shard_stats));
                continue;
            }
        };
        let n = batch.len();
        // Fixed-shape backends require padding to their batch size.
        let padded = if spec.fixed_batch { spec.max_batch } else { n };
        stats.pad_slots.fetch_add((padded - n) as u64, Ordering::Relaxed);
        let mut x = crate::linalg::Matrix::zeros(padded, spec.input_dim);
        for (i, job) in batch.iter().enumerate() {
            // Rows start zeroed, so sparse payloads only scatter.
            job.x.scatter_into(x.row_mut(i));
        }
        let run = {
            let _span = obs::span("serve.run_batch");
            backend.run_batch(&x)
        };
        match run {
            Ok(out) => {
                let _span = obs::span("serve.reply");
                // Chaos site: an error downgrades the whole batch to
                // error replies (still exactly once); a panic drops the
                // jobs and `Job::drop` answers them during the unwind.
                if let Err(e) = crate::faults::failpoint("coord.reply") {
                    answer_all_err(batch, &e.to_string(), &stats, Some(shard_stats));
                    continue;
                }
                for (i, mut job) in batch.into_iter().enumerate() {
                    let row = out.row(i).to_vec();
                    stats.completed.fetch_add(1, Ordering::Relaxed);
                    let lat = job.submitted.elapsed();
                    stats.record_latency(lat);
                    // Lock-free histogram record, per reply.
                    shard_stats.latency_us.record_f64(lat.as_secs_f64() * 1e6);
                    job.respond(Ok(row));
                }
            }
            Err(e) => {
                stats.backend_errors.fetch_add(1, Ordering::Relaxed);
                answer_all_err(batch, &e.to_string(), &stats, Some(shard_stats));
            }
        }
    }
}

fn answer_all_err(batch: Vec<Job>, msg: &str, stats: &Stats, shard: Option<&ShardStats>) {
    for mut job in batch {
        stats.completed.fetch_add(1, Ordering::Relaxed);
        let lat = job.submitted.elapsed();
        stats.record_latency(lat);
        if let Some(s) = shard {
            s.latency_us.record_f64(lat.as_secs_f64() * 1e6);
        }
        job.respond(Err(Error::Coordinator(msg.to_string())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::FeatureMap;
    use crate::kernels::Polynomial;
    use crate::maclaurin::{RandomMaclaurin, RmConfig};
    use crate::rng::Rng;

    fn native_factory(d: usize, n_feat: usize) -> (Arc<dyn BackendFactory>, Arc<RandomMaclaurin>) {
        let mut rng = Rng::seed_from(1);
        let map = Arc::new(RandomMaclaurin::sample(
            &Polynomial::new(3, 1.0),
            d,
            n_feat,
            RmConfig::default(),
            &mut rng,
        ));
        (Arc::new(NativeFactory::new(map.clone())), map)
    }

    #[test]
    fn single_request_roundtrip() {
        let (factory, map) = native_factory(4, 16);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let x = vec![0.1, -0.2, 0.3, 0.0];
        let z = coord.transform(x.clone()).unwrap();
        assert_eq!(z.len(), 16);
        assert_eq!(z, map.transform(&x));
    }

    #[test]
    fn intra_op_parallel_replies_match_serial_map() {
        // With intra-op threads > 1 the native backend fans each batch
        // out across the worker pool; replies must still be bit-identical
        // to the single-threaded transform. Submit a burst *before*
        // waiting so the batcher coalesces multi-row batches — a single
        // blocking transform() would only ever produce 1-row batches,
        // which the thread clamp runs inline.
        let (factory, map) = native_factory(5, 24);
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig {
                intra_op_threads: 4,
                workers: 1,
                max_wait: Duration::from_millis(20),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from(77);
        let inputs: Vec<Vec<f32>> =
            (0..40).map(|_| (0..5).map(|_| rng.f32() - 0.5).collect()).collect();
        let tickets: Vec<_> =
            inputs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
        for (x, t) in inputs.iter().zip(tickets) {
            assert_eq!(t.wait().unwrap(), map.transform(x));
        }
        // The burst must have produced at least one multi-row batch.
        let batches = coord.stats().batches.load(Ordering::Relaxed);
        assert!(batches < 40, "every batch was single-row ({batches} batches for 40 requests)");
    }

    #[test]
    fn rejects_wrong_dim() {
        let (factory, _) = native_factory(4, 8);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        assert!(coord.submit(vec![0.0; 3]).is_err());
        assert!(coord.submit_batch(vec![vec![0.0; 4], vec![0.0; 3]]).is_err());
        assert!(coord.submit_callback(vec![0.0; 5], |_| {}).is_err());
    }

    #[test]
    fn sparse_submit_matches_dense_submit() {
        // submit_sparse rides the same machinery: the reply must equal
        // the dense submission of the densified vector, exactly.
        let (factory, map) = native_factory(6, 24);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let indices = vec![0u32, 2, 5];
        let values = vec![0.4f32, -0.7, 0.25];
        let mut dense = vec![0.0f32; 6];
        for (&k, &v) in indices.iter().zip(&values) {
            dense[k as usize] = v;
        }
        let zs = coord.submit_sparse(indices, values).unwrap().wait().unwrap();
        let zd = coord.transform(dense.clone()).unwrap();
        assert_eq!(zs, zd);
        assert_eq!(zs, map.transform(&dense));
        // The empty sparse vector is the zero vector.
        let z0 = coord.submit_sparse(vec![], vec![]).unwrap().wait().unwrap();
        assert_eq!(z0, map.transform(&[0.0f32; 6]));
    }

    #[test]
    fn sparse_submit_validates_indices() {
        let (factory, _) = native_factory(4, 8);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        // Length mismatch.
        assert!(coord.submit_sparse(vec![0], vec![]).is_err());
        // Out of range.
        assert!(coord.submit_sparse(vec![4], vec![1.0]).is_err());
        // Duplicate / descending.
        assert!(coord.submit_sparse(vec![1, 1], vec![1.0, 2.0]).is_err());
        assert!(coord.submit_sparse(vec![2, 0], vec![1.0, 2.0]).is_err());
        // Batch validation is all-or-nothing, before anything queues.
        assert!(coord
            .submit_batch_sparse(vec![(vec![0], vec![1.0]), (vec![9], vec![1.0])])
            .is_err());
        // None of the rejects consumed a queue slot.
        assert_eq!(coord.stats().submitted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn batch_submission_replies_in_order() {
        let (factory, map) = native_factory(3, 12);
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig { max_batch: 4, workers: 2, ..Default::default() },
        );
        let mut rng = Rng::seed_from(5);
        let xs: Vec<Vec<f32>> =
            (0..11).map(|_| (0..3).map(|_| rng.f32() - 0.5).collect()).collect();
        let ticket = coord.submit_batch(xs.clone()).unwrap();
        assert_eq!(ticket.accepted(), 11);
        let replies = ticket.wait();
        assert_eq!(replies.len(), 11);
        for (x, r) in xs.iter().zip(replies) {
            assert_eq!(r.unwrap(), map.transform(x), "batch reply out of order");
        }
        // The empty batch is legal and resolves immediately.
        assert!(coord.submit_batch(Vec::new()).unwrap().wait().is_empty());
    }

    #[test]
    fn full_batch_bypass_keeps_order_exactly_once_and_stats() {
        // 11 rows at max_batch = 4: two full chunks take the direct
        // shard push, the 3-row tail rides the batcher. The pool bound
        // is (workers * 2).max(shards) = 4, so both direct pushes fit
        // deterministically and the bypass is observable in the meter.
        let (factory, map) = native_factory(3, 12);
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig { max_batch: 4, workers: 2, ..Default::default() },
        );
        let mut rng = Rng::seed_from(11);
        let xs: Vec<Vec<f32>> =
            (0..11).map(|_| (0..3).map(|_| rng.f32() - 0.5).collect()).collect();
        let ticket = coord.submit_batch(xs.clone()).unwrap();
        assert_eq!(ticket.accepted(), 11);
        let replies = ticket.wait();
        assert_eq!(replies.len(), 11);
        for (i, (x, r)) in xs.iter().zip(replies).enumerate() {
            assert_eq!(r.unwrap(), map.transform(x), "bypass reply {i} out of order");
        }
        let stats = coord.stats();
        assert_eq!(stats.direct_batches.load(Ordering::Relaxed), 2);
        assert_eq!(stats.submitted.load(Ordering::Relaxed), 11);
        assert_eq!(stats.completed.load(Ordering::Relaxed), 11);
        assert_eq!(stats.batched_items.load(Ordering::Relaxed), 11);
        // Direct chunks count as batches like batcher-built ones; the
        // tail coalesces into 1..=3 batches depending on timing.
        let batches = stats.batches.load(Ordering::Relaxed);
        assert!((3..=5).contains(&batches), "batches = {batches}");

        // An exact multiple of max_batch bypasses the batcher entirely,
        // sparse rows included (they share submit_batch_payloads).
        let rows: Vec<(Vec<u32>, Vec<f32>)> = (0..8)
            .map(|_| (vec![0u32, 2], vec![rng.f32() - 0.5, rng.f32() - 0.5]))
            .collect();
        let replies = coord.submit_batch_sparse(rows.clone()).unwrap().wait();
        for ((indices, values), r) in rows.iter().zip(replies) {
            let mut dense = vec![0.0f32; 3];
            for (&k, &v) in indices.iter().zip(values) {
                dense[k as usize] = v;
            }
            assert_eq!(r.unwrap(), map.transform(&dense));
        }
        assert_eq!(stats.direct_batches.load(Ordering::Relaxed), 4);
        assert_eq!(stats.submitted.load(Ordering::Relaxed), 19);
        assert_eq!(stats.completed.load(Ordering::Relaxed), 19);
        // Worker-side shard accounting is topology-blind: every item a
        // worker saw — direct or batcher-built — lands in shard stats.
        let shard_items: u64 = coord.shard_snapshots().iter().map(|s| s.items).sum();
        assert_eq!(shard_items, stats.batched_items.load(Ordering::Relaxed));
    }

    #[test]
    fn batch_backpressure_slots_keep_reply_accounting_exact() {
        // Rejected slots must carry exactly their backpressure error and
        // never consume an accepted slot's reply (the Job drop guard is
        // disarmed for never-enqueued jobs).
        struct SlowEcho;
        impl Backend for SlowEcho {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false }
            }
            fn run_batch(&self, x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                std::thread::sleep(Duration::from_millis(10));
                Ok(x.clone())
            }
        }
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false },
            f: || Ok(Box::new(SlowEcho) as Box<dyn Backend>),
        });
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig {
                max_batch: 1,
                queue_depth: 2,
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let xs: Vec<Vec<f32>> = (0..30).map(|i| vec![i as f32, -(i as f32)]).collect();
        let ticket = coord.submit_batch(xs.clone()).unwrap();
        let accepted = ticket.accepted();
        assert!(accepted < 30, "the tiny queue must reject part of the batch");
        assert!(accepted > 0, "the queue must accept part of the batch");
        let replies = ticket.wait();
        assert_eq!(replies.len(), 30);
        let mut ok = 0;
        for (x, r) in xs.iter().zip(&replies) {
            match r {
                Ok(z) => {
                    assert_eq!(z, x, "reply landed in the wrong slot");
                    ok += 1;
                }
                Err(e) => assert!(
                    e.to_string().contains("backpressure"),
                    "rejected slot must carry its own error, got {e}"
                ),
            }
        }
        assert_eq!(ok, accepted, "every accepted request must produce exactly one Ok reply");
    }

    #[test]
    fn poll_surface_delivers_exactly_once() {
        let (factory, map) = native_factory(4, 8);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let x = vec![0.3f32, -0.1, 0.0, 0.9];
        let mut ticket = coord.submit(x.clone()).unwrap();
        let reply = loop {
            match ticket.poll() {
                Some(r) => break r,
                None => std::thread::sleep(Duration::from_micros(200)),
            }
        };
        assert_eq!(reply.unwrap(), map.transform(&x));
        // The ticket is spent: further polls surface an error, they
        // never hang or double-deliver.
        match ticket.poll() {
            Some(Err(e)) => assert!(e.to_string().contains("already taken"), "{e}"),
            other => panic!("spent ticket must answer with an error, got {other:?}"),
        }
    }

    #[test]
    fn callback_surface_runs_on_completion() {
        let (factory, map) = native_factory(4, 8);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let x = vec![0.5f32, 0.25, -0.5, 0.1];
        let (tx, rx) = std::sync::mpsc::channel();
        coord
            .submit_callback(x.clone(), move |r| {
                tx.send(r).unwrap();
            })
            .unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.unwrap(), map.transform(&x));
    }

    #[test]
    fn shared_topology_and_sharded_topology_answer_identically() {
        // shards = 1 is the pre-shard shared queue; any other shard
        // count must produce bit-identical replies (scheduling, never
        // semantics).
        let (factory, map) = native_factory(5, 16);
        let mut rng = Rng::seed_from(31);
        let inputs: Vec<Vec<f32>> =
            (0..24).map(|_| (0..5).map(|_| rng.f32() - 0.5).collect()).collect();
        for shards in [1usize, 2, 4] {
            let coord = Coordinator::start(
                factory.clone(),
                CoordinatorConfig { workers: 3, shards, ..Default::default() },
            );
            assert_eq!(coord.shards(), shards);
            let tickets: Vec<_> =
                inputs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
            for (x, t) in inputs.iter().zip(tickets) {
                assert_eq!(t.wait().unwrap(), map.transform(x), "shards={shards}");
            }
            // Per-shard accounting covers every batch exactly once.
            let snaps = coord.shard_snapshots();
            assert_eq!(snaps.len(), shards);
            let batches: u64 = snaps.iter().map(|s| s.batches).sum();
            assert_eq!(batches, coord.stats().batches.load(Ordering::Relaxed));
            let items: u64 = snaps.iter().map(|s| s.items).sum();
            assert_eq!(items, coord.stats().batched_items.load(Ordering::Relaxed));
            let recorded: usize = snaps.iter().map(|s| s.latency_us.n).sum();
            assert_eq!(recorded as u64, coord.stats().completed.load(Ordering::Relaxed));
        }
    }

    #[test]
    fn pad_accounting_balances_for_every_ragged_tail() {
        // Property-style satellite: against a fixed-shape backend, drive
        // bursts of every size 1..=max_batch and check that (a) each
        // reply is the echo of its own input (reply slicing is correct
        // whatever the padding), and (b) the metered pad slots balance
        // exactly: pad_slots == batches·B − batched_items, whatever
        // batch boundaries the scheduler happened to pick.
        struct Echo;
        impl Backend for Echo {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 3, output_dim: 3, max_batch: 4, fixed_batch: true }
            }
            fn run_batch(&self, x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                assert_eq!(x.rows(), 4, "fixed batch must always be padded to full size");
                Ok(x.clone())
            }
        }
        let b = 4usize;
        for tail in 1..=b {
            let factory = Arc::new(ClosureFactory {
                spec: BackendSpec { input_dim: 3, output_dim: 3, max_batch: b, fixed_batch: true },
                f: || Ok(Box::new(Echo) as Box<dyn Backend>),
            });
            let mut coord = Coordinator::start(
                factory,
                CoordinatorConfig {
                    max_batch: b,
                    max_wait: Duration::from_millis(5),
                    workers: 1,
                    ..Default::default()
                },
            );
            let inputs: Vec<Vec<f32>> =
                (0..tail).map(|i| vec![i as f32, 10.0 + i as f32, -(i as f32)]).collect();
            let tickets: Vec<_> =
                inputs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
            for (x, t) in inputs.iter().zip(tickets) {
                assert_eq!(&t.wait().unwrap(), x, "tail {tail}: reply must echo its own input");
            }
            coord.shutdown();
            let stats = coord.stats();
            let batches = stats.batches.load(Ordering::Relaxed);
            let items = stats.batched_items.load(Ordering::Relaxed);
            let pads = stats.pad_slots.load(Ordering::Relaxed);
            assert_eq!(items, tail as u64, "tail {tail}");
            assert!(batches >= 1, "tail {tail}");
            assert_eq!(
                pads,
                batches * b as u64 - items,
                "tail {tail}: pad accounting must balance ({batches} batches, {items} items)"
            );
        }
    }

    #[test]
    fn many_concurrent_clients_all_answered_exactly_once() {
        let (factory, _) = native_factory(6, 32);
        let coord = Arc::new(Coordinator::start(
            factory,
            CoordinatorConfig { max_batch: 16, workers: 3, ..Default::default() },
        ));
        let clients = 8;
        let per_client = 50;
        let mut handles = Vec::new();
        for c in 0..clients {
            let coord = coord.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from(c as u64);
                let mut got = 0usize;
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..6).map(|_| rng.f32() - 0.5).collect();
                    match coord.submit(x) {
                        Ok(t) => {
                            t.wait().unwrap();
                            got += 1;
                        }
                        Err(_) => {} // backpressure: allowed
                    }
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let stats = coord.stats();
        assert_eq!(total as u64, stats.completed.load(Ordering::Relaxed));
        assert_eq!(
            stats.submitted.load(Ordering::Relaxed),
            stats.completed.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn replies_are_routed_to_the_right_client() {
        // Content check: each client's reply must be the transform of
        // *its own* input.
        let (factory, map) = native_factory(3, 8);
        let coord = Arc::new(Coordinator::start(
            factory,
            CoordinatorConfig { max_batch: 4, workers: 2, ..Default::default() },
        ));
        let mut handles = Vec::new();
        for c in 0..6 {
            let coord = coord.clone();
            let map = map.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from(100 + c as u64);
                for _ in 0..25 {
                    let x: Vec<f32> = (0..3).map(|_| rng.f32() - 0.5).collect();
                    if let Ok(t) = coord.submit(x.clone()) {
                        let z = t.wait().unwrap();
                        assert_eq!(z, map.transform(&x), "client {c} got someone else's reply");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // A slow backend + tiny queue must surface rejections instead of
        // queueing without bound.
        struct Slow;
        impl Backend for Slow {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false }
            }
            fn run_batch(&self, x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                std::thread::sleep(Duration::from_millis(20));
                Ok(x.clone())
            }
        }
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false },
            f: || Ok(Box::new(Slow) as Box<dyn Backend>),
        });
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig {
                max_batch: 1,
                queue_depth: 2,
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let mut rejected = 0;
        let mut tickets = Vec::new();
        for _ in 0..50 {
            match coord.submit(vec![0.0, 0.0]) {
                Ok(t) => tickets.push(t),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn backend_errors_propagate_to_every_job() {
        struct Failing;
        impl Backend for Failing {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: false }
            }
            fn run_batch(&self, _x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                Err(Error::Runtime("injected failure".into()))
            }
        }
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: false },
            f: || Ok(Box::new(Failing) as Box<dyn Backend>),
        });
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let tickets: Vec<_> =
            (0..10).filter_map(|_| coord.submit(vec![1.0, 2.0]).ok()).collect();
        for t in tickets {
            let err = t.wait().unwrap_err();
            assert!(err.to_string().contains("injected failure"));
        }
        assert!(coord.stats().backend_errors.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn worker_build_failure_still_answers() {
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: false },
            f: || Err(Error::Runtime("no such artifact".into())),
        });
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let t = coord.submit(vec![1.0, 2.0]).unwrap();
        let err = t.wait().unwrap_err();
        assert!(err.to_string().contains("backend build failed"), "{err}");
    }

    #[test]
    fn shutdown_drains_inflight() {
        let (factory, _) = native_factory(4, 8);
        let mut coord = Coordinator::start(
            factory,
            CoordinatorConfig { max_wait: Duration::from_millis(10), ..Default::default() },
        );
        let tickets: Vec<_> =
            (0..32).filter_map(|_| coord.submit(vec![0.1; 4]).ok()).collect();
        coord.shutdown();
        // Every accepted request must still be answered.
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        // New submissions are refused.
        assert!(coord.submit(vec![0.1; 4]).is_err());
    }

    #[test]
    fn batches_respect_max_batch() {
        let (factory, _) = native_factory(2, 4);
        let coord = Arc::new(Coordinator::start(
            factory,
            CoordinatorConfig {
                max_batch: 5,
                max_wait: Duration::from_millis(50),
                workers: 1,
                ..Default::default()
            },
        ));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let coord = coord.clone();
            handles.push(std::thread::spawn(move || {
                let tickets: Vec<_> =
                    (0..25).filter_map(|_| coord.submit(vec![0.5, 0.5]).ok()).collect();
                for t in tickets {
                    t.wait().unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let batches = coord.stats().batches.load(Ordering::Relaxed);
        let items = coord.stats().batched_items.load(Ordering::Relaxed);
        assert!(batches >= items / 5, "batch size exceeded: {items} items in {batches} batches");
    }

    #[test]
    fn padding_metered_for_fixed_batch() {
        // Fixed batch of 8 with single requests: each batch pads 7 slots.
        struct Echo;
        impl Backend for Echo {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: true }
            }
            fn run_batch(&self, x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                assert_eq!(x.rows(), 8, "fixed batch must always be full-size");
                Ok(x.clone())
            }
        }
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: true },
            f: || Ok(Box::new(Echo) as Box<dyn Backend>),
        });
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                workers: 1,
                ..Default::default()
            },
        );
        let t = coord.submit(vec![1.0, 2.0]).unwrap();
        assert_eq!(t.wait().unwrap(), vec![1.0, 2.0]);
        assert!(coord.stats().pad_slots.load(Ordering::Relaxed) >= 7);
    }
}
