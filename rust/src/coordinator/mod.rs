//! L3 serving coordinator: request router + dynamic batcher + workers.
//!
//! The paper's feature maps turn kernel-machine serving into *linear*
//! serving: transform a vector, dot it with a weight vector. This module
//! is the production shell around that hot path:
//!
//! ```text
//! clients ──submit(x)──▶ bounded queue ──▶ batcher thread
//!                                            │ (coalesce ≤ max_batch
//!                                            │  within max_wait)
//!                                            ▼
//!                                     batch queue ──▶ N worker threads
//!                                                       │ thread-local
//!                                                       │ Backend::run_batch
//!                                                       ▼
//!                                            per-request reply channels
//! ```
//!
//! * **Backpressure** — the submit queue is bounded; when full, callers
//!   get [`Error::Coordinator`] instead of unbounded memory growth.
//! * **Thread-local backends** — PJRT handles are `!Send`, so each
//!   worker builds its own executable from a shared [`BackendFactory`].
//! * **Fixed-shape backends** — the PJRT artifacts take a fixed batch;
//!   ragged tails are padded and the replies sliced (pad waste is
//!   metered in [`crate::metrics::Stats::pad_slots`]).
//! * **Exactly-once replies** — every accepted request receives exactly
//!   one reply, including on worker build failure, backend failure or
//!   shutdown drain; the tests in this module drive random schedules
//!   against that invariant.
//! * **Sparse submissions** — [`Coordinator::submit_sparse`] accepts
//!   CSR (index, value) pairs; they scatter into the same zeroed batch
//!   rows dense submissions copy into, so batching, padding and the
//!   exactly-once contract are shared and the reply equals the dense
//!   submission of the densified vector.

pub mod backend;

pub use backend::{
    Backend, BackendFactory, BackendSpec, ClosureFactory, NativeBackend, NativeFactory,
    PjrtBucketedBackend, PjrtBucketedFactory, PjrtScoreBackend, PjrtScoreFactory,
    PjrtTransformBackend, PjrtTransformFactory,
};

use crate::metrics::Stats;
use crate::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching/queueing policy.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Largest batch handed to the backend (clamped to the backend's
    /// own `max_batch`).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch after the first
    /// request arrives.
    pub max_wait: Duration,
    /// Bound on the submit queue (backpressure threshold).
    pub queue_depth: usize,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Data-parallel threads each worker's backend may use *within* a
    /// batch ([`Backend::set_intra_op_threads`]; honored by the native
    /// engine, ignored by PJRT). `0` = the global [`crate::parallel`]
    /// knob; the default of 1 keeps per-batch work serial because
    /// batches already fan out across `workers`.
    pub intra_op_threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 128,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            workers: 2,
            intra_op_threads: 1,
        }
    }
}

/// One request's feature payload: a dense vector or CSR index/value
/// pairs. Both scatter into the same batch matrix row, so the backend
/// (and the reply) cannot tell them apart — sparse submission is a
/// wire-format optimization, not a semantic fork.
enum Payload {
    Dense(Vec<f32>),
    Sparse { indices: Vec<u32>, values: Vec<f32> },
}

impl Payload {
    /// Write the payload into a zeroed batch row.
    fn scatter_into(&self, row: &mut [f32]) {
        match self {
            Payload::Dense(x) => row.copy_from_slice(x),
            Payload::Sparse { indices, values } => {
                for (&k, &v) in indices.iter().zip(values) {
                    row[k as usize] = v;
                }
            }
        }
    }
}

struct Job {
    x: Payload,
    submitted: Instant,
    reply: SyncSender<Result<Vec<f32>>>,
}

/// A handle to a reply; `wait` blocks until the coordinator answers.
pub struct Ticket {
    rx: Receiver<Result<Vec<f32>>>,
}

impl Ticket {
    /// Block for the result.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))?
    }

    /// Block with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<Vec<f32>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => {
                Err(Error::Coordinator("timed out waiting for reply".into()))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Coordinator("coordinator dropped the request".into()))
            }
        }
    }
}

/// The serving coordinator. Create with [`Coordinator::start`], submit
/// vectors with [`Coordinator::submit`], stop with
/// [`Coordinator::shutdown`] (also runs on drop).
pub struct Coordinator {
    submit_tx: Option<SyncSender<Job>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Stats>,
    spec: BackendSpec,
}

impl Coordinator {
    /// Spin up the batcher + workers over a backend factory.
    pub fn start(factory: Arc<dyn BackendFactory>, config: CoordinatorConfig) -> Coordinator {
        let stats = Arc::new(Stats::new());
        let spec = factory.spec();
        let max_batch = config.max_batch.min(spec.max_batch).max(1);
        let (submit_tx, submit_rx) = sync_channel::<Job>(config.queue_depth);
        // Batch queue depth: enough to keep workers busy without
        // hoarding requests away from latency accounting.
        let (batch_tx, batch_rx) = sync_channel::<Vec<Job>>(config.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let mut threads = Vec::new();

        // Batcher thread.
        {
            let stats = stats.clone();
            let max_wait = config.max_wait;
            threads.push(
                std::thread::Builder::new()
                    .name("rfdot-batcher".into())
                    .spawn(move || {
                        batcher_loop(submit_rx, batch_tx, max_batch, max_wait, stats);
                    })
                    .expect("spawn batcher"),
            );
        }

        // Worker threads (each builds its own thread-local backend).
        for w in 0..config.workers.max(1) {
            let rx = batch_rx.clone();
            let factory = factory.clone();
            let stats = stats.clone();
            let intra_op_threads = config.intra_op_threads;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rfdot-worker-{w}"))
                    .spawn(move || worker_loop(rx, factory, stats, intra_op_threads))
                    .expect("spawn worker"),
            );
        }

        Coordinator { submit_tx: Some(submit_tx), threads, stats, spec }
    }

    /// Submit one vector; returns a [`Ticket`] for the reply, or an
    /// immediate backpressure/shape error.
    pub fn submit(&self, x: Vec<f32>) -> Result<Ticket> {
        if x.len() != self.spec.input_dim {
            return Err(Error::shape(
                format!("dim {}", self.spec.input_dim),
                format!("{}", x.len()),
            ));
        }
        self.submit_payload(Payload::Dense(x))
    }

    /// Submit one CSR vector as (index, value) pairs — indices strictly
    /// ascending and `< input_dim` (validated, like LIBSVM rows). The
    /// request rides the same queue, batching, padding and exactly-once
    /// reply machinery as [`Coordinator::submit`]; the reply equals the
    /// dense submission of the densified vector.
    pub fn submit_sparse(&self, indices: Vec<u32>, values: Vec<f32>) -> Result<Ticket> {
        if indices.len() != values.len() {
            return Err(Error::shape(
                format!("{} indices", indices.len()),
                format!("{} values", values.len()),
            ));
        }
        for (p, &k) in indices.iter().enumerate() {
            if k as usize >= self.spec.input_dim {
                return Err(Error::Data(format!(
                    "sparse index {k} out of range (dim = {})",
                    self.spec.input_dim
                )));
            }
            if p > 0 && indices[p - 1] >= k {
                return Err(Error::Data(format!(
                    "sparse indices must be strictly ascending ({} then {k})",
                    indices[p - 1]
                )));
            }
        }
        self.submit_payload(Payload::Sparse { indices, values })
    }

    fn submit_payload(&self, payload: Payload) -> Result<Ticket> {
        let tx = self
            .submit_tx
            .as_ref()
            .ok_or_else(|| Error::Coordinator("coordinator is shut down".into()))?;
        let (reply_tx, reply_rx) = sync_channel(1);
        let job = Job { x: payload, submitted: Instant::now(), reply: reply_tx };
        match tx.try_send(job) {
            Ok(()) => {
                self.stats.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(Ticket { rx: reply_rx })
            }
            Err(TrySendError::Full(_)) => {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Coordinator("queue full (backpressure)".into()))
            }
            Err(TrySendError::Disconnected(_)) => {
                Err(Error::Coordinator("coordinator is shut down".into()))
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn transform(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(x)?.wait()
    }

    /// Output dimensionality of replies.
    pub fn output_dim(&self) -> usize {
        self.spec.output_dim
    }

    /// Live metrics handle.
    pub fn stats(&self) -> &Stats {
        &self.stats
    }

    /// Stop accepting requests, drain in-flight batches, join threads.
    pub fn shutdown(&mut self) {
        self.submit_tx.take(); // closes the submit queue
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn batcher_loop(
    submit_rx: Receiver<Job>,
    batch_tx: SyncSender<Vec<Job>>,
    max_batch: usize,
    max_wait: Duration,
    stats: Arc<Stats>,
) {
    loop {
        // Block for the first job of the batch.
        let first = match submit_rx.recv() {
            Ok(j) => j,
            Err(_) => return, // submit side closed: drain done
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match submit_rx.recv_timeout(deadline - now) {
                Ok(j) => batch.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_items.fetch_add(batch.len() as u64, Ordering::Relaxed);
        if batch_tx.send(batch).is_err() {
            return; // workers gone
        }
    }
}

fn worker_loop(
    batch_rx: Arc<Mutex<Receiver<Vec<Job>>>>,
    factory: Arc<dyn BackendFactory>,
    stats: Arc<Stats>,
    intra_op_threads: usize,
) {
    // Build the thread-local backend; on failure, keep serving errors so
    // accepted requests are still answered exactly once.
    let mut backend = factory.build();
    if let Ok(b) = backend.as_mut() {
        b.set_intra_op_threads(intra_op_threads);
    }
    let spec = factory.spec();
    loop {
        let batch = {
            let guard = batch_rx.lock().expect("batch queue lock");
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // batcher gone and queue drained
            }
        };
        let backend = match &backend {
            Ok(b) => b,
            Err(e) => {
                stats.backend_errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("backend build failed: {e}");
                answer_all_err(batch, &msg, &stats);
                continue;
            }
        };
        let n = batch.len();
        // Fixed-shape backends require padding to their batch size.
        let padded = if spec.fixed_batch { spec.max_batch } else { n };
        stats.pad_slots.fetch_add((padded - n) as u64, Ordering::Relaxed);
        let mut x = crate::linalg::Matrix::zeros(padded, spec.input_dim);
        for (i, job) in batch.iter().enumerate() {
            // Rows start zeroed, so sparse payloads only scatter.
            job.x.scatter_into(x.row_mut(i));
        }
        match backend.run_batch(&x) {
            Ok(out) => {
                for (i, job) in batch.into_iter().enumerate() {
                    let row = out.row(i).to_vec();
                    stats.completed.fetch_add(1, Ordering::Relaxed);
                    stats.record_latency(job.submitted.elapsed());
                    let _ = job.reply.send(Ok(row));
                }
            }
            Err(e) => {
                stats.backend_errors.fetch_add(1, Ordering::Relaxed);
                answer_all_err(batch, &e.to_string(), &stats);
            }
        }
    }
}

fn answer_all_err(batch: Vec<Job>, msg: &str, stats: &Stats) {
    for job in batch {
        stats.completed.fetch_add(1, Ordering::Relaxed);
        stats.record_latency(job.submitted.elapsed());
        let _ = job.reply.send(Err(Error::Coordinator(msg.to_string())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Polynomial;
    use crate::features::FeatureMap;
    use crate::maclaurin::{RandomMaclaurin, RmConfig};
    use crate::rng::Rng;

    fn native_factory(d: usize, n_feat: usize) -> (Arc<dyn BackendFactory>, Arc<RandomMaclaurin>) {
        let mut rng = Rng::seed_from(1);
        let map = Arc::new(RandomMaclaurin::sample(
            &Polynomial::new(3, 1.0),
            d,
            n_feat,
            RmConfig::default(),
            &mut rng,
        ));
        (Arc::new(NativeFactory::new(map.clone())), map)
    }

    #[test]
    fn single_request_roundtrip() {
        let (factory, map) = native_factory(4, 16);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let x = vec![0.1, -0.2, 0.3, 0.0];
        let z = coord.transform(x.clone()).unwrap();
        assert_eq!(z.len(), 16);
        assert_eq!(z, map.transform(&x));
    }

    #[test]
    fn intra_op_parallel_replies_match_serial_map() {
        // With intra-op threads > 1 the native backend fans each batch
        // out across the worker pool; replies must still be bit-identical
        // to the single-threaded transform. Submit a burst *before*
        // waiting so the batcher coalesces multi-row batches — a single
        // blocking transform() would only ever produce 1-row batches,
        // which the thread clamp runs inline.
        let (factory, map) = native_factory(5, 24);
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig {
                intra_op_threads: 4,
                workers: 1,
                max_wait: Duration::from_millis(20),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from(77);
        let inputs: Vec<Vec<f32>> =
            (0..40).map(|_| (0..5).map(|_| rng.f32() - 0.5).collect()).collect();
        let tickets: Vec<_> =
            inputs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
        for (x, t) in inputs.iter().zip(tickets) {
            assert_eq!(t.wait().unwrap(), map.transform(x));
        }
        // The burst must have produced at least one multi-row batch.
        let batches = coord.stats().batches.load(Ordering::Relaxed);
        assert!(batches < 40, "every batch was single-row ({batches} batches for 40 requests)");
    }

    #[test]
    fn rejects_wrong_dim() {
        let (factory, _) = native_factory(4, 8);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        assert!(coord.submit(vec![0.0; 3]).is_err());
    }

    #[test]
    fn sparse_submit_matches_dense_submit() {
        // submit_sparse rides the same machinery: the reply must equal
        // the dense submission of the densified vector, exactly.
        let (factory, map) = native_factory(6, 24);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let indices = vec![0u32, 2, 5];
        let values = vec![0.4f32, -0.7, 0.25];
        let mut dense = vec![0.0f32; 6];
        for (&k, &v) in indices.iter().zip(&values) {
            dense[k as usize] = v;
        }
        let zs = coord.submit_sparse(indices, values).unwrap().wait().unwrap();
        let zd = coord.transform(dense.clone()).unwrap();
        assert_eq!(zs, zd);
        assert_eq!(zs, map.transform(&dense));
        // The empty sparse vector is the zero vector.
        let z0 = coord.submit_sparse(vec![], vec![]).unwrap().wait().unwrap();
        assert_eq!(z0, map.transform(&[0.0f32; 6]));
    }

    #[test]
    fn sparse_submit_validates_indices() {
        let (factory, _) = native_factory(4, 8);
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        // Length mismatch.
        assert!(coord.submit_sparse(vec![0], vec![]).is_err());
        // Out of range.
        assert!(coord.submit_sparse(vec![4], vec![1.0]).is_err());
        // Duplicate / descending.
        assert!(coord.submit_sparse(vec![1, 1], vec![1.0, 2.0]).is_err());
        assert!(coord.submit_sparse(vec![2, 0], vec![1.0, 2.0]).is_err());
        // None of the rejects consumed a queue slot.
        assert_eq!(coord.stats().submitted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pad_accounting_balances_for_every_ragged_tail() {
        // Property-style satellite: against a fixed-shape backend, drive
        // bursts of every size 1..=max_batch and check that (a) each
        // reply is the echo of its own input (reply slicing is correct
        // whatever the padding), and (b) the metered pad slots balance
        // exactly: pad_slots == batches·B − batched_items, whatever
        // batch boundaries the scheduler happened to pick.
        struct Echo;
        impl Backend for Echo {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 3, output_dim: 3, max_batch: 4, fixed_batch: true }
            }
            fn run_batch(&self, x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                assert_eq!(x.rows(), 4, "fixed batch must always be padded to full size");
                Ok(x.clone())
            }
        }
        let b = 4usize;
        for tail in 1..=b {
            let factory = Arc::new(ClosureFactory {
                spec: BackendSpec { input_dim: 3, output_dim: 3, max_batch: b, fixed_batch: true },
                f: || Ok(Box::new(Echo) as Box<dyn Backend>),
            });
            let mut coord = Coordinator::start(
                factory,
                CoordinatorConfig {
                    max_batch: b,
                    max_wait: Duration::from_millis(5),
                    workers: 1,
                    ..Default::default()
                },
            );
            let inputs: Vec<Vec<f32>> =
                (0..tail).map(|i| vec![i as f32, 10.0 + i as f32, -(i as f32)]).collect();
            let tickets: Vec<_> =
                inputs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
            for (x, t) in inputs.iter().zip(tickets) {
                assert_eq!(&t.wait().unwrap(), x, "tail {tail}: reply must echo its own input");
            }
            coord.shutdown();
            let stats = coord.stats();
            let batches = stats.batches.load(Ordering::Relaxed);
            let items = stats.batched_items.load(Ordering::Relaxed);
            let pads = stats.pad_slots.load(Ordering::Relaxed);
            assert_eq!(items, tail as u64, "tail {tail}");
            assert!(batches >= 1, "tail {tail}");
            assert_eq!(
                pads,
                batches * b as u64 - items,
                "tail {tail}: pad accounting must balance ({batches} batches, {items} items)"
            );
        }
    }

    #[test]
    fn many_concurrent_clients_all_answered_exactly_once() {
        let (factory, _) = native_factory(6, 32);
        let coord = Arc::new(Coordinator::start(
            factory,
            CoordinatorConfig { max_batch: 16, workers: 3, ..Default::default() },
        ));
        let clients = 8;
        let per_client = 50;
        let mut handles = Vec::new();
        for c in 0..clients {
            let coord = coord.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from(c as u64);
                let mut got = 0usize;
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..6).map(|_| rng.f32() - 0.5).collect();
                    match coord.submit(x) {
                        Ok(t) => {
                            t.wait().unwrap();
                            got += 1;
                        }
                        Err(_) => {} // backpressure: allowed
                    }
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let stats = coord.stats();
        assert_eq!(total as u64, stats.completed.load(Ordering::Relaxed));
        assert_eq!(
            stats.submitted.load(Ordering::Relaxed),
            stats.completed.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn replies_are_routed_to_the_right_client() {
        // Content check: each client's reply must be the transform of
        // *its own* input.
        let (factory, map) = native_factory(3, 8);
        let coord = Arc::new(Coordinator::start(
            factory,
            CoordinatorConfig { max_batch: 4, workers: 2, ..Default::default() },
        ));
        let mut handles = Vec::new();
        for c in 0..6 {
            let coord = coord.clone();
            let map = map.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from(100 + c as u64);
                for _ in 0..25 {
                    let x: Vec<f32> = (0..3).map(|_| rng.f32() - 0.5).collect();
                    if let Ok(t) = coord.submit(x.clone()) {
                        let z = t.wait().unwrap();
                        assert_eq!(z, map.transform(&x), "client {c} got someone else's reply");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // A slow backend + tiny queue must surface rejections instead of
        // queueing without bound.
        struct Slow;
        impl Backend for Slow {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false }
            }
            fn run_batch(&self, x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                std::thread::sleep(Duration::from_millis(20));
                Ok(x.clone())
            }
        }
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 1, fixed_batch: false },
            f: || Ok(Box::new(Slow) as Box<dyn Backend>),
        });
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig {
                max_batch: 1,
                queue_depth: 2,
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let mut rejected = 0;
        let mut tickets = Vec::new();
        for _ in 0..50 {
            match coord.submit(vec![0.0, 0.0]) {
                Ok(t) => tickets.push(t),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn backend_errors_propagate_to_every_job() {
        struct Failing;
        impl Backend for Failing {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: false }
            }
            fn run_batch(&self, _x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                Err(Error::Runtime("injected failure".into()))
            }
        }
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: false },
            f: || Ok(Box::new(Failing) as Box<dyn Backend>),
        });
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let tickets: Vec<_> =
            (0..10).filter_map(|_| coord.submit(vec![1.0, 2.0]).ok()).collect();
        for t in tickets {
            let err = t.wait().unwrap_err();
            assert!(err.to_string().contains("injected failure"));
        }
        assert!(coord.stats().backend_errors.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn worker_build_failure_still_answers() {
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: false },
            f: || Err(Error::Runtime("no such artifact".into())),
        });
        let coord = Coordinator::start(factory, CoordinatorConfig::default());
        let t = coord.submit(vec![1.0, 2.0]).unwrap();
        let err = t.wait().unwrap_err();
        assert!(err.to_string().contains("backend build failed"), "{err}");
    }

    #[test]
    fn shutdown_drains_inflight() {
        let (factory, _) = native_factory(4, 8);
        let mut coord = Coordinator::start(
            factory,
            CoordinatorConfig { max_wait: Duration::from_millis(10), ..Default::default() },
        );
        let tickets: Vec<_> =
            (0..32).filter_map(|_| coord.submit(vec![0.1; 4]).ok()).collect();
        coord.shutdown();
        // Every accepted request must still be answered.
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        // New submissions are refused.
        assert!(coord.submit(vec![0.1; 4]).is_err());
    }

    #[test]
    fn batches_respect_max_batch() {
        let (factory, _) = native_factory(2, 4);
        let coord = Arc::new(Coordinator::start(
            factory,
            CoordinatorConfig {
                max_batch: 5,
                max_wait: Duration::from_millis(50),
                workers: 1,
                ..Default::default()
            },
        ));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let coord = coord.clone();
            handles.push(std::thread::spawn(move || {
                let tickets: Vec<_> =
                    (0..25).filter_map(|_| coord.submit(vec![0.5, 0.5]).ok()).collect();
                for t in tickets {
                    t.wait().unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let batches = coord.stats().batches.load(Ordering::Relaxed);
        let items = coord.stats().batched_items.load(Ordering::Relaxed);
        assert!(batches >= items / 5, "batch size exceeded: {items} items in {batches} batches");
    }

    #[test]
    fn padding_metered_for_fixed_batch() {
        // Fixed batch of 8 with single requests: each batch pads 7 slots.
        struct Echo;
        impl Backend for Echo {
            fn spec(&self) -> BackendSpec {
                BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: true }
            }
            fn run_batch(&self, x: &crate::linalg::Matrix) -> Result<crate::linalg::Matrix> {
                assert_eq!(x.rows(), 8, "fixed batch must always be full-size");
                Ok(x.clone())
            }
        }
        let factory = Arc::new(ClosureFactory {
            spec: BackendSpec { input_dim: 2, output_dim: 2, max_batch: 8, fixed_batch: true },
            f: || Ok(Box::new(Echo) as Box<dyn Backend>),
        });
        let coord = Coordinator::start(
            factory,
            CoordinatorConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                workers: 1,
                ..Default::default()
            },
        );
        let t = coord.submit(vec![1.0, 2.0]).unwrap();
        assert_eq!(t.wait().unwrap(), vec![1.0, 2.0]);
        assert!(coord.stats().pad_slots.load(Ordering::Relaxed) >= 7);
    }
}
