//! Algorithm 2: Random Maclaurin feature maps for **compositional
//! kernels** `K_co(x, y) = K_dp(K(x, y)) = f(K(x, y))`.
//!
//! Instead of Rademacher projections (whose products estimate powers of
//! the *dot product*), each output coordinate multiplies `N` independent
//! draws of a black-box scalar feature map `W` for the inner kernel `K`:
//! `E[W(x)W(y)] = K(x, y)` makes `Π_j W_j(x) · Π_j W_j(y)` an unbiased
//! estimate of `K(x, y)^N`, and the same external-measure reweighting as
//! Algorithm 1 assembles `f(K(x, y))`. The paper's assumptions on `W`
//! (unbiased, bounded by `√C_W`, Lipschitz on expectation — §5, items
//! 4–6) are captured by [`ScalarMap`] / [`ScalarMapFactory`];
//! [`crate::rff::RffScalarFactory`] realizes them for the Gaussian RBF.
//!
//! Note the paper's observation that Algorithm 1 *is* the special case
//! where the inner map is a Rademacher projection (`W(x) = ω^T x`).

use super::rm::RmConfig;
use crate::features::FeatureMap;
use crate::kernels::DotProductKernel;
use crate::rng::{Geometric, Rng};

/// A single sampled scalar feature `W: R^d → R` for the inner kernel.
pub trait ScalarMap: Send + Sync {
    /// Evaluate `W(x)`.
    fn eval(&self, x: &[f32]) -> f32;

    /// `sup_x |W(x)| = √C_W` (assumption 5 of §5).
    fn bound(&self) -> f64;
}

/// The black-box feature map selection routine `A` of §5: each call
/// returns an independent scalar feature map for the inner kernel `K`.
pub trait ScalarMapFactory: Send + Sync {
    type Map: ScalarMap;

    /// Input dimensionality the maps accept.
    fn input_dim(&self) -> usize;

    /// Draw one independent scalar map.
    fn sample_scalar(&self, rng: &mut Rng) -> Self::Map;

    /// The inner kernel `K(x, y) = E[W(x)W(y)]` (used by tests/benches).
    fn kernel(&self, x: &[f32], y: &[f32]) -> f64;

    /// `√C_W` for the maps this factory draws.
    fn bound(&self) -> f64;
}

/// A sampled compositional feature map (Algorithm 2).
pub struct CompositionalMaclaurin<F: ScalarMapFactory> {
    factory: F,
    n_features: usize,
    /// `sqrt(a_N / P[N]) / sqrt(D)` per feature.
    weights: Vec<f32>,
    /// Feature `i` multiplies `maps[offsets[i]..offsets[i+1]]`.
    offsets: Vec<u32>,
    maps: Vec<F::Map>,
    kernel_name: String,
}

impl<F: ScalarMapFactory> CompositionalMaclaurin<F> {
    /// Sample a map for `f(K(·,·))` where `f` is `outer`'s Maclaurin
    /// function and `K` is the kernel realized by `factory`.
    pub fn sample(
        outer: &dyn DotProductKernel,
        factory: F,
        n_features: usize,
        config: RmConfig,
        rng: &mut Rng,
    ) -> Self {
        assert!(n_features > 0);
        assert!(!config.h01, "H0/1 applies to dot-product maps only");
        let measure = Geometric::new(config.p);
        let max_order = match outer.max_order() {
            Some(m) => m.min(config.max_order),
            None => config.max_order,
        };
        let scale = 1.0 / (n_features as f64).sqrt();
        let mut weights = Vec::with_capacity(n_features);
        let mut offsets = vec![0u32];
        let mut maps = Vec::new();
        for _ in 0..n_features {
            let n = measure.sample_capped(max_order, rng);
            let inv_pmf = 1.0 / measure.pmf_capped(n, max_order);
            let w = (outer.coeff(n) * inv_pmf).sqrt() * scale;
            weights.push(w as f32);
            for _ in 0..n {
                maps.push(factory.sample_scalar(rng));
            }
            offsets.push(maps.len() as u32);
        }
        let kernel_name = format!("compositional({})", outer.name());
        CompositionalMaclaurin { factory, n_features, weights, offsets, maps, kernel_name }
    }

    /// Order (number of inner-map factors) of feature `i`.
    pub fn order(&self, i: usize) -> u32 {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// The factory the map was sampled from.
    pub fn factory(&self) -> &F {
        &self.factory
    }

    pub fn kernel_name(&self) -> &str {
        &self.kernel_name
    }

    /// Lemma 13 bound: `|Z(x)Z(y)| ≤ p·f(p·C_W)` per coordinate (at the
    /// normalized measure, `p/(p−1)·f(p·C_W)`).
    pub fn estimator_bound(&self, outer: &dyn DotProductKernel, p: f64) -> f64 {
        let c_w = self.factory.bound() * self.factory.bound();
        outer.f(p * c_w) * p / (p - 1.0)
    }
}

impl<F: ScalarMapFactory> FeatureMap for CompositionalMaclaurin<F> {
    fn input_dim(&self) -> usize {
        self.factory.input_dim()
    }

    fn output_dim(&self) -> usize {
        self.n_features
    }

    fn transform_into(&self, x: &[f32], out: &mut [f32]) {
        let _span = crate::obs::span("transform.compositional");
        assert_eq!(x.len(), self.input_dim(), "input dim mismatch");
        assert_eq!(out.len(), self.n_features, "output dim mismatch");
        for i in 0..self.n_features {
            let (lo, hi) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
            let mut prod = self.weights[i];
            for m in &self.maps[lo..hi] {
                prod *= m.eval(x);
            }
            out[i] = prod;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Exponential, Polynomial};
    use crate::linalg::dot;
    use crate::rff::RffScalarFactory;
    use crate::rng::Rng;

    fn unit_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        crate::linalg::normalize(&mut v);
        v
    }

    /// K_co(x, y) = f(K_rbf(x, y)) computed exactly.
    fn exact_compositional(
        outer: &dyn crate::kernels::DotProductKernel,
        gamma: f64,
        x: &[f32],
        y: &[f32],
    ) -> f64 {
        outer.f(crate::rff::rbf(gamma, x, y))
    }

    #[test]
    fn unbiased_for_poly_of_rbf() {
        // K_co = (1 + K_rbf)^3: average <Z(x), Z(y)> over many maps.
        let mut rng = Rng::seed_from(1);
        let outer = Polynomial::new(3, 1.0);
        let gamma = 0.8;
        let d = 5;
        let x = unit_vec(d, 2);
        let y = unit_vec(d, 3);
        let exact = exact_compositional(&outer, gamma, &x, &y);
        let maps = 300;
        let mut acc = 0.0;
        for _ in 0..maps {
            let map = CompositionalMaclaurin::sample(
                &outer,
                RffScalarFactory::new(gamma, d),
                64,
                RmConfig::default(),
                &mut rng,
            );
            acc += dot(&map.transform(&x), &map.transform(&y)) as f64;
        }
        let mean = acc / maps as f64;
        assert!((mean - exact).abs() < 0.2, "mean {mean} vs exact {exact}");
    }

    #[test]
    fn unbiased_for_exp_of_rbf() {
        let mut rng = Rng::seed_from(4);
        let outer = Exponential::new(2.0);
        let gamma = 1.0;
        let d = 4;
        let x = unit_vec(d, 5);
        let y = unit_vec(d, 6);
        let exact = exact_compositional(&outer, gamma, &x, &y);
        let maps = 300;
        let mut acc = 0.0;
        for _ in 0..maps {
            let map = CompositionalMaclaurin::sample(
                &outer,
                RffScalarFactory::new(gamma, d),
                64,
                RmConfig::default(),
                &mut rng,
            );
            acc += dot(&map.transform(&x), &map.transform(&y)) as f64;
        }
        let mean = acc / maps as f64;
        assert!((mean - exact).abs() < 0.15, "mean {mean} vs exact {exact}");
    }

    #[test]
    fn estimator_bounded_lemma13() {
        let mut rng = Rng::seed_from(7);
        let outer = Exponential::new(2.0);
        let d = 6;
        let n = 128;
        let map = CompositionalMaclaurin::sample(
            &outer,
            RffScalarFactory::new(1.0, d),
            n,
            RmConfig::default(),
            &mut rng,
        );
        let bound = map.estimator_bound(&outer, 2.0);
        for s in 0..30 {
            let x = unit_vec(d, 100 + s);
            let y = unit_vec(d, 200 + s);
            let zx = map.transform(&x);
            let zy = map.transform(&y);
            for i in 0..n {
                let v = (zx[i] * zy[i]).abs() as f64 * n as f64;
                assert!(v <= bound * (1.0 + 1e-5), "feature {i}: {v} > {bound}");
            }
        }
    }

    #[test]
    fn orders_match_offsets() {
        let mut rng = Rng::seed_from(9);
        let outer = Polynomial::new(4, 1.0);
        let map = CompositionalMaclaurin::sample(
            &outer,
            RffScalarFactory::new(1.0, 3),
            32,
            RmConfig::default(),
            &mut rng,
        );
        let total: u32 = (0..32).map(|i| map.order(i)).sum();
        assert_eq!(total, map.maps.len() as u32);
        for i in 0..32 {
            assert!(map.order(i) <= 4, "order capped by outer degree");
        }
    }

    #[test]
    #[should_panic]
    fn h01_is_rejected() {
        let mut rng = Rng::seed_from(1);
        let outer = Polynomial::new(2, 1.0);
        CompositionalMaclaurin::sample(
            &outer,
            RffScalarFactory::new(1.0, 3),
            8,
            RmConfig::default().with_h01(true),
            &mut rng,
        );
    }
}
