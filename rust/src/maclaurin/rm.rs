//! Algorithm 1: the Random Maclaurin feature map.
//!
//! For each of `D` output coordinates:
//! 1. draw an order `N` from the external measure `P[N=n] ∝ p^{-(n+1)}`
//!    (normalized geometric; exactly the paper's measure at `p = 2`);
//! 2. draw `N` Rademacher vectors `ω_1..ω_N ∈ {±1}^d`;
//! 3. emit `Z_i(x) = w_N · Π_{j≤N} ω_j^T x` with
//!    `w_N = sqrt(a_N / P[N=N])` (`= sqrt(a_N p^{N+1})` at `p = 2`).
//!
//! The concatenation `Z = (Z_1..Z_D)/√D` satisfies
//! `E⟨Z(x), Z(y)⟩ = f(⟨x, y⟩)` (Lemma 7), `|Z_i(x)Z_i(y)| ≤ C_Ω` with
//! `C_Ω = p·f(pR²)` at `p = 2` (Lemma 8), and the uniform convergence
//! bound of Theorem 12.
//!
//! With **H0/1** (§6.1) the `n = 0` and `n = 1` terms are computed
//! exactly instead of estimated: the output is
//! `[√a_0, √a_1·x, random features for N ≥ 2]`, drawing the random
//! orders from the conditional law `P[N | N ≥ 2]` (memorylessness makes
//! that `2 + Geometric`). The constant coordinate carries the `a_0` term
//! so a bias-free linear model can absorb it, as the paper absorbs it
//! into the SVM offset.
//!
//! # Dense vs structured projections
//!
//! The per-feature projections `ω_j^T x` are computed through the
//! [`crate::structured::Projection`] abstraction, selected by
//! [`RmConfig::projection`]:
//!
//! * **Dense** (default): an explicit Rademacher stack — `O(D·d)` per
//!   input, bit-identical to the original Algorithm 1 implementation.
//! * **Structured**: FWHT-backed HD blocks
//!   ([`crate::structured::StructuredProjection`]) — `O(D·log d)` per
//!   input, with the paper's statistics preserved as follows. Each HD
//!   row is *marginally* an exact Rademacher vector, and the sampler
//!   uses the layered `rademacher_for_segments` layout: the `N` factors
//!   of one feature always come from `N` distinct, independently seeded
//!   blocks, so `E[Z_i(x)Z_i(y)]` factorizes exactly and the estimator
//!   is **unbiased at every order**, exactly like the dense map. It is
//!   *not* a drop-in for the dense map's joint law: features whose
//!   same-position factors share a layer block are correlated, so
//!   per-map variance (the constant in the `1/√D` Figure-1 decay, and
//!   the Theorem-12 concentration constants) can differ by a modest
//!   factor even though the decay *rate* is identical — the
//!   Gram-envelope tests pin structured and dense errors to the same
//!   tolerance band. Lemma 8's deterministic bound survives untouched
//!   because HD rows are genuine ±1 sign patterns. Structured maps
//!   serialize as a seed + layout (see [`super::serialize`]), and are
//!   served natively (the PJRT `transform` artifacts consume dense Ω
//!   tensors only).

use crate::features::{FeatureMap, Scratch};
use crate::kernels::DotProductKernel;
use crate::rng::{Geometric, RademacherMatrix, Rng};
use crate::artifact::WeightStore;
use crate::structured::{DenseProjection, Projection, ProjectionKind, StructuredProjection};

/// Sampling configuration for [`RandomMaclaurin`].
#[derive(Clone, Copy, Debug)]
pub struct RmConfig {
    /// External measure parameter `p > 1` (paper recommends 2).
    pub p: f64,
    /// Use the H0/1 heuristic (§6.1): exact constant + linear terms,
    /// random features only for orders ≥ 2.
    pub h01: bool,
    /// Hard cap on sampled orders. At `p = 2` the probability of ever
    /// seeing `N > 30` across a million features is < 1e-3, and the
    /// clamped estimator's bias is bounded by the tail mass
    /// `Σ_{n>cap} a_n R^{2n} ≤ f(R²)/2^{cap+1}`-ish — far below float
    /// noise for the defaults. A finite cap is also what makes the
    /// fixed-shape AOT artifact possible (orders become a padded axis).
    pub max_order: u32,
    /// Restrict the external measure to orders with `a_n > 0`,
    /// renormalizing (importance sampling over the kernel's support).
    /// Still exactly unbiased, never increases any per-feature weight,
    /// and avoids spending features on identically-zero terms — without
    /// this, a homogeneous `⟨x,y⟩^10` kernel gets a useful (order-10)
    /// feature only once per `2^11` draws and the Figure-1a error curve
    /// cannot decay. `bench fig1 --ablation` compares both. Default on.
    pub restrict_support: bool,
    /// How the per-feature projections are realized: a dense Rademacher
    /// stack or the subquadratic FWHT-backed HD blocks (see the module
    /// docs for the statistical trade-off). Default dense.
    pub projection: ProjectionKind,
    /// Randomness recycling (Choromanski & Sindhwani) for structured
    /// stacks: HD/Fastfood blocks draw their per-block state as views
    /// into one shared pool instead of independent samples, shrinking
    /// sampled (and serialized) state toward `O(d)`. Default **off** so
    /// numerics stay bit-identical to the unrecycled build; see
    /// [`StructuredProjection::rademacher_for_segments_opts`] for the
    /// statistical fine print. No effect on dense maps.
    pub recycle: bool,
}

impl Default for RmConfig {
    fn default() -> Self {
        RmConfig {
            p: 2.0,
            h01: false,
            max_order: 30,
            restrict_support: true,
            projection: ProjectionKind::Dense,
            recycle: false,
        }
    }
}

impl RmConfig {
    pub fn with_h01(mut self, on: bool) -> Self {
        self.h01 = on;
        self
    }

    pub fn with_p(mut self, p: f64) -> Self {
        self.p = p;
        self
    }

    pub fn with_max_order(mut self, cap: u32) -> Self {
        self.max_order = cap;
        self
    }

    pub fn with_restrict_support(mut self, on: bool) -> Self {
        self.restrict_support = on;
        self
    }

    pub fn with_projection(mut self, kind: ProjectionKind) -> Self {
        self.projection = kind;
        self
    }

    pub fn with_recycle(mut self, on: bool) -> Self {
        self.recycle = on;
        self
    }
}

/// The discrete order distribution actually sampled from: the capped
/// geometric measure, optionally restricted to the kernel's support and
/// renormalized. `weight(n) = a_n / P[N = n]` stays an exact importance
/// weight in every variant.
struct OrderTable {
    /// (order, emission probability) — probabilities sum to 1.
    entries: Vec<(u32, f64)>,
    /// CDF for inverse-transform sampling.
    cdf: Vec<f64>,
}

impl OrderTable {
    fn build(
        kernel: &dyn DotProductKernel,
        measure: &Geometric,
        min_order: u32,
        max_order: u32,
        restrict_support: bool,
    ) -> Option<OrderTable> {
        // Raw emission mass of order n under the (possibly H0/1-shifted)
        // capped geometric measure.
        let mass = |n: u32| measure.pmf_capped(n - min_order, max_order - min_order);
        let mut entries: Vec<(u32, f64)> = (min_order..=max_order)
            .filter(|&n| !restrict_support || kernel.coeff(n) > 0.0)
            .map(|n| (n, mass(n)))
            .collect();
        let z: f64 = entries.iter().map(|(_, m)| m).sum();
        if entries.is_empty() || z <= 0.0 {
            return None;
        }
        for (_, m) in entries.iter_mut() {
            *m /= z;
        }
        let mut cdf = Vec::with_capacity(entries.len());
        let mut acc = 0.0;
        for (_, m) in &entries {
            acc += m;
            cdf.push(acc);
        }
        Some(OrderTable { entries, cdf })
    }

    /// Draw (order, emission probability).
    fn sample(&self, rng: &mut Rng) -> (u32, f64) {
        let u = rng.f64();
        let idx = self.cdf.partition_point(|&c| c < u).min(self.entries.len() - 1);
        self.entries[idx]
    }
}

/// A sampled Random Maclaurin feature map (Algorithm 1).
///
/// Immutable after sampling; `transform*` is the hot path. All the
/// Rademacher vectors of all features live in one bit-packed
/// [`RademacherMatrix`]; feature `i` owns the row range
/// `offsets[i]..offsets[i+1]` (its order is the range length).
#[derive(Clone, Debug)]
pub struct RandomMaclaurin {
    d: usize,
    /// Number of random coordinates `D` (excludes H0/1 exact terms).
    n_random: usize,
    config: RmConfig,
    /// Sampled order `N_i` per random feature. All three index vectors
    /// live behind [`WeightStore`]s (ISSUE 8): owned when sampled,
    /// zero-copy views into a shared [`crate::artifact::MapArtifact`]
    /// region when loaded.
    orders: WeightStore<u32>,
    /// `sqrt(a_N / P[N]) / sqrt(D)` per random feature (the `1/√D`
    /// concatenation scale is folded in).
    weights: WeightStore<f32>,
    /// Row offsets into `omegas`: feature `i` uses rows
    /// `offsets[i]..offsets[i+1]`.
    offsets: WeightStore<u32>,
    /// All Rademacher vectors, bit-packed (canonical/serialized form of
    /// the *dense* projection; empty for structured maps).
    omegas: RademacherMatrix,
    /// Lazily expanded dense `d × rows` ±1 projection (column per omega
    /// row): the dense hot path computes all projections as one GEMM
    /// `X · Ω^T`, which vectorizes ~7× better than per-bit sign flips
    /// (see EXPERIMENTS.md §Perf) and mirrors the MXU formulation the
    /// Pallas kernel uses on TPU.
    dense: std::sync::OnceLock<DenseProjection>,
    /// FWHT-backed projection stack (`None` for dense maps), plus the
    /// seed that reconstructs it (the serialized form: seed + layout).
    structured: Option<StructuredProjection>,
    /// Seed behind `structured` (0 for dense maps).
    proj_seed: u64,
    /// `√a_0` — the H0/1 constant coordinate (0 when h01 is off).
    w_const: f32,
    /// `√a_1` — the H0/1 linear block scale (0 when h01 is off).
    w_linear: f32,
    /// Kernel name (for artifacts manifests / debugging).
    kernel_name: String,
}

impl RandomMaclaurin {
    /// Sample a map for `kernel` on `R^d` with `n_random` random
    /// features — the paper's Algorithm 1 sampling scheme: per feature,
    /// draw an order `N` from the external measure `P[N=n] ∝ p^{-(n+1)}`
    /// (step 1), draw `N` Rademacher vectors through the configured
    /// [`Projection`] stack (step 2), and store the importance weight
    /// `√(a_N / P[N])/√D` that makes the estimator exactly unbiased
    /// (Lemma 7). With `config.h01` the output dimension is
    /// `1 + d + n_random`, otherwise `n_random`.
    pub fn sample(
        kernel: &dyn DotProductKernel,
        d: usize,
        n_random: usize,
        config: RmConfig,
        rng: &mut Rng,
    ) -> Self {
        assert!(d > 0 && n_random > 0, "d and D must be positive");
        let measure = Geometric::new(config.p);
        let max_order = match kernel.max_order() {
            // Never sample orders whose coefficient is identically zero
            // past the polynomial's degree — they would waste features on
            // exact zeros.
            Some(m) => m.min(config.max_order),
            None => config.max_order,
        };

        let mut orders = Vec::with_capacity(n_random);
        let mut weights = Vec::with_capacity(n_random);
        let mut offsets = Vec::with_capacity(n_random + 1);
        offsets.push(0u32);
        let scale = 1.0 / (n_random as f64).sqrt();

        // Emission law: capped geometric (tail mass on the cap, keeping
        // the estimator exactly unbiased for the order-cap truncation of
        // the kernel), shifted to N >= 2 under H0/1 (memorylessness:
        // P[N = n | N >= 2] = pmf(n − 2)), optionally restricted to the
        // kernel's support orders. The importance weight always divides
        // by the *actual* emission probability, so every variant stays
        // unbiased.
        let min_order = if config.h01 { 2 } else { 0 };
        let table = if max_order >= min_order {
            OrderTable::build(kernel, &measure, min_order, max_order, config.restrict_support)
        } else {
            None
        };

        let mut total_rows = 0u32;
        for _ in 0..n_random {
            let (n, a_n, emit_p) = match &table {
                Some(t) => {
                    let (n, p) = t.sample(rng);
                    (n, kernel.coeff(n), p)
                }
                // Degenerate kernel (no support above min_order): emit
                // identically-zero features — correct, since the exact
                // prefix terms carry the whole kernel.
                None => (0, 0.0, 1.0),
            };
            let w = (a_n / emit_p).sqrt() * scale;
            orders.push(n);
            weights.push(w as f32);
            total_rows += n;
            offsets.push(total_rows);
        }

        let (omegas, structured, proj_seed) = match config.projection {
            ProjectionKind::Dense => {
                (RademacherMatrix::sample(total_rows as usize, d, rng), None, 0)
            }
            ProjectionKind::Structured => {
                // The stack is a pure function of (d, offsets, recycle,
                // seed), so the seed alone serializes it (see
                // `super::serialize`; recycled stacks serialize
                // materialized, as RFDM0003).
                let seed = rng.next_u64();
                let proj = StructuredProjection::rademacher_for_segments_opts(
                    d,
                    &offsets,
                    config.recycle,
                    &mut Rng::seed_from(seed),
                );
                (RademacherMatrix::from_words(0, d, Vec::new()), Some(proj), seed)
            }
        };

        let (w_const, w_linear) = if config.h01 {
            (kernel.coeff(0).sqrt() as f32, kernel.coeff(1).sqrt() as f32)
        } else {
            (0.0, 0.0)
        };

        RandomMaclaurin {
            d,
            n_random,
            config,
            orders: WeightStore::from_vec(orders),
            weights: WeightStore::from_vec(weights),
            offsets: WeightStore::from_vec(offsets),
            omegas,
            dense: std::sync::OnceLock::new(),
            structured,
            proj_seed,
            w_const,
            w_linear,
            kernel_name: kernel.name(),
        }
    }

    /// The projection stack this map samples through: structured when
    /// configured, otherwise the lazily expanded dense ±1 matrix.
    pub fn projection(&self) -> &dyn Projection {
        match &self.structured {
            Some(p) => p,
            None => self.dense.get_or_init(|| DenseProjection::from_rademacher(&self.omegas)),
        }
    }

    /// Convenience: the §4.2 variant — truncate `kernel`'s series at the
    /// smallest order whose tail mass (at radius `r`) is ≤ `eps`, then
    /// sample a map for the truncated kernel. The returned
    /// [`crate::kernels::Truncation`] carries the chosen order plus the
    /// tail mass actually achieved and a `saturated` flag, so callers
    /// can tell "the bound was met at order k" apart from "no
    /// materialized prefix met `eps` and the order merely capped at
    /// `config.max_order`".
    pub fn truncated(
        kernel: &dyn DotProductKernel,
        r: f64,
        eps: f64,
        d: usize,
        n_random: usize,
        config: RmConfig,
        rng: &mut Rng,
    ) -> (Self, crate::kernels::Truncation) {
        let series = crate::kernels::MaclaurinSeries::materialize(kernel, config.max_order, r);
        let truncation = series.truncation(eps);
        let k = truncation.order;
        struct Shim<'a> {
            inner: &'a dyn DotProductKernel,
            order: u32,
        }
        impl DotProductKernel for Shim<'_> {
            fn name(&self) -> String {
                format!("truncated(k={}, {})", self.order, self.inner.name())
            }
            fn coeff(&self, n: u32) -> f64 {
                if n <= self.order {
                    self.inner.coeff(n)
                } else {
                    0.0
                }
            }
            fn f(&self, t: f64) -> f64 {
                let mut acc = 0.0;
                for n in (0..=self.order).rev() {
                    acc = acc * t + self.inner.coeff(n);
                }
                acc
            }
            fn f_prime(&self, t: f64) -> f64 {
                let mut acc = 0.0;
                for n in (1..=self.order).rev() {
                    acc = acc * t + n as f64 * self.inner.coeff(n);
                }
                acc
            }
            fn max_order(&self) -> Option<u32> {
                Some(self.order)
            }
        }
        let shim = Shim { inner: kernel, order: k };
        let map = RandomMaclaurin::sample(&shim, d, n_random, config.with_max_order(k), rng);
        (map, truncation)
    }

    pub fn config(&self) -> &RmConfig {
        &self.config
    }

    /// Number of random coordinates `D`.
    pub fn n_random(&self) -> usize {
        self.n_random
    }

    /// Sampled order of random feature `i` (Algorithm 1 step 1: the
    /// draw from the external measure).
    pub fn order(&self, i: usize) -> u32 {
        self.orders.as_slice()[i]
    }

    /// All sampled orders.
    pub fn orders(&self) -> &[u32] {
        self.orders.as_slice()
    }

    /// Largest sampled order (0 for an empty map).
    pub fn max_sampled_order(&self) -> u32 {
        self.orders.as_slice().iter().copied().max().unwrap_or(0)
    }

    /// Per-feature estimator weights `√(a_N / P[N])` with `1/√D` folded
    /// in — the importance weights Lemma 7's unbiasedness and Lemma 8's
    /// bound `|Z_i(x)Z_i(y)| ≤ C_Ω/D` (at `C_Ω = p·f(pR²)`) are proved
    /// for.
    pub fn weights(&self) -> &[f32] {
        self.weights.as_slice()
    }

    /// Feature-to-row offsets into the Rademacher stack.
    pub fn offsets(&self) -> &[u32] {
        self.offsets.as_slice()
    }

    /// The packed Rademacher stack (empty for structured maps, whose
    /// projections live behind [`RandomMaclaurin::projection`]).
    pub fn omegas(&self) -> &RademacherMatrix {
        &self.omegas
    }

    /// True when the projections are the FWHT-backed structured stack.
    pub fn is_structured(&self) -> bool {
        self.structured.is_some()
    }

    /// Seed that reconstructs the structured stack (0 for dense maps).
    pub fn proj_seed(&self) -> u64 {
        self.proj_seed
    }

    /// H0/1 constant-coordinate value `√a_0`.
    pub fn w_const(&self) -> f32 {
        self.w_const
    }

    /// H0/1 linear block scale `√a_1`.
    pub fn w_linear(&self) -> f32 {
        self.w_linear
    }

    /// Kernel this map was sampled for.
    pub fn kernel_name(&self) -> &str {
        &self.kernel_name
    }

    /// The FWHT-backed stack, when this map is structured (the artifact
    /// serializer walks its blocks).
    pub(crate) fn structured_projection(&self) -> Option<&StructuredProjection> {
        self.structured.as_ref()
    }

    /// Rebuild from serialized parts (see [`super::serialize`]). For
    /// structured records the stack is reconstructed from `proj_seed`
    /// and the offsets, which is bit-exact by construction.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        d: usize,
        n_random: usize,
        config: RmConfig,
        orders: Vec<u32>,
        weights: Vec<f32>,
        offsets: Vec<u32>,
        omegas: RademacherMatrix,
        proj_seed: u64,
        w_const: f32,
        w_linear: f32,
        kernel_name: String,
    ) -> Self {
        let structured = match config.projection {
            ProjectionKind::Dense => None,
            ProjectionKind::Structured => {
                Some(StructuredProjection::rademacher_for_segments_opts(
                    d,
                    &offsets,
                    config.recycle,
                    &mut Rng::seed_from(proj_seed),
                ))
            }
        };
        RandomMaclaurin {
            d,
            n_random,
            config,
            orders: WeightStore::from_vec(orders),
            weights: WeightStore::from_vec(weights),
            offsets: WeightStore::from_vec(offsets),
            omegas,
            dense: std::sync::OnceLock::new(),
            structured,
            proj_seed,
            w_const,
            w_linear,
            kernel_name,
        }
    }

    /// Rebuild over artifact-backed stores — zero weight copies; the
    /// structured stack (if any) is handed in pre-assembled from the
    /// artifact's block views rather than re-derived from the seed
    /// ([`crate::artifact::MapArtifact::instantiate`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_artifact_parts(
        d: usize,
        n_random: usize,
        config: RmConfig,
        orders: WeightStore<u32>,
        weights: WeightStore<f32>,
        offsets: WeightStore<u32>,
        omegas: RademacherMatrix,
        structured: Option<StructuredProjection>,
        proj_seed: u64,
        w_const: f32,
        w_linear: f32,
        kernel_name: String,
    ) -> Self {
        debug_assert_eq!(
            structured.is_some(),
            matches!(config.projection, ProjectionKind::Structured)
        );
        RandomMaclaurin {
            d,
            n_random,
            config,
            orders,
            weights,
            offsets,
            omegas,
            dense: std::sync::OnceLock::new(),
            structured,
            proj_seed,
            w_const,
            w_linear,
            kernel_name,
        }
    }

    /// Expand the map into the dense tensors the AOT artifact consumes:
    /// `Ω ∈ R^{n_max × d × D}` (order-padded Rademacher stacks, zeros in
    /// padded slots), `mask ∈ {0,1}^{n_max × D}` and `coeff ∈ R^D` (the
    /// per-feature weights, `1/√D` included). The artifact computes
    /// `Z[b,i] = coeff[i] · Π_j (mask[j,i]·(X Ω_j)[b,i] + (1 − mask[j,i]))`,
    /// which equals the native [`FeatureMap::transform`] random block.
    ///
    /// Panics if any sampled order exceeds `n_max`, or if the map is
    /// structured (the artifact formulation consumes dense Ω tensors;
    /// structured maps are served natively).
    pub fn to_padded_dense(&self, n_max: u32) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        assert!(
            !self.is_structured(),
            "structured maps have no dense Ω expansion; serve them natively"
        );
        assert!(
            self.max_sampled_order() <= n_max,
            "sampled order {} exceeds padding {n_max}",
            self.max_sampled_order()
        );
        let (d, dd) = (self.d, self.n_random);
        let offsets = self.offsets.as_slice();
        let orders = self.orders.as_slice();
        let mut omega = vec![0.0f32; n_max as usize * d * dd];
        let mut mask = vec![0.0f32; n_max as usize * dd];
        for i in 0..dd {
            let base = offsets[i];
            for j in 0..orders[i] {
                let row = (base + j) as usize;
                mask[j as usize * dd + i] = 1.0;
                for k in 0..d {
                    omega[(j as usize * d + k) * dd + i] = self.omegas.sign(row, k);
                }
            }
        }
        (omega, mask, self.weights.as_slice().to_vec())
    }

    /// Segmented product: turn the projection vector `proj[rows]` into
    /// features `out[i] = w_i · Π proj[offsets[i]..offsets[i+1]]`
    /// (order-0 features are the empty product, i.e. just `w_i`).
    #[inline]
    fn products_from_projections(&self, proj: &[f32], out: &mut [f32]) {
        let offsets = self.offsets.as_slice();
        let weights = self.weights.as_slice();
        for i in 0..self.n_random {
            let (lo, hi) = (offsets[i] as usize, offsets[i + 1] as usize);
            let mut prod = weights[i];
            for &p in &proj[lo..hi] {
                prod *= p;
            }
            out[i] = prod;
        }
    }

    /// Write the H0/1 exact prefix `[√a_0, √a_1·x]` for a CSR row: the
    /// constant slot, then the scaled stored entries scattered into a
    /// zeroed linear block (the dense path's `√a_1 · 0` terms are exact
    /// zeros, so the block is equal either way).
    fn h01_prefix_sparse_into(&self, x: crate::linalg::SparseRow<'_>, out: &mut [f32]) {
        out[0] = self.w_const;
        let linear = &mut out[1..1 + self.d];
        linear.fill(0.0);
        for (&k, &v) in x.indices.iter().zip(x.values) {
            linear[k as usize] = self.w_linear * v;
        }
    }
}

impl FeatureMap for RandomMaclaurin {
    fn input_dim(&self) -> usize {
        self.d
    }

    fn output_dim(&self) -> usize {
        if self.config.h01 {
            1 + self.d + self.n_random
        } else {
            self.n_random
        }
    }

    fn transform_into(&self, x: &[f32], out: &mut [f32]) {
        self.transform_into_scratch(x, out, &mut Scratch::new());
    }

    /// The allocation-free hot path: all projections are computed at
    /// once through the sampled [`Projection`] stack — a streaming
    /// dense matvec (the §Perf pass measured the bit-by-bit packed walk
    /// at ~7× slower than vectorized f32 math) or the FWHT chain, with
    /// the projection vector and the chain's pads living in the
    /// caller's reusable [`Scratch`] — then reduced by the segmented
    /// product. Bit-identical to [`FeatureMap::transform_into`] (which
    /// delegates here with a throwaway scratch).
    fn transform_into_scratch(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        let _span = crate::obs::span("transform.rm");
        assert_eq!(x.len(), self.d, "input dim mismatch");
        assert_eq!(out.len(), self.output_dim(), "output dim mismatch");
        let prefix = if self.config.h01 {
            out[0] = self.w_const;
            for (o, &xi) in out[1..1 + self.d].iter_mut().zip(x) {
                *o = self.w_linear * xi;
            }
            1 + self.d
        } else {
            0
        };
        let projection = self.projection();
        let (proj, work) = scratch.two(projection.rows(), projection.scratch_len());
        projection.project_into_scratch(x, proj, work);
        self.products_from_projections(proj, &mut out[prefix..]);
    }

    /// Batch override: the sampled [`Projection`] stack computes every
    /// projection of every example in one pass — a blocked GEMM
    /// `P = X · Ω^T` for dense maps (the CPU mirror of the Pallas
    /// kernel's per-order MXU matmuls), row-chunked FWHT chains for
    /// structured ones — then the segmented products. Both passes fan
    /// row blocks out over `threads` scoped workers (`0` = the global
    /// [`crate::parallel`] knob); every output row runs the identical
    /// serial routine, so results are bit-identical for any thread
    /// count.
    fn transform_batch_threads(
        &self,
        x: &crate::linalg::Matrix,
        threads: usize,
    ) -> crate::linalg::Matrix {
        let _span = crate::obs::span("transform.rm");
        assert_eq!(x.cols(), self.d, "input dim mismatch");
        let b = x.rows();
        let mut out = crate::linalg::Matrix::zeros(b, self.output_dim());
        if b == 0 {
            return out;
        }
        let proj = self.projection().project_batch(x, threads);
        let prefix = if self.config.h01 { 1 + self.d } else { 0 };
        let dd = self.output_dim();
        // Segmented products cost ~(projections + outputs) per row; the
        // GEMM above applies its own small-work cutoff internally.
        let work = b.saturating_mul(proj.cols() + dd);
        let threads = crate::parallel::resolve_threads_for_work(threads, b, work);
        crate::parallel::par_chunks(threads, dd, out.as_mut_slice(), |row0, block| {
            for (i, row_out) in block.chunks_mut(dd).enumerate() {
                let r = row0 + i;
                if self.config.h01 {
                    row_out[0] = self.w_const;
                    for (o, &xi) in row_out[1..1 + self.d].iter_mut().zip(x.row(r)) {
                        *o = self.w_linear * xi;
                    }
                }
                self.products_from_projections(proj.row(r), &mut row_out[prefix..]);
            }
        });
        out
    }

    /// Sparse single-vector fast path: `O(rows · nnz)` projections
    /// through the sampled stack, then the segmented products. Equal to
    /// [`FeatureMap::transform_into`] on the densified row (the sparse
    /// parity contract).
    fn transform_sparse_into(&self, x: crate::linalg::SparseRow<'_>, out: &mut [f32]) {
        self.transform_sparse_into_scratch(x, out, &mut Scratch::new());
    }

    /// CSR twin of [`FeatureMap::transform_into_scratch`]: the
    /// projections run through
    /// [`Projection::project_sparse_into_scratch`] (`O(rows · nnz)` for
    /// dense stacks), then the same segmented product — bit-identical
    /// to the dense path on the densified row, allocation-free with a
    /// reused scratch.
    fn transform_sparse_into_scratch(
        &self,
        x: crate::linalg::SparseRow<'_>,
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let _span = crate::obs::span("transform.rm");
        assert_eq!(x.dim, self.d, "input dim mismatch");
        assert_eq!(out.len(), self.output_dim(), "output dim mismatch");
        let prefix = if self.config.h01 {
            self.h01_prefix_sparse_into(x, out);
            1 + self.d
        } else {
            0
        };
        let projection = self.projection();
        let (proj, work) = scratch.two(projection.rows(), projection.scratch_len());
        projection.project_sparse_into_scratch(x, proj, work);
        self.products_from_projections(proj, &mut out[prefix..]);
    }

    /// Sparse batch override: one [`Projection::project_batch_sparse`]
    /// pass, then the same segmented-product fan-out as the dense batch
    /// path — bit-identical per row to both the dense batch and the
    /// sparse single-vector path, for any thread count.
    fn transform_batch_sparse_threads(
        &self,
        x: &crate::linalg::SparseMatrix,
        threads: usize,
    ) -> crate::linalg::Matrix {
        let _span = crate::obs::span("transform.rm");
        assert_eq!(x.cols(), self.d, "input dim mismatch");
        let b = x.rows();
        let mut out = crate::linalg::Matrix::zeros(b, self.output_dim());
        if b == 0 {
            return out;
        }
        let proj = self.projection().project_batch_sparse(x, threads);
        let prefix = if self.config.h01 { 1 + self.d } else { 0 };
        let dd = self.output_dim();
        let work = b.saturating_mul(proj.cols() + dd);
        let threads = crate::parallel::resolve_threads_for_work(threads, b, work);
        crate::parallel::par_chunks(threads, dd, out.as_mut_slice(), |row0, block| {
            for (i, row_out) in block.chunks_mut(dd).enumerate() {
                let r = row0 + i;
                if self.config.h01 {
                    self.h01_prefix_sparse_into(x.row(r), row_out);
                }
                self.products_from_projections(proj.row(r), &mut row_out[prefix..]);
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Exponential, Homogeneous, Polynomial};
    use crate::linalg::dot;

    fn unit_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        crate::linalg::normalize(&mut v);
        v
    }

    #[test]
    fn output_dims() {
        let mut rng = Rng::seed_from(1);
        let k = Polynomial::new(3, 1.0);
        let plain = RandomMaclaurin::sample(&k, 5, 100, RmConfig::default(), &mut rng);
        assert_eq!(plain.output_dim(), 100);
        let h01 = RandomMaclaurin::sample(&k, 5, 100, RmConfig::default().with_h01(true), &mut rng);
        assert_eq!(h01.output_dim(), 1 + 5 + 100);
    }

    #[test]
    fn unbiasedness_lemma7() {
        // E[<Z(x), Z(y)>] = K(x, y): average over many independent maps.
        let mut rng = Rng::seed_from(42);
        let k = Polynomial::new(4, 1.0);
        let d = 6;
        let x = unit_vec(d, 1);
        let y = unit_vec(d, 2);
        let exact = k.eval(&x, &y);
        let mut acc = 0.0f64;
        let maps = 400;
        for _ in 0..maps {
            let map = RandomMaclaurin::sample(&k, d, 64, RmConfig::default(), &mut rng);
            acc += dot(&map.transform(&x), &map.transform(&y)) as f64;
        }
        let mean = acc / maps as f64;
        // K(x,y) <= 2^4 = 16 on the unit ball; CLT tolerance.
        assert!((mean - exact).abs() < 0.35, "mean {mean} vs exact {exact}");
    }

    #[test]
    fn unbiasedness_h01() {
        let mut rng = Rng::seed_from(43);
        let k = Exponential::new(1.0);
        let d = 5;
        let x = unit_vec(d, 3);
        let y = unit_vec(d, 4);
        let exact = k.eval(&x, &y);
        let mut acc = 0.0f64;
        let maps = 400;
        for _ in 0..maps {
            let map =
                RandomMaclaurin::sample(&k, d, 64, RmConfig::default().with_h01(true), &mut rng);
            acc += dot(&map.transform(&x), &map.transform(&y)) as f64;
        }
        let mean = acc / maps as f64;
        assert!((mean - exact).abs() < 0.1, "mean {mean} vs exact {exact}");
    }

    #[test]
    fn estimator_bound_lemma8() {
        // |Z_i(x) Z_i(y)| * D <= C_Omega = p f(p R^2) for every feature,
        // for x, y in B_1(0, 1).
        let mut rng = Rng::seed_from(7);
        let k = Exponential::new(1.0);
        let d = 8;
        let bound = k.estimator_bound(2.0, 1.0);
        let n_random = 256;
        let map = RandomMaclaurin::sample(&k, d, n_random, RmConfig::default(), &mut rng);
        for trial in 0..50 {
            // Points in the L1 ball of radius 1 (the paper's domain).
            let mut x = unit_vec(d, 100 + trial);
            let mut y = unit_vec(d, 200 + trial);
            let sx = crate::linalg::norm1(&x);
            let sy = crate::linalg::norm1(&y);
            crate::linalg::scale(1.0 / sx, &mut x);
            crate::linalg::scale(1.0 / sy, &mut y);
            let zx = map.transform(&x);
            let zy = map.transform(&y);
            for i in 0..n_random {
                let prod = (zx[i] * zy[i]).abs() as f64 * n_random as f64;
                assert!(
                    prod <= bound * (1.0 + 1e-5),
                    "feature {i}: |Z Z| = {prod} > C = {bound}"
                );
            }
        }
    }

    #[test]
    fn error_decays_with_d() {
        // Concentration: mean abs gram error should drop roughly like
        // 1/sqrt(D). Compare D and 16*D (expect ~4x, assert >= 2x).
        let mut rng = Rng::seed_from(9);
        let k = Polynomial::new(3, 1.0);
        let d = 8;
        let n_pts = 30;
        let rows: Vec<Vec<f32>> = (0..n_pts).map(|i| unit_vec(d, 300 + i as u64)).collect();
        let x = crate::linalg::Matrix::from_rows(&rows).unwrap();
        let exact = crate::kernels::gram(&k, &x);
        let err_at = |dd: usize, rng: &mut Rng| {
            let trials = 3;
            (0..trials)
                .map(|_| {
                    let map = RandomMaclaurin::sample(&k, d, dd, RmConfig::default(), rng);
                    let approx = super::super::feature_gram(&map, &x);
                    crate::kernels::mean_abs_gram_error(&exact, &approx)
                })
                .sum::<f64>()
                / trials as f64
        };
        let e_small = err_at(32, &mut rng);
        let e_big = err_at(512, &mut rng);
        assert!(
            e_big < e_small / 2.0,
            "no concentration: err(32) = {e_small}, err(512) = {e_big}"
        );
    }

    #[test]
    fn homogeneous_orders_are_exactly_degree() {
        // For <x,y>^p only a_p != 0; sampled orders beyond the degree are
        // clipped by max_order=degree, and features with N != p would have
        // zero weight. The cap makes all orders equal p.
        let mut rng = Rng::seed_from(11);
        let k = Homogeneous::new(4);
        let map = RandomMaclaurin::sample(&k, 5, 64, RmConfig::default(), &mut rng);
        for i in 0..64 {
            // weight is zero unless order == 4
            if map.order(i) != 4 {
                assert_eq!(map.weights()[i], 0.0);
            }
        }
        // The only informative features are order-4 ones; at p=2 the
        // capped sampler maps everything >= 4 to 4, so most features hit it.
        let informative = (0..64).filter(|&i| map.order(i) == 4).count();
        assert!(informative > 0);
    }

    #[test]
    fn h01_prefix_is_exact_terms() {
        let mut rng = Rng::seed_from(13);
        let k = Polynomial::new(10, 1.0);
        let d = 4;
        let map = RandomMaclaurin::sample(&k, d, 32, RmConfig::default().with_h01(true), &mut rng);
        let x = unit_vec(d, 5);
        let z = map.transform(&x);
        // a_0 = 1, a_1 = 10 for (1 + t)^10.
        assert!((z[0] - 1.0).abs() < 1e-6);
        for j in 0..d {
            assert!((z[1 + j] - (10.0f32).sqrt() * x[j]).abs() < 1e-5);
        }
        // All random features have order >= 2.
        for i in 0..32 {
            assert!(map.order(i) >= 2, "order {} < 2 under H0/1", map.order(i));
        }
    }

    #[test]
    fn order_zero_features_are_constant() {
        // With p=2 roughly half the features have N=0; their value must
        // be w = sqrt(a_0 * 2) / sqrt(D) regardless of x.
        let mut rng = Rng::seed_from(17);
        let k = Exponential::new(1.0);
        let d = 3;
        let n = 64;
        let map = RandomMaclaurin::sample(&k, d, n, RmConfig::default(), &mut rng);
        let z1 = map.transform(&unit_vec(d, 6));
        let z2 = map.transform(&unit_vec(d, 7));
        let mut seen_zero = false;
        for i in 0..n {
            if map.order(i) == 0 {
                seen_zero = true;
                assert_eq!(z1[i], z2[i], "order-0 feature must not depend on x");
                let expected = (2.0f64).sqrt() / (n as f64).sqrt();
                assert!((z1[i] as f64 - expected).abs() < 1e-6);
            }
        }
        assert!(seen_zero, "no order-0 features sampled (p=2 should give ~half)");
    }

    #[test]
    fn truncated_variant_reports_order() {
        let mut rng = Rng::seed_from(19);
        let k = Exponential::new(1.0);
        let (map, t) =
            RandomMaclaurin::truncated(&k, 1.0, 1e-4, 6, 64, RmConfig::default(), &mut rng);
        assert!(t.order >= 3 && t.order <= 12, "order {}", t.order);
        assert!(!t.saturated, "1e-4 is reachable within the default order cap");
        assert!(t.tail_mass <= 1e-4, "tail {}", t.tail_mass);
        assert!(map.max_sampled_order() <= t.order);
        assert!(map.kernel_name().contains("truncated"));
    }

    #[test]
    fn truncated_variant_flags_unreachable_eps() {
        // The saturation signal must reach the sampler's caller, not
        // stop at the series layer.
        let mut rng = Rng::seed_from(20);
        let k = Exponential::new(1.0);
        let (map, t) = RandomMaclaurin::truncated(
            &k,
            1.0,
            1e-30,
            6,
            32,
            RmConfig::default().with_max_order(5),
            &mut rng,
        );
        assert!(t.saturated, "1e-30 is unreachable with 5 materialized orders");
        assert_eq!(t.order, 5);
        assert!(t.tail_mass > 1e-30);
        assert!(map.max_sampled_order() <= 5);
    }

    #[test]
    fn padded_dense_matches_native_transform() {
        // Evaluate the padded-tensor formulation (what the PJRT artifact
        // computes) in plain rust and compare with transform().
        let mut rng = Rng::seed_from(23);
        let k = Exponential::new(1.0);
        let (d, dd) = (5usize, 24usize);
        let map = RandomMaclaurin::sample(&k, d, dd, RmConfig::default().with_max_order(8), &mut rng);
        let n_max = 8u32;
        let (omega, mask, coeff) = map.to_padded_dense(n_max);
        let x = unit_vec(d, 31);
        let native = map.transform(&x);
        for i in 0..dd {
            let mut prod = 1.0f32;
            for j in 0..n_max as usize {
                let mut p = 0.0f32;
                for kk in 0..d {
                    p += x[kk] * omega[(j * d + kk) * dd + i];
                }
                let m = mask[j * dd + i];
                prod *= m * p + (1.0 - m);
            }
            let z = coeff[i] * prod;
            assert!(
                (z - native[i]).abs() < 1e-4 * (1.0 + native[i].abs()),
                "feature {i}: padded {z} vs native {}",
                native[i]
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let k = Polynomial::new(3, 1.0);
        let m1 = RandomMaclaurin::sample(&k, 4, 16, RmConfig::default(), &mut Rng::seed_from(5));
        let m2 = RandomMaclaurin::sample(&k, 4, 16, RmConfig::default(), &mut Rng::seed_from(5));
        assert_eq!(m1.orders(), m2.orders());
        assert_eq!(m1.weights(), m2.weights());
        assert_eq!(m1.omegas(), m2.omegas());
    }

    #[test]
    fn sparse_transform_matches_dense_bitwise() {
        // CSR inputs through the O(D·nnz) path must equal the dense
        // path exactly — single vector and batch, h01 on and off.
        let k = Exponential::new(1.0);
        let d = 19;
        let mut data_rng = Rng::seed_from(61);
        let mut x = crate::linalg::Matrix::zeros(7, d);
        for i in 0..7 {
            for j in 0..d {
                if data_rng.f64() < 0.25 {
                    x.set(i, j, data_rng.f32() - 0.5);
                }
            }
        }
        let sx = crate::linalg::SparseMatrix::from_dense(&x);
        for h01 in [false, true] {
            let map = RandomMaclaurin::sample(
                &k,
                d,
                48,
                RmConfig::default().with_h01(h01),
                &mut Rng::seed_from(62),
            );
            let dense = map.transform_batch_threads(&x, 1);
            for i in 0..7 {
                let mut got = vec![0.0f32; map.output_dim()];
                map.transform_sparse_into(sx.row(i), &mut got);
                assert_eq!(&got[..], dense.row(i), "h01={h01} row {i}");
            }
            for threads in [1usize, 3, 8] {
                assert_eq!(
                    map.transform_batch_sparse_threads(&sx, threads),
                    dense,
                    "h01={h01} threads={threads}"
                );
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_input_dim() {
        let mut rng = Rng::seed_from(1);
        let k = Polynomial::new(2, 1.0);
        let map = RandomMaclaurin::sample(&k, 4, 8, RmConfig::default(), &mut rng);
        map.transform(&[0.0; 3]);
    }

    fn structured_config() -> RmConfig {
        RmConfig::default().with_projection(crate::structured::ProjectionKind::Structured)
    }

    #[test]
    fn structured_unbiasedness_lemma7() {
        // The layered HD layout keeps E[<Z(x), Z(y)>] = K(x, y) exactly
        // (each feature's factors sit in independent blocks). Same CLT
        // check as the dense test, with a wider tolerance for the
        // cross-feature correlations' variance inflation.
        let mut rng = Rng::seed_from(52);
        let k = Polynomial::new(4, 1.0);
        let d = 6;
        let x = unit_vec(d, 1);
        let y = unit_vec(d, 2);
        let exact = k.eval(&x, &y);
        let mut acc = 0.0f64;
        let maps = 400;
        for _ in 0..maps {
            let map = RandomMaclaurin::sample(&k, d, 64, structured_config(), &mut rng);
            acc += dot(&map.transform(&x), &map.transform(&y)) as f64;
        }
        let mean = acc / maps as f64;
        assert!((mean - exact).abs() < 0.5, "mean {mean} vs exact {exact}");
    }

    #[test]
    fn structured_estimator_bound_lemma8() {
        // HD rows are genuine ±1 patterns, so Lemma 8's deterministic
        // bound holds verbatim for structured maps.
        let mut rng = Rng::seed_from(53);
        let k = Exponential::new(1.0);
        let d = 8;
        let bound = k.estimator_bound(2.0, 1.0);
        let n_random = 128;
        let map = RandomMaclaurin::sample(&k, d, n_random, structured_config(), &mut rng);
        assert!(map.is_structured());
        for trial in 0..20 {
            let mut x = unit_vec(d, 500 + trial);
            let mut y = unit_vec(d, 600 + trial);
            crate::linalg::scale(1.0 / crate::linalg::norm1(&x), &mut x);
            crate::linalg::scale(1.0 / crate::linalg::norm1(&y), &mut y);
            let zx = map.transform(&x);
            let zy = map.transform(&y);
            for i in 0..n_random {
                let prod = (zx[i] * zy[i]).abs() as f64 * n_random as f64;
                assert!(prod <= bound * (1.0 + 1e-5), "feature {i}: {prod} > {bound}");
            }
        }
    }

    #[test]
    fn structured_batch_matches_single_bitwise() {
        let mut rng = Rng::seed_from(54);
        let k = Exponential::new(1.0);
        let d = 11;
        let map = RandomMaclaurin::sample(&k, d, 48, structured_config().with_h01(true), &mut rng);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| unit_vec(d, 700 + i)).collect();
        let x = crate::linalg::Matrix::from_rows(&rows).unwrap();
        let zb = map.transform_batch(&x);
        for i in 0..6 {
            assert_eq!(zb.row(i), &map.transform(x.row(i))[..], "row {i}");
        }
        for threads in [2usize, 3, 16] {
            assert_eq!(map.transform_batch_threads(&x, threads), zb);
        }
    }

    #[test]
    fn structured_deterministic_given_seed() {
        let k = Polynomial::new(3, 1.0);
        let m1 = RandomMaclaurin::sample(&k, 4, 16, structured_config(), &mut Rng::seed_from(5));
        let m2 = RandomMaclaurin::sample(&k, 4, 16, structured_config(), &mut Rng::seed_from(5));
        assert_eq!(m1.orders(), m2.orders());
        assert_eq!(m1.weights(), m2.weights());
        assert_eq!(m1.proj_seed(), m2.proj_seed());
        let x = unit_vec(4, 8);
        assert_eq!(m1.transform(&x), m2.transform(&x));
    }

    #[test]
    fn structured_error_decays_with_d() {
        // Same 1/sqrt(D) decay *rate* as dense (the Figure-1 claim),
        // correlations only perturb the constant.
        let mut rng = Rng::seed_from(55);
        let k = Polynomial::new(3, 1.0);
        let d = 8;
        let rows: Vec<Vec<f32>> = (0..30).map(|i| unit_vec(d, 900 + i as u64)).collect();
        let x = crate::linalg::Matrix::from_rows(&rows).unwrap();
        let exact = crate::kernels::gram(&k, &x);
        let err_at = |dd: usize, rng: &mut Rng| {
            (0..3)
                .map(|_| {
                    let map = RandomMaclaurin::sample(&k, d, dd, structured_config(), rng);
                    let approx = super::super::feature_gram(&map, &x);
                    crate::kernels::mean_abs_gram_error(&exact, &approx)
                })
                .sum::<f64>()
                / 3.0
        };
        let e_small = err_at(32, &mut rng);
        let e_big = err_at(512, &mut rng);
        assert!(e_big < e_small / 2.0, "no concentration: {e_small} -> {e_big}");
    }

    #[test]
    #[should_panic]
    fn structured_maps_have_no_padded_dense_expansion() {
        let mut rng = Rng::seed_from(56);
        let k = Exponential::new(1.0);
        let map = RandomMaclaurin::sample(&k, 5, 16, structured_config(), &mut rng);
        let _ = map.to_padded_dense(8);
    }
}
