//! Random Maclaurin feature maps — the paper's contribution.
//!
//! * [`RandomMaclaurin`] — Algorithm 1: randomized embeddings
//!   `Z: R^d → R^D` with `⟨Z(x), Z(y)⟩ ≈ f(⟨x, y⟩)` for any positive
//!   definite dot product kernel, including the **H0/1** heuristic
//!   (§6.1) and the **truncated** variant (§4.2).
//! * [`compositional`] — Algorithm 2: feature maps for
//!   `K_co(x, y) = f(K(x, y))` given black-box scalar feature maps for
//!   the inner kernel `K`.
//! * [`serialize`] — a canonical binary wire format for sampled maps, so
//!   the Rust native engine, the PJRT artifact path and the Python
//!   oracle all evaluate the *same* map (same seed ⇒ same bytes ⇒ same
//!   features to float tolerance).
//!
//! The [`FeatureMap`] trait and [`feature_gram`] used to live here;
//! they are now owned by the crate-level [`crate::features`] layer
//! (which `rff`, `tensorsketch` and `nystrom` implement as peers) and
//! re-exported below so existing `maclaurin::FeatureMap` imports keep
//! compiling during the migration.

pub mod compositional;
pub mod rm;
pub mod serialize;

pub use compositional::{CompositionalMaclaurin, ScalarMap, ScalarMapFactory};
pub use rm::{RandomMaclaurin, RmConfig};

/// Deprecated location — import from [`crate::features`] instead. Kept
/// as a re-export so downstream code migrates incrementally.
pub use crate::features::{feature_gram, FeatureMap};

#[cfg(test)]
mod tests {
    // Deliberately imports the trait through the `maclaurin` re-export:
    // these tests pin the deprecated path alongside the behavior.
    use super::*;
    use crate::kernels::Polynomial;
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    #[test]
    fn transform_batch_matches_single() {
        let mut rng = Rng::seed_from(1);
        let k = Polynomial::new(3, 1.0);
        let map = RandomMaclaurin::sample(&k, 6, 64, RmConfig::default(), &mut rng);
        let x = Matrix::from_rows(&[vec![0.1, 0.2, -0.1, 0.0, 0.3, -0.2], vec![0.0; 6]]).unwrap();
        let zb = map.transform_batch(&x);
        for i in 0..2 {
            let zi = map.transform(x.row(i));
            assert_eq!(zb.row(i), &zi[..]);
        }
    }

    #[test]
    fn feature_gram_is_symmetric() {
        let mut rng = Rng::seed_from(2);
        let k = Polynomial::new(2, 1.0);
        let map = RandomMaclaurin::sample(&k, 4, 32, RmConfig::default(), &mut rng);
        let x = Matrix::from_rows(&[
            vec![0.5, 0.0, 0.0, 0.1],
            vec![0.0, 0.5, 0.1, 0.0],
            vec![0.2, 0.2, 0.2, 0.2],
        ])
        .unwrap();
        let g = feature_gram(&map, &x);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
            assert!(g.get(i, i) >= 0.0); // ⟨Z, Z⟩ ≥ 0
        }
    }
}
