//! Random Maclaurin feature maps — the paper's contribution.
//!
//! * [`RandomMaclaurin`] — Algorithm 1: randomized embeddings
//!   `Z: R^d → R^D` with `⟨Z(x), Z(y)⟩ ≈ f(⟨x, y⟩)` for any positive
//!   definite dot product kernel, including the **H0/1** heuristic
//!   (§6.1) and the **truncated** variant (§4.2).
//! * [`compositional`] — Algorithm 2: feature maps for
//!   `K_co(x, y) = f(K(x, y))` given black-box scalar feature maps for
//!   the inner kernel `K`.
//! * [`FeatureMap`] — the embedding interface shared by all maps (and by
//!   [`crate::rff`]), consumed by the SVM pipelines, the coordinator and
//!   the bench harness.
//! * [`serialize`] — a canonical binary wire format for sampled maps, so
//!   the Rust native engine, the PJRT artifact path and the Python
//!   oracle all evaluate the *same* map (same seed ⇒ same bytes ⇒ same
//!   features to float tolerance).

pub mod compositional;
pub mod rm;
pub mod serialize;

pub use compositional::{CompositionalMaclaurin, ScalarMap, ScalarMapFactory};
pub use rm::{RandomMaclaurin, RmConfig};

use crate::linalg::Matrix;

/// A (possibly randomized, already-sampled) feature embedding
/// `R^input_dim → R^output_dim`.
pub trait FeatureMap: Send + Sync {
    /// Input dimensionality `d`.
    fn input_dim(&self) -> usize;

    /// Output dimensionality (`D`, or `1 + d + D` with H0/1).
    fn output_dim(&self) -> usize;

    /// Apply the map to one vector, writing into `out`
    /// (`out.len() == output_dim()`).
    fn transform_into(&self, x: &[f32], out: &mut [f32]);

    /// Apply the map to one vector.
    fn transform(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.transform_into(x, &mut out);
        out
    }

    /// Apply the map to every row of `x`.
    fn transform_batch(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        let mut out = Matrix::zeros(x.rows(), self.output_dim());
        for i in 0..x.rows() {
            let row = x.row(i);
            // Split borrow: rows of `out` are disjoint.
            self.transform_into(row, out.row_mut(i));
        }
        out
    }
}

/// Approximate Gram matrix `⟨Z(x_i), Z(x_j)⟩` of a feature map over the
/// rows of `x` — compared against [`crate::kernels::gram`] in the
/// Figure 1 experiments.
pub fn feature_gram(map: &dyn FeatureMap, x: &Matrix) -> Matrix {
    let z = map.transform_batch(x);
    let n = z.rows();
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = crate::linalg::dot(z.row(i), z.row(j));
            g.set(i, j, v);
            g.set(j, i, v);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Polynomial;
    use crate::rng::Rng;

    #[test]
    fn transform_batch_matches_single() {
        let mut rng = Rng::seed_from(1);
        let k = Polynomial::new(3, 1.0);
        let map = RandomMaclaurin::sample(&k, 6, 64, RmConfig::default(), &mut rng);
        let x = Matrix::from_rows(&[vec![0.1, 0.2, -0.1, 0.0, 0.3, -0.2], vec![0.0; 6]]).unwrap();
        let zb = map.transform_batch(&x);
        for i in 0..2 {
            let zi = map.transform(x.row(i));
            assert_eq!(zb.row(i), &zi[..]);
        }
    }

    #[test]
    fn feature_gram_is_symmetric() {
        let mut rng = Rng::seed_from(2);
        let k = Polynomial::new(2, 1.0);
        let map = RandomMaclaurin::sample(&k, 4, 32, RmConfig::default(), &mut rng);
        let x = Matrix::from_rows(&[
            vec![0.5, 0.0, 0.0, 0.1],
            vec![0.0, 0.5, 0.1, 0.0],
            vec![0.2, 0.2, 0.2, 0.2],
        ])
        .unwrap();
        let g = feature_gram(&map, &x);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
            assert!(g.get(i, i) >= 0.0); // ⟨Z, Z⟩ ≥ 0
        }
    }
}
