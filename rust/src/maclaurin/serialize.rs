//! Canonical binary serialization of sampled Random Maclaurin maps.
//!
//! The same bytes are read by the Python build path
//! (`python/compile/rm_map.py`) to expand the map into the dense
//! `Ω / mask / coeff` tensors the AOT artifact consumes, which is how the
//! native Rust engine, the PJRT engine and the pure-jnp oracle are held
//! to *identical* sampled maps in the cross-engine tests.
//!
//! Two record kinds share the header layout (little-endian):
//!
//! **Dense** (`RFDM0001`) — the packed Rademacher stack is the payload:
//! ```text
//! magic   8  b"RFDM0001"
//! d       u32     input dim
//! D       u32     number of random features
//! p       f64     external measure parameter
//! h01     u8      0/1
//! maxord  u32     order cap
//! wconst  f32     H0/1 constant coordinate
//! wlin    f32     H0/1 linear scale
//! klen    u32     kernel name byte length, then that many bytes (utf-8)
//! orders  u32×D
//! weights f32×D
//! rows    u32     total Rademacher rows
//! words   u64×(rows * ceil(d/64))   packed sign bits
//! ```
//!
//! **Structured** (`RFDM0002`) — the FWHT/HD projection stack is a pure
//! function of `(d, orders, seed)` over the crate's cross-platform RNG,
//! so *seeded reconstruction* replaces the sign payload: the record is
//! the same header + `orders` + `weights` followed by a single
//! ```text
//! pseed   u64     StructuredProjection seed
//! ```
//! and deserialization rebuilds the identical stack
//! (`deserialize(serialize(m)).transform(x) == m.transform(x)`
//! bit-for-bit, pinned by tests).
//!
//! A third record kind lives in [`crate::artifact`]: **`RFDM0003`**,
//! the zero-copy container whose section layout matches the in-memory
//! typed views. [`from_bytes`] accepts it transparently (the loaded map
//! borrows from one shared region), and [`to_bytes`] *emits* it for
//! maps that seed-only reconstruction cannot express — structured
//! stacks sampled with `RmConfig::recycle` (their shared pools dedupe
//! in the materialized form, so the record stays small). Everything
//! else keeps its legacy format, byte-stable.
//!
//! The [`Reader`] here is the hardened bounds-checking cursor all three
//! record parsers share: truncated payloads, oversized counts and
//! non-canonical trailing bytes return `Error`, never panic or
//! over-read (`tests/serialize_malformed.rs` pins this per field).

use super::rm::{RandomMaclaurin, RmConfig};
use super::FeatureMap;
use crate::rng::RademacherMatrix;
use crate::structured::ProjectionKind;
use crate::{Error, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RFDM0001";
const MAGIC_STRUCTURED: &[u8; 8] = b"RFDM0002";

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian cursor over an untrusted blob. Every
/// read is a checked `take`; counts read from the blob must be bounded
/// by [`Reader::remaining`] before they size an allocation.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Current byte offset.
    pub(crate) fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left to read — the hard ceiling on any count field.
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // Overflow-proof form of `pos + n > len` (n is attacker data).
        if n > self.remaining() {
            return Err(Error::Data("truncated RFDM blob".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

/// Serialize a map to bytes (the record kind follows the map's
/// projection: dense stacks get `RFDM0001`, structured `RFDM0002` —
/// except recycled structured stacks, whose shared pools the seed-only
/// `RFDM0002` cannot express; those serialize as the materialized
/// zero-copy `RFDM0003` container, where pool interning keeps them
/// small).
pub fn to_bytes(map: &RandomMaclaurin) -> Vec<u8> {
    if map.is_structured() && map.config().recycle {
        return crate::artifact::MapArtifact::encode(map);
    }
    let mut out = Vec::new();
    out.extend_from_slice(if map.is_structured() { MAGIC_STRUCTURED } else { MAGIC });
    put_u32(&mut out, map.input_dim() as u32);
    put_u32(&mut out, map.n_random() as u32);
    out.extend_from_slice(&map.config().p.to_le_bytes());
    out.push(map.config().h01 as u8);
    put_u32(&mut out, map.config().max_order);
    put_f32(&mut out, map.w_const());
    put_f32(&mut out, map.w_linear());
    let kname = map.kernel_name().as_bytes();
    put_u32(&mut out, kname.len() as u32);
    out.extend_from_slice(kname);
    for &o in map.orders() {
        put_u32(&mut out, o);
    }
    for &w in map.weights() {
        put_f32(&mut out, w);
    }
    if map.is_structured() {
        out.extend_from_slice(&map.proj_seed().to_le_bytes());
    } else {
        put_u32(&mut out, map.omegas().rows() as u32);
        for &w in map.omegas().words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    out
}

/// Deserialize a map from bytes (any of the three record kinds;
/// `RFDM0003` containers come back artifact-backed — the map borrows
/// one shared region instead of owning copies).
pub fn from_bytes(buf: &[u8]) -> Result<RandomMaclaurin> {
    crate::faults::failpoint("rfdm.decode")?;
    if buf.len() >= 8 && &buf[..8] == crate::artifact::MAGIC_V3 {
        return crate::artifact::MapArtifact::from_bytes(buf)?.instantiate();
    }
    let mut r = Reader::new(buf);
    let structured = match r.take(8)? {
        m if m == MAGIC => false,
        m if m == MAGIC_STRUCTURED => true,
        _ => return Err(Error::Data("bad RFDM magic".into())),
    };
    let d = r.u32()? as usize;
    let n_random = r.u32()? as usize;
    let p = r.f64()?;
    let h01 = r.take(1)?[0] != 0;
    let max_order = r.u32()?;
    let w_const = r.f32()?;
    let w_linear = r.f32()?;
    let klen = r.u32()? as usize;
    let kernel_name = String::from_utf8(r.take(klen)?.to_vec())
        .map_err(|_| Error::Data("kernel name not utf-8".into()))?;
    if d == 0 || n_random == 0 || !(p > 1.0) {
        return Err(Error::Data("invalid RFDM header".into()));
    }
    // A crafted `D` can claim up to u32::MAX features; cap the eager
    // reservation by the bytes actually present so the header alone can
    // never force a multi-gigabyte allocation (the reads below fail
    // fast on the first missing byte either way).
    if n_random.checked_mul(8).is_none_or(|need| need > r.remaining()) {
        return Err(Error::Data("truncated RFDM blob: orders/weights payload missing".into()));
    }
    let mut orders = Vec::with_capacity(n_random);
    for _ in 0..n_random {
        orders.push(r.u32()?);
    }
    let mut weights = Vec::with_capacity(n_random);
    for _ in 0..n_random {
        weights.push(r.f32()?);
    }
    let expected_rows: u64 = orders.iter().map(|&o| o as u64).sum();
    let (omegas, proj_seed) = if structured {
        // The dense branch is implicitly bounded by its sign payload
        // (rows × words must be present in the buffer); the structured
        // branch reconstructs from a seed, so a crafted header could
        // otherwise demand unbounded work. Enforce the sampler's own
        // invariants instead of trusting the blob.
        let max_ord = orders.iter().copied().max().unwrap_or(0);
        if max_ord > max_order {
            return Err(Error::Data(format!(
                "structured record order {max_ord} exceeds its max_order {max_order}"
            )));
        }
        // Reconstruction allocates one next_pow2(d)-length sign vector
        // per HD block, and the layered layout creates at most
        // rows + max_ord·next_pow2(d) sign slots in total — cap that
        // budget (in f32 units) so a ~60-byte blob can never demand
        // gigabytes. Legitimate maps (d ≤ ~1M, orders ≤ 30) sit far
        // below it; records with no rows allocate nothing and need no
        // cap.
        const MAX_STRUCTURED_WORK: u64 = 1 << 26;
        let n = (d as u64).next_power_of_two();
        let work = expected_rows.saturating_add((max_ord as u64).saturating_mul(n));
        if work > MAX_STRUCTURED_WORK {
            return Err(Error::Data(format!(
                "structured record reconstruction budget exceeded: rows {expected_rows} + \
                 max order {max_ord} × padded dim {n} > {MAX_STRUCTURED_WORK}"
            )));
        }
        let seed = r.u64()?;
        (RademacherMatrix::from_words(0, d, Vec::new()), seed)
    } else {
        let rows = r.u32()? as usize;
        if rows as u64 != expected_rows {
            return Err(Error::Data(format!(
                "row count {rows} does not match order sum {expected_rows}"
            )));
        }
        let words_per_row = d.div_ceil(64);
        let n_words = rows
            .checked_mul(words_per_row)
            .ok_or_else(|| Error::Data("RFDM word count overflows".into()))?;
        // Same bomb guard as orders/weights: prove the payload bytes
        // exist before reserving for them.
        if n_words.checked_mul(8).is_none_or(|need| need > r.remaining()) {
            return Err(Error::Data("truncated RFDM blob: sign payload missing".into()));
        }
        let mut words = Vec::with_capacity(n_words);
        for _ in 0..n_words {
            words.push(r.u64()?);
        }
        (RademacherMatrix::from_words(rows, d, words), 0)
    };
    if r.pos != buf.len() {
        return Err(Error::Data("trailing bytes in RFDM blob".into()));
    }
    let mut offsets = Vec::with_capacity(n_random + 1);
    offsets.push(0u32);
    let mut acc = 0u32;
    for &o in &orders {
        // The checks above bound the sum (dense: equals the declared
        // u32 row count; structured: the work budget), so a checked add
        // is belt-and-braces against a parser change upstream.
        acc = acc
            .checked_add(o)
            .ok_or_else(|| Error::Data("RFDM order sum overflows".into()))?;
        offsets.push(acc);
    }
    // `restrict_support` only affects sampling, not evaluation of an
    // already-sampled map, so it is not part of the wire format; legacy
    // records predate recycling, so it is always off here (recycled
    // maps serialize as RFDM0003).
    let config = RmConfig {
        p,
        h01,
        max_order,
        restrict_support: true,
        projection: if structured { ProjectionKind::Structured } else { ProjectionKind::Dense },
        recycle: false,
    };
    Ok(RandomMaclaurin::from_parts(
        d,
        n_random,
        config,
        orders,
        weights,
        offsets,
        omegas,
        proj_seed,
        w_const,
        w_linear,
        kernel_name,
    ))
}

/// Save to a file.
pub fn save(map: &RandomMaclaurin, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_bytes(map))?;
    Ok(())
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<RandomMaclaurin> {
    let mut f = std::fs::File::open(path)?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    from_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Exponential, Polynomial};
    use crate::features::FeatureMap;
    use crate::maclaurin::RmConfig;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_preserves_transform() {
        let mut rng = Rng::seed_from(1);
        let k = Polynomial::new(5, 1.0);
        let map = RandomMaclaurin::sample(&k, 7, 48, RmConfig::default(), &mut rng);
        let bytes = to_bytes(&map);
        let map2 = from_bytes(&bytes).unwrap();
        let x: Vec<f32> = (0..7).map(|i| (i as f32 * 0.13).sin() * 0.3).collect();
        assert_eq!(map.transform(&x), map2.transform(&x));
        assert_eq!(map.orders(), map2.orders());
        assert_eq!(map.kernel_name(), map2.kernel_name());
    }

    #[test]
    fn roundtrip_h01() {
        let mut rng = Rng::seed_from(2);
        let k = Exponential::new(1.0);
        let map =
            RandomMaclaurin::sample(&k, 5, 16, RmConfig::default().with_h01(true), &mut rng);
        let map2 = from_bytes(&to_bytes(&map)).unwrap();
        assert_eq!(map.output_dim(), map2.output_dim());
        assert_eq!(map.w_const(), map2.w_const());
        assert_eq!(map.w_linear(), map2.w_linear());
        let x = vec![0.1f32, -0.2, 0.05, 0.3, 0.0];
        assert_eq!(map.transform(&x), map2.transform(&x));
    }

    #[test]
    fn rejects_corruption() {
        let mut rng = Rng::seed_from(3);
        let k = Polynomial::new(2, 1.0);
        let map = RandomMaclaurin::sample(&k, 4, 8, RmConfig::default(), &mut rng);
        let bytes = to_bytes(&map);
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad).is_err());
        // Truncated.
        assert!(from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage.
        let mut long = bytes.clone();
        long.push(0);
        assert!(from_bytes(&long).is_err());
        // Empty.
        assert!(from_bytes(&[]).is_err());
    }

    #[test]
    fn structured_roundtrip_is_bit_identical() {
        let mut rng = Rng::seed_from(5);
        let k = Exponential::new(1.0);
        let config = RmConfig::default()
            .with_projection(crate::structured::ProjectionKind::Structured);
        let map = RandomMaclaurin::sample(&k, 9, 32, config, &mut rng);
        assert!(map.is_structured());
        let bytes = to_bytes(&map);
        assert_eq!(&bytes[..8], b"RFDM0002");
        let map2 = from_bytes(&bytes).unwrap();
        assert!(map2.is_structured());
        assert_eq!(map.proj_seed(), map2.proj_seed());
        assert_eq!(map.orders(), map2.orders());
        // Seeded reconstruction is exact: transforms agree bit-for-bit,
        // and re-serialization reproduces the identical blob.
        let x: Vec<f32> = (0..9).map(|i| (i as f32 * 0.21).sin() * 0.4).collect();
        assert_eq!(map.transform(&x), map2.transform(&x));
        assert_eq!(to_bytes(&map2), bytes);
    }

    #[test]
    fn structured_roundtrip_h01() {
        let mut rng = Rng::seed_from(6);
        let k = Exponential::new(1.0);
        let config = RmConfig::default()
            .with_h01(true)
            .with_projection(crate::structured::ProjectionKind::Structured);
        let map = RandomMaclaurin::sample(&k, 5, 16, config, &mut rng);
        let map2 = from_bytes(&to_bytes(&map)).unwrap();
        assert_eq!(map.output_dim(), map2.output_dim());
        let x = vec![0.1f32, -0.2, 0.05, 0.3, 0.0];
        assert_eq!(map.transform(&x), map2.transform(&x));
    }

    #[test]
    fn structured_rejects_corruption() {
        let mut rng = Rng::seed_from(7);
        let k = Polynomial::new(2, 1.0);
        let config = RmConfig::default()
            .with_projection(crate::structured::ProjectionKind::Structured);
        let map = RandomMaclaurin::sample(&k, 4, 8, config, &mut rng);
        let bytes = to_bytes(&map);
        // Truncated (missing seed bytes).
        assert!(from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage.
        let mut long = bytes.clone();
        long.push(0);
        assert!(from_bytes(&long).is_err());
        // Unknown magic version.
        let mut bad = bytes.clone();
        bad[7] = b'9';
        assert!(from_bytes(&bad).is_err());
        // A crafted order larger than the record's own max_order must
        // be rejected, not handed to seeded reconstruction (the orders
        // array starts right after the kernel-name bytes).
        let name_len = map.kernel_name().len();
        let orders_at = 8 + 4 + 4 + 8 + 1 + 4 + 4 + 4 + 4 + name_len;
        let mut huge = bytes.clone();
        huge[orders_at..orders_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = from_bytes(&huge).unwrap_err();
        assert!(err.to_string().contains("max_order"), "{err}");
    }

    #[test]
    fn structured_rejects_reconstruction_bombs() {
        // A crafted input dim (with at least one nonzero order) must be
        // rejected before reconstruction allocates next_pow2(d)-length
        // sign buffers — Homogeneous(2) guarantees every order is 2.
        let mut rng = Rng::seed_from(8);
        let k = crate::kernels::Homogeneous::new(2);
        let config = RmConfig::default()
            .with_projection(crate::structured::ProjectionKind::Structured);
        let map = RandomMaclaurin::sample(&k, 4, 8, config, &mut rng);
        assert!(map.orders().iter().all(|&o| o == 2));
        let mut wide = to_bytes(&map);
        wide[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = from_bytes(&wide).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::seed_from(4);
        let k = Polynomial::new(3, 0.5);
        let map = RandomMaclaurin::sample(&k, 6, 12, RmConfig::default(), &mut rng);
        let dir = std::env::temp_dir().join("rfdot_serialize_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("map.rfdm");
        save(&map, &path).unwrap();
        let map2 = load(&path).unwrap();
        let x = vec![0.2f32; 6];
        assert_eq!(map.transform(&x), map2.transform(&x));
        std::fs::remove_file(&path).ok();
    }
}
