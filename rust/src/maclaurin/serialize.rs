//! Canonical binary serialization of sampled Random Maclaurin maps.
//!
//! The same bytes are read by the Python build path
//! (`python/compile/rm_map.py`) to expand the map into the dense
//! `Ω / mask / coeff` tensors the AOT artifact consumes, which is how the
//! native Rust engine, the PJRT engine and the pure-jnp oracle are held
//! to *identical* sampled maps in the cross-engine tests.
//!
//! Layout (little-endian):
//! ```text
//! magic   8  b"RFDM0001"
//! d       u32     input dim
//! D       u32     number of random features
//! p       f64     external measure parameter
//! h01     u8      0/1
//! maxord  u32     order cap
//! wconst  f32     H0/1 constant coordinate
//! wlin    f32     H0/1 linear scale
//! klen    u32     kernel name byte length, then that many bytes (utf-8)
//! orders  u32×D
//! weights f32×D
//! rows    u32     total Rademacher rows
//! words   u64×(rows * ceil(d/64))   packed sign bits
//! ```

use super::rm::{RandomMaclaurin, RmConfig};
use super::FeatureMap;
use crate::rng::RademacherMatrix;
use crate::{Error, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RFDM0001";

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Data("truncated RFDM blob".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

/// Serialize a map to bytes.
pub fn to_bytes(map: &RandomMaclaurin) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, map.input_dim() as u32);
    put_u32(&mut out, map.n_random() as u32);
    out.extend_from_slice(&map.config().p.to_le_bytes());
    out.push(map.config().h01 as u8);
    put_u32(&mut out, map.config().max_order);
    put_f32(&mut out, map.w_const());
    put_f32(&mut out, map.w_linear());
    let kname = map.kernel_name().as_bytes();
    put_u32(&mut out, kname.len() as u32);
    out.extend_from_slice(kname);
    for &o in map.orders() {
        put_u32(&mut out, o);
    }
    for &w in map.weights() {
        put_f32(&mut out, w);
    }
    put_u32(&mut out, map.omegas().rows() as u32);
    for &w in map.omegas().words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

/// Deserialize a map from bytes.
pub fn from_bytes(buf: &[u8]) -> Result<RandomMaclaurin> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(8)? != MAGIC {
        return Err(Error::Data("bad RFDM magic".into()));
    }
    let d = r.u32()? as usize;
    let n_random = r.u32()? as usize;
    let p = r.f64()?;
    let h01 = r.take(1)?[0] != 0;
    let max_order = r.u32()?;
    let w_const = r.f32()?;
    let w_linear = r.f32()?;
    let klen = r.u32()? as usize;
    let kernel_name = String::from_utf8(r.take(klen)?.to_vec())
        .map_err(|_| Error::Data("kernel name not utf-8".into()))?;
    if d == 0 || n_random == 0 || !(p > 1.0) {
        return Err(Error::Data("invalid RFDM header".into()));
    }
    let mut orders = Vec::with_capacity(n_random);
    for _ in 0..n_random {
        orders.push(r.u32()?);
    }
    let mut weights = Vec::with_capacity(n_random);
    for _ in 0..n_random {
        weights.push(r.f32()?);
    }
    let rows = r.u32()? as usize;
    let expected_rows: u64 = orders.iter().map(|&o| o as u64).sum();
    if rows as u64 != expected_rows {
        return Err(Error::Data(format!(
            "row count {rows} does not match order sum {expected_rows}"
        )));
    }
    let words_per_row = d.div_ceil(64);
    let mut words = Vec::with_capacity(rows * words_per_row);
    for _ in 0..rows * words_per_row {
        words.push(r.u64()?);
    }
    if r.pos != buf.len() {
        return Err(Error::Data("trailing bytes in RFDM blob".into()));
    }
    let mut offsets = Vec::with_capacity(n_random + 1);
    offsets.push(0u32);
    let mut acc = 0u32;
    for &o in &orders {
        acc += o;
        offsets.push(acc);
    }
    let omegas = RademacherMatrix::from_words(rows, d, words);
    // `restrict_support` only affects sampling, not evaluation of an
    // already-sampled map, so it is not part of the wire format.
    let config = RmConfig { p, h01, max_order, restrict_support: true };
    Ok(RandomMaclaurin::from_parts(
        d, n_random, config, orders, weights, offsets, omegas, w_const, w_linear, kernel_name,
    ))
}

/// Save to a file.
pub fn save(map: &RandomMaclaurin, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_bytes(map))?;
    Ok(())
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<RandomMaclaurin> {
    let mut f = std::fs::File::open(path)?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    from_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Exponential, Polynomial};
    use crate::features::FeatureMap;
    use crate::maclaurin::RmConfig;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_preserves_transform() {
        let mut rng = Rng::seed_from(1);
        let k = Polynomial::new(5, 1.0);
        let map = RandomMaclaurin::sample(&k, 7, 48, RmConfig::default(), &mut rng);
        let bytes = to_bytes(&map);
        let map2 = from_bytes(&bytes).unwrap();
        let x: Vec<f32> = (0..7).map(|i| (i as f32 * 0.13).sin() * 0.3).collect();
        assert_eq!(map.transform(&x), map2.transform(&x));
        assert_eq!(map.orders(), map2.orders());
        assert_eq!(map.kernel_name(), map2.kernel_name());
    }

    #[test]
    fn roundtrip_h01() {
        let mut rng = Rng::seed_from(2);
        let k = Exponential::new(1.0);
        let map =
            RandomMaclaurin::sample(&k, 5, 16, RmConfig::default().with_h01(true), &mut rng);
        let map2 = from_bytes(&to_bytes(&map)).unwrap();
        assert_eq!(map.output_dim(), map2.output_dim());
        assert_eq!(map.w_const(), map2.w_const());
        assert_eq!(map.w_linear(), map2.w_linear());
        let x = vec![0.1f32, -0.2, 0.05, 0.3, 0.0];
        assert_eq!(map.transform(&x), map2.transform(&x));
    }

    #[test]
    fn rejects_corruption() {
        let mut rng = Rng::seed_from(3);
        let k = Polynomial::new(2, 1.0);
        let map = RandomMaclaurin::sample(&k, 4, 8, RmConfig::default(), &mut rng);
        let bytes = to_bytes(&map);
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad).is_err());
        // Truncated.
        assert!(from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage.
        let mut long = bytes.clone();
        long.push(0);
        assert!(from_bytes(&long).is_err());
        // Empty.
        assert!(from_bytes(&[]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::seed_from(4);
        let k = Polynomial::new(3, 0.5);
        let map = RandomMaclaurin::sample(&k, 6, 12, RmConfig::default(), &mut rng);
        let dir = std::env::temp_dir().join("rfdot_serialize_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("map.rfdm");
        save(&map, &path).unwrap();
        let map2 = load(&path).unwrap();
        let x = vec![0.2f32; 6];
        assert_eq!(map.transform(&x), map2.transform(&x));
        std::fs::remove_file(&path).ok();
    }
}
