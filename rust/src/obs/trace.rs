//! Tracing spans: RAII guards recording begin/end events into
//! per-thread ring buffers, drained centrally, exportable as Chrome
//! `trace_event` JSON.
//!
//! # Recording
//!
//! [`span`] checks the process-wide enable flag ([`super::enabled`],
//! one relaxed atomic load) and, when tracing is off, returns an inert
//! guard — no allocation, no time source, nothing observable (pinned
//! by `rust/tests/alloc_free_transform.rs`). When tracing is on, the
//! guard records a `Begin` event at construction and an `End` event on
//! drop into the calling thread's ring.
//!
//! Each ring is owned by exactly one writer thread and pre-allocates
//! its full capacity ([`RING_CAP`] events), so the steady-state record
//! path never allocates either. The writer never blocks: it `try_lock`s
//! its own ring (the only possible contender is a central drain) and
//! counts the event as dropped instead of waiting. When a ring fills,
//! the *newest* events are dropped and counted — the retained prefix
//! stays begin/end-consistent, so exports remain balanced.
//!
//! # Export
//!
//! [`drain`] empties every ring (events survive their thread: rings
//! are registered globally and kept alive by `Arc`).
//! [`chrome_trace`] pairs begin/end events per thread and emits only
//! matched pairs as `"B"`/`"E"` `traceEvents` — balanced by
//! construction, loadable in `chrome://tracing` / Perfetto, and
//! checkable offline with `rfdot trace-check`.

use crate::config::json::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Events retained per thread ring (~1.5 MiB per traced thread).
pub const RING_CAP: usize = 65_536;

/// Begin or end of a span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Begin,
    End,
}

/// One recorded trace event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Span name (static: span names are a fixed taxonomy, see
    /// `ARCHITECTURE.md`).
    pub name: &'static str,
    pub kind: EventKind,
    /// Nanoseconds since the shared process trace epoch.
    pub t_ns: u64,
}

/// One thread's event ring. Single writer (the owning thread), drained
/// centrally.
#[derive(Debug)]
struct ThreadRing {
    tid: u64,
    epoch: Instant,
    dropped: AtomicU64,
    buf: Mutex<Vec<Event>>,
}

impl ThreadRing {
    fn record(&self, name: &'static str, kind: EventKind) {
        let t_ns = self.epoch.elapsed().as_nanos() as u64;
        match self.buf.try_lock() {
            Ok(mut buf) => {
                if buf.len() < RING_CAP {
                    buf.push(Event { name, kind, t_ns });
                } else {
                    // Drop-newest: the retained prefix keeps its
                    // begin/end structure intact.
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            // A drain holds the lock; never block the hot path.
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

static RINGS: Mutex<Vec<Arc<ThreadRing>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: Mutex<Option<Instant>> = Mutex::new(None);

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The process-wide trace epoch, fixed on first use so timestamps from
/// different threads share one time base.
fn shared_epoch() -> Instant {
    *lock(&EPOCH).get_or_insert_with(Instant::now)
}

thread_local! {
    static LOCAL: Arc<ThreadRing> = {
        let ring = Arc::new(ThreadRing {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            epoch: shared_epoch(),
            dropped: AtomicU64::new(0),
            buf: Mutex::new(Vec::with_capacity(RING_CAP)),
        });
        lock(&RINGS).push(ring.clone());
        ring
    };
}

fn record(name: &'static str, kind: EventKind) {
    // `try_with` tolerates TLS teardown: a span on a dying thread is
    // silently not recorded rather than panicking.
    let _ = LOCAL.try_with(|r| r.record(name, kind));
}

/// RAII span guard: see [`span`].
#[must_use = "a span measures the scope it is bound to; bind it to a named guard"]
#[derive(Debug)]
pub struct Span {
    name: &'static str,
    armed: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            record(self.name, EventKind::End);
        }
    }
}

/// Open a span covering the enclosing scope:
///
/// ```
/// let _span = rfdot::obs::span("transform.rm");
/// // ... traced work ...
/// ```
///
/// When tracing is disabled this is one relaxed atomic load and an
/// inert guard (no allocation); when enabled, a `Begin` event is
/// recorded now and the matching `End` when the guard drops.
pub fn span(name: &'static str) -> Span {
    if !super::enabled() {
        return Span { name, armed: false };
    }
    record(name, EventKind::Begin);
    Span { name, armed: true }
}

/// Record an instantaneous marker (a zero-length span) — used for
/// point events like a work-steal.
pub fn mark(name: &'static str) {
    if super::enabled() {
        record(name, EventKind::Begin);
        record(name, EventKind::End);
    }
}

/// Everything one thread recorded since the last drain.
#[derive(Clone, Debug)]
pub struct ThreadEvents {
    pub tid: u64,
    /// Events lost to ring overflow or drain contention.
    pub dropped: u64,
    pub events: Vec<Event>,
}

/// Empty every thread ring and return the events, ordered by thread
/// id. Rings stay registered (and keep their capacity), so tracing
/// continues seamlessly after a drain.
pub fn drain() -> Vec<ThreadEvents> {
    let rings: Vec<Arc<ThreadRing>> = lock(&RINGS).clone();
    let mut out: Vec<ThreadEvents> = rings
        .iter()
        .map(|r| ThreadEvents {
            tid: r.tid,
            dropped: r.dropped.load(Ordering::Relaxed),
            events: lock(&r.buf).drain(..).collect(),
        })
        .collect();
    out.sort_by_key(|t| t.tid);
    out
}

/// Render drained events as a Chrome `trace_event` document. Begin/end
/// events are paired per thread with a name-checked stack and only
/// matched pairs are emitted, so the output always contains balanced
/// `"B"`/`"E"` events (unpaired remnants of ring overflow are
/// discarded).
pub fn chrome_trace(threads: &[ThreadEvents]) -> Json {
    let mut trace_events = Vec::new();
    for t in threads {
        let mut matched = vec![false; t.events.len()];
        let mut stack: Vec<usize> = Vec::new();
        for (i, e) in t.events.iter().enumerate() {
            match e.kind {
                EventKind::Begin => stack.push(i),
                EventKind::End => {
                    if let Some(&j) = stack.last() {
                        if t.events[j].name == e.name {
                            stack.pop();
                            matched[j] = true;
                            matched[i] = true;
                        }
                    }
                }
            }
        }
        for (i, e) in t.events.iter().enumerate() {
            if !matched[i] {
                continue;
            }
            let mut m = BTreeMap::new();
            m.insert("cat".to_string(), Json::Str("rfdot".to_string()));
            m.insert("name".to_string(), Json::Str(e.name.to_string()));
            m.insert(
                "ph".to_string(),
                Json::Str(
                    match e.kind {
                        EventKind::Begin => "B",
                        EventKind::End => "E",
                    }
                    .to_string(),
                ),
            );
            m.insert("pid".to_string(), Json::Num(1.0));
            m.insert("tid".to_string(), Json::Num(t.tid as f64));
            m.insert("ts".to_string(), Json::Num(e.t_ns as f64 / 1000.0));
            trace_events.push(Json::Obj(m));
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    doc.insert("traceEvents".to_string(), Json::Arr(trace_events));
    Json::Obj(doc)
}

/// Statistics of a validated Chrome trace document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCheck {
    /// Total `traceEvents` entries.
    pub events: usize,
    /// Matched begin/end pairs.
    pub spans: usize,
    /// Distinct thread ids.
    pub threads: usize,
}

/// Validate a Chrome `trace_event` document: every `"B"` must be
/// closed by a same-name `"E"` on the same `pid`/`tid`, with nothing
/// left open. This is the `rfdot trace-check` gate CI runs on the file
/// `rfdot serve --trace-out` writes.
pub fn check_balanced(doc: &Json) -> Result<TraceCheck> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Config("trace document has no traceEvents array".into()))?;
    let mut stacks: BTreeMap<(u64, u64), Vec<String>> = BTreeMap::new();
    let mut tids: std::collections::BTreeSet<u64> = Default::default();
    let mut spans = 0usize;
    for (i, e) in events.iter().enumerate() {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Config(format!("traceEvents[{i}]: missing name")))?;
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Config(format!("traceEvents[{i}]: missing ph")))?;
        let pid = e.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let tid = e.get("tid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        if e.get("ts").and_then(Json::as_f64).is_none() {
            return Err(Error::Config(format!("traceEvents[{i}]: missing ts")));
        }
        tids.insert(tid);
        let stack = stacks.entry((pid, tid)).or_default();
        match ph {
            "B" => stack.push(name.to_string()),
            "E" => match stack.pop() {
                Some(open) if open == name => spans += 1,
                Some(open) => {
                    return Err(Error::Config(format!(
                        "traceEvents[{i}]: end of {name:?} while {open:?} is open (tid {tid})"
                    )))
                }
                None => {
                    return Err(Error::Config(format!(
                        "traceEvents[{i}]: end of {name:?} with no span open (tid {tid})"
                    )))
                }
            },
            other => {
                return Err(Error::Config(format!(
                    "traceEvents[{i}]: unsupported phase {other:?} (only B/E are emitted)"
                )))
            }
        }
    }
    for ((_, tid), stack) in &stacks {
        if let Some(open) = stack.last() {
            return Err(Error::Config(format!(
                "unbalanced trace: span {open:?} never ends (tid {tid})"
            )));
        }
    }
    Ok(TraceCheck { events: events.len(), spans, threads: tids.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, kind: EventKind, t_ns: u64) -> Event {
        Event { name, kind, t_ns }
    }

    #[test]
    fn chrome_export_pairs_and_balances() {
        // A well-nested thread plus a thread with an orphan Begin (ring
        // overflow dropped its End): the orphan must not be emitted.
        let threads = vec![
            ThreadEvents {
                tid: 1,
                dropped: 0,
                events: vec![
                    ev("outer", EventKind::Begin, 100),
                    ev("inner", EventKind::Begin, 200),
                    ev("inner", EventKind::End, 300),
                    ev("outer", EventKind::End, 400),
                ],
            },
            ThreadEvents {
                tid: 2,
                dropped: 1,
                events: vec![
                    ev("orphan", EventKind::Begin, 50),
                    ev("ok", EventKind::Begin, 60),
                    ev("ok", EventKind::End, 70),
                ],
            },
        ];
        let doc = chrome_trace(&threads);
        let check = check_balanced(&doc).unwrap();
        assert_eq!(check.spans, 3, "outer, inner, ok");
        assert_eq!(check.events, 6);
        assert_eq!(check.threads, 2);
        let text = doc.pretty();
        assert!(!text.contains("orphan"), "unmatched Begin must be discarded:\n{text}");
        // Deterministic and re-parseable.
        assert_eq!(Json::parse(&text).unwrap().pretty(), text);
    }

    #[test]
    fn check_balanced_rejects_malformed() {
        let mk = |events: &str| {
            Json::parse(&format!("{{\"traceEvents\": [{events}]}}")).unwrap()
        };
        let e = |name: &str, ph: &str, tid: u64| {
            format!("{{\"name\": \"{name}\", \"ph\": \"{ph}\", \"pid\": 1, \"tid\": {tid}, \"ts\": 1.5}}")
        };
        // Balanced.
        assert!(check_balanced(&mk(&format!("{}, {}", e("a", "B", 1), e("a", "E", 1)))).is_ok());
        // End with nothing open.
        assert!(check_balanced(&mk(&e("a", "E", 1))).is_err());
        // Never-closed Begin.
        assert!(check_balanced(&mk(&e("a", "B", 1))).is_err());
        // Cross-name nesting violation.
        let bad = format!("{}, {}, {}", e("a", "B", 1), e("b", "B", 1), e("a", "E", 1));
        assert!(check_balanced(&mk(&bad)).is_err());
        // Same names on *different* threads do not interact.
        let ok = format!(
            "{}, {}, {}, {}",
            e("a", "B", 1),
            e("a", "B", 2),
            e("a", "E", 2),
            e("a", "E", 1)
        );
        assert!(check_balanced(&mk(&ok)).is_ok());
        // Not a trace document at all.
        assert!(check_balanced(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn disabled_spans_are_inert() {
        // The flag defaults off (unless the suite runs under
        // RFDOT_TRACE=1, in which case this test is vacuous for the
        // disabled branch but the guard still must not panic).
        let was = super::super::enabled();
        if !was {
            let before: usize = drain().iter().map(|t| t.events.len()).sum();
            {
                let _span = span("test.disabled");
            }
            let after: usize = drain().iter().map(|t| t.events.len()).sum();
            assert_eq!(before, after, "disabled spans must record nothing");
        }
    }
}
