//! Observability: always-on metrics, tracing spans and structured
//! export for the transform + serving stack.
//!
//! Three pieces, threaded through every hot path:
//!
//! 1. **Metrics** — named [`Counter`]s, [`Gauge`]s and log-bucketed
//!    [`Histogram`]s (base-2 octaves with linear sub-buckets, bounded
//!    memory, mergeable across shards — `hist.rs`). The serving layer's
//!    per-shard latency moved here from the freeze-after-cap
//!    [`crate::metrics::SampleBuffer`], so steady-state latency is
//!    recorded for the whole life of the process, not just a warm-up
//!    window.
//! 2. **Tracing spans** — [`span`] returns an RAII guard that records
//!    begin/end events with monotonic timestamps into a per-thread ring
//!    buffer (`trace.rs`), drained centrally. Near-zero cost when
//!    disabled: one relaxed atomic load and an inert guard, no
//!    allocation (asserted by `rust/tests/alloc_free_transform.rs`).
//! 3. **Export** — [`MetricsSnapshot`] renders the registry to
//!    deterministic JSON via [`Json::pretty`], and
//!    [`trace::chrome_trace`] emits Chrome `trace_event` JSON
//!    (`rfdot serve --trace-out trace.json`, loadable in
//!    `chrome://tracing` / Perfetto).
//!
//! # The enable flag
//!
//! Tracing follows the same process-wide knob pattern as
//! [`crate::simd`] and [`crate::parallel`]: `--trace` on the CLI, the
//! `RFDOT_TRACE` environment variable (any value other than empty,
//! `0` or `false` enables), or `"trace": true` in a config file —
//! resolved lazily on first use, overridable via [`set_enabled`].
//! Metrics (counters/gauges/histograms) are *always on*: they are a
//! handful of relaxed atomic operations and never allocate on the
//! record path.

pub mod hist;
pub mod trace;

pub use hist::Histogram;
pub use trace::{span, Span};

use crate::config::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Process-wide trace enable flag. 0 = unresolved (consult
/// `RFDOT_TRACE` on first use), 1 = off, 2 = on.
static ENABLED: AtomicU8 = AtomicU8::new(0);

/// Is tracing enabled? One relaxed atomic load on the hot path; the
/// first call resolves the `RFDOT_TRACE` environment variable.
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let on = std::env::var("RFDOT_TRACE")
                .map(|s| {
                    let t = s.trim();
                    !t.is_empty() && t != "0" && !t.eq_ignore_ascii_case("false")
                })
                .unwrap_or(false);
            // Benign race: every initializer computes the same value.
            ENABLED.store(if on { 2 } else { 1 }, Ordering::Relaxed);
            on
        }
    }
}

/// Override the trace flag (the CLI's `--trace` and config `"trace"`
/// call this; tests toggle it explicitly).
pub fn set_enabled(on: bool) {
    ENABLED.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// A named monotonic counter (relaxed atomics, never allocates).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A named point-in-time value (relaxed atomics, never allocates).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Adjust the gauge by a signed delta (e.g. connection open/close).
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The process-global registry of named metrics. Registration locks a
/// mutex once per *name* (the returned `Arc` is cached by the caller);
/// recording through the returned handles is lock-free. Keys are owned
/// strings so dynamically-scoped metrics (per-model serving counters
/// like `net.model.<name>.requests`) register through the same path as
/// the static hot-path names and flow into [`MetricsSnapshot`].
#[derive(Debug)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

static REGISTRY: Registry = Registry {
    counters: Mutex::new(BTreeMap::new()),
    gauges: Mutex::new(BTreeMap::new()),
    histograms: Mutex::new(BTreeMap::new()),
};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // Tolerate poisoning: metrics must never compound a failure.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The process-global metric registry.
pub fn registry() -> &'static Registry {
    &REGISTRY
}

/// Get or create the named counter. Accepts dynamic names (the key is
/// stored as an owned `String`); callers on hot paths should cache the
/// returned handle rather than re-registering per record.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut g = lock(&REGISTRY.counters);
    if let Some(c) = g.get(name) {
        return c.clone();
    }
    let c = Arc::new(Counter::default());
    g.insert(name.to_string(), c.clone());
    c
}

/// Get or create the named gauge (dynamic names accepted; see
/// [`counter`]).
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut g = lock(&REGISTRY.gauges);
    if let Some(v) = g.get(name) {
        return v.clone();
    }
    let v = Arc::new(Gauge::default());
    g.insert(name.to_string(), v.clone());
    v
}

/// Get or create the named histogram (dynamic names accepted; see
/// [`counter`]).
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut g = lock(&REGISTRY.histograms);
    if let Some(h) = g.get(name) {
        return h.clone();
    }
    let h = Arc::new(Histogram::new());
    g.insert(name.to_string(), h.clone());
    h
}

/// A point-in-time copy of every registered metric, renderable to
/// deterministic JSON (object keys come out in `BTreeMap` order, so
/// equal snapshots produce byte-identical documents).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, crate::metrics::Summary>,
}

impl MetricsSnapshot {
    /// Snapshot the global registry.
    pub fn collect() -> MetricsSnapshot {
        MetricsSnapshot {
            counters: lock(&REGISTRY.counters)
                .iter()
                .map(|(k, v)| (k.to_string(), v.get()))
                .collect(),
            gauges: lock(&REGISTRY.gauges)
                .iter()
                .map(|(k, v)| (k.to_string(), v.get()))
                .collect(),
            histograms: lock(&REGISTRY.histograms)
                .iter()
                .map(|(k, v)| (k.to_string(), v.summary()))
                .collect(),
        }
    }

    /// Deterministic JSON rendering (see [`Json::pretty`]).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in &self.counters {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in &self.gauges {
            gauges.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut hists = BTreeMap::new();
        for (k, s) in &self.histograms {
            let mut h = BTreeMap::new();
            h.insert("n".to_string(), Json::Num(s.n as f64));
            h.insert("mean".to_string(), Json::Num(s.mean));
            h.insert("min".to_string(), Json::Num(s.min));
            h.insert("p50".to_string(), Json::Num(s.p50));
            h.insert("p90".to_string(), Json::Num(s.p90));
            h.insert("max".to_string(), Json::Num(s.max));
            hists.insert(k.clone(), Json::Obj(h));
        }
        let mut doc = BTreeMap::new();
        doc.insert("counters".to_string(), Json::Obj(counters));
        doc.insert("gauges".to_string(), Json::Obj(gauges));
        doc.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let c = counter("test.obs.counter");
        c.add(2);
        c.add(3);
        assert_eq!(c.get(), 5);
        // Same name, same handle.
        assert_eq!(counter("test.obs.counter").get(), 5);
        let g = gauge("test.obs.gauge");
        g.set(-7);
        assert_eq!(gauge("test.obs.gauge").get(), -7);
    }

    #[test]
    fn dynamic_names_register_and_snapshot() {
        let name = format!("test.obs.dyn.{}", "model-a");
        counter(&name).add(3);
        let g = gauge("test.obs.dyn_gauge");
        g.add(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        let snap = MetricsSnapshot::collect();
        assert_eq!(snap.counters.get(&name), Some(&3));
        // Re-registering by the same dynamic name returns the same handle.
        assert_eq!(counter(&name).get(), 3);
    }

    #[test]
    fn snapshot_renders_deterministic_json() {
        counter("test.obs.snap").add(1);
        gauge("test.obs.snap_gauge").set(4);
        histogram("test.obs.snap_hist").record(100);
        let snap = MetricsSnapshot::collect();
        let json = snap.to_json().pretty();
        assert_eq!(json, snap.to_json().pretty(), "rendering must be stable");
        assert!(json.contains("\"test.obs.snap\": 1"), "{json}");
        assert!(json.contains("\"test.obs.snap_gauge\": 4"), "{json}");
        assert!(json.contains("\"test.obs.snap_hist\""), "{json}");
        // And it parses back through the in-tree parser.
        crate::config::json::Json::parse(&json).unwrap();
    }
}
