//! Log-bucketed histogram: base-2 octaves split into linear
//! sub-buckets (HDR-style log-linear layout), atomic counts, bounded
//! memory, mergeable across shards.
//!
//! # Layout
//!
//! Non-negative integer values (the serving layer records microseconds)
//! index into one of [`NBUCKETS`] buckets:
//!
//! * values `< 32` land in unit-width buckets (exact);
//! * a value with most-significant bit `e >= 5` lands in octave `e`,
//!   which is split into [`SUBS`] = 32 equal sub-buckets of width
//!   `2^(e-5)`.
//!
//! # Error bound
//!
//! [`Histogram::quantile`] walks the cumulative counts to the
//! nearest-rank bucket and interpolates linearly inside it. The exact
//! nearest-rank sample lies in that same bucket, so the estimate is off
//! by at most one bucket width:
//!
//! ```text
//! |estimate − exact| ≤ max(1, exact / 32)
//! ```
//!
//! i.e. relative error at most `1/32 ≈ 3.2%` for values ≥ 32 and
//! absolute error < 1 below that (where buckets are exact). The
//! property tests below pin this bound against exact nearest-rank over
//! adversarial distributions. Memory is a fixed ~15 KiB per histogram
//! regardless of sample count — unlike
//! [`crate::metrics::SampleBuffer`], which stores raw samples and stops
//! recording at its cap, this records forever.

use crate::metrics::Summary;
use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the sub-buckets per octave.
pub const SUB_BITS: u32 = 5;

/// Linear sub-buckets per base-2 octave (the `1/SUBS` relative-error
/// knob).
pub const SUBS: usize = 1 << SUB_BITS;

/// Total buckets: the unit-width linear region plus every octave a
/// `u64` value can land in.
pub const NBUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUBS;

/// A concurrent log-linear histogram of non-negative integer samples.
/// Recording is lock-free (a handful of relaxed atomic RMWs) and
/// allocation-free; all allocation happens in [`Histogram::new`].
#[derive(Debug)]
pub struct Histogram {
    counts: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram (allocates its fixed bucket array once).
    pub fn new() -> Histogram {
        let counts: Vec<AtomicU64> = (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            counts: counts.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Bucket index of a value.
    fn index(v: u64) -> usize {
        if v < SUBS as u64 {
            return v as usize;
        }
        let e = 63 - v.leading_zeros() as usize;
        let sub = ((v >> (e - SUB_BITS as usize)) & (SUBS as u64 - 1)) as usize;
        (e - SUB_BITS as usize + 1) * SUBS + sub
    }

    /// Inclusive lower edge of bucket `idx`.
    fn bucket_lo(idx: usize) -> u64 {
        let block = idx / SUBS;
        let sub = (idx % SUBS) as u64;
        if block == 0 {
            return sub;
        }
        let e = block + SUB_BITS as usize - 1;
        (1u64 << e) + (sub << (e - SUB_BITS as usize))
    }

    /// Width of bucket `idx` (1 in the linear region).
    fn bucket_width(idx: usize) -> u64 {
        let block = idx / SUBS;
        if block == 0 {
            1
        } else {
            1u64 << (block - 1)
        }
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.counts[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a float sample, rounded to the nearest non-negative
    /// integer (the serving layer records latency in microseconds).
    pub fn record_f64(&self, v: f64) {
        self.record(v.max(0.0).round() as u64);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min_value(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX {
            0
        } else {
            m
        }
    }

    /// Largest recorded sample (0 when empty).
    pub fn max_value(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Exact mean of the recorded samples (sums are kept exactly).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Estimated nearest-rank quantile, linearly interpolated inside
    /// the nearest-rank bucket and clamped to the observed `[min, max]`
    /// range. See the module docs for the error bound.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let (lo_clamp, hi_clamp) = (self.min_value() as f64, self.max_value() as f64);
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let c = c.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if acc + c >= target {
                let lo = Self::bucket_lo(i) as f64;
                let w = Self::bucket_width(i) as f64;
                let frac = (target - acc) as f64 / c as f64;
                return (lo + w * frac).clamp(lo_clamp, hi_clamp);
            }
            acc += c;
        }
        hi_clamp
    }

    /// Fold another histogram's samples into this one (shard
    /// aggregation). Addition of bucket counts is associative and
    /// commutative, so any merge tree yields the same histogram.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter().zip(other.counts.iter()) {
            let c = theirs.load(Ordering::Relaxed);
            if c > 0 {
                mine.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Percentile summary in the crate-wide [`Summary`] shape: exact
    /// `n`/`mean`/`min`/`max`, estimated `p50`/`p90` (within the
    /// documented bucket error bound).
    pub fn summary(&self) -> Summary {
        let n = self.count() as usize;
        if n == 0 {
            return Summary { n: 0, mean: 0.0, min: 0.0, p50: 0.0, p90: 0.0, max: 0.0 };
        }
        Summary {
            n,
            mean: self.mean(),
            min: self.min_value() as f64,
            p50: self.quantile(0.5),
            p90: self.quantile(0.9),
            max: self.max_value() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Exact nearest-rank quantile (the oracle the histogram is pinned
    /// against — same rule as [`Summary::from_samples`]).
    fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
        let rank = ((q * sorted.len() as f64).ceil() as usize).max(1) - 1;
        sorted[rank.min(sorted.len() - 1)]
    }

    /// The documented bound: |estimate − exact| ≤ max(1, exact/SUBS).
    fn assert_within_bound(h: &Histogram, sorted: &[u64], label: &str) {
        for q in [0.0, 0.01, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let exact = exact_quantile(sorted, q) as f64;
            let est = h.quantile(q);
            let bound = (exact / SUBS as f64).max(1.0);
            assert!(
                (est - exact).abs() <= bound,
                "{label}: q={q} exact={exact} est={est} bound={bound}"
            );
        }
    }

    fn build(samples: &[u64]) -> (Histogram, Vec<u64>) {
        let h = Histogram::new();
        for &v in samples {
            h.record(v);
        }
        let mut sorted = samples.to_vec();
        sorted.sort_unstable();
        (h, sorted)
    }

    #[test]
    fn bucket_indexing_is_monotone_and_self_consistent() {
        let mut prev = 0usize;
        for v in (0u64..4096).chain([1 << 20, (1 << 20) + 1, u64::MAX / 2, u64::MAX]) {
            let idx = Histogram::index(v);
            assert!(idx < NBUCKETS, "v={v} idx={idx}");
            assert!(idx >= prev, "index must be monotone in the value");
            prev = idx;
            let lo = Histogram::bucket_lo(idx);
            let w = Histogram::bucket_width(idx);
            assert!(lo <= v, "v={v} below its bucket lo={lo}");
            assert!(v - lo < w, "v={v} beyond its bucket [{lo}, {lo}+{w})");
        }
        // Octave boundary continuity: bucket 31 ends exactly where
        // octave 5's first sub-bucket begins.
        assert_eq!(Histogram::bucket_lo(SUBS), SUBS as u64);
    }

    #[test]
    fn quantiles_within_bound_constant() {
        for v in [0u64, 1, 7, 31, 32, 1000, 123_456_789] {
            let (h, sorted) = build(&vec![v; 100]);
            assert_within_bound(&h, &sorted, &format!("constant {v}"));
            // Constant distributions are exact: the clamp to [min, max]
            // collapses the bucket interpolation.
            assert_eq!(h.quantile(0.5), v as f64);
        }
    }

    #[test]
    fn quantiles_within_bound_bimodal() {
        let mut samples = vec![3u64; 500];
        samples.extend(vec![2_000_000u64; 500]);
        let (h, sorted) = build(&samples);
        assert_within_bound(&h, &sorted, "bimodal");
    }

    #[test]
    fn quantiles_within_bound_heavy_tail() {
        // Pareto-ish tail: u^-2 over a seeded uniform stream.
        let mut rng = Rng::seed_from(0x0b5);
        let samples: Vec<u64> = (0..4000)
            .map(|_| {
                let u = rng.f64().max(1e-6);
                (10.0 / (u * u)) as u64
            })
            .collect();
        let (h, sorted) = build(&samples);
        assert_within_bound(&h, &sorted, "heavy-tail");
    }

    #[test]
    fn degenerate_sizes() {
        let empty = Histogram::new();
        assert!(empty.is_empty());
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.summary().n, 0);
        assert_eq!(empty.mean(), 0.0);

        let (one, sorted) = build(&[42]);
        assert_within_bound(&one, &sorted, "n=1");
        assert_eq!(one.quantile(0.0), 42.0);
        assert_eq!(one.quantile(1.0), 42.0);
        let s = one.summary();
        assert_eq!((s.n, s.min, s.max), (1, 42.0, 42.0));
    }

    #[test]
    fn merge_is_associative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let (a, b, c) =
            (mk(&[1, 2, 3]), mk(&[1000, 2000, 3000]), mk(&[7, 7_000_000, u64::MAX / 3]));

        // (a ∪ b) ∪ c
        let left = Histogram::new();
        left.merge_from(&a);
        left.merge_from(&b);
        let left_all = Histogram::new();
        left_all.merge_from(&left);
        left_all.merge_from(&c);

        // a ∪ (b ∪ c)
        let right = Histogram::new();
        right.merge_from(&b);
        right.merge_from(&c);
        let right_all = Histogram::new();
        right_all.merge_from(&a);
        right_all.merge_from(&right);

        assert_eq!(left_all.count(), 9);
        assert_eq!(left_all.count(), right_all.count());
        assert_eq!(left_all.summary(), right_all.summary());
        for (l, r) in left_all.counts.iter().zip(right_all.counts.iter()) {
            assert_eq!(l.load(Ordering::Relaxed), r.load(Ordering::Relaxed));
        }
        // And merging preserves the exact moments of the union.
        let union = mk(&[1, 2, 3, 1000, 2000, 3000, 7, 7_000_000, u64::MAX / 3]);
        assert_eq!(left_all.summary(), union.summary());
    }

    #[test]
    fn mean_min_max_are_exact() {
        let (h, _) = build(&[10, 20, 60]);
        assert_eq!(h.mean(), 30.0);
        assert_eq!(h.min_value(), 10);
        assert_eq!(h.max_value(), 60);
        assert_eq!(h.count(), 3);
        h.record_f64(-5.0);
        assert_eq!(h.min_value(), 0, "negative floats clamp to 0");
    }
}
