//! Iterative radix-2 complex FFT — substrate for the TensorSketch
//! baseline (circular convolution of count sketches).

use crate::{Error, Result};

/// [`fft`] with a recoverable shape error instead of a panic — the
/// entry point for caller-controlled lengths (internal callers that
/// already round up with [`super::next_pow2`] use [`fft`] directly).
pub fn fft_checked(re: &mut [f32], im: &mut [f32], inverse: bool) -> Result<()> {
    if re.len() != im.len() {
        return Err(Error::shape(format!("im length {}", re.len()), format!("{}", im.len())));
    }
    if re.len() > 1 && !re.len().is_power_of_two() {
        return Err(Error::shape("power-of-two length", format!("{}", re.len())));
    }
    fft(re, im, inverse);
    Ok(())
}

/// In-place iterative Cooley-Tukey FFT over interleaved complex buffers
/// (`re`, `im`); `inverse` applies the conjugate transform *and* the 1/n
/// scale. Lengths must be powers of two.
pub fn fft(re: &mut [f32], im: &mut [f32], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    if n <= 1 {
        return;
    }
    assert!(n.is_power_of_two(), "fft length must be a power of two, got {n}");

    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = i.reverse_bits() >> (usize::BITS - bits);
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }

    let sign = if inverse { 1.0f64 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            let mut cur_r = 1.0f64;
            let mut cur_i = 0.0f64;
            for k in 0..len / 2 {
                let a = start + k;
                let b = start + k + len / 2;
                let (ar, ai) = (re[a] as f64, im[a] as f64);
                let (br, bi) = (re[b] as f64, im[b] as f64);
                let tr = br * cur_r - bi * cur_i;
                let ti = br * cur_i + bi * cur_r;
                re[a] = (ar + tr) as f32;
                im[a] = (ai + ti) as f32;
                re[b] = (ar - tr) as f32;
                im[b] = (ai - ti) as f32;
                let nr = cur_r * wr - cur_i * wi;
                cur_i = cur_r * wi + cur_i * wr;
                cur_r = nr;
            }
        }
        len <<= 1;
    }

    if inverse {
        let scale = 1.0 / n as f32;
        for v in re.iter_mut() {
            *v *= scale;
        }
        for v in im.iter_mut() {
            *v *= scale;
        }
    }
}

/// Elementwise complex multiply: `(ar, ai) *= (br, bi)`.
pub fn complex_mul_inplace(ar: &mut [f32], ai: &mut [f32], br: &[f32], bi: &[f32]) {
    for k in 0..ar.len() {
        let r = ar[k] * br[k] - ai[k] * bi[k];
        let i = ar[k] * bi[k] + ai[k] * br[k];
        ar[k] = r;
        ai[k] = i;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn forward_inverse_roundtrip() {
        let mut rng = Rng::seed_from(1);
        for n in [1usize, 2, 8, 64, 256] {
            let orig: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let mut re = orig.clone();
            let mut im = vec![0.0f32; n];
            fft(&mut re, &mut im, false);
            fft(&mut re, &mut im, true);
            for k in 0..n {
                assert!((re[k] - orig[k]).abs() < 1e-4, "n={n} k={k}");
                assert!(im[k].abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = Rng::seed_from(2);
        let n = 16;
        let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let mut re = x.clone();
        let mut im = vec![0.0f32; n];
        fft(&mut re, &mut im, false);
        for k in 0..n {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for t in 0..n {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                sr += x[t] as f64 * ang.cos();
                si += x[t] as f64 * ang.sin();
            }
            assert!((re[k] as f64 - sr).abs() < 1e-3, "k={k}: {} vs {sr}", re[k]);
            assert!((im[k] as f64 - si).abs() < 1e-3);
        }
    }

    #[test]
    fn convolution_theorem() {
        // Circular convolution via FFT equals the naive sum.
        let n = 8;
        let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut naive = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..n {
                naive[(i + j) % n] += a[i] * b[j];
            }
        }
        let (mut ar, mut ai) = (a.clone(), vec![0.0f32; n]);
        let (mut br, mut bi) = (b.clone(), vec![0.0f32; n]);
        fft(&mut ar, &mut ai, false);
        fft(&mut br, &mut bi, false);
        complex_mul_inplace(&mut ar, &mut ai, &br, &bi);
        fft(&mut ar, &mut ai, true);
        for k in 0..n {
            assert!((ar[k] - naive[k]).abs() < 1e-4, "k={k}: {} vs {}", ar[k], naive[k]);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        let mut re = vec![0.0f32; 6];
        let mut im = vec![0.0f32; 6];
        fft(&mut re, &mut im, false);
    }

    #[test]
    fn checked_entry_point_errors_instead_of_panicking() {
        let mut re = vec![0.0f32; 6];
        let mut im = vec![0.0f32; 6];
        let e = fft_checked(&mut re, &mut im, false).unwrap_err();
        assert!(e.to_string().contains("power-of-two"), "{e}");
        let mut re = vec![0.0f32; 8];
        let mut im = vec![0.0f32; 7];
        assert!(fft_checked(&mut re, &mut im, false).is_err());
        // Zero-padding to the shared next_pow2 length makes any input
        // length valid.
        let mut re = crate::linalg::zero_pad_pow2(&[1.0, 2.0, 3.0]);
        let mut im = vec![0.0f32; re.len()];
        assert!(fft_checked(&mut re, &mut im, false).is_ok());
        assert_eq!(re.len(), 4);
    }
}
