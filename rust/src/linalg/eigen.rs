//! Symmetric eigendecomposition (cyclic Jacobi) — substrate for the
//! Nyström baseline (`K_mm^{-1/2}`) and kernel PCA.
//!
//! Jacobi is O(n³) per sweep but robust and dependency-free; the
//! landmark counts used here (m ≤ a few hundred) keep it comfortably
//! fast.

use super::Matrix;

/// Eigendecomposition of a symmetric matrix: `a = V diag(λ) Vᵀ`.
/// Returns (eigenvalues descending, V with eigenvectors as *columns*).
pub fn eigh(a: &Matrix, max_sweeps: usize, tol: f64) -> (Vec<f64>, Matrix) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "matrix must be square");
    // Work in f64 for stability.
    let mut m: Vec<f64> = a.as_slice().iter().map(|&v| v as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let off = |m: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    s += m[i * n + j] * m[i * n + j];
                }
            }
        }
        s.sqrt()
    };

    for _ in 0..max_sweeps {
        if off(&m) < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q.
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract, sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).expect("finite eigenvalues"));
    let eigvals: Vec<f64> = pairs.iter().map(|&(l, _)| l).collect();
    let mut vecs = Matrix::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vecs.set(r, new_col, v[r * n + old_col] as f32);
        }
    }
    (eigvals, vecs)
}

/// `a^(−1/2)` for a symmetric PSD matrix, with eigenvalue floor `eps`
/// (pseudo-inverse on the near-null space) — the Nyström normalizer.
pub fn inv_sqrt_psd(a: &Matrix, eps: f64) -> Matrix {
    let n = a.rows();
    let (vals, vecs) = eigh(a, 30, 1e-10);
    // B = V diag(1/sqrt(max(λ, eps_rel))) Vᵀ, dropping tiny/negative λ.
    let lmax = vals.first().copied().unwrap_or(0.0).max(0.0);
    let floor = (eps * lmax.max(1e-30)).max(1e-30);
    let mut out = Matrix::zeros(n, n);
    for k in 0..n {
        let lk = vals[k];
        if lk <= floor {
            continue; // pseudo-inverse: skip the null space
        }
        let w = 1.0 / lk.sqrt();
        for i in 0..n {
            let vik = vecs.get(i, k) as f64;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                let add = (w * vik * vecs.get(j, k) as f64) as f32;
                out.set(i, j, out.get(i, j) + add);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_sym(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.f32() - 0.5;
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn reconstructs_diagonal_matrix() {
        let mut a = Matrix::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 1.0);
        a.set(2, 2, 2.0);
        let (vals, _) = eigh(&a, 20, 1e-12);
        assert!((vals[0] - 3.0).abs() < 1e-6);
        assert!((vals[1] - 2.0).abs() < 1e-6);
        assert!((vals[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn decomposition_reconstructs_matrix() {
        let a = random_sym(8, 1);
        let (vals, vecs) = eigh(&a, 30, 1e-12);
        // A ?= V diag(vals) V^T
        for i in 0..8 {
            for j in 0..8 {
                let mut s = 0.0f64;
                for k in 0..8 {
                    s += vals[k] * vecs.get(i, k) as f64 * vecs.get(j, k) as f64;
                }
                assert!(
                    (s - a.get(i, j) as f64).abs() < 1e-4,
                    "({i},{j}): {s} vs {}",
                    a.get(i, j)
                );
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_sym(10, 2);
        let (_, vecs) = eigh(&a, 30, 1e-12);
        for p in 0..10 {
            for q in 0..10 {
                let dot: f64 = (0..10)
                    .map(|k| vecs.get(k, p) as f64 * vecs.get(k, q) as f64)
                    .sum();
                let expect = if p == q { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-5, "({p},{q}): {dot}");
            }
        }
    }

    #[test]
    fn inv_sqrt_of_psd() {
        // Build PSD A = B B^T, check (A^-1/2)^2 · A ≈ I on the range.
        let b = random_sym(6, 3);
        let a = b.matmul_transposed(&b).unwrap();
        let s = inv_sqrt_psd(&a, 1e-12);
        let s2 = s.matmul(&s).unwrap();
        let prod = s2.matmul(&a).unwrap();
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod.get(i, j) - expect).abs() < 1e-2,
                    "({i},{j}): {}",
                    prod.get(i, j)
                );
            }
        }
    }

    #[test]
    fn inv_sqrt_handles_rank_deficiency() {
        // Rank-1 PSD matrix: pseudo-inverse must not blow up.
        let mut a = Matrix::zeros(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                a.set(i, j, 1.0); // ones = rank 1, eigenvalue 4
            }
        }
        let s = inv_sqrt_psd(&a, 1e-9);
        for v in s.as_slice() {
            assert!(v.is_finite());
        }
        // A^{-1/2} of ones/4-projector scaled: s·a·s should be the projector.
        let p = s.matmul(&a).unwrap().matmul(&s).unwrap();
        assert!((p.get(0, 0) - 0.25).abs() < 1e-3, "{}", p.get(0, 0));
    }
}
