//! In-place fast Walsh–Hadamard transform — the O(n log n) butterfly at
//! the heart of the [`crate::structured`] projection subsystem (HD
//! blocks, SRHT), sited next to its radix-2 sibling [`super::fft`].
//!
//! Convention: the **unnormalized** transform, `y = H_n x` with
//! `H_n[i, k] = (−1)^{popcount(i & k)} ∈ {±1}` (Sylvester ordering).
//! Consequences the structured subsystem relies on:
//!
//! * every entry of `H_n` is ±1, so a row of `H_n · D` (D a Rademacher
//!   diagonal) is *exactly* a Rademacher vector in distribution — the
//!   structured projections inherit the dense maps' marginal law and
//!   deterministic bounds (`|⟨h, x⟩| ≤ ‖x‖₁`);
//! * `H_n H_n = n·I` (involution up to `1/n`), and
//!   `‖H_n x‖² = n‖x‖²` (Parseval) — both pinned by property tests.
//!
//! The butterfly is the standard iterative doubling scheme: pass `h`
//! combines elements `h` apart, so the innermost loops stream two
//! contiguous runs — cache-friendly without an explicit bit-reversal
//! permutation (the Walsh–Hadamard transform is permutation-symmetric
//! enough that none is needed for Sylvester ordering). The two runs
//! feed [`crate::simd::fwht_butterfly`]: once `h` reaches the selected
//! path's lane width the pass is vectorized, and because the butterfly
//! is pure IEEE add/sub the transform is **bitwise identical on every
//! kernel path** (`h` is a power of two, so vector passes have no
//! remainder tail).

use crate::{Error, Result};

/// In-place unnormalized Walsh–Hadamard transform. Panics unless the
/// length is a power of two (or ≤ 1); library entry points that accept
/// caller-sized buffers should use [`fwht_checked`].
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    if n <= 1 {
        return;
    }
    assert!(n.is_power_of_two(), "fwht length must be a power of two, got {n}");
    let path = crate::simd::selected();
    let mut h = 1;
    while h < n {
        let mut start = 0;
        while start < n {
            let (a, b) = x[start..start + 2 * h].split_at_mut(h);
            crate::simd::fwht_butterfly_with(path, a, b);
            start += h * 2;
        }
        h *= 2;
    }
}

/// [`fwht`] with a recoverable shape error instead of a panic — the
/// entry point for caller-controlled lengths.
pub fn fwht_checked(x: &mut [f32]) -> Result<()> {
    if x.len() > 1 && !x.len().is_power_of_two() {
        return Err(Error::shape("power-of-two length", format!("{}", x.len())));
    }
    fwht(x);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// `H_n[i, k] = (−1)^{popcount(i & k)}` — the O(n²) reference.
    fn naive_hadamard(x: &[f32]) -> Vec<f32> {
        let n = x.len();
        (0..n)
            .map(|i| {
                (0..n)
                    .map(|k| {
                        let sign = if (i & k).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                        sign * x[k]
                    })
                    .sum()
            })
            .collect()
    }

    #[test]
    fn matches_naive_hadamard() {
        let mut rng = Rng::seed_from(1);
        for n in [1usize, 2, 4, 8, 32, 64] {
            let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let want = naive_hadamard(&x);
            let mut got = x.clone();
            fwht(&mut got);
            for k in 0..n {
                assert!((got[k] - want[k]).abs() < 1e-4, "n={n} k={k}: {} vs {}", got[k], want[k]);
            }
        }
    }

    #[test]
    fn involution_up_to_n() {
        let mut rng = Rng::seed_from(2);
        for n in [2usize, 8, 128, 512] {
            let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let mut y = x.clone();
            fwht(&mut y);
            fwht(&mut y);
            for k in 0..n {
                assert!((y[k] / n as f32 - x[k]).abs() < 1e-4, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn parseval_norm_scaling() {
        let mut rng = Rng::seed_from(3);
        let n = 256usize;
        let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let before: f64 = x.iter().map(|&v| (v as f64) * v as f64).sum();
        let mut y = x;
        fwht(&mut y);
        let after: f64 = y.iter().map(|&v| (v as f64) * v as f64).sum();
        assert!((after - n as f64 * before).abs() < 1e-2 * after.max(1.0), "{after} vs {before}");
    }

    #[test]
    fn empty_and_singleton_are_noops() {
        fwht(&mut []);
        let mut one = [3.5f32];
        fwht(&mut one);
        assert_eq!(one, [3.5]);
    }

    #[test]
    fn checked_rejects_bad_lengths() {
        let mut bad = vec![0.0f32; 6];
        let e = fwht_checked(&mut bad).unwrap_err();
        assert!(e.to_string().contains("power-of-two"), "{e}");
        let mut good = vec![1.0f32; 8];
        assert!(fwht_checked(&mut good).is_ok());
    }

    #[test]
    #[should_panic]
    fn unchecked_panics_on_bad_length() {
        fwht(&mut [0.0; 3]);
    }
}
