//! Row-major dense `f32` matrix.

use crate::{Error, Result};

/// A dense row-major matrix of `f32`, the interchange layout for the
/// feature engines, the SVM solvers and the PJRT literals.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(
                format!("{rows}x{cols} ({} elems)", rows * cols),
                format!("{} elems", data.len()),
            ));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from a row iterator of equal-length slices.
    pub fn from_rows(rows: &[Vec<f32>]) -> Result<Self> {
        if rows.is_empty() {
            return Ok(Matrix::zeros(0, 0));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            if r.len() != cols {
                return Err(Error::shape(format!("row len {cols}"), format!("{}", r.len())));
            }
            data.extend_from_slice(r);
        }
        Ok(Matrix { rows: rows.len(), cols, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Whole backing buffer (row-major).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Cache-blocked GEMM: `C = A · B` (ikj loop order with a 64-wide
    /// column block, which keeps the `B` panel in L1/L2), row-blocked
    /// across the global [`crate::parallel`] worker budget.
    pub fn matmul(&self, b: &Matrix) -> Result<Matrix> {
        self.matmul_threads(b, 0)
    }

    /// [`Matrix::matmul`] with an explicit worker count (`0` = the
    /// global knob). Each worker runs the identical serial kernel on a
    /// disjoint block of output rows and the per-element accumulation
    /// order never changes, so any thread count is bit-identical to the
    /// serial product.
    pub fn matmul_threads(&self, b: &Matrix, threads: usize) -> Result<Matrix> {
        if self.cols != b.rows {
            return Err(Error::shape(
                format!("inner dim {} == {}", self.cols, b.rows),
                "mismatch".to_string(),
            ));
        }
        let (m, n) = (self.rows, b.cols);
        let mut c = Matrix::zeros(m, n);
        if m == 0 || n == 0 {
            return Ok(c);
        }
        let work = m.saturating_mul(self.cols).saturating_mul(n);
        let threads = crate::parallel::resolve_threads_for_work(threads, m, work);
        crate::parallel::par_chunks(threads, n, &mut c.data, |row0, block| {
            self.matmul_rows_into(b, row0, block);
        });
        Ok(c)
    }

    /// The serial GEMM kernel over output rows `row0 ..` of `C = A · B`,
    /// writing into `c_block` (`block_rows × n`, row-major). The inner
    /// column run is exactly an `axpy` of a `B` panel row into the `C`
    /// row, dispatched through [`crate::simd`] (path hoisted once per
    /// call). Each output element still accumulates strictly in `k`
    /// order — `axpy` is elementwise, so the column blocking never
    /// reorders a single element's sum — which keeps the GEMM
    /// bit-consistent with every other dense/sparse path that folds
    /// rank-1 updates through the same dispatched `axpy`.
    fn matmul_rows_into(&self, b: &Matrix, row0: usize, c_block: &mut [f32]) {
        let (k, n) = (self.cols, b.cols);
        let rows = c_block.len() / n;
        let path = crate::simd::selected();
        const JB: usize = 64;
        for j0 in (0..n).step_by(JB) {
            let j1 = (j0 + JB).min(n);
            for i in 0..rows {
                let a_row = self.row(row0 + i);
                let c_row = &mut c_block[i * n..(i + 1) * n];
                for (kk, &a_ik) in a_row.iter().enumerate().take(k) {
                    if a_ik == 0.0 {
                        continue;
                    }
                    let b_row = &b.data[kk * n..(kk + 1) * n];
                    crate::simd::axpy_with(path, a_ik, &b_row[j0..j1], &mut c_row[j0..j1]);
                }
            }
        }
    }

    /// `C = A · Bᵀ` without materializing `b.transpose()`: both operands
    /// stream row-major (`C[i][j] = ⟨A[i], B[j]⟩`), row-blocked across
    /// the worker budget like [`Matrix::matmul`].
    pub fn matmul_transposed(&self, b: &Matrix) -> Result<Matrix> {
        self.matmul_transposed_threads(b, 0)
    }

    /// [`Matrix::matmul_transposed`] with an explicit worker count
    /// (`0` = the global knob); bit-identical for any thread count.
    pub fn matmul_transposed_threads(&self, b: &Matrix, threads: usize) -> Result<Matrix> {
        if self.cols != b.cols {
            return Err(Error::shape(
                format!("shared dim {} == {}", self.cols, b.cols),
                "mismatch".to_string(),
            ));
        }
        let (m, n) = (self.rows, b.rows);
        let mut c = Matrix::zeros(m, n);
        if m == 0 || n == 0 {
            return Ok(c);
        }
        let work = m.saturating_mul(self.cols).saturating_mul(n);
        let threads = crate::parallel::resolve_threads_for_work(threads, m, work);
        crate::parallel::par_chunks(threads, n, &mut c.data, |row0, block| {
            for (i, c_row) in block.chunks_mut(n).enumerate() {
                let a_row = self.row(row0 + i);
                for (j, cj) in c_row.iter_mut().enumerate() {
                    *cj = super::dot(a_row, b.row(j));
                }
            }
        });
        Ok(c)
    }

    /// `out = self · v` (matrix-vector), row-blocked across the global
    /// [`crate::parallel`] worker budget.
    pub fn matvec(&self, v: &[f32]) -> Result<Vec<f32>> {
        self.matvec_threads(v, 0)
    }

    /// [`Matrix::matvec`] with an explicit worker count (`0` = the
    /// global knob); each `out[i]` is one independent dot product, so
    /// any thread count is bit-identical to the serial path.
    pub fn matvec_threads(&self, v: &[f32], threads: usize) -> Result<Vec<f32>> {
        if v.len() != self.cols {
            return Err(Error::shape(format!("vec len {}", self.cols), format!("{}", v.len())));
        }
        let work = self.rows.saturating_mul(self.cols);
        let threads = crate::parallel::resolve_threads_for_work(threads, self.rows, work);
        let mut out = vec![0.0f32; self.rows];
        crate::parallel::par_chunks(threads, 1, &mut out, |i0, block| {
            for (k, o) in block.iter_mut().enumerate() {
                *o = super::dot(self.row(i0 + k), v);
            }
        });
        Ok(out)
    }

    /// Vertical concatenation.
    pub fn vstack(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.cols {
            return Err(Error::shape(format!("cols {}", self.cols), format!("{}", other.cols)));
        }
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Horizontal concatenation (row-wise append of columns).
    pub fn hstack(&self, other: &Matrix) -> Result<Matrix> {
        if self.rows != other.rows {
            return Err(Error::shape(format!("rows {}", self.rows), format!("{}", other.rows)));
        }
        let cols = self.cols + other.cols;
        let mut data = Vec::with_capacity(self.rows * cols);
        for i in 0..self.rows {
            data.extend_from_slice(self.row(i));
            data.extend_from_slice(other.row(i));
        }
        Matrix::from_vec(self.rows, cols, data)
    }

    /// Copy of the sub-block of rows `[r0, r1)`.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Maximum absolute entry difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert!(Matrix::from_vec(2, 2, vec![1.0]).is_err());
    }

    #[test]
    fn from_rows_validates() {
        assert!(Matrix::from_rows(&[vec![1., 2.], vec![3.]]).is_err());
        let m = Matrix::from_rows(&[vec![1., 2.], vec![3., 4.]]).unwrap();
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.as_slice(), &[3., 3., 7., 7.]);
        assert!(a.matmul(&Matrix::zeros(3, 3)).is_err());
    }

    #[test]
    fn matmul_matches_naive_odd_shapes() {
        let mut rng = crate::rng::Rng::seed_from(1);
        let (m, k, n) = (7, 13, 70); // crosses the column-block boundary
        let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.f32() - 0.5).collect()).unwrap();
        let b = Matrix::from_vec(k, n, (0..k * n).map(|_| rng.f32() - 0.5).collect()).unwrap();
        let c = a.matmul(&b).unwrap();
        for i in 0..m {
            for j in 0..n {
                let naive: f32 = (0..k).map(|kk| a.get(i, kk) * b.get(kk, j)).sum();
                assert!((c.get(i, j) - naive).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_threads_bit_identical() {
        let mut rng = crate::rng::Rng::seed_from(3);
        for (m, k, n) in [(1usize, 1usize, 1usize), (5, 9, 70), (17, 4, 130), (0, 3, 4)] {
            let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.f32() - 0.5).collect()).unwrap();
            let b = Matrix::from_vec(k, n, (0..k * n).map(|_| rng.f32() - 0.5).collect()).unwrap();
            let serial = a.matmul_threads(&b, 1).unwrap();
            for threads in [2usize, 3, 8, 64] {
                // 64 > m exercises the threads-exceed-rows clamp.
                assert_eq!(a.matmul_threads(&b, threads).unwrap(), serial);
            }
        }
    }

    #[test]
    fn matmul_transposed_matches_materialized_transpose() {
        let mut rng = crate::rng::Rng::seed_from(4);
        let (m, k, n) = (9usize, 13usize, 11usize);
        let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.f32() - 0.5).collect()).unwrap();
        let b = Matrix::from_vec(n, k, (0..n * k).map(|_| rng.f32() - 0.5).collect()).unwrap();
        let direct = a.matmul_transposed(&b).unwrap();
        let via_transpose = a.matmul(&b.transpose()).unwrap();
        assert_eq!((direct.rows(), direct.cols()), (m, n));
        // Different accumulation orders ⇒ float tolerance, not equality.
        assert!(direct.max_abs_diff(&via_transpose) < 1e-4);
        let serial = a.matmul_transposed_threads(&b, 1).unwrap();
        for threads in [2usize, 5, 32] {
            assert_eq!(a.matmul_transposed_threads(&b, threads).unwrap(), serial);
        }
        assert!(a.matmul_transposed(&Matrix::zeros(2, k + 1)).is_err());
    }

    #[test]
    fn matvec_threads_bit_identical() {
        let mut rng = crate::rng::Rng::seed_from(5);
        let (m, k) = (23usize, 17usize);
        let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.f32() - 0.5).collect()).unwrap();
        let v: Vec<f32> = (0..k).map(|_| rng.f32() - 0.5).collect();
        let serial = a.matvec_threads(&v, 1).unwrap();
        for threads in [2usize, 4, 64] {
            assert_eq!(a.matvec_threads(&v, threads).unwrap(), serial);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = crate::rng::Rng::seed_from(2);
        let a = Matrix::from_vec(3, 5, (0..15).map(|_| rng.f32()).collect()).unwrap();
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_vec(2, 3, vec![1., 0., 2., 0., 1., 1.]).unwrap();
        let v = vec![1., 2., 3.];
        assert_eq!(a.matvec(&v).unwrap(), vec![7., 5.]);
        assert!(a.matvec(&[1.0]).is_err());
    }

    #[test]
    fn stacking() {
        let a = Matrix::from_vec(1, 2, vec![1., 2.]).unwrap();
        let b = Matrix::from_vec(1, 2, vec![3., 4.]).unwrap();
        assert_eq!(a.vstack(&b).unwrap().as_slice(), &[1., 2., 3., 4.]);
        assert_eq!(a.hstack(&b).unwrap().as_slice(), &[1., 2., 3., 4.]);
        assert_eq!(a.hstack(&b).unwrap().cols(), 4);
        assert!(a.vstack(&Matrix::zeros(1, 3)).is_err());
    }

    #[test]
    fn slice_rows_copies_block() {
        let m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let s = m.slice_rows(1, 3);
        assert_eq!(s.as_slice(), &[3., 4., 5., 6.]);
    }
}
