//! Compressed sparse row (CSR) matrices — the storage format of the
//! paper's actual workloads.
//!
//! Table 1's datasets arrive as LIBSVM text, which is sparse by
//! construction (Nursery/Adult one-hot encodings are > 85% zeros), and
//! TensorSketch is explicitly an `O(nnz)` algorithm — yet the original
//! data path densified every row at parse time and every feature map
//! paid `O(D·d)` per input regardless of nnz. [`SparseMatrix`] is the
//! fix: three flat buffers (`indptr`/`indices`/`values`), cheap
//! [`SparseRow`] views, and a layout that row-chunks exactly like
//! [`Matrix`] does, so the [`crate::parallel`] batch paths fan sparse
//! inputs out over the same worker pool.
//!
//! **Bit-identical parity contract.** Every sparse kernel in this crate
//! accumulates over the stored entries in ascending column order — the
//! *same* order the dense hot paths use after their explicit
//! `x[k] != 0` skips ([`crate::structured::DenseProjection`], the GEMM
//! in [`Matrix::matmul`], TensorSketch's count sketch). Terms the dense
//! paths do *not* skip are exact zeros, and `t + 0.0` never changes a
//! nonzero `t`, so sparse and dense outputs are equal (enforced by
//! `rust/tests/sparse_parity.rs`; the only representable difference is
//! the sign of a zero, which `==` ignores). For the handful of dense
//! routines that do **not** skip zeros — the lane-blocked
//! [`super::dot`] behind row norms and the SVM solver —
//! [`SparseRow::dot_dense`] and [`SparseRow::self_dot`] replicate the
//! *selected kernel path's* lane structure by column position (scalar:
//! `lane = k mod 4`; AVX2: `k mod 32`; NEON: `k mod 16` — the mirrors
//! live in [`crate::simd`]), so even those reductions match the dense
//! path exactly within any fixed dispatch choice.

use super::Matrix;
use crate::{Error, Result};

/// A CSR matrix: row `i` stores its nonzero entries as parallel slices
/// `indices[indptr[i]..indptr[i+1]]` (strictly ascending columns) and
/// `values[..]`.
///
/// This is the storage the paper's cost claims are stated against: the
/// per-input feature cost of Algorithm 1 is really `O(D · nnz)` once
/// the `ω_j^T x` projections skip stored zeros, and Pham & Pagh's count
/// sketch (the TensorSketch inner loop) is `O(nnz)` by construction.
/// The crate-wide parity contract (module docs) guarantees the
/// subquadratic paths change cost only, never results.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseMatrix {
    rows: usize,
    cols: usize,
    /// `rows + 1` monotone offsets into `indices`/`values`.
    indptr: Vec<usize>,
    /// Column indices, strictly ascending within each row.
    indices: Vec<u32>,
    /// Stored values (explicit zeros are permitted but never produced
    /// by [`SparseMatrix::from_dense`]).
    values: Vec<f32>,
}

/// A borrowed view of one CSR row — what every sparse fast path
/// ([`crate::features::FeatureMap::transform_sparse_into`], the
/// projection kernels, the LIBLINEAR-style solver rows) consumes.
/// Iterating `indices`/`values` in order visits the nonzeros exactly
/// as the dense loops do after their zero skips, which is the whole
/// parity argument.
#[derive(Clone, Copy, Debug)]
pub struct SparseRow<'a> {
    /// Logical (dense) dimensionality of the row.
    pub dim: usize,
    /// Stored column indices, strictly ascending.
    pub indices: &'a [u32],
    /// Stored values, parallel to `indices`.
    pub values: &'a [f32],
}

impl<'a> SparseRow<'a> {
    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Expand into a fresh dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.write_dense_into(&mut out);
        out
    }

    /// Zero `out` and scatter the stored entries into it.
    pub fn write_dense_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim, "dense buffer len mismatch");
        out.fill(0.0);
        for (&k, &v) in self.indices.iter().zip(self.values) {
            out[k as usize] = v;
        }
    }

    /// `⟨row, w⟩` replicating [`super::dot`]'s lane accumulation over
    /// the virtual dense row: an entry at column `k` lands in the lane
    /// the selected [`crate::simd`] path assigns to position `k`
    /// (ascending within each lane), the lanes reduce in the dense
    /// path's order, and the tail beyond the lane-blocked cut is
    /// folded in last. The skipped zero entries contribute exact
    /// `+0.0` adds in the dense path, so the result equals
    /// `dot(dense_row, w)` bitwise (up to zero sign).
    pub fn dot_dense(&self, w: &[f32]) -> f32 {
        debug_assert_eq!(self.dim, w.len(), "dim mismatch");
        crate::simd::sparse_dot_dense(self.indices, self.values, w)
    }

    /// `⟨row, row⟩` with the same lane replication as
    /// [`SparseRow::dot_dense`] — equals `dot(dense_row, dense_row)`.
    pub fn self_dot(&self) -> f32 {
        crate::simd::sparse_self_dot(self.indices, self.values, self.dim)
    }

    /// Euclidean norm of the virtual dense row (matches
    /// [`super::norm2`] on the densified row).
    pub fn norm2(&self) -> f32 {
        self.self_dot().sqrt()
    }

    /// `w[k] += alpha · v` over the stored entries — the sparse
    /// counterpart of [`super::axpy`], with the update fused or not
    /// exactly as the selected [`crate::simd`] path's dense `axpy` is
    /// (the skipped terms are `alpha · 0.0`, exact no-ops either way).
    pub fn axpy_into(&self, alpha: f32, w: &mut [f32]) {
        debug_assert_eq!(self.dim, w.len(), "dim mismatch");
        crate::simd::sparse_axpy(alpha, self.indices, self.values, w);
    }
}

impl SparseMatrix {
    /// Construct from raw CSR buffers, validating the invariants:
    /// `indptr` has `rows + 1` monotone offsets ending at the buffer
    /// length, and each row's indices are strictly ascending and
    /// `< cols` (strictness also rejects duplicates).
    pub fn new(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if indptr.len() != rows + 1 || indptr.first() != Some(&0) {
            return Err(Error::Data(format!(
                "indptr must hold {} offsets starting at 0, got {}",
                rows + 1,
                indptr.len()
            )));
        }
        if indices.len() != values.len() || *indptr.last().expect("non-empty") != indices.len() {
            return Err(Error::Data(format!(
                "indptr end {} must match {} indices / {} values",
                indptr.last().expect("non-empty"),
                indices.len(),
                values.len()
            )));
        }
        for i in 0..rows {
            let (lo, hi) = (indptr[i], indptr[i + 1]);
            if lo > hi {
                return Err(Error::Data(format!("indptr decreases at row {i}")));
            }
            let row = &indices[lo..hi];
            for (p, &k) in row.iter().enumerate() {
                if k as usize >= cols {
                    return Err(Error::Data(format!(
                        "row {i}: column {k} out of range (cols = {cols})"
                    )));
                }
                if p > 0 && row[p - 1] >= k {
                    return Err(Error::Data(format!(
                        "row {i}: column indices must be strictly ascending ({} then {k})",
                        row[p - 1]
                    )));
                }
            }
        }
        Ok(SparseMatrix { rows, cols, indptr, indices, values })
    }

    /// Build from per-row entry lists (each strictly ascending by
    /// column, validated).
    pub fn from_rows(cols: usize, rows: &[Vec<(u32, f32)>]) -> Result<Self> {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        indptr.push(0usize);
        let nnz: usize = rows.iter().map(Vec::len).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for row in rows {
            for &(k, v) in row {
                indices.push(k);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        SparseMatrix::new(rows.len(), cols, indptr, indices, values)
    }

    /// Compress a dense matrix (drops exact zeros).
    pub fn from_dense(m: &Matrix) -> Self {
        let mut indptr = Vec::with_capacity(m.rows() + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        SparseMatrix { rows: m.rows(), cols: m.cols(), indptr, indices, values }
    }

    /// Expand to a dense matrix.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let row = self.row(i);
            let out = m.row_mut(i);
            for (&k, &v) in row.indices.iter().zip(row.values) {
                out[k as usize] = v;
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Stored entries in row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Fraction of stored entries (`nnz / (rows · cols)`; 0 for empty).
    pub fn density(&self) -> f64 {
        let cells = self.rows * self.cols;
        if cells == 0 {
            return 0.0;
        }
        self.nnz() as f64 / cells as f64
    }

    /// Borrow row `i` as a [`SparseRow`] view (cheap: two slice reborrows).
    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        SparseRow { dim: self.cols, indices: &self.indices[lo..hi], values: &self.values[lo..hi] }
    }

    /// Mutably borrow row `i`'s stored values (indices stay fixed —
    /// this is the in-place scaling hook `Dataset::normalize_rows`
    /// uses).
    pub fn row_values_mut(&mut self, i: usize) -> &mut [f32] {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        &mut self.values[lo..hi]
    }

    /// Copy of the sub-block of rows `[r0, r1)` (CSR analogue of
    /// [`Matrix::slice_rows`]).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> SparseMatrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        let (lo, hi) = (self.indptr[r0], self.indptr[r1]);
        let indptr = self.indptr[r0..=r1].iter().map(|&p| p - lo).collect();
        SparseMatrix {
            rows: r1 - r0,
            cols: self.cols,
            indptr,
            indices: self.indices[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
        }
    }

    /// Gather a new matrix from the given row ids (the sparse analogue
    /// of the dense split's row copy).
    pub fn select_rows(&self, ids: &[usize]) -> SparseMatrix {
        let nnz: usize = ids.iter().map(|&i| self.row_nnz(i)).sum();
        let mut indptr = Vec::with_capacity(ids.len() + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for &i in ids {
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            indices.extend_from_slice(&self.indices[lo..hi]);
            values.extend_from_slice(&self.values[lo..hi]);
            indptr.push(indices.len());
        }
        SparseMatrix { rows: ids.len(), cols: self.cols, indptr, indices, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Random batch at a given density, returned dense + compressed.
    fn sparse_pair(rows: usize, d: usize, keep: f64, seed: u64) -> (Matrix, SparseMatrix) {
        let mut rng = Rng::seed_from(seed);
        let mut m = Matrix::zeros(rows, d);
        for i in 0..rows {
            for j in 0..d {
                if rng.f64() < keep {
                    m.set(i, j, rng.f32() - 0.5);
                }
            }
        }
        let s = SparseMatrix::from_dense(&m);
        (m, s)
    }

    #[test]
    fn dense_round_trip() {
        let (m, s) = sparse_pair(7, 23, 0.2, 1);
        assert_eq!(s.rows(), 7);
        assert_eq!(s.cols(), 23);
        assert_eq!(s.to_dense(), m);
        assert!(s.nnz() < 7 * 23);
        assert!((s.density() - s.nnz() as f64 / (7.0 * 23.0)).abs() < 1e-12);
        // Row views see the same entries.
        for i in 0..7 {
            assert_eq!(s.row(i).to_dense(), m.row(i));
            assert_eq!(s.row(i).nnz(), s.row_nnz(i));
        }
    }

    #[test]
    fn validation_rejects_malformed() {
        // Duplicate column (non-strict ascent).
        assert!(SparseMatrix::from_rows(4, &[vec![(1, 1.0), (1, 2.0)]]).is_err());
        // Out-of-order columns.
        assert!(SparseMatrix::from_rows(4, &[vec![(2, 1.0), (0, 2.0)]]).is_err());
        // Column out of range.
        assert!(SparseMatrix::from_rows(4, &[vec![(4, 1.0)]]).is_err());
        // indptr wrong length / not ending at nnz.
        assert!(SparseMatrix::new(2, 3, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(SparseMatrix::new(1, 3, vec![0, 2], vec![0], vec![1.0]).is_err());
        // Valid empty rows are fine.
        let ok = SparseMatrix::from_rows(3, &[vec![], vec![(0, 1.0), (2, -1.0)], vec![]]).unwrap();
        assert_eq!(ok.nnz(), 2);
        assert_eq!(ok.row(0).nnz(), 0);
    }

    #[test]
    fn dot_dense_matches_dense_dot_bitwise() {
        let mut rng = Rng::seed_from(2);
        // Odd dims exercise the 4-lane tail.
        for d in [1usize, 3, 4, 17, 64, 131] {
            let (m, s) = sparse_pair(5, d, 0.3, 10 + d as u64);
            let w: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
            for i in 0..5 {
                assert_eq!(s.row(i).dot_dense(&w), crate::linalg::dot(m.row(i), &w), "d={d} i={i}");
                assert_eq!(
                    s.row(i).self_dot(),
                    crate::linalg::dot(m.row(i), m.row(i)),
                    "self d={d} i={i}"
                );
                assert_eq!(s.row(i).norm2(), crate::linalg::norm2(m.row(i)));
            }
        }
    }

    #[test]
    fn axpy_into_matches_dense_axpy() {
        let (m, s) = sparse_pair(4, 29, 0.25, 3);
        let mut rng = Rng::seed_from(4);
        for i in 0..4 {
            let base: Vec<f32> = (0..29).map(|_| rng.f32() - 0.5).collect();
            let mut dense = base.clone();
            let mut sparse = base.clone();
            crate::linalg::axpy(0.7, m.row(i), &mut dense);
            s.row(i).axpy_into(0.7, &mut sparse);
            assert_eq!(dense, sparse, "row {i}");
        }
    }

    #[test]
    fn slice_and_select_rows() {
        let (m, s) = sparse_pair(9, 13, 0.3, 5);
        let sl = s.slice_rows(2, 6);
        assert_eq!(sl.to_dense(), m.slice_rows(2, 6));
        let ids = [8usize, 0, 3, 3];
        let sel = s.select_rows(&ids);
        assert_eq!(sel.rows(), 4);
        for (p, &i) in ids.iter().enumerate() {
            assert_eq!(sel.row(p).to_dense(), m.row(i));
        }
        // Empty selections stay well-formed.
        assert_eq!(s.select_rows(&[]).rows(), 0);
        assert_eq!(s.slice_rows(4, 4).nnz(), 0);
    }

    #[test]
    fn write_dense_into_clears_stale_entries() {
        let s = SparseMatrix::from_rows(4, &[vec![(1, 2.0)]]).unwrap();
        let mut buf = vec![9.0f32; 4];
        s.row(0).write_dense_into(&mut buf);
        assert_eq!(buf, vec![0.0, 2.0, 0.0, 0.0]);
    }
}
